"""RecSys ranking models: AutoInt, DCN-v2, DIEN (AUGRU), DLRM (MLPerf).

Shared substrate: **EmbeddingBag implemented from scratch** — JAX has no
native EmbeddingBag or CSR sparse, so lookups are `jnp.take` and multi-hot
bags are gather + `jax.ops.segment_sum` (assignment: "this IS part of the
system"). Tables are per-field arrays so each can shard independently
(row-sharded over the mesh ``model`` axis).

The ``retrieval_cand`` shape (1 query x 1M candidates) is served by
``retrieval_scores`` — one batched matmul against the item table feeding the
fused top-k kernel (kernels/topk_scoring), never a loop.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

# Criteo cardinalities: Kaggle display-advertising (AutoInt/DCN-family) and
# Terabyte (MLPerf DLRM). Public values from the respective benchmarks.
CRITEO_KAGGLE_CARDS = (
    1460, 583, 10131227, 2202608, 305, 24, 12517, 633, 3, 93145, 5683,
    8351593, 3194, 27, 14992, 5461306, 10, 5652, 2173, 4, 7046547, 18, 15,
    286181, 105, 142572)
CRITEO_TB_CARDS = (
    45833188, 36746, 17245, 7413, 20243, 3, 7114, 1441, 62, 29275261,
    1572176, 345138, 10, 2209, 11267, 128, 4, 974, 14, 48937457, 11316796,
    40094537, 452104, 12606, 104, 35)


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    arch: str                      # autoint | dcn_v2 | dien | dlrm
    n_dense: int = 13
    n_sparse: int = 26
    embed_dim: int = 16
    vocab_sizes: Sequence[int] = CRITEO_KAGGLE_CARDS
    # autoint
    n_attn_layers: int = 3
    n_heads: int = 2
    d_attn: int = 32
    # dcn-v2
    n_cross_layers: int = 3
    mlp_dims: Sequence[int] = (1024, 1024, 512)
    # dlrm
    bot_mlp: Sequence[int] = (512, 256, 128)
    top_mlp: Sequence[int] = (1024, 1024, 512, 256, 1)
    # dien
    seq_len: int = 100
    gru_dim: int = 108
    dien_mlp: Sequence[int] = (200, 80)
    item_vocab: int = 1_000_000
    cat_vocab: int = 10_000
    dtype: Any = jnp.float32


# ---------------------------------------------------------------------------
# EmbeddingBag substrate
# ---------------------------------------------------------------------------

def embedding_lookup(table: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    """Single-hot lookup: plain row gather."""
    return jnp.take(table, ids, axis=0)


def embedding_bag(table: jnp.ndarray, ids: jnp.ndarray,
                  offsets: jnp.ndarray, *, num_bags: int,
                  weights: Optional[jnp.ndarray] = None,
                  mode: str = "sum") -> jnp.ndarray:
    """torch.nn.EmbeddingBag semantics with (ids, offsets) layout.

    ids i32[nnz], offsets i32[num_bags] (bag b spans ids[offsets[b]:offsets[b+1]]).
    Implemented as gather + segment reduction.
    """
    nnz = ids.shape[0]
    seg = jnp.searchsorted(offsets, jnp.arange(nnz, dtype=offsets.dtype),
                           side="right") - 1
    rows = jnp.take(table, ids, axis=0)
    if weights is not None:
        rows = rows * weights[:, None]
    if mode == "sum":
        return jax.ops.segment_sum(rows, seg, num_segments=num_bags)
    if mode == "mean":
        s = jax.ops.segment_sum(rows, seg, num_segments=num_bags)
        c = jax.ops.segment_sum(jnp.ones((nnz, 1), rows.dtype), seg,
                                num_segments=num_bags)
        return s / jnp.maximum(c, 1.0)
    if mode == "max":
        return jax.ops.segment_max(rows, seg, num_segments=num_bags)
    raise ValueError(mode)


def masked_bag(table: jnp.ndarray, ids: jnp.ndarray, mask: jnp.ndarray,
               mode: str = "sum") -> jnp.ndarray:
    """Dense (B, nnz) multi-hot bag with mask — the padded-batch layout."""
    rows = jnp.take(table, jnp.maximum(ids, 0), axis=0)
    rows = rows * mask[..., None].astype(rows.dtype)
    if mode == "sum":
        return rows.sum(1)
    if mode == "mean":
        return rows.sum(1) / jnp.maximum(mask.sum(1, keepdims=True), 1.0)
    raise ValueError(mode)


def _pad_rows(v: int, multiple: int = 256) -> int:
    """Embedding tables are row-sharded over the mesh 'model' axis; rows are
    padded to a 256 multiple (covers any axis size up to a full 256-chip
    pod). Padding rows are never indexed."""
    return ((v + multiple - 1) // multiple) * multiple


def _field_tables(key, cfg: RecsysConfig, dim: int, cards) -> dict:
    keys = jax.random.split(key, len(cards))
    return {f"table_{i}": (jax.random.normal(keys[i], (_pad_rows(v), dim)) /
                           np.sqrt(dim)).astype(cfg.dtype)
            for i, v in enumerate(cards)}


def field_embeddings(tables: dict, sparse_ids: jnp.ndarray) -> jnp.ndarray:
    """(B, n_fields) ids -> (B, n_fields, D), one table per field."""
    cols = [embedding_lookup(tables[f"table_{i}"], sparse_ids[:, i])
            for i in range(sparse_ids.shape[1])]
    return jnp.stack(cols, axis=1)


def _mlp_init(key, dims, cfg, in_dim):
    params = []
    for i, d in enumerate(dims):
        key, k1 = jax.random.split(key)
        params.append({
            "w": (jax.random.normal(k1, (in_dim, d)) / np.sqrt(in_dim)).astype(cfg.dtype),
            "b": jnp.zeros((d,), cfg.dtype)})
        in_dim = d
    return params


def _mlp_apply(params, x, final_act=False):
    for i, lyr in enumerate(params):
        x = x @ lyr["w"] + lyr["b"]
        if i < len(params) - 1 or final_act:
            x = jax.nn.relu(x)
    return x


# ---------------------------------------------------------------------------
# DLRM (MLPerf config)
# ---------------------------------------------------------------------------

def init_dlrm(key, cfg: RecsysConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    d = cfg.embed_dim
    n_f = cfg.n_sparse + 1
    n_inter = n_f * (n_f - 1) // 2
    return {
        "tables": _field_tables(k1, cfg, d, cfg.vocab_sizes),
        "bot": _mlp_init(k2, cfg.bot_mlp, cfg, cfg.n_dense),
        "top": _mlp_init(k3, cfg.top_mlp, cfg, n_inter + d),
    }


def dlrm_forward(params, batch, cfg: RecsysConfig):
    dense = _mlp_apply(params["bot"], batch["dense"], final_act=True)  # (B,D)
    emb = field_embeddings(params["tables"], batch["sparse"])          # (B,F,D)
    feats = jnp.concatenate([dense[:, None, :], emb], axis=1)          # (B,F+1,D)
    inter = jnp.einsum("bfd,bgd->bfg", feats, feats)
    iu, ju = jnp.triu_indices(feats.shape[1], k=1)
    flat = inter[:, iu, ju]                                            # (B,F(F-1)/2)
    x = jnp.concatenate([flat, dense], axis=-1)
    return _mlp_apply(params["top"], x)[:, 0]


# ---------------------------------------------------------------------------
# DCN-v2
# ---------------------------------------------------------------------------

def init_dcn_v2(key, cfg: RecsysConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    d0 = cfg.n_dense + cfg.n_sparse * cfg.embed_dim
    cross = []
    for i in range(cfg.n_cross_layers):
        k2, kk = jax.random.split(k2)
        cross.append({"w": (jax.random.normal(kk, (d0, d0)) / np.sqrt(d0)
                            ).astype(cfg.dtype),
                      "b": jnp.zeros((d0,), cfg.dtype)})
    deep = _mlp_init(k3, cfg.mlp_dims, cfg, d0)
    k3, kk = jax.random.split(k3)
    head_in = d0 + cfg.mlp_dims[-1]
    return {"tables": _field_tables(k1, cfg, cfg.embed_dim, cfg.vocab_sizes),
            "cross": cross, "deep": deep,
            "head": {"w": (jax.random.normal(kk, (head_in, 1)) /
                           np.sqrt(head_in)).astype(cfg.dtype),
                     "b": jnp.zeros((1,), cfg.dtype)}}


def dcn_v2_forward(params, batch, cfg: RecsysConfig):
    emb = field_embeddings(params["tables"], batch["sparse"])
    x0 = jnp.concatenate([batch["dense"], emb.reshape(emb.shape[0], -1)], -1)
    x = x0
    for lyr in params["cross"]:                  # x_{l+1} = x0 ⊙ (W x_l + b) + x_l
        x = x0 * (x @ lyr["w"] + lyr["b"]) + x
    deep = _mlp_apply(params["deep"], x0, final_act=True)
    z = jnp.concatenate([x, deep], -1)
    return (z @ params["head"]["w"] + params["head"]["b"])[:, 0]


# ---------------------------------------------------------------------------
# AutoInt
# ---------------------------------------------------------------------------

def init_autoint(key, cfg: RecsysConfig):
    # 39 fields on Criteo = 13 bucketised dense + 26 categorical
    cards = tuple([1000] * (cfg.n_sparse - len(cfg.vocab_sizes))) + tuple(cfg.vocab_sizes) \
        if cfg.n_sparse > len(cfg.vocab_sizes) else tuple(cfg.vocab_sizes[:cfg.n_sparse])
    k1, k2, k3 = jax.random.split(key, 3)
    d, da, h = cfg.embed_dim, cfg.d_attn, cfg.n_heads
    layers = []
    in_d = d
    for i in range(cfg.n_attn_layers):
        k2, kq, kk, kv, kr = jax.random.split(k2, 5)
        layers.append({
            "wq": (jax.random.normal(kq, (in_d, h * da)) / np.sqrt(in_d)).astype(cfg.dtype),
            "wk": (jax.random.normal(kk, (in_d, h * da)) / np.sqrt(in_d)).astype(cfg.dtype),
            "wv": (jax.random.normal(kv, (in_d, h * da)) / np.sqrt(in_d)).astype(cfg.dtype),
            "wres": (jax.random.normal(kr, (in_d, h * da)) / np.sqrt(in_d)).astype(cfg.dtype),
        })
        in_d = h * da
    head_in = cfg.n_sparse * in_d
    return {"tables": _field_tables(k1, cfg, d, cards),
            "attn": layers,
            "head": {"w": (jax.random.normal(k3, (head_in, 1)) /
                           np.sqrt(head_in)).astype(cfg.dtype),
                     "b": jnp.zeros((1,), cfg.dtype)}}


def autoint_forward(params, batch, cfg: RecsysConfig):
    x = field_embeddings(params["tables"], batch["sparse"])  # (B,F,D)
    h, da = cfg.n_heads, cfg.d_attn
    for lyr in params["attn"]:
        b, f, d = x.shape
        q = (x @ lyr["wq"]).reshape(b, f, h, da)
        k = (x @ lyr["wk"]).reshape(b, f, h, da)
        v = (x @ lyr["wv"]).reshape(b, f, h, da)
        logits = jnp.einsum("bfhd,bghd->bhfg", q, k) / np.sqrt(da)
        p = jax.nn.softmax(logits, -1)
        o = jnp.einsum("bhfg,bghd->bfhd", p, v).reshape(b, f, h * da)
        x = jax.nn.relu(o + x @ lyr["wres"])
    flat = x.reshape(x.shape[0], -1)
    return (flat @ params["head"]["w"] + params["head"]["b"])[:, 0]


# ---------------------------------------------------------------------------
# DIEN (GRU + attention + AUGRU)
# ---------------------------------------------------------------------------

def _gru_init(key, in_dim, hid, cfg):
    k1, k2 = jax.random.split(key)
    return {"wx": (jax.random.normal(k1, (in_dim, 3 * hid)) /
                   np.sqrt(in_dim)).astype(cfg.dtype),
            "wh": (jax.random.normal(k2, (hid, 3 * hid)) /
                   np.sqrt(hid)).astype(cfg.dtype),
            "b": jnp.zeros((3 * hid,), cfg.dtype)}


def _gru_cell(p, h, x, att=None):
    """Standard GRU; if ``att`` given, AUGRU: update gate scaled by attention."""
    hid = h.shape[-1]
    gates = x @ p["wx"] + h @ p["wh"] + p["b"]
    r = jax.nn.sigmoid(gates[..., :hid])
    z = jax.nn.sigmoid(gates[..., hid:2 * hid])
    n = jnp.tanh(gates[..., 2 * hid:] + (r - 1.0) * (h @ p["wh"][:, 2 * hid:]))
    if att is not None:
        z = z * att[..., None]
    return (1.0 - z) * n + z * h


def init_dien(key, cfg: RecsysConfig):
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    d = cfg.embed_dim            # 18 for item and category each
    in_dim = 2 * d               # concat(item, cat) = 36
    hid = cfg.gru_dim
    mlp_in = hid + in_dim
    return {
        "item_table": (jax.random.normal(k1, (_pad_rows(cfg.item_vocab), d)) /
                       np.sqrt(d)).astype(cfg.dtype),
        "cat_table": (jax.random.normal(k2, (_pad_rows(cfg.cat_vocab), d)) /
                      np.sqrt(d)).astype(cfg.dtype),
        "gru1": _gru_init(k3, in_dim, hid, cfg),
        "augru": _gru_init(k4, hid, hid, cfg),
        "att": {"w": (jax.random.normal(k5, (hid + in_dim, 1)) /
                      np.sqrt(hid + in_dim)).astype(cfg.dtype)},
        "mlp": _mlp_init(k6, tuple(cfg.dien_mlp) + (1,), cfg, mlp_in),
    }


def dien_forward(params, batch, cfg: RecsysConfig):
    it = embedding_lookup(params["item_table"], batch["hist_items"])   # (B,T,d)
    ct = embedding_lookup(params["cat_table"], batch["hist_cats"])
    seq = jnp.concatenate([it, ct], -1)                                # (B,T,2d)
    tgt = jnp.concatenate([
        embedding_lookup(params["item_table"], batch["target_item"]),
        embedding_lookup(params["cat_table"], batch["target_cat"])], -1)
    mask = batch["hist_mask"].astype(seq.dtype)                        # (B,T)

    def gru1_step(h, xs):
        x, m = xs
        hn = _gru_cell(params["gru1"], h, x)
        return jnp.where(m[:, None] > 0, hn, h), jnp.where(m[:, None] > 0, hn, h)

    b, t, _ = seq.shape
    h0 = jnp.zeros((b, cfg.gru_dim), seq.dtype)
    _, states = lax.scan(gru1_step, h0, (seq.transpose(1, 0, 2), mask.T))
    states = states.transpose(1, 0, 2)                                 # (B,T,H)

    att_in = jnp.concatenate(
        [states, jnp.broadcast_to(tgt[:, None], (b, t, tgt.shape[-1]))], -1)
    att_logit = (att_in @ params["att"]["w"])[..., 0]
    att_logit = jnp.where(mask > 0, att_logit, -1e30)
    att = jax.nn.softmax(att_logit, -1)                                # (B,T)

    def augru_step(h, xs):
        x, a, m = xs
        hn = _gru_cell(params["augru"], h, x, att=a)
        return jnp.where(m[:, None] > 0, hn, h), None

    hT, _ = lax.scan(augru_step, h0,
                     (states.transpose(1, 0, 2), att.T, mask.T))
    z = jnp.concatenate([hT, tgt], -1)
    return _mlp_apply(params["mlp"], z)[:, 0]


# ---------------------------------------------------------------------------
# Common: loss, retrieval scoring
# ---------------------------------------------------------------------------

ARCHS = {
    "autoint": (init_autoint, autoint_forward),
    "dcn_v2": (init_dcn_v2, dcn_v2_forward),
    "dien": (init_dien, dien_forward),
    "dlrm": (init_dlrm, dlrm_forward),
}


def init_recsys(key, cfg: RecsysConfig):
    return ARCHS[cfg.arch][0](key, cfg)


def recsys_forward(params, batch, cfg: RecsysConfig):
    return ARCHS[cfg.arch][1](params, batch, cfg)


def bce_loss(params, batch, cfg: RecsysConfig):
    logit = recsys_forward(params, batch, cfg)
    y = batch["label"].astype(jnp.float32)
    logit = logit.astype(jnp.float32)
    return jnp.mean(jnp.maximum(logit, 0) - logit * y +
                    jnp.log1p(jnp.exp(-jnp.abs(logit))))


def user_vector(params, batch, cfg: RecsysConfig) -> jnp.ndarray:
    """Query-side tower for retrieval_cand scoring (per-arch)."""
    if cfg.arch == "dlrm":
        return _mlp_apply(params["bot"], batch["dense"], final_act=True)
    if cfg.arch == "dien":
        it = embedding_lookup(params["item_table"], batch["hist_items"])
        ct = embedding_lookup(params["cat_table"], batch["hist_cats"])
        seq = jnp.concatenate([it, ct], -1)
        m = batch["hist_mask"][..., None].astype(seq.dtype)
        return (seq * m).sum(1) / jnp.maximum(m.sum(1), 1.0)
    # autoint / dcn_v2: mean of field embeddings
    emb = field_embeddings(params["tables"], batch["sparse"])
    return emb.mean(1)


def item_matrix(params, cfg: RecsysConfig) -> jnp.ndarray:
    """Candidate-side embedding matrix used for retrieval scoring."""
    if cfg.arch == "dien":
        return jnp.concatenate(
            [params["item_table"],
             jnp.zeros((params["item_table"].shape[0], cfg.embed_dim),
                       params["item_table"].dtype)], -1)
    # largest categorical table acts as the item corpus
    big = max(range(len(cfg.vocab_sizes)), key=lambda i: cfg.vocab_sizes[i])
    return params["tables"][f"table_{big}"]


def item_matrix_dim(cfg: RecsysConfig) -> int:
    return 2 * cfg.embed_dim if cfg.arch == "dien" else cfg.embed_dim


def retrieval_scores(params, batch, cfg: RecsysConfig,
                     candidate_ids: jnp.ndarray) -> jnp.ndarray:
    """Score one query batch against a candidate set: (B, n_cand) dots.
    Top-k selection happens in kernels/topk_scoring."""
    u = user_vector(params, batch, cfg)                       # (B, D)
    items = jnp.take(item_matrix(params, cfg), candidate_ids, axis=0)
    return u @ items.T
