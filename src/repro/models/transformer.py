"""One composable transformer covering all five assigned LM architectures.

Dense or MoE FFN, GQA/MQA, RoPE, full / sliding-window / chunked-causal
attention, GeGLU/SwiGLU/GELU, tied or untied embeddings, scanned layers
(O(1) HLO size in depth — critical for 48-56 layer dry-run compiles on one
CPU core), selectable remat, and a KV-cache decode path (rolling buffer for
windowed archs, which is what makes the long_500k cells sub-quadratic).

Design notes
------------
* Params are plain pytrees (dict of jnp arrays); every leaf has a parallel
  entry of *logical axis names* (``param_logical_axes``) which
  distributed/sharding.py maps to mesh PartitionSpecs via per-arch rules —
  the MaxText pattern, so DP/TP/EP/SP changes never touch model code.
* Layer stack is ``lax.scan`` over stacked (L, ...) params.
* Attention has a naive reference and a blocked online-softmax
  implementation (flash-attention algorithm in pure JAX; the Pallas kernel
  in kernels/flash_attention implements the same tiling for TPU). Blocked is
  the default above ``block_q`` tokens — materialising (B, H, S, S) scores
  at 32k context is exactly the memory-roofline failure §Perf documents.
* MoE uses group-local top-k routing with capacity dropping (GShard/MaxText
  style): tokens compete within their own batch row, dispatch/combine are
  one-hot scatters, expert compute is a single einsum so the ``experts``
  axis shards cleanly over the mesh ``model`` axis (EP).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    vocab_size: int
    d_model: int
    n_layers: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    d_head: Optional[int] = None          # default d_model // n_heads
    activation: str = "swiglu"            # swiglu | geglu | gelu
    moe: Optional[MoEConfig] = None
    rope_theta: float = 10_000.0
    window: Optional[int] = None          # sliding-window attention size
    attention_chunk: Optional[int] = None  # llama4-style chunked attention
    causal: bool = True                   # False -> bidirectional encoder
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    embed_scale: bool = False             # gemma scales embeds by sqrt(d)
    dtype: Any = jnp.bfloat16             # activation/compute dtype
    param_dtype: Any = jnp.float32
    remat: str = "none"                   # none | full
    block_q: int = 1024                   # blocked-attention thresholds
    block_kv: int = 1024
    vocab_chunks: int = 1                 # >1 -> blocked cross-entropy
    use_flash_kernel: bool = False        # route attention to Pallas kernel
    # activation sharding constraints (set by launch/cells.py per mesh):
    # batch dims -> act_batch_axes, head/ffn/vocab dims -> act_model_axis.
    # Without these GSPMD may partition contraction dims instead of tokens,
    # replicating activations 16x (measured; see EXPERIMENTS.md §Dry-run).
    act_batch_axes: Optional[tuple] = None
    act_model_axis: Optional[str] = None
    # attention activation sharding: 'heads' when n_heads fills the model
    # axis, else 'dh' (MQA/small-H archs like gemma-2b pad heads 2x+ and
    # trigger involuntary SPMD remat — measured; see EXPERIMENTS.md §Perf)
    attn_shard: str = "heads"
    # Megatron-style sequence parallelism: shard the residual stream's seq
    # dim over the model axis between blocks, so layer-boundary activations
    # (what remat must save) shrink by the TP width. Enabled by cells.py
    # for train/prefill when seq_len divides the model axis.
    seq_parallel: bool = False

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else self.d_model // self.n_heads

    @property
    def group_size(self) -> int:
        return self.n_heads // self.n_kv_heads


# ---------------------------------------------------------------------------
# Parameter init + logical axes
# ---------------------------------------------------------------------------

def _dense_init(key, shape, in_axis, dtype):
    fan_in = np.prod([shape[a] for a in np.atleast_1d(in_axis)])
    return (jax.random.normal(key, shape) / np.sqrt(fan_in)).astype(dtype)


def init_transformer(key: jax.Array, cfg: TransformerConfig):
    dh, h, hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    L, D, F, V = cfg.n_layers, cfg.d_model, cfg.d_ff, cfg.vocab_size
    pd = cfg.param_dtype
    keys = jax.random.split(key, 12)
    glu = cfg.activation in ("swiglu", "geglu")
    wi_cols = 2 * F if glu else F

    layers = {
        "ln1": jnp.ones((L, D), pd),
        "ln2": jnp.ones((L, D), pd),
        "wq": _dense_init(keys[0], (L, D, h * dh), 1, pd),
        "wk": _dense_init(keys[1], (L, D, hkv * dh), 1, pd),
        "wv": _dense_init(keys[2], (L, D, hkv * dh), 1, pd),
        "wo": _dense_init(keys[3], (L, h * dh, D), 1, pd),
    }
    if cfg.moe is None:
        layers["wi"] = _dense_init(keys[4], (L, D, wi_cols), 1, pd)
        layers["wo_ff"] = _dense_init(keys[5], (L, F, D), 1, pd)
    else:
        E = cfg.moe.num_experts
        layers["router"] = _dense_init(keys[6], (L, D, E), 1, pd)
        layers["wi"] = _dense_init(keys[7], (L, E, D, wi_cols), 2, pd)
        layers["wo_ff"] = _dense_init(keys[8], (L, E, F, D), 2, pd)

    params = {
        "embed": _dense_init(keys[9], (V, D), 1, pd),
        "layers": layers,
        "ln_f": jnp.ones((D,), pd),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = _dense_init(keys[10], (D, V), 0, pd)
    return params


def param_logical_axes(cfg: TransformerConfig):
    """Logical axis names per parameter dim (sharding rules map these)."""
    glu_cols = "ffn"
    layers = {
        "ln1": ("layers", "embed_noshard"),
        "ln2": ("layers", "embed_noshard"),
        "wq": ("layers", "embed", "qkv_features"),
        "wk": ("layers", "embed", "kv_features"),
        "wv": ("layers", "embed", "kv_features"),
        "wo": ("layers", "qkv_features", "embed"),
    }
    if cfg.moe is None:
        layers["wi"] = ("layers", "embed", glu_cols)
        layers["wo_ff"] = ("layers", "ffn", "embed")
    else:
        layers["router"] = ("layers", "embed", "experts_noshard")
        layers["wi"] = ("layers", "experts", "embed", glu_cols)
        layers["wo_ff"] = ("layers", "experts", "ffn", "embed")
    out = {
        "embed": ("vocab", "embed"),
        "layers": layers,
        "ln_f": ("embed_noshard",),
    }
    if not cfg.tie_embeddings:
        out["lm_head"] = ("embed", "vocab")
    return out


def _sc(x, cfg: "TransformerConfig", *axes):
    """with_sharding_constraint by logical position: 'b' -> batch axes,
    'm' -> model axis, None -> unsharded. No-op when constraints are off."""
    if cfg.act_batch_axes is None and cfg.act_model_axis is None:
        return x
    spec = []
    for a in axes:
        if a == "b":
            spec.append(cfg.act_batch_axes if cfg.act_batch_axes and
                        len(cfg.act_batch_axes) > 1
                        else (cfg.act_batch_axes[0] if cfg.act_batch_axes
                              else None))
        elif a == "m":
            spec.append(cfg.act_model_axis)
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.PartitionSpec(*spec))



def _res_axes(cfg):
    """Residual-stream constraint: seq over model when seq_parallel."""
    return ("b", "m", None) if cfg.seq_parallel else ("b", None, None)


def _attn_axes(cfg):
    """('b', None, 'm', None) for head sharding, ('b', None, None, 'm')
    for dh sharding (small-H archs)."""
    if cfg.attn_shard == "dh":
        return ("b", None, None, "m")
    return ("b", None, "m", None)

# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, S, H, Dh); positions: (B, S) absolute token positions."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs          # (B,S,half)
    cos = jnp.cos(ang)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(ang)[:, :, None, :].astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)


# ---------------------------------------------------------------------------
# Attention (naive reference + blocked online-softmax)
# ---------------------------------------------------------------------------

def _mask_fn(cfg: TransformerConfig):
    """(q_pos, k_pos) -> allowed (bool), broadcasting over arrays."""
    def allowed(qp, kp):
        m = jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), bool)
        if cfg.causal:
            m &= kp <= qp
        if cfg.window is not None:
            m &= kp > qp - cfg.window
        if cfg.attention_chunk is not None:
            m &= (kp // cfg.attention_chunk) == (qp // cfg.attention_chunk)
        return m
    return allowed


def expand_kv(k, n_heads):
    """GQA kv (B,S,Hkv,Dh) -> flat (B,S,H,Dh). Keeping attention in flat-H
    layout lets the 'heads' sharding survive (the grouped (Hkv, G) reshape
    breaks GSPMD head propagation — measured 16x activation replication)."""
    g = n_heads // k.shape[2]
    return jnp.repeat(k, g, axis=2) if g > 1 else k


def attention_naive(q, k, v, q_pos, k_pos, cfg, k_valid=None):
    """q, k, v: (B,S,H,Dh) (kv pre-expanded). Returns (B,Sq,H,Dh)."""
    b, sq, h, dh = q.shape
    logits = jnp.einsum("bqhd,bshd->bhqs", q, k).astype(jnp.float32)
    logits *= 1.0 / np.sqrt(dh)
    mask = _mask_fn(cfg)(q_pos[:, None, :, None], k_pos[:, None, None, :])
    if k_valid is not None:
        mask &= k_valid[:, None, None, :]
    logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqs,bshd->bqhd", p, v)


def attention_blocked(q, k, v, q_pos, k_pos, cfg, k_valid=None):
    """Online-softmax attention: scan over KV blocks, never materialising
    the (Sq, Sk) score matrix. Same tiling as the Pallas kernel.
    q, k, v: (B,S,H,Dh) flat-H (kv pre-expanded)."""
    b, sq, h, dh = q.shape
    sk = k.shape[1]
    bk = min(cfg.block_kv, sk)
    n_blocks = (sk + bk - 1) // bk
    pad = n_blocks * bk - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-1)
        kv_ok = jnp.pad(jnp.ones((b, sk), bool) if k_valid is None else k_valid,
                        ((0, 0), (0, pad)))
    else:
        kv_ok = jnp.ones((b, sk), bool) if k_valid is None else k_valid

    qh = (q * (1.0 / np.sqrt(dh))).astype(q.dtype)
    kb = k.reshape(b, n_blocks, bk, h, dh).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, n_blocks, bk, h, dh).transpose(1, 0, 2, 3, 4)
    pb = k_pos.reshape(b, n_blocks, bk).transpose(1, 0, 2)
    ob = kv_ok.reshape(b, n_blocks, bk).transpose(1, 0, 2)
    allowed = _mask_fn(cfg)

    def step(carry, blk):
        m, l, acc = carry
        kblk, vblk, pblk, okblk = blk
        s = jnp.einsum("bqhd,bshd->bhqs", qh, kblk).astype(jnp.float32)
        mask = allowed(q_pos[:, None, :, None], pblk[:, None, None, :])
        mask &= okblk[:, None, None, :]
        s = jnp.where(mask, s, -1e30)
        m_new = jnp.maximum(m, s.max(-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + p.sum(-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhqs,bshd->bhqd", p.astype(q.dtype), vblk).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    a0 = jnp.zeros((b, h, sq, dh), jnp.float32)
    (m, l, acc), _ = lax.scan(step, (m0, l0, a0), (kb, vb, pb, ob))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def attention(q, k, v, q_pos, k_pos, cfg, k_valid=None):
    if cfg.use_flash_kernel and k_valid is None and cfg.attention_chunk is None:
        from repro.kernels.flash_attention import ops as flash_ops
        return flash_ops.flash_attention(
            q, k, v, q_pos, k_pos, causal=cfg.causal, window=cfg.window)
    if q.shape[1] >= cfg.block_q or k.shape[1] > 4 * cfg.block_kv:
        f = attention_blocked
        if cfg.remat == "full":
            f = jax.checkpoint(f, static_argnums=(5,))
        return f(q, k, v, q_pos, k_pos, cfg, k_valid)
    return attention_naive(q, k, v, q_pos, k_pos, cfg, k_valid)


# ---------------------------------------------------------------------------
# FFN: dense GLU / MoE
# ---------------------------------------------------------------------------

def _act(x, kind):
    if kind == "swiglu" or kind == "silu":
        return jax.nn.silu(x)
    if kind == "geglu" or kind == "gelu":
        return jax.nn.gelu(x)
    raise ValueError(kind)


def dense_ffn(x, wi, wo, cfg):
    glu = cfg.activation in ("swiglu", "geglu")
    h = _sc(x @ wi, cfg, "b", None, "m")
    if glu:
        gate, up = jnp.split(h, 2, axis=-1)
        h = _act(gate, cfg.activation) * up
    else:
        h = _act(h, cfg.activation)
    return _sc(h @ wo, cfg, "b", None, None)


def moe_ffn(x, router_w, wi, wo, cfg):
    """x: (B, T, D). Group = batch row; top-k routing with capacity drop.

    GShard-style one-hot einsum dispatch/combine: scatter/gather dispatch
    lowers to batched u32 index tensors that GSPMD replicates to global
    batch (measured 48-60 GiB/device at mixtral scale); one-hot matmuls
    partition like every other dot. The (T, E*C) dispatch tensor is the
    known GShard overhead — sort-based dispatch on TPU is a §Perf lever.

    Returns (B, T, D) plus the Switch load-balancing auxiliary loss.
    """
    b, t, d = x.shape
    e, k = cfg.moe.num_experts, cfg.moe.top_k
    cap = max(1, int(t * k * cfg.moe.capacity_factor / e))

    logits = (x @ router_w).astype(jnp.float32)            # (B,T,E)
    probs = jax.nn.softmax(logits, -1)
    topw, topi = lax.top_k(probs, k)                       # (B,T,k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    # queue slot per assignment, k-major priority (k=0 fills first)
    oh = jax.nn.one_hot(topi, e, dtype=jnp.int32)          # (B,T,k,E)
    ohk = oh.transpose(0, 2, 1, 3)                         # (B,k,T,E)
    pos = jnp.cumsum(ohk.reshape(b, k * t, e), axis=1) - 1
    slot = (pos * ohk.reshape(b, k * t, e)).sum(-1)        # (B,k*t)
    keep = (slot < cap).reshape(b, k, t)
    slot = slot.reshape(b, k, t)

    glu = cfg.activation in ("swiglu", "geglu")
    xb = jnp.zeros((b, e, cap, d), x.dtype)
    disp = []
    for kk in range(k):
        slot_oh = jax.nn.one_hot(slot[:, kk], cap, dtype=x.dtype)  # (B,T,C)
        dk = (ohk[:, kk].astype(x.dtype)[..., None]
              * slot_oh[:, :, None, :]
              * keep[:, kk, :, None, None].astype(x.dtype))        # (B,T,E,C)
        disp.append(dk)
        xb = xb + jnp.einsum("btec,btd->becd", dk, x)
    xb = _sc(xb, cfg, "b", None, None, None)

    h = _sc(jnp.einsum("becd,edf->becf", xb, wi), cfg, "b", None, None, "m")
    if glu:
        gate, up = jnp.split(h, 2, axis=-1)
        h = _act(gate, cfg.activation) * up
    else:
        h = _act(h, cfg.activation)
    yb = _sc(jnp.einsum("becf,efd->becd", h, wo),
             cfg, "b", None, None, None)                   # (B,E,C,D)

    y = jnp.zeros_like(x)
    for kk in range(k):
        wk = topw[:, :, kk].astype(x.dtype)[:, :, None, None]
        y = y + jnp.einsum("btec,becd->btd", disp[kk] * wk, yb)

    # Switch aux loss: E * sum_e f_e * P_e
    me = probs.mean(axis=(0, 1))
    fe = (oh.sum((1, 2)).astype(jnp.float32) / jnp.float32(t * k)).mean(0)
    aux = e * jnp.sum(fe * me)
    return y, aux


# ---------------------------------------------------------------------------
# Blocks / full model
# ---------------------------------------------------------------------------

def rmsnorm(x, scale, eps):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), -1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


def _layer(x, lp, cfg, q_pos, k_pos, k_valid=None):
    """One transformer block (training/prefill path). Returns (x, aux)."""
    b, s, _ = x.shape
    dh, h, hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    dt = cfg.dtype

    hx = rmsnorm(x, lp["ln1"].astype(dt), cfg.norm_eps)
    q = _sc((hx @ lp["wq"].astype(dt)).reshape(b, s, h, dh),
            cfg, *_attn_axes(cfg))
    kk = (hx @ lp["wk"].astype(dt)).reshape(b, s, hkv, dh)
    vv = (hx @ lp["wv"].astype(dt)).reshape(b, s, hkv, dh)
    q = rope(q, q_pos, cfg.rope_theta)
    kk = rope(kk, q_pos, cfg.rope_theta)
    kk = _sc(expand_kv(kk, h), cfg, *_attn_axes(cfg))
    vv = _sc(expand_kv(vv, h), cfg, *_attn_axes(cfg))
    att = attention(q, kk, vv, q_pos, k_pos, cfg, k_valid)
    att = _sc(att, cfg, *_attn_axes(cfg))
    x = x + (att.reshape(b, s, h * dh) @ lp["wo"].astype(dt))
    x = _sc(x, cfg, *_res_axes(cfg))

    hx = rmsnorm(x, lp["ln2"].astype(dt), cfg.norm_eps)
    if cfg.moe is None:
        y = dense_ffn(hx, lp["wi"].astype(dt), lp["wo_ff"].astype(dt), cfg)
        aux = jnp.float32(0.0)
    else:
        y, aux = moe_ffn(hx, lp["router"].astype(dt), lp["wi"].astype(dt),
                         lp["wo_ff"].astype(dt), cfg)
    return _sc(x + y, cfg, *_res_axes(cfg)), aux


def transformer_forward(params, tokens, cfg: TransformerConfig, *,
                        positions=None, k_valid=None, return_hidden=False):
    """tokens (B, S) -> logits (B, S, V) [or hidden (B, S, D)]."""
    b, s = tokens.shape
    dt = cfg.dtype
    x = _sc(params["embed"].astype(dt)[tokens], cfg, *_res_axes(cfg))
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), dt)
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body(carry, lp):
        x = carry
        x, aux = _layer(x, lp, cfg, positions, positions, k_valid)
        return x, aux

    body_fn = body
    if cfg.remat == "full":
        body_fn = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, auxs = lax.scan(body_fn, x, params["layers"])
    x = rmsnorm(x, params["ln_f"].astype(dt), cfg.norm_eps)
    if return_hidden:
        return x, auxs.sum()
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"]).astype(dt)
    return _sc(x @ head, cfg, "b", None, "m"), auxs.sum()


def encode(params, tokens, cfg: TransformerConfig, valid=None):
    """Mean-pooled L2-normalised sentence embedding (retrieval encoder)."""
    hidden, _ = transformer_forward(params, tokens, cfg, k_valid=valid,
                                    return_hidden=True)
    if valid is None:
        pooled = hidden.mean(1)
    else:
        w = valid[..., None].astype(hidden.dtype)
        pooled = (hidden * w).sum(1) / jnp.maximum(w.sum(1), 1.0)
    pooled = pooled.astype(jnp.float32)
    return pooled / jnp.maximum(jnp.linalg.norm(pooled, axis=-1, keepdims=True), 1e-9)


def lm_loss(params, tokens, cfg: TransformerConfig, aux_weight=0.01):
    """Next-token cross-entropy; optional blocked (chunked-vocab) logsumexp."""
    logits, aux = transformer_forward(params, tokens[:, :-1], cfg)
    targets = tokens[:, 1:]
    logits = logits.astype(jnp.float32)
    if cfg.vocab_chunks > 1:
        v = logits.shape[-1]
        csz = -(-v // cfg.vocab_chunks)
        padv = cfg.vocab_chunks * csz - v
        lp = jnp.pad(logits, ((0, 0), (0, 0), (0, padv)), constant_values=-1e30)
        chunks = lp.reshape(*lp.shape[:2], cfg.vocab_chunks, csz)
        lse = jax.nn.logsumexp(jax.nn.logsumexp(chunks, -1), -1)
    else:
        lse = jax.nn.logsumexp(logits, -1)
    tgt_logit = jnp.take_along_axis(logits, targets[..., None], -1)[..., 0]
    nll = (lse - tgt_logit).mean()
    return nll + aux_weight * aux


def prefill(params, tokens, cfg: TransformerConfig):
    """Prefill pass for serving: tokens (B, S) -> (last-token logits (B, V),
    cache {k, v: (L, B, S_cache, Hkv, Dh), pos}). Windowed archs emit only
    the rolling tail of the KV stream (cache_length)."""
    b, s = tokens.shape
    dt = cfg.dtype
    dh, h, hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    s_cache = cache_length(cfg, s)
    x = params["embed"].astype(dt)[tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), dt)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body(carry, lp):
        x = carry
        hx = rmsnorm(x, lp["ln1"].astype(dt), cfg.norm_eps)
        q = _sc((hx @ lp["wq"].astype(dt)).reshape(b, s, h, dh),
                cfg, *_attn_axes(cfg))
        kk = (hx @ lp["wk"].astype(dt)).reshape(b, s, hkv, dh)
        vv = (hx @ lp["wv"].astype(dt)).reshape(b, s, hkv, dh)
        q = rope(q, positions, cfg.rope_theta)
        kk = rope(kk, positions, cfg.rope_theta)
        ke = _sc(expand_kv(kk, h), cfg, *_attn_axes(cfg))
        ve = _sc(expand_kv(vv, h), cfg, *_attn_axes(cfg))
        att = _sc(attention(q, ke, ve, positions, positions, cfg),
                  cfg, *_attn_axes(cfg))
        x = x + (att.reshape(b, s, h * dh) @ lp["wo"].astype(dt))
        x = _sc(x, cfg, "b", None, None)
        hx = rmsnorm(x, lp["ln2"].astype(dt), cfg.norm_eps)
        if cfg.moe is None:
            y = dense_ffn(hx, lp["wi"].astype(dt), lp["wo_ff"].astype(dt), cfg)
        else:
            y, _ = moe_ffn(hx, lp["router"].astype(dt), lp["wi"].astype(dt),
                           lp["wo_ff"].astype(dt), cfg)
        # rolling tail goes to the cache; roll so slot = pos % s_cache
        ktail = jnp.roll(kk[:, -s_cache:], s % s_cache, axis=1)
        vtail = jnp.roll(vv[:, -s_cache:], s % s_cache, axis=1)
        return _sc(x + y, cfg, *_res_axes(cfg)), (ktail, vtail)

    x, (ks, vs) = lax.scan(body, x, params["layers"])
    x = rmsnorm(x[:, -1], params["ln_f"].astype(dt), cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"]).astype(dt)
    logits = x @ head
    cache = {"k": ks, "v": vs,
             "pos": jnp.full((b,), s, jnp.int32)}
    return logits, cache


# ---------------------------------------------------------------------------
# KV-cache serving
# ---------------------------------------------------------------------------

def cache_length(cfg: TransformerConfig, max_seq: int) -> int:
    """Windowed/chunked archs keep a rolling buffer — this is what makes the
    524k-context decode cells sub-quadratic (DESIGN.md §5)."""
    if cfg.window is not None:
        return min(max_seq, cfg.window)
    if cfg.attention_chunk is not None:
        return min(max_seq, cfg.attention_chunk)
    return max_seq


def init_kv_cache(cfg: TransformerConfig, batch: int, max_seq: int,
                  dtype=None):
    s = cache_length(cfg, max_seq)
    dt = dtype or cfg.dtype
    shape = (cfg.n_layers, batch, s, cfg.n_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, dt),
        "v": jnp.zeros(shape, dt),
        "pos": jnp.zeros((batch,), jnp.int32),   # next absolute position
    }


def decode_step(params, cache, tokens, cfg: TransformerConfig):
    """One-token decode: tokens (B, 1) -> (logits (B, 1, V), new cache)."""
    b = tokens.shape[0]
    s_cache = cache["k"].shape[2]
    dt = cfg.dtype
    pos = cache["pos"]                               # (B,)
    q_pos = pos[:, None]                             # (B,1)
    slot = pos % s_cache                             # rolling buffer slot

    x = params["embed"].astype(dt)[tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), dt)

    # absolute position of each rolling-buffer slot after this step's write:
    # largest a ≡ slot (mod S) with a <= pos  ->  a = pos - ((pos - slot) mod S)
    slots = jnp.arange(s_cache, dtype=jnp.int32)[None]            # (1,S)
    k_pos = pos[:, None] - jnp.mod(pos[:, None] - slots, s_cache)
    k_valid = k_pos >= 0

    def body(x, lp_cache):
        lp, ck, cv = lp_cache
        hx = rmsnorm(x, lp["ln1"].astype(dt), cfg.norm_eps)
        q = (hx @ lp["wq"].astype(dt)).reshape(b, 1, cfg.n_heads, cfg.head_dim)
        kk = (hx @ lp["wk"].astype(dt)).reshape(b, 1, cfg.n_kv_heads, cfg.head_dim)
        vv = (hx @ lp["wv"].astype(dt)).reshape(b, 1, cfg.n_kv_heads, cfg.head_dim)
        q = rope(q, q_pos, cfg.rope_theta)
        kk = rope(kk, q_pos, cfg.rope_theta)
        ck = ck.at[jnp.arange(b), slot].set(kk[:, 0])
        cv = cv.at[jnp.arange(b), slot].set(vv[:, 0])
        ke = _sc(expand_kv(ck, cfg.n_heads), cfg, *_attn_axes(cfg))
        ve = _sc(expand_kv(cv, cfg.n_heads), cfg, *_attn_axes(cfg))
        att = attention_naive(q, ke, ve, q_pos, k_pos, cfg, k_valid)
        x = x + att.reshape(b, 1, -1) @ lp["wo"].astype(dt)
        hx = rmsnorm(x, lp["ln2"].astype(dt), cfg.norm_eps)
        if cfg.moe is None:
            y = dense_ffn(hx, lp["wi"].astype(dt), lp["wo_ff"].astype(dt), cfg)
        else:
            y, _ = moe_ffn(hx, lp["router"].astype(dt), lp["wi"].astype(dt),
                           lp["wo_ff"].astype(dt), cfg)
        return x + y, (ck, cv)

    x, (new_k, new_v) = lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    x = rmsnorm(x, params["ln_f"].astype(dt), cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"]).astype(dt)
    logits = x @ head
    new_cache = {"k": new_k, "v": new_v, "pos": pos + 1}
    return logits, new_cache


# ---------------------------------------------------------------------------
# Accounting
# ---------------------------------------------------------------------------

def count_params(cfg: TransformerConfig) -> int:
    dh, h, hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    L, D, F, V = cfg.n_layers, cfg.d_model, cfg.d_ff, cfg.vocab_size
    glu = cfg.activation in ("swiglu", "geglu")
    attn = D * h * dh + 2 * D * hkv * dh + h * dh * D
    if cfg.moe is None:
        ffn = D * F * (3 if glu else 2)
    else:
        ffn = cfg.moe.num_experts * D * F * (3 if glu else 2) + D * cfg.moe.num_experts
    total = L * (attn + ffn + 2 * D) + V * D + D
    if not cfg.tie_embeddings:
        total += D * V
    return total


def active_params(cfg: TransformerConfig) -> int:
    """Params touched per token (MoE: top-k experts only) — the N in the
    MODEL_FLOPS = 6*N*D roofline term."""
    dh, h, hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    L, D, F, V = cfg.n_layers, cfg.d_model, cfg.d_ff, cfg.vocab_size
    glu = cfg.activation in ("swiglu", "geglu")
    attn = D * h * dh + 2 * D * hkv * dh + h * dh * D
    k = cfg.moe.top_k if cfg.moe else 1
    ffn = k * D * F * (3 if glu else 2)
    total = L * (attn + ffn) + V * D
    if not cfg.tie_embeddings:
        total += D * V
    return total
