"""Model zoo: one composable transformer family covering the five assigned
LM architectures (dense + MoE, GQA/MQA, RoPE, sliding-window / chunked
attention, GeGLU/SwiGLU, scanned layers, KV-cache serving); four recsys
rankers over a shared EmbeddingBag substrate; and an E(3)-equivariant MACE
implementation with its own spherical-harmonic / Clebsch-Gordan machinery.
"""
from repro.models.transformer import (TransformerConfig, MoEConfig,
                                      init_transformer, transformer_forward,
                                      lm_loss, decode_step, init_kv_cache)

__all__ = ["TransformerConfig", "MoEConfig", "init_transformer",
           "transformer_forward", "lm_loss", "decode_step", "init_kv_cache"]
