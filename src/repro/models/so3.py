"""SO(3) machinery for E(3)-equivariant message passing (MACE), from scratch.

No e3nn dependency offline: real spherical harmonics (l <= 2 closed-form,
Condon-Shortley-consistent) and real-basis Clebsch-Gordan coefficients
computed at import time from Racah's formula + the complex->real unitary.

Conventions: m-index order is m = -l..l; the l=1 components are (y, z, x).
Real CG tensors are either purely real or purely imaginary; the nonzero part
is taken (a global phase per (l1,l2,l3) path is absorbed by the learnable
path weights and does not affect equivariance, whose D-matrices are real in
this basis). tests/test_so3.py verifies equivariance numerically against
least-squares-fitted Wigner-D matrices.
"""
from __future__ import annotations

import functools
import math

import numpy as np

L_MAX = 2


# ---------------------------------------------------------------------------
# Complex CG via Racah's formula
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _fact(n: int) -> float:
    return float(math.factorial(n))


def clebsch_gordan_complex(l1, m1, l2, m2, l3, m3) -> float:
    if m3 != m1 + m2:
        return 0.0
    if not (abs(l1 - l2) <= l3 <= l1 + l2):
        return 0.0
    if abs(m1) > l1 or abs(m2) > l2 or abs(m3) > l3:
        return 0.0
    pref = math.sqrt(
        (2 * l3 + 1) * _fact(l3 + l1 - l2) * _fact(l3 - l1 + l2)
        * _fact(l1 + l2 - l3) / _fact(l1 + l2 + l3 + 1))
    pref *= math.sqrt(_fact(l3 + m3) * _fact(l3 - m3) * _fact(l1 - m1)
                      * _fact(l1 + m1) * _fact(l2 - m2) * _fact(l2 + m2))
    s = 0.0
    for k in range(0, l1 + l2 - l3 + 1):
        denom_terms = [k, l1 + l2 - l3 - k, l1 - m1 - k, l2 + m2 - k,
                       l3 - l2 + m1 + k, l3 - l1 - m2 + k]
        if any(t < 0 for t in denom_terms):
            continue
        denom = 1.0
        for t in denom_terms:
            denom *= _fact(t)
        s += (-1.0) ** k / denom
    return pref * s


def _real_unitary(l: int) -> np.ndarray:
    """U[m_real, m_complex]: real SH = U @ complex SH (C-S phase)."""
    dim = 2 * l + 1
    u = np.zeros((dim, dim), np.complex128)
    for m in range(-l, l + 1):
        i = m + l
        if m == 0:
            u[i, l] = 1.0
        elif m > 0:
            u[i, -m + l] = 1.0 / math.sqrt(2)
            u[i, m + l] = (-1.0) ** m / math.sqrt(2)
        else:  # m < 0
            am = -m
            u[i, m + l] = 1j / math.sqrt(2)
            u[i, am + l] = -1j * (-1.0) ** am / math.sqrt(2)
    return u


@functools.lru_cache(maxsize=None)
def real_clebsch_gordan(l1: int, l2: int, l3: int) -> np.ndarray:
    """Real-basis CG tensor C[(2l1+1), (2l2+1), (2l3+1)]."""
    c = np.zeros((2 * l1 + 1, 2 * l2 + 1, 2 * l3 + 1), np.complex128)
    for m1 in range(-l1, l1 + 1):
        for m2 in range(-l2, l2 + 1):
            m3 = m1 + m2
            if abs(m3) <= l3:
                c[m1 + l1, m2 + l2, m3 + l3] = clebsch_gordan_complex(
                    l1, m1, l2, m2, l3, m3)
    u1, u2, u3 = _real_unitary(l1), _real_unitary(l2), _real_unitary(l3)
    cr = np.einsum("am,bn,co,mno->abc", u1, u2, np.conj(u3), c)
    re, im = np.real(cr), np.imag(cr)
    out = re if np.abs(re).max() >= np.abs(im).max() else im
    assert min(np.abs(re).max(), np.abs(im).max()) < 1e-10, (l1, l2, l3)
    return np.ascontiguousarray(out.astype(np.float32))


def valid_paths(l_max: int = L_MAX):
    """All (l1, l2, l3) coupling paths with every l <= l_max."""
    paths = []
    for l1 in range(l_max + 1):
        for l2 in range(l_max + 1):
            for l3 in range(abs(l1 - l2), min(l1 + l2, l_max) + 1):
                paths.append((l1, l2, l3))
    return paths


# ---------------------------------------------------------------------------
# Real spherical harmonics l <= 2 (orthonormal, unit vectors)
# ---------------------------------------------------------------------------

_C1 = math.sqrt(3.0 / (4.0 * math.pi))
_C2a = 0.5 * math.sqrt(15.0 / math.pi)    # xy, yz, xz
_C2b = 0.25 * math.sqrt(5.0 / math.pi)    # 3z^2 - 1
_C2c = 0.25 * math.sqrt(15.0 / math.pi)   # x^2 - y^2
_C0 = 0.5 / math.sqrt(math.pi)


def spherical_harmonics(vec, jnp):
    """vec: (..., 3) unit vectors -> dict {l: (..., 2l+1)} for l = 0..2.

    Pass ``jax.numpy`` (or numpy) as ``jnp`` so the same code serves both
    the model and host-side tests.
    """
    x, y, z = vec[..., 0], vec[..., 1], vec[..., 2]
    y0 = jnp.full(x.shape + (1,), _C0, vec.dtype)
    y1 = jnp.stack([_C1 * y, _C1 * z, _C1 * x], axis=-1)
    y2 = jnp.stack([
        _C2a * x * y,
        _C2a * y * z,
        _C2b * (3.0 * z * z - 1.0),
        _C2a * x * z,
        _C2c * (x * x - y * y),
    ], axis=-1)
    return {0: y0, 1: y1, 2: y2}
