"""MACE — higher-order E(3)-equivariant message passing [arXiv:2206.07697].

Assigned config: n_layers=2, d_hidden=128 channels, l_max=2,
correlation_order=3, n_rbf=8.

Implementation notes (DESIGN.md §8):
* Node states are real-irrep dicts {l: (N, C, 2l+1)}, l = 0..2, one channel
  width C for every l.
* Messages: for each coupling path (l1 from h_j, l2 from Y(r_ij) -> l3),
  m_e = R_path,c(r_ij) * CG[l1,l2,l3](h_j, Y), aggregated with
  ``jax.ops.segment_sum`` over destination nodes (the GNN scatter primitive
  the assignment calls out; JAX sparse is BCOO-only so message passing IS
  edge-index + segment ops).
* Correlation order 3 via iterated CG products (ACE construction):
  B1 = A;  B2 = CG(A, A);  B3 = CG(B2, A) — per-channel learnable path
  weights. Iterated products span the symmetric tensor-product space the
  paper contracts in one shot; over-completeness is absorbed by weights.
* Energies are invariant (l=0) readouts summed per graph; forces are
  -dE/dpositions via jax.grad (tests verify E invariance + F equivariance
  under random rotations).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import so3


@dataclasses.dataclass(frozen=True)
class MACEConfig:
    n_layers: int = 2
    channels: int = 128            # d_hidden
    l_max: int = 2
    correlation: int = 3
    n_rbf: int = 8
    r_cut: float = 5.0
    d_feat: int = 16               # input node feature width
    readout_hidden: int = 16
    dtype: Any = jnp.float32
    remat: bool = True             # checkpoint each interaction layer:
    # per-edge message tensors at 61.9M edges x 128ch are the memory wall
    act_grid_axes: Any = None      # mesh axes to shard edge/node tensors over
    # §Perf levers (EXPERIMENTS.md): fuse the 3 per-l3 scatters into one
    # segment_sum (1 all-reduce per layer instead of 3) and carry messages
    # in bf16 (halves scatter/all-reduce bytes)
    fused_scatter: bool = False
    msg_dtype: Any = None          # e.g. jnp.bfloat16


def _paths(cfg):
    return [p for p in so3.valid_paths(cfg.l_max)]


def _scg(x, cfg):
    """Shard an edge-/node-major tensor's leading dim over the device grid.
    Without these constraints GSPMD replicates the per-edge message tensors
    (61.9M x 128 x 5 floats = 158 GB each at ogb_products scale)."""
    if not cfg.act_grid_axes:
        return x
    import jax as _jax
    return _jax.lax.with_sharding_constraint(
        x, _jax.sharding.PartitionSpec(tuple(cfg.act_grid_axes),
                                       *([None] * (x.ndim - 1))))


def _cg(l1, l2, l3):
    return jnp.asarray(so3.real_clebsch_gordan(l1, l2, l3))


def init_mace(key, cfg: MACEConfig):
    C = cfg.channels
    paths = _paths(cfg)
    n_paths = len(paths)
    ks = list(jax.random.split(key, 6 + 4 * cfg.n_layers))
    pd = cfg.dtype

    def lin(k, i, o):
        return (jax.random.normal(k, (i, o)) / np.sqrt(i)).astype(pd)

    layers = []
    for li in range(cfg.n_layers):
        k1, k2, k3, k4 = jax.random.split(ks[6 + li], 4)
        layers.append({
            # radial MLP: rbf -> hidden -> per-(path, channel) weights
            "rad_w1": lin(k1, cfg.n_rbf, 64),
            "rad_w2": lin(k2, 64, n_paths * C),
            # per-l channel-mixing linears for self, A, B2, B3 terms
            "mix": {l: {
                "self": lin(jax.random.fold_in(k3, 10 * l), C, C),
                "a": lin(jax.random.fold_in(k3, 10 * l + 1), C, C),
                "b2": lin(jax.random.fold_in(k3, 10 * l + 2), C, C),
                "b3": lin(jax.random.fold_in(k3, 10 * l + 3), C, C),
            } for l in range(cfg.l_max + 1)},
            # per-path per-channel product weights for B2 / B3
            "w_b2": (jax.random.normal(k4, (n_paths, C)) / np.sqrt(n_paths)).astype(pd),
            "w_b3": (jax.random.normal(jax.random.fold_in(k4, 1),
                                       (n_paths, C)) / np.sqrt(n_paths)).astype(pd),
        })
    return {
        "embed": lin(ks[0], cfg.d_feat, C),
        "layers_list": layers,
        "readout_w1": lin(ks[1], C, cfg.readout_hidden),
        "readout_w2": lin(ks[2], cfg.readout_hidden, 1),
    }


def _rbf(r, cfg):
    """Gaussian radial basis with cosine cutoff envelope."""
    mu = jnp.linspace(0.0, cfg.r_cut, cfg.n_rbf, dtype=r.dtype)
    gamma = (cfg.n_rbf / cfg.r_cut) ** 2
    basis = jnp.exp(-gamma * (r[..., None] - mu) ** 2)
    env = 0.5 * (jnp.cos(jnp.pi * jnp.clip(r / cfg.r_cut, 0, 1)) + 1.0)
    return basis * env[..., None]


def _cg_product(x, y, l1, l2, l3):
    """x: (N, C, 2l1+1), y: (N, C, 2l2+1) -> (N, C, 2l3+1)."""
    return jnp.einsum("abc,nia,nib->nic", _cg(l1, l2, l3), x, y)


def _cg_product_edge(x, y, l1, l2, l3):
    """x: (E, C, 2l1+1), y: (E, 2l2+1) (Y shared over channels)."""
    return jnp.einsum("abc,nia,nb->nic", _cg(l1, l2, l3), x, y)


def mace_forward(params, batch, cfg: MACEConfig, return_nodes: bool = False):
    """batch: positions (N,3), node_feats (N,d_feat), edge_src/dst (E,),
    edge_mask (E,), graph_ids (N,), n_graphs int.
    Returns per-graph energies (G,) (or per-node readouts)."""
    pos = batch["positions"]
    src, dst = batch["edge_src"], batch["edge_dst"]
    emask = batch["edge_mask"].astype(pos.dtype)
    n = pos.shape[0]
    C = cfg.channels
    paths = _paths(cfg)

    # edge geometry
    rvec = _scg(pos[src] - pos[dst], cfg)                       # (E,3)
    r = jnp.sqrt(jnp.sum(rvec * rvec, -1) + 1e-12)
    rhat = rvec / r[..., None]
    ylm = {l: _scg(y, cfg) for l, y in
           so3.spherical_harmonics(rhat, jnp).items()}          # {l: (E,2l+1)}
    rbf = _scg(_rbf(r, cfg), cfg)                               # (E,n_rbf)

    # initial node state: scalars from features, higher l zero
    h = {0: _scg((batch["node_feats"] @ params["embed"])[:, :, None], cfg)}
    for l in range(1, cfg.l_max + 1):
        h[l] = jnp.zeros((n, C, 2 * l + 1), pos.dtype)

    def layer_fn(lp, h):
        rad = jax.nn.silu(rbf @ lp["rad_w1"]) @ lp["rad_w2"]    # (E, n_paths*C)
        rad = _scg(rad.reshape(-1, len(paths), C) * emask[:, None, None], cfg)

        # --- messages + aggregation: A[l3] = sum_j R * CG(h_j, Y_ij) ---
        # Sum every path's (radially weighted) message per edge FIRST, then
        # scatter once per l3: GSPMD lowers each scatter-add to a
        # replicated-output + all-reduce, so one (N, C, 2l3+1) replicated
        # buffer per l3 per layer instead of one per path (15x fewer).
        msg = {l: jnp.zeros((src.shape[0], C, 2 * l + 1), pos.dtype)
               for l in range(cfg.l_max + 1)}
        gathered = {l: _scg(h[l][src], cfg) for l in range(cfg.l_max + 1)}
        for pi, (l1, l2, l3) in enumerate(paths):
            m = _cg_product_edge(gathered[l1], ylm[l2], l1, l2, l3)
            msg[l3] = _scg(msg[l3] + m * rad[:, pi, :, None], cfg)
        mdt = cfg.msg_dtype or pos.dtype
        if cfg.fused_scatter:
            flat = jnp.concatenate(
                [msg[l].reshape(src.shape[0], -1)
                 for l in range(cfg.l_max + 1)], axis=-1).astype(mdt)
            agg = _scg(jax.ops.segment_sum(flat, dst, num_segments=n), cfg)
            agg = agg.astype(pos.dtype)
            a, off = {}, 0
            for l in range(cfg.l_max + 1):
                width = C * (2 * l + 1)
                a[l] = agg[:, off:off + width].reshape(n, C, 2 * l + 1)
                off += width
        else:
            a = {l: _scg(jax.ops.segment_sum(msg[l].astype(mdt), dst,
                                             num_segments=n).astype(pos.dtype),
                         cfg)
                 for l in range(cfg.l_max + 1)}

        # --- higher-order products (correlation 3): B2 = AxA, B3 = B2xA ---
        b2 = {l: jnp.zeros_like(a[l]) for l in a}
        for pi, (l1, l2, l3) in enumerate(paths):
            t = _cg_product(a[l1], a[l2], l1, l2, l3)
            b2[l3] = _scg(b2[l3] + t * lp["w_b2"][pi][None, :, None], cfg)
        b3 = {l: jnp.zeros_like(a[l]) for l in a}
        if cfg.correlation >= 3:
            for pi, (l1, l2, l3) in enumerate(paths):
                t = _cg_product(b2[l1], a[l2], l1, l2, l3)
                b3[l3] = _scg(b3[l3] + t * lp["w_b3"][pi][None, :, None], cfg)

        # --- update: residual + channel mixes (einsum on channel dim) ---
        new_h = {}
        for l in range(cfg.l_max + 1):
            mix = lp["mix"][l]
            new_h[l] = _scg(jnp.einsum("ncm,cd->ndm", h[l], mix["self"])
                            + jnp.einsum("ncm,cd->ndm", a[l], mix["a"])
                            + jnp.einsum("ncm,cd->ndm", b2[l], mix["b2"])
                            + jnp.einsum("ncm,cd->ndm", b3[l], mix["b3"]), cfg)
        return new_h

    if cfg.remat:
        layer_fn = jax.checkpoint(layer_fn)
    for lp in params["layers_list"]:
        h = layer_fn(lp, h)

    # invariant readout -> per-node energy -> per-graph sum
    e_node = (jax.nn.silu(h[0][:, :, 0] @ params["readout_w1"])
              @ params["readout_w2"])[:, 0]
    if return_nodes:
        return e_node
    n_graphs = batch["n_graphs"]
    return jax.ops.segment_sum(e_node, batch["graph_ids"],
                               num_segments=n_graphs)


def mace_energy_forces(params, batch, cfg: MACEConfig):
    def etot(pos):
        return mace_forward(params, {**batch, "positions": pos}, cfg).sum()
    e = mace_forward(params, batch, cfg)
    forces = -jax.grad(etot)(batch["positions"])
    return e, forces


def mace_loss(params, batch, cfg: MACEConfig, force_weight: float = 10.0):
    e, f = mace_energy_forces(params, batch, cfg)
    le = jnp.mean((e - batch["energy_target"]) ** 2)
    lf = jnp.mean(jnp.sum((f - batch["force_target"]) ** 2, -1))
    return le + force_weight * lf


def mace_node_loss(params, batch, cfg: MACEConfig):
    """Sampled-training objective (minibatch_lg): per-node invariant
    prediction, MSE over the labelled batch nodes only."""
    preds = mace_forward(params, batch, cfg, return_nodes=True)
    mask = batch["node_mask"].astype(preds.dtype)
    err = (preds - batch["node_target"]) ** 2 * mask
    return jnp.sum(err) / jnp.maximum(jnp.sum(mask), 1.0)


# ---------------------------------------------------------------------------
# Synthetic graph batches (tests / smoke / dry-run input builders)
# ---------------------------------------------------------------------------

def random_graph_batch(key, *, n_nodes, n_edges, d_feat, n_graphs=1,
                       dtype=jnp.float32):
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    pos = jax.random.normal(k1, (n_nodes, 3), dtype) * 2.0
    feats = jax.random.normal(k2, (n_nodes, d_feat), dtype)
    src = jax.random.randint(k3, (n_edges,), 0, n_nodes)
    dst = jax.random.randint(k4, (n_edges,), 0, n_nodes)
    # avoid self loops (zero-length edge vectors)
    dst = jnp.where(dst == src, (dst + 1) % n_nodes, dst)
    gid = jnp.sort(jax.random.randint(k5, (n_nodes,), 0, n_graphs))
    return {
        "positions": pos, "node_feats": feats,
        "edge_src": src, "edge_dst": dst,
        "edge_mask": jnp.ones((n_edges,), bool),
        "graph_ids": gid, "n_graphs": n_graphs,
        "energy_target": jnp.zeros((n_graphs,), dtype),
        "force_target": jnp.zeros((n_nodes, 3), dtype),
    }
