"""Experiment-grid evaluation CLI (DESIGN.md §8) — runs a declarative
(sampler × retrieval engine × k × metric) grid over the synthetic corpus
through the trie-shared plan executor and prints the sample-fidelity report.

  PYTHONPATH=src python -m repro.launch.evaluate --grid default
  PYTHONPATH=src python -m repro.launch.evaluate --grid smoke --json results/eval.json
  PYTHONPATH=src python -m repro.launch.evaluate --engines exact,lsh --ks 3,10,20
  PYTHONPATH=src python -m repro.launch.evaluate --grid smoke --backend pallas --sharded --mesh host
  PYTHONPATH=src python -m repro.launch.evaluate --grid smoke --streamed --mesh auto
  PYTHONPATH=src python -m repro.launch.evaluate --grid smoke --backend int8 --no-tuned-kernels
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import logging
import os

from repro.data.synthetic import generate_corpus
from repro.eval import (GridSpec, SearchConfig, available_backends,
                        available_retrieval_engines, available_samplers,
                        backend_recall_curve, build_fidelity_report,
                        format_backend_curve, format_fidelity_report,
                        get_backend, get_retrieval_engine, get_sampler,
                        run_grid)
from repro.kernels import tuning
from repro.launch.logs import (add_logging_args, add_obs_args, init_obs,
                               setup_logging, write_metrics)
from repro.launch.mesh import parse_mesh

log = logging.getLogger("repro.launch.evaluate")

GRIDS = {
    # 3 samplers x 4 engines x 2 ks x 4 metrics = 96 cells
    "default": GridSpec(),
    # minimal end-to-end check: 3 samplers x 2 engines x 1 k x 2 metrics
    "smoke": GridSpec(engines=("exact", "tfidf"), ks=(3,),
                      metrics=("precision", "mrr"), max_queries=128),
}


def _csv(s):
    return tuple(x for x in s.split(",") if x)


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--grid", default="default", choices=sorted(GRIDS),
                   help="grid preset; axis flags below override it")
    p.add_argument("--samplers", default=None,
                   help="comma list from " + ",".join(available_samplers()))
    p.add_argument("--engines", default=None,
                   help="comma list from "
                        + ",".join(available_retrieval_engines()))
    p.add_argument("--ks", default=None, help="comma list of cutoffs")
    p.add_argument("--metrics", default=None,
                   help="comma list of precision,recall,ndcg,mrr")
    p.add_argument("--backend", default="jnp",
                   help="scoring backend for the search core "
                        "(retrieval/backends.py): "
                        + ",".join(available_backends()))
    p.add_argument("--sharded", action="store_true",
                   help="run index search mesh-partitioned through "
                        "retrieval/sharded.py")
    p.add_argument("--streamed", action="store_true",
                   help="shard each corpus from birth: stream it chunk-wise "
                        "into per-device buffers and build the index "
                        "shard-locally (retrieval/sharded.sharded_build; "
                        "implies --sharded)")
    p.add_argument("--stream-chunk", type=int, default=65536,
                   help="host->device streaming chunk rows for --streamed")
    p.add_argument("--mesh", default="host",
                   help="mesh for --sharded/--streamed: host (1-device, "
                        "production axis names) or auto (all local devices)")
    p.add_argument("--no-tuned-kernels", action="store_true",
                   help="CLI escape hatch: ignore the autotuned block table "
                        "(kernels/tuning.py) and use the hard-coded kernel "
                        "defaults (env equivalent: REPRO_TUNED_KERNELS=off)")
    p.add_argument("--no-backend-curve", action="store_true",
                   help="skip the backend recall-vs-speed curve appended to "
                        "the fidelity output")
    p.add_argument("--sample-frac", type=float, default=None)
    p.add_argument("--max-queries", type=int, default=None)
    p.add_argument("--queries", type=int, default=512,
                   help="synthetic corpus size (queries)")
    p.add_argument("--qrels-per-query", type=int, default=16)
    p.add_argument("--topics", type=int, default=48)
    p.add_argument("--aux-fraction", type=float, default=1.0)
    p.add_argument("--vocab", type=int, default=2048)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--json", default=None, metavar="PATH",
                   help="persist grid cells + fidelity report as JSON")
    add_logging_args(p)
    add_obs_args(p)
    args = p.parse_args(argv)
    setup_logging(args)
    init_obs(args)

    spec = GRIDS[args.grid]
    overrides = {}
    if args.samplers:
        overrides["samplers"] = _csv(args.samplers)
    if args.engines:
        overrides["engines"] = _csv(args.engines)
    if args.ks:
        overrides["ks"] = tuple(int(k) for k in _csv(args.ks))
    if args.metrics:
        overrides["metrics"] = _csv(args.metrics)
    if args.sample_frac is not None:
        overrides["sample_frac"] = args.sample_frac
    if args.max_queries is not None:
        overrides["max_queries"] = args.max_queries
    overrides["seed"] = args.seed
    spec = dataclasses.replace(spec, **overrides)

    # unknown sampler/engine/backend names fail here with the registry's
    # error message (the core/engines.py UX), before any corpus work —
    # the same error contract as launch/sample.py --strategy
    for name in spec.samplers:
        get_sampler(name)
    for name in spec.engines:
        get_retrieval_engine(name)
    get_backend(args.backend)
    if args.no_tuned_kernels:
        tuning.set_table(None)      # force hard-coded kernel defaults
    search = SearchConfig(backend=args.backend,
                          sharded=args.sharded or args.streamed,
                          streamed=args.streamed,
                          stream_chunk=args.stream_chunk,
                          mesh=(parse_mesh(args.mesh)
                                if args.sharded or args.streamed else None))

    corpus = generate_corpus(
        num_queries=args.queries, qrels_per_query=args.qrels_per_query,
        num_topics=args.topics, aux_fraction=args.aux_fraction,
        vocab_size=args.vocab, query_len=24, seed=args.seed)
    log.info("corpus: %d entities (%d judged), %d queries",
             corpus.num_entities, corpus.num_primary, corpus.num_queries)
    log.info("grid: %d samplers x %d engines x %d ks x %d metrics "
             "= %d cells (backend=%s, sharded=%s)",
             len(spec.samplers), len(spec.engines), len(spec.ks),
             len(spec.metrics), spec.num_cells, args.backend, args.sharded)

    result = run_grid(corpus, spec, search=search, verbose=True)

    log.info("\ncells (sampler, engine, k, metric -> value):")
    for (s, e, k, m), v in sorted(result.cells.items()):
        log.info("  %-11s %-8s k=%-3d %-10s %.4f", s, e, k, m, v)

    log.info("\nplan-trie stage counters (shared prefixes executed once):")
    log.info("%s", result.trie.summary())

    report = None
    if "full" in spec.samplers:
        report = build_fidelity_report(result.cells, spec)
        log.info("\n%s", format_fidelity_report(report, spec))
    else:
        log.info("\n(no 'full' sampler in the grid -> skipping the "
                 "fidelity report; add full to --samplers for deltas and "
                 "Kendall-tau)")

    curve = None
    if not args.no_backend_curve:
        # backend-level recall-vs-speed on the grid's own embedding: the
        # int8 backend's recall@10 vs jnp exact, swept over rerank_factor
        import jax.numpy as jnp
        from repro.eval import tfidf_embedder
        ev, qv = tfidf_embedder(corpus)
        nq = min(128, qv.shape[0])
        curve = backend_recall_curve(jnp.asarray(ev), jnp.asarray(qv[:nq]),
                                     k=10)
        log.info("\n%s", format_backend_curve(curve, k=10))

    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        out = {"grid": result.to_json()}
        if report is not None:
            out["fidelity"] = report.to_json()
        if curve is not None:
            out["backend_curve"] = curve
        with open(args.json, "w") as f:
            json.dump(out, f, indent=2)
        log.info("\nwrote %s", args.json)
    metrics_path = write_metrics(
        args, {"plan": result.trie.metrics.snapshot()})
    if metrics_path:
        log.info("wrote %s", metrics_path)


if __name__ == "__main__":
    main()
