"""Trace reader CLI (DESIGN.md §12) — per-stage aggregates from a span
JSONL produced by ``repro.obs.trace`` (``REPRO_TRACE=<path>`` or the
CLIs' ``--trace``).

  PYTHONPATH=src python -m repro.launch.trace results/trace.jsonl
  PYTHONPATH=src python -m repro.launch.trace results/trace.jsonl --json -
  PYTHONPATH=src python -m repro.launch.trace results/trace.jsonl --sort total

Per span name: count, total/mean wall seconds, exact p50/p99 over the
recorded durations, and — for JAX-aware spans — the compile share: the
fraction of total stage time spent in *first* calls beyond the
steady-state cost (first call = trace + XLA compile + execute; steady
calls = execute only).  With one call and no steady sample the whole
first-call time is reported as the (upper-bound) compile share.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, Iterable, List, Optional

__all__ = ["aggregate", "format_table", "load_spans", "main"]


def load_spans(path: str) -> List[dict]:
    """Parse one span record per JSONL line (blank lines skipped)."""
    spans = []
    with open(path, encoding="utf-8") as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                spans.append(json.loads(line))
            except json.JSONDecodeError as e:
                raise ValueError(
                    f"{path}:{lineno}: not a JSON span record: {e}") from e
    return spans


def _percentile(sorted_vals: List[float], p: float) -> float:
    """Exact percentile (linear interpolation between closest ranks)."""
    if not sorted_vals:
        return 0.0
    if len(sorted_vals) == 1:
        return sorted_vals[0]
    pos = p / 100.0 * (len(sorted_vals) - 1)
    lo = int(pos)
    hi = min(lo + 1, len(sorted_vals) - 1)
    return sorted_vals[lo] + (pos - lo) * (sorted_vals[hi] - sorted_vals[lo])


def aggregate(spans: Iterable[dict], *,
              prefix: Optional[str] = None) -> Dict[str, dict]:
    """name -> {count, total_s, mean_s, p50_s, p99_s, first_count,
    compile_s, compile_share, errors}.  ``prefix`` keeps only span names
    under one namespace (e.g. ``serve.`` isolates the serving tier's
    ``serve.tick``/``serve.batch``/``serve.compact`` spans from a trace
    that also recorded builds and evals)."""
    by_name: Dict[str, List[dict]] = {}
    for rec in spans:
        name = rec.get("name", "?")
        if prefix is not None and not name.startswith(prefix):
            continue
        by_name.setdefault(name, []).append(rec)
    out: Dict[str, dict] = {}
    for name, recs in sorted(by_name.items()):
        durs = sorted(float(r.get("dur_s", 0.0)) for r in recs)
        total = sum(durs)
        first = [float(r.get("dur_s", 0.0)) for r in recs
                 if r.get("first") is True]
        steady = [float(r.get("dur_s", 0.0)) for r in recs
                  if r.get("first") is False]
        if first:
            steady_mean = (sum(steady) / len(steady)) if steady else 0.0
            compile_s = max(sum(first) - steady_mean * len(first), 0.0)
        else:
            compile_s = 0.0
        out[name] = {
            "count": len(recs),
            "total_s": total,
            "mean_s": total / len(recs),
            "p50_s": _percentile(durs, 50),
            "p99_s": _percentile(durs, 99),
            "first_count": len(first),
            "compile_s": compile_s,
            "compile_share": compile_s / total if total > 0 else 0.0,
            "errors": sum(1 for r in recs if "error" in r),
        }
    return out


def format_table(aggs: Dict[str, dict], *, sort: str = "name") -> str:
    rows = sorted(aggs.items(),
                  key=(lambda kv: -kv[1]["total_s"]) if sort == "total"
                  else (lambda kv: kv[0]))
    width = max([len(n) for n in aggs] + [5])
    lines = [f"{'stage':<{width}s} {'count':>6s} {'total_s':>9s} "
             f"{'mean_s':>9s} {'p50_s':>9s} {'p99_s':>9s} {'compile%':>8s}"]
    for name, a in rows:
        share = (f"{a['compile_share'] * 100:7.1f}%"
                 if a["first_count"] else f"{'-':>8s}")
        lines.append(
            f"{name:<{width}s} {a['count']:6d} {a['total_s']:9.4f} "
            f"{a['mean_s']:9.5f} {a['p50_s']:9.5f} {a['p99_s']:9.5f} "
            f"{share}")
    return "\n".join(lines)


def main(argv: Optional[List[str]] = None) -> int:
    p = argparse.ArgumentParser(
        description="aggregate a repro.obs.trace span JSONL per stage")
    p.add_argument("path", help="trace JSONL (REPRO_TRACE / --trace sink)")
    p.add_argument("--json", default=None, metavar="PATH",
                   help="write {spans, stages} JSON to PATH ('-' = stdout)")
    p.add_argument("--sort", default="total", choices=("name", "total"),
                   help="table order (default: total time, descending)")
    p.add_argument("--filter", default=None, metavar="PREFIX",
                   help="only aggregate span names starting with PREFIX "
                        "(e.g. 'serve.' for the serving tier)")
    args = p.parse_args(argv)
    try:
        spans = load_spans(args.path)
    except OSError as e:
        print(f"error: cannot read trace: {e}", file=sys.stderr)
        return 2
    aggs = aggregate(spans, prefix=args.filter)
    if args.json:
        payload = json.dumps({"spans": len(spans), "stages": aggs}, indent=2)
        if args.json == "-":
            print(payload)
        else:
            with open(args.json, "w") as f:
                f.write(payload + "\n")
    if args.json != "-":
        print(f"{len(spans)} spans in {args.path}")
        print(format_table(aggs, sort=args.sort))
    return 0


if __name__ == "__main__":
    sys.exit(main())
