"""Cell builders: one (architecture x input-shape x mesh) dry-run cell.

``build_cell`` returns the jitted step function plus ShapeDtypeStruct
argument specs carrying NamedShardings — exactly what
``jax.jit(fn).lower(*args)`` needs, with zero real allocation. The SAME
builders power the smoke tests (reduced configs on a 1-device mesh with
real arrays) and the launchers, so the dry-run proves the code path that
actually trains/serves.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_arch
from repro.distributed.sharding import (GNN_RULES, LM_RULES, RECSYS_RULES,
                                        logical_to_spec, tree_shardings)
from repro.models import recsys as rs
from repro.models import mace as mc
from repro.models import transformer as tf
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update


@dataclasses.dataclass
class Cell:
    arch_id: str
    shape_name: str
    fn: Callable            # jitted
    args: tuple             # ShapeDtypeStructs with shardings (for lower)
    kind: str               # train | prefill | decode | serve | retrieval
    model_flops_per_step: float  # 6*N*D style estimate (§Roofline)
    donate: tuple = ()


def _sds(tree, shardings):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        tree, shardings)


def _replicated(mesh, tree):
    rep = NamedSharding(mesh, P())
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                                       sharding=rep), tree)


def _batch_spec(mesh):
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return axes if len(axes) > 1 else (axes[0] if axes else None)


def _divisible_axes(mesh, b: int) -> tuple:
    """Largest prefix-trimmed ('pod','data') axis set whose product divides
    the batch (batch=1 decode cells replicate their batch dim)."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    while axes and b % int(np.prod([sizes[a] for a in axes])) != 0:
        axes = axes[1:]
    return axes


def _axes_or_none(axes: tuple):
    return axes if len(axes) > 1 else (axes[0] if axes else None)


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------

def _lm_param_specs(mesh, cfg, dtype=None, rules_override=None):
    shapes = jax.eval_shape(lambda k: tf.init_transformer(k, cfg),
                            jax.random.PRNGKey(0))
    if dtype is not None:
        shapes = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, dtype), shapes)
    rules = {**LM_RULES, **(rules_override or {})}
    shard = tree_shardings(mesh, tf.param_logical_axes(cfg), rules)
    return _sds(shapes, shard), shard


def _lm_opt_specs(mesh, params_sds, param_shard):
    opt_shapes = jax.eval_shape(adamw_init, params_sds)
    rep = NamedSharding(mesh, P())
    opt_shard = {"m": param_shard, "v": param_shard, "step": rep}
    return _sds(opt_shapes, opt_shard)


def _cache_specs(mesh, cfg, batch, max_seq):
    shapes = jax.eval_shape(
        lambda: tf.init_kv_cache(cfg, batch, max_seq))
    b_ax = _axes_or_none(_divisible_axes(mesh, batch))
    model_size = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    # shard kv heads over 'model' when they divide; else the head_dim; the
    # rolling (L, B, S, Hkv, Dh) cache is the decode-cell memory budget
    if cfg.n_kv_heads % model_size == 0:
        kv_spec = NamedSharding(mesh, P(None, b_ax, None, "model", None))
    elif cfg.head_dim % model_size == 0:
        kv_spec = NamedSharding(mesh, P(None, b_ax, None, None, "model"))
    else:
        kv_spec = NamedSharding(mesh, P(None, b_ax, None, None, None))
    pos_spec = NamedSharding(mesh, P(b_ax))
    return _sds(shapes, {"k": kv_spec, "v": kv_spec, "pos": pos_spec})


def lm_model_flops(cfg, n_tokens, kind):
    n_active = tf.active_params(cfg)
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_active * n_tokens


def build_lm_cell(arch_id, shape_name, mesh, *, reduced=False,
                  overrides: Optional[dict] = None) -> Cell:
    spec = get_arch(arch_id)
    cfg = spec.make_reduced() if reduced else spec.make_config()
    # activation sharding constraints (see transformer._sc): batch over
    # pod+data, heads/ffn/vocab over model
    b_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    model_size = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    shape0 = spec.shapes[shape_name]
    sp = (shape0["kind"] in ("train", "prefill")
          and shape0["seq_len"] % max(model_size, 1) == 0 and not reduced)
    cfg = dataclasses.replace(
        cfg, act_batch_axes=b_axes or None,
        act_model_axis="model" if "model" in mesh.axis_names else None,
        seq_parallel=sp)
    cfg_overrides = {k: v for k, v in (overrides or {}).items()
                     if k != "microbatches"}
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = dict(spec.shapes[shape_name])
    if reduced:
        shape.update({"seq_len": min(shape["seq_len"], 64),
                      "global_batch": min(shape["global_batch"], 4)})
    kind = shape["kind"]
    b, s = shape["global_batch"], shape["seq_len"]
    d_axes = _divisible_axes(mesh, b)
    b_ax = _axes_or_none(d_axes)
    cfg = dataclasses.replace(cfg, act_batch_axes=d_axes or None)

    if kind == "train":
        params_sds, param_shard = _lm_param_specs(
            mesh, cfg, rules_override=spec.rules_override)
        opt_sds = _lm_opt_specs(mesh, params_sds, param_shard)
        tokens = jax.ShapeDtypeStruct(
            (b, s + 1), jnp.int32, sharding=NamedSharding(mesh, P(b_ax, None)))
        opt_cfg = AdamWConfig()
        # §Perf lever: microbatched gradient accumulation — activation and
        # dispatch temps scale with the per-microbatch batch; the grad
        # all-reduce of microbatch i overlaps microbatch i+1's forward
        mb = int((overrides or {}).get("microbatches", 1))

        def train_step(params, opt_state, tokens):
            if mb > 1:
                mbt = tokens.reshape(mb, b // mb, s + 1)

                def one(acc, t):
                    loss, g = jax.value_and_grad(tf.lm_loss)(params, t, cfg)
                    return jax.tree.map(
                        lambda a_, g_: a_ + g_.astype(jnp.float32) / mb,
                        acc, g), loss

                zeros = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                grads, losses = jax.lax.scan(one, zeros, mbt)
                loss = losses.mean()
            else:
                loss, grads = jax.value_and_grad(tf.lm_loss)(params, tokens,
                                                             cfg)
            params, opt_state, info = adamw_update(grads, opt_state, params,
                                                   opt_cfg)
            return params, opt_state, loss

        return Cell(arch_id, shape_name, jax.jit(train_step,
                                                 donate_argnums=(0, 1)),
                    (params_sds, opt_sds, tokens), kind,
                    lm_model_flops(cfg, b * s, "train"), donate=(0, 1))

    serve_dtype = cfg.dtype
    params_sds, _ = _lm_param_specs(mesh, cfg, dtype=serve_dtype,
                                    rules_override=spec.rules_override)
    if kind == "prefill":
        tokens = jax.ShapeDtypeStruct(
            (b, s), jnp.int32, sharding=NamedSharding(mesh, P(b_ax, None)))

        def prefill_step(params, tokens):
            return tf.prefill(params, tokens, cfg)

        return Cell(arch_id, shape_name, jax.jit(prefill_step),
                    (params_sds, tokens), kind,
                    lm_model_flops(cfg, b * s, "prefill"))

    # decode: one new token against a seq_len-deep KV cache
    cache_sds = _cache_specs(mesh, cfg, b, s)
    tokens = jax.ShapeDtypeStruct(
        (b, 1), jnp.int32, sharding=NamedSharding(mesh, P(b_ax, None)))

    def decode(params, cache, tokens):
        return tf.decode_step(params, cache, tokens, cfg)

    return Cell(arch_id, shape_name, jax.jit(decode, donate_argnums=(1,)),
                (params_sds, cache_sds, tokens), kind,
                lm_model_flops(cfg, b, "decode"), donate=(1,))


# ---------------------------------------------------------------------------
# RecSys cells
# ---------------------------------------------------------------------------

def _recsys_param_specs(mesh, cfg):
    shapes = jax.eval_shape(lambda k: rs.init_recsys(k, cfg),
                            jax.random.PRNGKey(0))
    table_spec = NamedSharding(mesh, P("model", None))
    rep = NamedSharding(mesh, P())

    def shard_for(path, leaf):
        name = "/".join(str(getattr(k, "key", k)) for k in path)
        if "table" in name:
            # rows over the whole grid: the 96GB Criteo-TB tables + AdamW
            # slots must split 256 ways, not 16 (measured 16GB/dev at 16)
            return NamedSharding(mesh, P(tuple(
                a for a in ("model", "data") if a in mesh.axis_names), None))
        return rep

    flat, treedef = jax.tree_util.tree_flatten_with_path(shapes)
    shard = treedef.unflatten([shard_for(p, l) for p, l in flat])
    return _sds(shapes, shard), shard


def _recsys_batch(mesh, cfg, batch):
    b_ax = _axes_or_none(_divisible_axes(mesh, batch))
    bs = lambda shape, dt: jax.ShapeDtypeStruct(
        shape, dt, sharding=NamedSharding(mesh, P(b_ax, *([None] * (len(shape) - 1)))))
    if cfg.arch == "dien":
        return {
            "target_item": bs((batch,), jnp.int32),
            "target_cat": bs((batch,), jnp.int32),
            "hist_items": bs((batch, cfg.seq_len), jnp.int32),
            "hist_cats": bs((batch, cfg.seq_len), jnp.int32),
            "hist_mask": bs((batch, cfg.seq_len), jnp.float32),
            "label": bs((batch,), jnp.float32),
        }
    out = {"sparse": bs((batch, cfg.n_sparse), jnp.int32),
           "label": bs((batch,), jnp.float32)}
    if cfg.n_dense:
        out["dense"] = bs((batch, cfg.n_dense), jnp.float32)
    return out


def build_recsys_cell(arch_id, shape_name, mesh, *, reduced=False,
                      overrides=None) -> Cell:
    spec = get_arch(arch_id)
    cfg = spec.make_reduced() if reduced else spec.make_config()
    cfg_overrides = {k: v for k, v in (overrides or {}).items()
                     if k != "sharded_topk"}
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = dict(spec.shapes[shape_name])
    if reduced:
        shape["batch"] = min(shape["batch"], 8)
        shape["n_candidates"] = min(shape.get("n_candidates", 0), 512)
    kind = shape["kind"]
    b = shape["batch"]
    params_sds, param_shard = _recsys_param_specs(mesh, cfg)
    batch_sds = _recsys_batch(mesh, cfg, b)

    # rough flops: embedding gathers + MLP/attention matmuls (dense dims)
    flops = _recsys_flops(cfg, b)

    if kind == "train":
        opt_sds = _lm_opt_specs(mesh, params_sds, param_shard)
        opt_cfg = AdamWConfig()

        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(rs.bce_loss)(params, batch, cfg)
            params, opt_state, info = adamw_update(grads, opt_state, params,
                                                   opt_cfg)
            return params, opt_state, loss

        return Cell(arch_id, shape_name,
                    jax.jit(train_step, donate_argnums=(0, 1)),
                    (params_sds, opt_sds, batch_sds), kind, 3 * flops,
                    donate=(0, 1))

    if kind == "serve":
        def serve_step(params, batch):
            return rs.recsys_forward(params, batch, cfg)

        return Cell(arch_id, shape_name, jax.jit(serve_step),
                    (params_sds, batch_sds), kind, flops)

    # retrieval: 1 query batch x n_candidates, fused top-k
    nc = shape["n_candidates"]
    grid = tuple(a for a in ("model", "data") if a in mesh.axis_names)
    grid_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    grid_n = int(np.prod([grid_sizes[a] for a in grid])) or 1
    k_top = min(100, nc)
    sharded_topk = (overrides or {}).get("sharded_topk", False)
    model_size = grid_sizes.get("model", 1)
    if sharded_topk == "local":
        nc = ((nc + grid_n - 1) // grid_n) * grid_n   # pad to the grid
    cand_spec = P(grid) if sharded_topk == "local" else P("model")
    cand = jax.ShapeDtypeStruct(
        (nc,), jnp.int32, sharding=NamedSharding(mesh, cand_spec))
    batch_sds.pop("label")

    def retrieval_step(params, batch, candidate_ids):
        if sharded_topk == "local" and nc % grid_n == 0:
            # §Perf lever 2: shard-local candidate pools — each shard
            # scores candidates resident in ITS table rows (production
            # sharded-ANN layout), so the 512MB cross-shard row
            # gather/all-reduce disappears; only (grid x k) merge payloads
            # cross the wire.
            from jax.experimental.shard_map import shard_map
            u = rs.user_vector(params, batch, cfg)          # (B, D) replicated
            items = rs.item_matrix(params, cfg)             # rows grid-sharded

            def local_score(u_, table_l, cand_l):
                rows = table_l.shape[0]
                it = jnp.take(table_l, cand_l % rows, axis=0)
                s = u_ @ it.T                               # (B, nc/grid)
                ls, li = jax.lax.top_k(s, k_top)
                shard = jax.lax.axis_index(grid[0])
                if len(grid) > 1:
                    shard = shard * grid_sizes[grid[1]] + \
                        jax.lax.axis_index(grid[1])
                li = li + shard * cand_l.shape[0]
                return ls, li

            ls, li = shard_map(
                local_score, mesh=mesh,
                in_specs=(P(), P(grid, None), P(grid)),
                out_specs=(P(None, grid), P(None, grid)))(
                u, items, candidate_ids)
            top_s, pos = jax.lax.top_k(ls, k_top)
            return top_s, jnp.take_along_axis(li, pos, axis=1)
        scores = rs.retrieval_scores(params, batch, cfg, candidate_ids)
        if sharded_topk and nc % model_size == 0:
            # §Perf lever: per-shard local top-k then merge — the global
            # lax.top_k over a model-sharded axis otherwise all-gathers the
            # full (B, n_candidates) score matrix
            from jax.experimental.shard_map import shard_map

            def local_topk(s):
                ls, li = jax.lax.top_k(s, k_top)
                li = li + jax.lax.axis_index("model") * s.shape[-1]
                return ls, li

            ls, li = shard_map(
                local_topk, mesh=mesh,
                in_specs=P(None, "model"),
                out_specs=(P(None, "model"), P(None, "model")))(scores)
            top_s, pos = jax.lax.top_k(ls, k_top)
            return top_s, jnp.take_along_axis(li, pos, axis=1)
        return jax.lax.top_k(scores, k_top)

    d = rs.item_matrix_dim(cfg)
    return Cell(arch_id, shape_name, jax.jit(retrieval_step),
                (params_sds, batch_sds, cand), kind, 2.0 * b * nc * d)


def _recsys_flops(cfg, b):
    if cfg.arch == "dlrm":
        dims = [cfg.n_dense] + list(cfg.bot_mlp)
        f = sum(2 * a * c for a, c in zip(dims[:-1], dims[1:]))
        n_f = cfg.n_sparse + 1
        f += 2 * n_f * n_f * cfg.embed_dim
        top_in = n_f * (n_f - 1) // 2 + cfg.embed_dim
        dims = [top_in] + list(cfg.top_mlp)
        f += sum(2 * a * c for a, c in zip(dims[:-1], dims[1:]))
        return b * f
    if cfg.arch == "dcn_v2":
        d0 = cfg.n_dense + cfg.n_sparse * cfg.embed_dim
        f = cfg.n_cross_layers * 2 * d0 * d0
        dims = [d0] + list(cfg.mlp_dims)
        f += sum(2 * a * c for a, c in zip(dims[:-1], dims[1:]))
        return b * f
    if cfg.arch == "autoint":
        fdim = cfg.n_sparse
        f = 0
        in_d = cfg.embed_dim
        for _ in range(cfg.n_attn_layers):
            hd = cfg.n_heads * cfg.d_attn
            f += fdim * (4 * 2 * in_d * hd) + 2 * fdim * fdim * hd * 2
            in_d = hd
        return b * f
    if cfg.arch == "dien":
        in_d, hd = 2 * cfg.embed_dim, cfg.gru_dim
        per_step = 2 * 3 * hd * (in_d + hd) * 2   # gru1 + augru
        return b * cfg.seq_len * per_step
    return b * 1e6


# ---------------------------------------------------------------------------
# GNN (MACE) cells
# ---------------------------------------------------------------------------

def _mace_batch_sds(mesh, n_nodes, n_edges, d_feat, n_graphs, node_loss):
    grid = tuple(a for a in ("data", "model") if a in mesh.axis_names)
    grid = grid if len(grid) > 1 else (grid[0] if grid else None)
    nd = lambda shape: NamedSharding(mesh, P(grid, *([None] * (len(shape) - 1))))
    out = {
        "positions": jax.ShapeDtypeStruct((n_nodes, 3), jnp.float32,
                                          sharding=nd((n_nodes, 3))),
        "node_feats": jax.ShapeDtypeStruct((n_nodes, d_feat), jnp.float32,
                                           sharding=nd((n_nodes, d_feat))),
        "edge_src": jax.ShapeDtypeStruct((n_edges,), jnp.int32,
                                         sharding=nd((n_edges,))),
        "edge_dst": jax.ShapeDtypeStruct((n_edges,), jnp.int32,
                                         sharding=nd((n_edges,))),
        "edge_mask": jax.ShapeDtypeStruct((n_edges,), jnp.bool_,
                                          sharding=nd((n_edges,))),
        "graph_ids": jax.ShapeDtypeStruct((n_nodes,), jnp.int32,
                                          sharding=nd((n_nodes,))),
    }
    if node_loss:
        out["node_target"] = jax.ShapeDtypeStruct(
            (n_nodes,), jnp.float32, sharding=nd((n_nodes,)))
        out["node_mask"] = jax.ShapeDtypeStruct(
            (n_nodes,), jnp.float32, sharding=nd((n_nodes,)))
    else:
        out["energy_target"] = jax.ShapeDtypeStruct(
            (n_graphs,), jnp.float32, sharding=NamedSharding(mesh, P()))
        out["force_target"] = jax.ShapeDtypeStruct(
            (n_nodes, 3), jnp.float32, sharding=nd((n_nodes, 3)))
    return out


def mace_flops(cfg, n_edges, n_nodes):
    import repro.models.so3 as so3
    paths = so3.valid_paths(cfg.l_max)
    path_f = sum((2 * l1 + 1) * (2 * l2 + 1) * (2 * l3 + 1)
                 for l1, l2, l3 in paths)
    per_edge = 2 * path_f * cfg.channels
    per_node = 2 * 2 * path_f * cfg.channels + 8 * cfg.channels ** 2
    return cfg.n_layers * (n_edges * per_edge + n_nodes * per_node)


def build_gnn_cell(arch_id, shape_name, mesh, *, reduced=False,
                   overrides=None) -> Cell:
    spec = get_arch(arch_id)
    cfg = spec.make_reduced() if reduced else spec.make_config()
    shape = dict(spec.shapes[shape_name])
    kind = shape["kind"]

    if kind == "train_sampled":
        # static padded block sizes from the fanout schedule
        bn = shape["batch_nodes"]
        f1, f2 = shape["fanouts"]
        n2 = bn * (f2 + 1)
        n_nodes = n2 * (f1 + 1)
        n_edges = bn * f2 + n2 * f1
        d_feat, n_graphs, node_loss = cfg.d_feat, 1, True
    else:
        n_nodes, n_edges = shape["n_nodes"], shape["n_edges"]
        d_feat = shape.get("d_feat", cfg.d_feat)
        n_graphs = shape.get("batch", shape.get("n_graphs", 1))
        if "batch" in shape:   # batched small graphs
            n_nodes, n_edges = n_nodes * n_graphs, n_edges * n_graphs
        node_loss = kind == "train_node"
    if reduced:
        n_nodes, n_edges = min(n_nodes, 64), min(n_edges, 256)
        d_feat, n_graphs = min(d_feat, 8), min(n_graphs, 2)
    # pad node/edge counts to the device-grid multiple (padded entries are
    # masked; the data model is already mask-based)
    grid_axes = tuple(a for a in ("data", "model") if a in mesh.axis_names)
    grid_n = int(np.prod([s for a, s in zip(mesh.axis_names,
                                            mesh.devices.shape)
                          if a in grid_axes])) or 1
    n_nodes = ((n_nodes + grid_n - 1) // grid_n) * grid_n
    n_edges = ((n_edges + grid_n - 1) // grid_n) * grid_n
    cfg = dataclasses.replace(cfg, d_feat=d_feat,
                              act_grid_axes=grid_axes or None)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)

    params_shapes = jax.eval_shape(lambda k: mc.init_mace(k, cfg),
                                   jax.random.PRNGKey(0))
    params_sds = _replicated(mesh, params_shapes)
    rep_shard = jax.tree.map(lambda s: s.sharding, params_sds)
    batch_sds = _mace_batch_sds(mesh, n_nodes, n_edges, d_feat, n_graphs,
                                node_loss)
    opt_sds = _lm_opt_specs(mesh, params_sds, rep_shard)
    opt_cfg = AdamWConfig()
    loss_fn = mc.mace_node_loss if node_loss else mc.mace_loss

    def train_step(params, opt_state, batch):
        batch = dict(batch, n_graphs=n_graphs)
        loss, grads = jax.value_and_grad(loss_fn)(params, batch, cfg)
        params, opt_state, info = adamw_update(grads, opt_state, params,
                                               opt_cfg)
        return params, opt_state, loss

    mult = 3.0 if node_loss else 7.0   # fwd+bwd (+force second-order)
    return Cell(arch_id, shape_name,
                jax.jit(train_step, donate_argnums=(0, 1)),
                (params_sds, opt_sds, batch_sds), "train",
                mult * mace_flops(cfg, n_edges, n_nodes), donate=(0, 1))


# ---------------------------------------------------------------------------

def build_cell(arch_id: str, shape_name: str, mesh, *, reduced=False,
               overrides=None) -> Cell:
    family = get_arch(arch_id).family
    builder = {"lm": build_lm_cell, "recsys": build_recsys_cell,
               "gnn": build_gnn_cell}[family]
    return builder(arch_id, shape_name, mesh, reduced=reduced,
                   overrides=overrides)
