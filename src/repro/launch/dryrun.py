import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()
# The two lines above MUST run before any other import (jax locks the device
# count at first init). Smoke tests and benchmarks never import this module.

"""Multi-pod dry-run: lower + compile EVERY (architecture x input-shape)
cell on the production meshes and extract the roofline terms.

  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out results/dryrun

Success of ``.lower().compile()`` for the 16x16 (single-pod, 256-chip) and
2x16x16 (multi-pod, 512-chip) meshes is the deliverable; the per-cell
memory_analysis / cost_analysis / collective-bytes parse feeds
EXPERIMENTS.md §Dry-run and §Roofline.
"""
import argparse
import json
import logging
import re
import sys
import time
import traceback

import jax

from repro.configs import get_arch, iter_cells, list_archs
from repro.launch.cells import build_cell
from repro.launch.logs import add_logging_args, setup_logging
from repro.launch.mesh import make_production_mesh
# the hardware constants live at the bottom of the stack (kernels/tuning.py)
# so the autotuner's roofline never imports upward into launch
from repro.kernels.tuning import HBM_BW, ICI_BW, PEAK_FLOPS_BF16  # noqa: F401

log = logging.getLogger("repro.launch.dryrun")

_COLLECTIVE_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[^\n]*?\s*=\s*([a-z0-9_]+)\[([0-9,]*)\]")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


def collective_bytes(hlo_text: str) -> dict:
    """Sum OUTPUT operand bytes of every collective op in the (SPMD-
    partitioned, per-device) HLO. Returns {op_kind: bytes}."""
    out: dict = {}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(
            r".*=\s*(?:\(([^)]*)\)|([a-z0-9]+\[[0-9,]*\][^ ]*))\s*"
            r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
            r"collective-permute)", s)
        if not m:
            continue
        shapes_str = m.group(1) or m.group(2)
        kind = m.group(3)
        total = 0
        for dt, dims in _SHAPE_RE.findall(shapes_str):
            nbytes = _DTYPE_BYTES.get(dt)
            if nbytes is None:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * nbytes
        out[kind] = out.get(kind, 0) + total
    return out


def run_cell(arch_id: str, shape_name: str, mesh, n_chips: int,
             verbose: bool = True) -> dict:
    t0 = time.time()
    cell = build_cell(arch_id, shape_name, mesh)
    with mesh:
        lowered = cell.fn.lower(*cell.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    cost = cost[0] if isinstance(cost, (list, tuple)) else cost
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)

    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    coll_total = float(sum(coll.values()))

    # NOTE on units: cost_analysis / collective parse are per-DEVICE numbers
    # (SPMD partitioned module). Roofline terms are therefore per device.
    res = {
        "arch": arch_id, "shape": shape_name, "n_chips": n_chips,
        "ok": True,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "flops_per_device": flops,
        "bytes_per_device": bytes_accessed,
        "collective_bytes_per_device": coll_total,
        "collectives": coll,
        "model_flops_per_step": cell.model_flops_per_step,
        "memory": {
            "argument_size": getattr(mem, "argument_size_in_bytes", None),
            "output_size": getattr(mem, "output_size_in_bytes", None),
            "temp_size": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_size": getattr(mem, "generated_code_size_in_bytes",
                                           None),
        },
        "roofline": {
            "compute_s": flops / PEAK_FLOPS_BF16,
            "memory_s": bytes_accessed / HBM_BW,
            "collective_s": coll_total / ICI_BW,
        },
    }
    r = res["roofline"]
    r["bottleneck"] = max(r, key=lambda k: r[k] if k.endswith("_s") else -1)
    total_useful = cell.model_flops_per_step / n_chips
    r["useful_flops_ratio"] = (total_useful / flops) if flops else 0.0
    if verbose:
        log.info("[%s x %s] ok (lower %.0fs compile %.0fs) "
                 "compute %.2fms memory %.2fms collective %.2fms -> %s",
                 arch_id, shape_name, t_lower, t_compile,
                 r["compute_s"] * 1e3, r["memory_s"] * 1e3,
                 r["collective_s"] * 1e3, r["bottleneck"])
        log.info("    temp %.2f GiB/device; args %.2f GiB/device",
                 (res["memory"]["temp_size"] or 0) / 2**30,
                 (res["memory"]["argument_size"] or 0) / 2**30)
    return res


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None, choices=list_archs())
    p.add_argument("--shape", default=None)
    p.add_argument("--all", action="store_true")
    p.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    p.add_argument("--out", default=None, help="write JSON results here")
    add_logging_args(p)
    args = p.parse_args(argv)
    setup_logging(args)

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single-pod-16x16", make_production_mesh(), 256))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi-pod-2x16x16",
                       make_production_mesh(multi_pod=True), 512))

    cells = (list(iter_cells()) if args.all or not args.arch
             else [(args.arch, s) for s in
                   (get_arch(args.arch).shapes if not args.shape
                    else [args.shape])
                   if s not in get_arch(args.arch).skip_shapes])

    results = []
    failures = 0
    for mesh_name, mesh, n_chips in meshes:
        log.info("=== mesh %s (%d chips, %d devices visible) ===",
                 mesh_name, n_chips, len(jax.devices()))
        for arch_id, shape_name in cells:
            try:
                res = run_cell(arch_id, shape_name, mesh, n_chips)
            except Exception as e:
                failures += 1
                traceback.print_exc()
                res = {"arch": arch_id, "shape": shape_name, "ok": False,
                       "mesh": mesh_name, "error": repr(e)[:500]}
            res["mesh"] = mesh_name
            results.append(res)
            if args.out:
                with open(args.out + ".json", "w") as f:
                    json.dump(results, f, indent=2)
    log.info("\n%d/%d cells compiled OK", len(results) - failures,
             len(results))
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
