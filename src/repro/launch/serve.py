"""Serving-tier CLI (DESIGN.md §14) — drive a :class:`~repro.serve.engine.
SearchServer` (bounded queue -> microbatch scheduler -> per-tenant live
indexes) as a load generator or for a single query.

  # load-generate: 512 requests over 4 tenants, report throughput + p50/p99
  PYTHONPATH=src python -m repro.launch.serve --requests 512 --tenants 4 \
      --rate 2000 --out results/serve.json

  # one query against a warm single-tenant server
  PYTHONPATH=src python -m repro.launch.serve --single --k 5

  # live ingest mid-run: append documents every N requests
  PYTHONPATH=src python -m repro.launch.serve --append-every 128 \
      --append-rows 64 --compact-threshold 256

  # observe it: spans to a trace, metrics snapshot on exit
  PYTHONPATH=src python -m repro.launch.serve --trace results/trace.jsonl \
      --metrics-json results/metrics.json
  PYTHONPATH=src python -m repro.launch.trace results/trace.jsonl --filter serve.

Engine/backend/mesh names resolve through the same registries as every
other CLI, so an unknown name fails fast with the registry's message
(launch/sample.py error contract).
"""
from __future__ import annotations

import argparse
import json
import logging
import os
from typing import Optional

import numpy as np

from repro.launch.logs import (add_logging_args, add_obs_args, init_obs,
                               setup_logging, write_metrics)
from repro.obs import recompile
from repro.launch.mesh import parse_mesh
from repro.retrieval.backends import get_backend
from repro.retrieval.engines import (available_retrieval_engines,
                                     get_retrieval_engine)
from repro.retrieval.search_core import SearchConfig
from repro.serve import (IngestConfig, LoadSpec, SchedulerConfig,
                         SearchServer, run_load)

log = logging.getLogger("repro.launch.serve")


def _tenant_corpus(tenant: str, *, docs: int, dim: int, seed: int):
    """Deterministic per-tenant synthetic corpus — the provider the
    TenantCache rebuilds evicted tenants from."""
    tid = int(tenant.rsplit("-", 1)[-1]) if "-" in tenant else 0
    rng = np.random.default_rng(seed * 100_003 + tid)
    return rng.normal(size=(docs, dim)).astype(np.float32)


def build_server(args) -> SearchServer:
    mesh = (parse_mesh(args.mesh)
            if args.sharded or args.streamed else None)
    config = SearchConfig(
        engine=args.engine, backend=args.backend,
        sharded=args.sharded or args.streamed, streamed=args.streamed,
        mesh=mesh,
        engine_opts=json.loads(args.engine_opts) if args.engine_opts
        else None)
    return SearchServer(
        lambda t: _tenant_corpus(t, docs=args.docs, dim=args.dim,
                                 seed=args.seed),
        config=config,
        scheduler=SchedulerConfig(max_queue=args.max_queue,
                                  max_batch=args.max_batch,
                                  k_max=max(args.k_max, args.k)),
        ingest=IngestConfig(append_cap=args.append_cap,
                            compact_threshold=args.compact_threshold),
        max_tenants=args.max_tenants)


def run_recompile_check(server, rng, *, dim: int, k: int,
                        n_ticks: int) -> dict:
    """The scheduler's steady-state contract, measured: warm every batch
    bucket once, mark the sentinel waterline, then drive ``n_ticks`` more
    ticks across the bucket set — any XLA compilation past the mark is a
    retrace leak (a shape escaped the bucket/k_max pinning)."""
    sched = server.scheduler
    buckets = sched.config.bucket_set()

    def _submit_fill(fill: int) -> None:
        for _ in range(fill):
            q = rng.normal(size=(dim,)).astype(np.float32)
            if server.submit(q, k=k, tenant="tenant-0") is None:
                raise RuntimeError("queue full during recompile check; "
                                   "raise --max-queue")

    for b in buckets:                    # warmup: one compile per bucket
        _submit_fill(b)
        sched.tick()
    recompile.mark()
    steady_ticks = 0
    for i in range(n_ticks):             # steady state: every shape warm
        _submit_fill(buckets[i % len(buckets)])
        if sched.tick():
            steady_ticks += 1
    return {"steady_ticks": steady_ticks,
            "steady_recompiles": recompile.since()}


def main(argv: Optional[list] = None) -> int:
    p = argparse.ArgumentParser(
        description="load-generate against (or query) the serving tier")
    p.add_argument("--docs", type=int, default=4096,
                   help="synthetic corpus rows per tenant")
    p.add_argument("--dim", type=int, default=64)
    p.add_argument("--engine", default="exact",
                   help="retrieval engine (retrieval/engines.py): "
                        + ",".join(available_retrieval_engines()))
    p.add_argument("--backend", default="jnp",
                   help="scoring backend (retrieval/backends.py): "
                        "jnp, pallas, int8")
    p.add_argument("--engine-opts", default=None, metavar="JSON",
                   help='engine overrides, e.g. \'{"n_lists": 16}\'')
    p.add_argument("--sharded", action="store_true",
                   help="mesh-partitioned search (retrieval/sharded.py)")
    p.add_argument("--streamed", action="store_true",
                   help="shard each tenant's corpus from birth "
                        "(implies --sharded)")
    p.add_argument("--mesh", default="host", choices=["host", "auto"])
    p.add_argument("--k", type=int, default=10)
    p.add_argument("--single", action="store_true",
                   help="submit ONE query, print scores/ids, exit")
    p.add_argument("--requests", type=int, default=256,
                   help="load-generator arrivals")
    p.add_argument("--rate", type=float, default=float("inf"),
                   help="offered load, requests/s (default: back-to-back)")
    p.add_argument("--tenants", type=int, default=1,
                   help="tenant count, arrivals round-robin")
    p.add_argument("--max-tenants", type=int, default=8,
                   help="tenant-cache capacity (LRU evicts past this)")
    p.add_argument("--max-queue", type=int, default=256)
    p.add_argument("--max-batch", type=int, default=32)
    p.add_argument("--k-max", type=int, default=16,
                   help="fixed top-k width of every dispatched batch")
    p.add_argument("--append-every", type=int, default=0, metavar="N",
                   help="live-ingest --append-rows docs to tenant-0 every "
                        "N requests (0: no ingest)")
    p.add_argument("--append-rows", type=int, default=64)
    p.add_argument("--append-cap", type=int, default=256)
    p.add_argument("--compact-threshold", type=int, default=4096)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--recompile-check", type=int, default=0, metavar="N",
                   help="after the load: warm every scheduler bucket, mark "
                        "the recompile sentinel, run N more ticks and exit "
                        "1 on any steady-state XLA compilation")
    p.add_argument("--out", default=None, metavar="PATH",
                   help="write the load report JSON to PATH")
    add_logging_args(p)
    add_obs_args(p)
    args = p.parse_args(argv)
    setup_logging(args)
    init_obs(args)
    if args.recompile_check > 0:
        recompile.enable()
    # fail fast with the registry error messages, before any build
    get_retrieval_engine(args.engine)
    get_backend(args.backend)

    server = build_server(args)
    rng = np.random.default_rng(args.seed + 1)

    if args.single:
        q = rng.normal(size=(args.dim,)).astype(np.float32)
        req = server.submit(q, k=args.k, tenant="tenant-0")
        if req is None:
            log.error("queue full")
            return 1
        server.drain()
        scores, ids = req.result(timeout=0)
        log.info("top-%d ids:    %s", args.k, ids.tolist())
        log.info("top-%d scores: %s",
                 args.k, [round(float(s), 4) for s in scores])
        write_metrics(args)
        return 0

    queries = rng.normal(size=(min(args.requests, 512),
                               args.dim)).astype(np.float32)
    spec = LoadSpec(n_requests=args.requests, rate=args.rate,
                    tenants=args.tenants, k=args.k, seed=args.seed)
    log.info("load: %d requests @ %s req/s over %d tenant(s), "
             "max_batch=%d engine=%s backend=%s", spec.n_requests,
             "inf" if not np.isfinite(spec.rate) else f"{spec.rate:g}",
             spec.tenants, args.max_batch, args.engine, args.backend)

    if args.append_every > 0:
        # interleave ingest with load: append via a wrapped scheduler tick
        done = {"n": 0}
        base_tick = server.scheduler.tick

        def tick_with_ingest():
            n = base_tick()
            done["n"] += n
            if n and done["n"] % max(args.append_every, 1) < n:
                server.append("tenant-0", rng.normal(
                    size=(args.append_rows, args.dim)).astype(np.float32))
            return n

        server.scheduler.tick = tick_with_ingest

    report = run_load(server.scheduler, queries, spec)
    row = report.to_row()
    log.info("throughput %.1f req/s   p50 %.2f ms   p99 %.2f ms   "
             "(%d completed, %d rejected, mean batch %.1f)",
             report.throughput_rps, report.p50_s * 1e3, report.p99_s * 1e3,
             report.completed, report.rejected, report.mean_batch)
    steady = None
    if args.recompile_check > 0:
        steady = run_recompile_check(server, rng, dim=args.dim, k=args.k,
                                     n_ticks=args.recompile_check)
        row.update(steady)
        log.info("recompile check: %d steady ticks, %d recompilations "
                 "past the warmup mark (per key: %s)",
                 steady["steady_ticks"], steady["steady_recompiles"],
                 recompile.counts())
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(row, f, indent=2)
        log.info("wrote %s", args.out)
    metrics_path = write_metrics(args)
    if metrics_path:
        log.info("wrote %s", metrics_path)
    if steady is not None and steady["steady_recompiles"]:
        log.error("steady-state recompile: the scheduler's bucket/k_max "
                  "pinning leaked a shape")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
