"""Training launcher: ``--arch <id>`` selects any registry architecture at
REDUCED scale on the host mesh (this container is CPU-only; the full-scale
path is exercised by dryrun.py), with checkpointing + elastic resume.

  PYTHONPATH=src python -m repro.launch.train --arch yi-9b --steps 20
  PYTHONPATH=src python -m repro.launch.train --arch dlrm-mlperf --steps 50
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, list_archs
from repro.launch.cells import build_cell
from repro.launch.mesh import make_host_mesh
from repro.train.loop import LoopConfig, train_loop
from repro.train.optimizer import adamw_init


def _batch_like(sds, step, rng):
    def one(s):
        if not hasattr(s, "shape"):
            return s
        if s.dtype == jnp.int32:
            return jnp.asarray(rng.integers(0, 2, size=s.shape), jnp.int32)
        if s.dtype == jnp.bool_:
            return jnp.ones(s.shape, bool)
        return jnp.asarray(rng.normal(size=s.shape).astype(np.float32))
    return jax.tree.map(one, sds)


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True, choices=list_archs())
    p.add_argument("--shape", default=None)
    p.add_argument("--steps", type=int, default=20)
    p.add_argument("--checkpoint-dir", default=None)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    spec = get_arch(args.arch)
    shape = args.shape or next(s for s, v in spec.shapes.items()
                               if v["kind"].startswith("train"))
    mesh = make_host_mesh()
    cell = build_cell(args.arch, shape, mesh, reduced=True)
    rng = np.random.default_rng(args.seed)

    params = _batch_like(cell.args[0], 0, rng)
    params = jax.tree.map(lambda x: x * 0.02, params)
    opt_state = adamw_init(params)
    batch_sds = cell.args[2]

    cfg = LoopConfig(total_steps=args.steps, log_every=5,
                     checkpoint_every=10, checkpoint_dir=args.checkpoint_dir)
    with mesh:
        train_loop(cell.fn, params, opt_state,
                   lambda step: _batch_like(batch_sds, step,
                                            np.random.default_rng(step)),
                   cfg)


if __name__ == "__main__":
    main()
