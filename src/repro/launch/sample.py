"""WindTunnel sampling CLI — the paper's end-to-end pipeline through the
sampling-core front door (DESIGN.md §10).

  PYTHONPATH=src python -m repro.launch.sample --queries 1280 --target-frac 0.15 \
      --out results/sample

  # size x seed sweep: graph build + label propagation run ONCE, every
  # (size, seed) draw reuses the cached labels (sizes <=1 are fractions
  # of the eligible universe, >1 absolute entity counts)
  PYTHONPATH=src python -m repro.launch.sample --sweep-sizes 0.05,0.1,0.15 \
      --sweep-seeds 0,1,2

  # baselines share the same session (and staged graph, when they need it)
  PYTHONPATH=src python -m repro.launch.sample --strategy degree_stratified

Generates (or loads) a corpus, stages GraphBuilder -> GraphSampler state in
a :class:`~repro.core.sampling_core.SamplerSession`, draws the sample(s),
reports community statistics and the Yule-Simon fit, and writes the sampled
qrel table + entity mask.
"""
from __future__ import annotations

import argparse
import json
import logging
import os

import jax.numpy as jnp
import numpy as np

from repro.core import (QRelTable, SamplerSession, SamplerSpec,
                        available_engines, available_samplers, fit_em,
                        get_sampler)
from repro.core.engines import get_engine
from repro.data.synthetic import generate_corpus
from repro.launch.logs import (add_logging_args, add_obs_args, init_obs,
                               setup_logging, write_metrics)
from repro.launch.mesh import parse_mesh

log = logging.getLogger("repro.launch.sample")


def _csv_floats(s):
    return tuple(float(x) for x in s.split(",") if x)


def _csv_ints(s):
    return tuple(int(x) for x in s.split(",") if x)


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--queries", type=int, default=1280)
    p.add_argument("--qrels-per-query", type=int, default=32)
    p.add_argument("--topics", type=int, default=96)
    p.add_argument("--aux-fraction", type=float, default=2.0)
    p.add_argument("--strategy", default="windtunnel",
                   help="sampling strategy from the registry "
                        "(core/samplers.py): " + ",".join(available_samplers()))
    p.add_argument("--target-frac", type=float, default=0.15)
    p.add_argument("--tau-quantile", type=float, default=0.5)
    p.add_argument("--fanout", type=int, default=16)
    p.add_argument("--lp-rounds", type=int, default=5)
    p.add_argument("--engine", default="sort",
                   help="label-prop engine from the registry "
                        "(core/engines.py): " + ",".join(available_engines()))
    p.add_argument("--sharded", action="store_true",
                   help="run the mesh-partitioned graph+LP stages "
                        "(core/sharded_pipeline.py; requires an ELL-family "
                        "engine)")
    p.add_argument("--streamed", action="store_true",
                   help="shard the qrel table from birth: route host-side, "
                        "stream per-shard buffers to their devices, and "
                        "build the graph shard-locally — no device ever "
                        "holds the global table (implies --sharded)")
    p.add_argument("--stream-chunk", type=int, default=65536,
                   help="host->device streaming chunk rows for --streamed")
    p.add_argument("--mesh", default="host", choices=["host", "auto"],
                   help="mesh for --sharded/--streamed: 1-device host mesh "
                        "or all local devices on the data axis")
    p.add_argument("--sweep-sizes", default=None, metavar="S1,S2,...",
                   help="comma list of target sizes (<=1: fraction of the "
                        "eligible universe; >1: entity count); runs "
                        "session.sweep against ONE staged graph+LP")
    p.add_argument("--sweep-seeds", default=None, metavar="R1,R2,...",
                   help="comma list of draw seeds for --sweep-sizes "
                        "(default: just --seed)")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--out", default=None)
    add_logging_args(p)
    add_obs_args(p)
    args = p.parse_args(argv)
    setup_logging(args)
    init_obs(args)
    # unknown names fail with the registry's error message before any
    # corpus work — the same error contract as launch/evaluate.py
    get_sampler(args.strategy)
    get_engine(args.engine)
    if (args.sharded or args.streamed) and args.engine == "sort":
        p.error("--sharded/--streamed require an ELL-family engine; "
                "pass --engine ell or --engine pallas")

    corpus = generate_corpus(
        num_queries=args.queries, qrels_per_query=args.qrels_per_query,
        num_topics=args.topics, aux_fraction=args.aux_fraction,
        seed=args.seed)
    log.info("corpus: %d entities (%d judged), %d queries",
             corpus.num_entities, corpus.num_primary, corpus.num_queries)

    qrels = QRelTable(*(jnp.asarray(x) for x in corpus.qrels))
    spec = SamplerSpec(
        strategy=args.strategy, engine=args.engine,
        tau_quantile=args.tau_quantile, fanout=args.fanout,
        lp_rounds=args.lp_rounds,
        target_size=args.target_frac * corpus.num_primary, seed=args.seed,
        sharded=args.sharded or args.streamed,
        streamed=args.streamed, stream_chunk=args.stream_chunk,
        mesh=(parse_mesh(args.mesh)
              if args.sharded or args.streamed else None))
    session = SamplerSession(qrels, num_queries=corpus.num_queries,
                             num_entities=corpus.num_entities, spec=spec)
    if args.sharded or args.streamed:
        log.info("%s graph+LP on mesh %s (engine=%s)",
                 "streamed shard-local" if args.streamed else "sharded",
                 dict(spec.mesh.shape), spec.engine)

    stats = {}
    if args.sweep_sizes:
        sizes = _csv_floats(args.sweep_sizes)
        seeds = (_csv_ints(args.sweep_seeds) if args.sweep_seeds
                 else (args.seed,))
        sweep = session.sweep(sizes, seeds)
        log.info("sweep: %d sizes x %d seeds (strategy=%s)",
                 len(sizes), len(seeds), sweep.strategy)
        for (size, seed), draw in sorted(sweep.draws.items()):
            mask = np.asarray(draw.entity_mask)
            log.info("  size=%-10g seed=%-3d -> %d entities, %d queries",
                     size, seed, int(mask.sum()),
                     int(draw.reconstructed.num_queries))
        log.info("session stage counters (graph+LP staged once per sweep):")
        log.info("%s", session.summary())
        stats["sweep"] = sweep.to_json()
        mask = np.asarray(sweep.draws[(sweep.sizes[0],
                                       sweep.seeds[0])].entity_mask)
        recon_valid = np.asarray(
            sweep.draws[(sweep.sizes[0], sweep.seeds[0])]
            .reconstructed.qrels.valid)
        labels = (np.asarray(session.labels()[0])
                  if get_sampler(args.strategy).needs_labels
                  else np.zeros(corpus.num_entities, np.int32))
    else:
        draw = session.draw()
        mask = np.asarray(draw.entity_mask)
        recon_valid = np.asarray(draw.reconstructed.qrels.valid)
        strat = get_sampler(args.strategy)
        labels = np.zeros(corpus.num_entities, np.int32)
        if strat.needs_graph:
            edges, degrees = session.graph()
            deg = np.asarray(degrees)
            fit = fit_em(jnp.asarray(deg[deg > 0]), max_iters=300)
            log.info("affinity graph: %d edges; degree-law gamma = %.3f "
                     "(se %.2e)", int(edges.num_valid), float(fit.gamma),
                     float(fit.stderr))
            stats["gamma"] = float(fit.gamma)
        if strat.needs_labels:
            labels_arr, changes = session.labels()
            labels = np.asarray(labels_arr)
            sizes_arr = np.asarray(draw.sample.community_sizes)
            n_comm = int((sizes_arr > 0).sum())
            log.info("%d communities; LP changes/round = %s", n_comm,
                     np.asarray(changes).tolist())
            stats["communities"] = n_comm
        log.info("sample[%s]: %d entities, %d associated queries",
                 args.strategy, int(mask.sum()),
                 int(draw.reconstructed.num_queries))

    stats["entities"] = int(mask.sum())
    if args.out:
        os.makedirs(args.out, exist_ok=True)
        np.savez(os.path.join(args.out, "sample.npz"),
                 entity_mask=mask, labels=labels, qrel_valid=recon_valid)
        with open(os.path.join(args.out, "stats.json"), "w") as f:
            json.dump(stats, f, indent=2)
        log.info("wrote %s/sample.npz", args.out)
    metrics_path = write_metrics(
        args, {"session_stage_counts": {
            st: {"executions": ex, "requests": rq}
            for st, (ex, rq) in session.stage_counts().items()}})
    if metrics_path:
        log.info("wrote %s", metrics_path)


if __name__ == "__main__":
    main()
