"""WindTunnel sampling CLI — the paper's end-to-end pipeline.

  PYTHONPATH=src python -m repro.launch.sample --queries 1280 --target-frac 0.15 \
      --out results/sample

Generates (or loads) a corpus, runs GraphBuilder -> GraphSampler ->
CorpusReconstructor, reports community statistics and the Yule-Simon fit,
and writes the sampled qrel table + entity mask.
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (QRelTable, WindTunnelConfig, available_engines,
                        fit_em, run_windtunnel, run_windtunnel_sharded)
from repro.core.engines import get_engine
from repro.data.synthetic import generate_corpus
from repro.launch.mesh import parse_mesh


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--queries", type=int, default=1280)
    p.add_argument("--qrels-per-query", type=int, default=32)
    p.add_argument("--topics", type=int, default=96)
    p.add_argument("--aux-fraction", type=float, default=2.0)
    p.add_argument("--target-frac", type=float, default=0.15)
    p.add_argument("--tau-quantile", type=float, default=0.5)
    p.add_argument("--fanout", type=int, default=16)
    p.add_argument("--lp-rounds", type=int, default=5)
    p.add_argument("--engine", default="sort",
                   help="label-prop engine from the registry "
                        "(core/engines.py): " + ",".join(available_engines()))
    p.add_argument("--sharded", action="store_true",
                   help="run the mesh-partitioned pipeline "
                        "(core/sharded_pipeline.py; requires an ELL-family "
                        "engine)")
    p.add_argument("--mesh", default="host", choices=["host", "auto"],
                   help="mesh for --sharded: 1-device host mesh or all "
                        "local devices on the data axis")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--out", default=None)
    args = p.parse_args(argv)
    get_engine(args.engine)        # unknown names fail with the registry's
                                   # error message before any corpus work
    if args.sharded and args.engine == "sort":
        p.error("--sharded requires an ELL-family engine; "
                "pass --engine ell or --engine pallas")

    corpus = generate_corpus(
        num_queries=args.queries, qrels_per_query=args.qrels_per_query,
        num_topics=args.topics, aux_fraction=args.aux_fraction,
        seed=args.seed)
    print(f"corpus: {corpus.num_entities} entities "
          f"({corpus.num_primary} judged), {corpus.num_queries} queries")

    qrels = QRelTable(*(jnp.asarray(x) for x in corpus.qrels))
    cfg = WindTunnelConfig(
        tau_quantile=args.tau_quantile, fanout=args.fanout,
        lp_rounds=args.lp_rounds, engine=args.engine,
        target_size=args.target_frac * corpus.num_primary, seed=args.seed)
    if args.sharded:
        mesh = parse_mesh(args.mesh)
        print(f"sharded pipeline on mesh {dict(mesh.shape)} "
              f"(engine={cfg.engine})")
        res = run_windtunnel_sharded(
            qrels, num_queries=corpus.num_queries,
            num_entities=corpus.num_entities, config=cfg, mesh=mesh)
    else:
        res = jax.jit(lambda q: run_windtunnel(
            q, num_queries=corpus.num_queries,
            num_entities=corpus.num_entities, config=cfg))(qrels)

    mask = np.asarray(res.sample.entity_mask)
    labels = np.asarray(res.labels)
    deg = np.asarray(res.degrees)
    sizes = np.asarray(res.sample.community_sizes)
    n_comm = int((sizes > 0).sum())
    fit = fit_em(jnp.asarray(deg[deg > 0]), max_iters=300)
    print(f"affinity graph: {int(res.edges.num_valid)} edges, "
          f"{n_comm} communities; degree-law gamma = {float(fit.gamma):.3f} "
          f"(se {float(fit.stderr):.2e})")
    print(f"sample: {int(mask.sum())} entities, "
          f"{int(res.reconstructed.num_queries)} associated queries; "
          f"LP changes/round = {np.asarray(res.changes_per_round).tolist()}")

    if args.out:
        os.makedirs(args.out, exist_ok=True)
        np.savez(os.path.join(args.out, "sample.npz"),
                 entity_mask=mask, labels=labels,
                 qrel_valid=np.asarray(res.reconstructed.qrels.valid))
        with open(os.path.join(args.out, "stats.json"), "w") as f:
            json.dump({"entities": int(mask.sum()),
                       "communities": n_comm,
                       "gamma": float(fit.gamma)}, f, indent=2)
        print(f"wrote {args.out}/sample.npz")


if __name__ == "__main__":
    main()
