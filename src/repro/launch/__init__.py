"""Launchers: production mesh builders, the multi-pod dry-run, training,
sampling and experiment-grid evaluation CLIs."""
