"""Launchers: production mesh builders, the multi-pod dry-run, training and
sampling CLIs."""
