"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module must never
touch jax device state (smoke tests see 1 CPU device; only dryrun.py sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax use).

Mesh geometry (TPU v5e pods):
  single-pod: (data=16, model=16)       = 256 chips
  multi-pod:  (pod=2, data=16, model=16) = 512 chips
The 'model' axis carries TP/EP/vocab sharding (highest-bandwidth inner
axis); 'data' carries DP + ZeRO-sharded parameter/optimizer state; 'pod'
carries pure DP whose gradient all-reduce crosses the DCI links — that is
the all-reduce gradient compression (distributed/compression.py) targets.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Degenerate 1-device mesh with the production axis NAMES, so the same
    sharded step functions run in smoke tests on CPU."""
    return jax.make_mesh((1, model_axis), ("data", "model"))


def batch_axes(mesh) -> tuple:
    """Mesh axes the global batch is sharded over."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def parse_mesh(name: str):
    """CLI ``--mesh`` flag -> Mesh: ``host`` is the 1-device mesh with
    production axis names, ``auto`` puts all local devices on the data
    axis.  Shared by launch/sample.py and launch/evaluate.py so the two
    entry points agree on mesh vocabulary."""
    if name == "host":
        return make_host_mesh()
    if name == "auto":
        return jax.make_mesh((len(jax.devices()), 1), ("data", "model"))
    raise ValueError(f"unknown mesh {name!r}; known meshes: auto, host")
