"""Contract analyzer CLI (DESIGN.md §15).

  PYTHONPATH=src python -m repro.launch.lint src/repro
  PYTHONPATH=src python -m repro.launch.lint --json src/repro
  PYTHONPATH=src python -m repro.launch.lint --imports
  PYTHONPATH=src python -m repro.launch.lint --write-baseline src/repro

(``python -m launch.lint`` also works — ``src/launch`` is a thin shim —
so the invocation matches the other launch entry points' shape.)

Exit codes: 0 clean; 1 when any finding at/above ``--fail-on`` severity
(default: error) is not in the committed baseline; 2 on usage errors.
The baseline (``lint_baseline.json`` at the repo root) holds accepted
finding fingerprints — line-number-free, so unrelated edits don't churn
it.  ``--write-baseline`` regenerates it after a reviewed change.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

from repro.analysis import core as acore

__all__ = ["main", "run"]

#: rules the --imports mode restricts to (the PR 2 layering contract)
IMPORT_RULES = ("import-cycle", "import-layering")

DEFAULT_PATHS = ("src/repro",)
DEFAULT_BASELINE = "lint_baseline.json"


def run(paths: List[str], *, rules: Optional[List[str]] = None,
        baseline_path: str = DEFAULT_BASELINE, fail_on: str = "error",
        write_baseline: bool = False) -> dict:
    """Analyze ``paths``; returns the report dict (the --json payload)."""
    acore.load_default_rules()
    project = acore.Project.load(paths)
    findings = acore.analyze(project, rules=rules)
    baseline = acore.load_baseline(baseline_path)
    fresh = acore.new_findings(findings, baseline)
    threshold = acore.SEVERITIES[fail_on]
    # --write-baseline ACCEPTS the current findings, so nothing fails
    failing = [] if write_baseline else \
        [f for f in fresh if acore.SEVERITIES[f.severity] >= threshold]
    counts = {sev: 0 for sev in acore.SEVERITIES}
    for f in findings:
        counts[f.severity] += 1
    if write_baseline:
        acore.save_baseline(baseline_path, findings)
    new_fps = {f.fingerprint for f in fresh}
    return {
        "version": 1,
        "paths": list(paths),
        "rules": list(rules) if rules else list(acore.available_rules()),
        "counts": counts,
        "new": len(fresh),
        "failing": len(failing),
        "fail_on": fail_on,
        "baseline": baseline_path,
        "findings": [dict(f.to_dict(), new=f.fingerprint in new_fps)
                     for f in findings],
    }


def main(argv: Optional[List[str]] = None) -> int:
    p = argparse.ArgumentParser(
        description="contract analyzer: JAX trace/donation, concurrency, "
                    "registry conformance, import hygiene")
    p.add_argument("paths", nargs="*", default=None,
                   help=f"files/dirs to analyze (default: "
                        f"{' '.join(DEFAULT_PATHS)})")
    p.add_argument("--json", action="store_true",
                   help="emit the full JSON report to stdout")
    p.add_argument("--json-out", default=None, metavar="PATH",
                   help="also write the JSON report to PATH (CI artifact)")
    p.add_argument("--rules", default=None, metavar="ID[,ID...]",
                   help="run only these rule ids "
                        "(see --list-rules)")
    p.add_argument("--list-rules", action="store_true",
                   help="print registered rule ids and exit")
    p.add_argument("--imports", action="store_true",
                   help="import hygiene only: package cycles + layering "
                        f"({', '.join(IMPORT_RULES)})")
    p.add_argument("--baseline", default=DEFAULT_BASELINE, metavar="PATH",
                   help="accepted-findings fingerprint file "
                        f"(default: {DEFAULT_BASELINE})")
    p.add_argument("--write-baseline", action="store_true",
                   help="accept the current findings as the new baseline")
    p.add_argument("--fail-on", default="error",
                   choices=tuple(acore.SEVERITIES),
                   help="exit 1 on new findings at/above this severity "
                        "(default: error)")
    args = p.parse_args(argv)

    acore.load_default_rules()
    if args.list_rules:
        for rule_id in acore.available_rules():
            rule = acore.get_rule(rule_id)
            print(f"{rule_id:26s} {rule.severity:8s} "
                  f"{(rule.__doc__ or '').strip().splitlines()[0]}")
        return 0

    rules: Optional[List[str]] = None
    if args.imports:
        rules = list(IMPORT_RULES)
    if args.rules:
        rules = [r.strip() for r in args.rules.split(",") if r.strip()]
        for r in rules:
            acore.get_rule(r)  # raise early on unknown ids

    paths = args.paths or list(DEFAULT_PATHS)
    try:
        report = run(paths, rules=rules, baseline_path=args.baseline,
                     fail_on=args.fail_on,
                     write_baseline=args.write_baseline)
    except (OSError, ValueError, SyntaxError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2

    payload = json.dumps(report, indent=2)
    if args.json_out:
        with open(args.json_out, "w") as f:
            f.write(payload + "\n")
    if args.json:
        print(payload)
    else:
        for f_dict in report["findings"]:
            marker = "NEW " if f_dict["new"] else ""
            print(f"{f_dict['path']}:{f_dict['line']}: "
                  f"{f_dict['severity']}: {marker}{f_dict['rule']}: "
                  f"{f_dict['message']}"
                  + (f" [{f_dict['symbol']}]" if f_dict["symbol"] else ""))
        c = report["counts"]
        print(f"{len(report['findings'])} findings "
              f"({c['error']} error, {c['warning']} warning, "
              f"{c['info']} info); {report['new']} not in baseline")
        if args.write_baseline:
            print(f"baseline written: {args.baseline}")
    return 1 if report["failing"] else 0


if __name__ == "__main__":
    sys.exit(main())
