"""Shared CLI plumbing for the ``repro.launch`` entry points: stdlib
logging under the ``repro.*`` logger hierarchy, plus the observability
flags every CLI carries (DESIGN.md §12).

Logging: progress / diagnostic output goes through ``logging.getLogger
("repro.<module>")`` instead of ad-hoc ``print`` — ``setup_logging``
installs one message-only stdout handler on the ``repro`` root logger
(so default CLI output looks exactly as before), ``--verbose`` drops the
level to DEBUG (and adds the logger name to the format), ``--quiet``
raises it to WARNING.  Library code just logs; only CLIs install
handlers.

Observability: ``--trace <path>`` enables the span tracer's JSONL sink
(equivalent to ``REPRO_TRACE=<path>``) and ``--metrics-json <path>``
writes the process-global metrics registry snapshot on exit via
:func:`write_metrics`.
"""
from __future__ import annotations

import argparse
import json
import logging
import os
import sys
from typing import Optional

from repro.obs import REGISTRY, trace

__all__ = ["add_logging_args", "add_obs_args", "init_obs", "setup_logging",
           "write_metrics"]


def add_logging_args(p: argparse.ArgumentParser) -> None:
    """Install the shared ``--verbose`` / ``--quiet`` flags."""
    g = p.add_mutually_exclusive_group()
    g.add_argument("--verbose", action="store_true",
                   help="debug-level progress output (repro.* loggers)")
    g.add_argument("--quiet", action="store_true",
                   help="warnings and errors only")


def add_obs_args(p: argparse.ArgumentParser) -> None:
    """Install the shared ``--trace`` / ``--metrics-json`` flags."""
    p.add_argument("--trace", default=None, metavar="PATH",
                   help="append structured spans to PATH as JSONL "
                        "(repro.obs.trace; env: REPRO_TRACE); read back "
                        "with python -m repro.launch.trace PATH")
    p.add_argument("--metrics-json", default=None, metavar="PATH",
                   help="write the metrics-registry snapshot (counters/"
                        "gauges/histograms) to PATH on exit")


class _StdoutHandler(logging.StreamHandler):
    """StreamHandler that resolves ``sys.stdout`` at emit time, so stream
    replacement after setup (pytest capsys, redirection) is honoured."""

    def __init__(self):
        super().__init__(sys.stdout)

    @property
    def stream(self):
        return sys.stdout

    @stream.setter
    def stream(self, value):   # StreamHandler.__init__ assigns; ignore
        pass


def setup_logging(args: Optional[argparse.Namespace] = None, *,
                  verbose: bool = False, quiet: bool = False
                  ) -> logging.Logger:
    """Configure the ``repro`` root logger for CLI use (idempotent)."""
    verbose = bool(getattr(args, "verbose", verbose))
    quiet = bool(getattr(args, "quiet", quiet))
    logger = logging.getLogger("repro")
    logger.setLevel(logging.DEBUG if verbose
                    else logging.WARNING if quiet else logging.INFO)
    if not logger.handlers:
        logger.addHandler(_StdoutHandler())
        logger.propagate = False
    fmt = ("%(name)s: %(message)s" if verbose else "%(message)s")
    for handler in logger.handlers:
        handler.setFormatter(logging.Formatter(fmt))
    return logger


def init_obs(args: argparse.Namespace) -> None:
    """Apply the parsed ``--trace`` flag (before any instrumented work)."""
    if getattr(args, "trace", None):
        trace.enable(args.trace)


def write_metrics(args: argparse.Namespace, extra: Optional[dict] = None
                  ) -> Optional[str]:
    """Write the global registry snapshot (plus optional component
    sections, e.g. a grid's plan-trie registry) to ``--metrics-json``."""
    path = getattr(args, "metrics_json", None)
    if not path:
        return None
    out = {"global": REGISTRY.snapshot()}
    if extra:
        out.update(extra)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    return path
