"""Sign-random-projection LSH index (the paper cites LSH [3] as an index
option and Grale-style LSH graph building [4]).

Vectors hash to ``n_bits`` sign bits packed into int32 lanes; search ranks by
Hamming distance (XOR + popcount) with optional exact rerank of the top
candidates.  The Hamming scan dispatches through the scoring-backend
registry (retrieval/backends.py): ``jnp`` materialises the (Q, N) distance
matrix, ``pallas`` streams it through the kernels/lsh_hamming kernel.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels.topk_scoring.ref import pad_topk  # noqa: F401 (re-export)
from repro.retrieval.backends import get_backend, rerank_candidates


class LSHIndex(NamedTuple):
    proj: jnp.ndarray    # (d, n_bits) random projection
    codes: jnp.ndarray   # (N, n_words) packed int32
    vecs: jnp.ndarray    # (N, d) kept for rerank


def _pack_bits(bits: jnp.ndarray) -> jnp.ndarray:
    """bits (..., n_bits) bool -> (..., n_bits/32) int32."""
    n_bits = bits.shape[-1]
    assert n_bits % 32 == 0
    b = bits.reshape(bits.shape[:-1] + (n_bits // 32, 32)).astype(jnp.uint32)
    weights = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))
    return (b * weights).sum(-1).astype(jnp.int32)


def popcount32(x: jnp.ndarray) -> jnp.ndarray:
    """Branch-free popcount on int32 (as uint32 bit tricks)."""
    x = x.astype(jnp.uint32)
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    return ((x * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)


def encode(proj: jnp.ndarray, vecs: jnp.ndarray) -> jnp.ndarray:
    return _pack_bits((vecs @ proj) > 0)


def build_lsh(key, corpus: jnp.ndarray, *, n_bits: int = 128) -> LSHIndex:
    d = corpus.shape[1]
    proj = jax.random.normal(key, (d, n_bits), corpus.dtype)
    return LSHIndex(proj, encode(proj, corpus), corpus)


@functools.partial(jax.jit, static_argnames=("k", "rerank", "backend"))
def search_lsh(index: LSHIndex, queries: jnp.ndarray, *, k: int,
               rerank: int = 0, backend: str = "jnp"):
    """Hamming-distance ANN; if ``rerank`` > 0, exact-rerank that many
    Hamming candidates with true inner products (higher score = better);
    with ``rerank`` <= 0 the first result is the POSITIVE Hamming distance
    (lower = better, +inf for misses), matching the historical API."""
    bk = get_backend(backend)
    qc = encode(index.proj, queries)                      # (Q, W)
    if rerank <= 0:
        neg, ids = bk.hamming_topk(qc, index.codes, k=k)
        return (-neg).astype(queries.dtype), ids
    _, cand = bk.hamming_topk(qc, index.codes, k=rerank)  # (Q, rerank)
    return rerank_candidates(index.vecs, queries, cand, k=k)
