"""IR evaluation metrics: precision@k over a QRel set (paper Table I) —
'the relevance percentage of entities responding to each query'."""
from __future__ import annotations

import numpy as np


def precision_at_k(retrieved_ids: np.ndarray, query_ids: np.ndarray,
                   qrel_pairs: set, k: int = 3) -> float:
    """retrieved_ids (Q, >=k) entity ids per query; qrel_pairs a set of
    (query_id, entity_id) judged-relevant pairs. Mean p@k over queries."""
    hits = 0
    total = 0
    for qi, row in zip(query_ids, retrieved_ids[:, :k]):
        for e in row:
            if e >= 0:
                hits += int((int(qi), int(e)) in qrel_pairs)
                total += 1
    return hits / max(total, 1)


def recall_at_k(retrieved_ids: np.ndarray, query_ids: np.ndarray,
                qrel_by_query: dict, k: int = 10) -> float:
    rec = []
    for qi, row in zip(query_ids, retrieved_ids[:, :k]):
        rel = qrel_by_query.get(int(qi), set())
        if rel:
            rec.append(len(rel & set(int(e) for e in row)) / len(rel))
    return float(np.mean(rec)) if rec else 0.0


def qrel_set(query_ids, entity_ids, valid) -> set:
    q = np.asarray(query_ids)[np.asarray(valid)]
    e = np.asarray(entity_ids)[np.asarray(valid)]
    return set(zip(q.tolist(), e.tolist()))


def qrel_dict(query_ids, entity_ids, valid) -> dict:
    out: dict = {}
    q = np.asarray(query_ids)[np.asarray(valid)]
    e = np.asarray(entity_ids)[np.asarray(valid)]
    for qi, ei in zip(q.tolist(), e.tolist()):
        out.setdefault(qi, set()).add(ei)
    return out
