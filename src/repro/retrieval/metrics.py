"""IR evaluation metrics over the QRel judgments: precision@k (paper Table I
— 'the relevance percentage of entities responding to each query'),
recall@k, binary-relevance nDCG@k, and MRR.  All take the (Q, >=k) retrieved
id matrix (−1 padding ignored) plus the judged-relevant structures built by
:func:`qrel_set` / :func:`qrel_dict`."""
from __future__ import annotations

import numpy as np


def precision_at_k(retrieved_ids: np.ndarray, query_ids: np.ndarray,
                   qrel_pairs: set, k: int = 3) -> float:
    """retrieved_ids (Q, >=k) entity ids per query; qrel_pairs a set of
    (query_id, entity_id) judged-relevant pairs. Mean p@k over queries."""
    hits = 0
    total = 0
    for qi, row in zip(query_ids, retrieved_ids[:, :k]):
        for e in row:
            if e >= 0:
                hits += int((int(qi), int(e)) in qrel_pairs)
                total += 1
    return hits / max(total, 1)


def recall_at_k(retrieved_ids: np.ndarray, query_ids: np.ndarray,
                qrel_by_query: dict, k: int = 10) -> float:
    rec = []
    for qi, row in zip(query_ids, retrieved_ids[:, :k]):
        rel = qrel_by_query.get(int(qi), set())
        if rel:
            rec.append(len(rel & set(int(e) for e in row)) / len(rel))
    return float(np.mean(rec)) if rec else 0.0


def ndcg_at_k(retrieved_ids: np.ndarray, query_ids: np.ndarray,
              qrel_by_query: dict, k: int = 10) -> float:
    """Binary-relevance nDCG@k: DCG = sum_i rel_i / log2(i + 1) over ranks
    i = 1..k, ideal DCG puts the query's min(|rel|, k) judged entities
    first.  Mean over queries with >=1 judgment."""
    vals = []
    for qi, row in zip(query_ids, retrieved_ids[:, :k]):
        rel = qrel_by_query.get(int(qi), set())
        if not rel:
            continue
        dcg = sum(1.0 / np.log2(i + 2.0)
                  for i, e in enumerate(row) if e >= 0 and int(e) in rel)
        idcg = sum(1.0 / np.log2(i + 2.0) for i in range(min(len(rel), k)))
        vals.append(dcg / idcg)
    return float(np.mean(vals)) if vals else 0.0


def mrr(retrieved_ids: np.ndarray, query_ids: np.ndarray,
        qrel_by_query: dict, k: int | None = None) -> float:
    """Mean reciprocal rank of the first judged-relevant entity (0 when no
    relevant entity appears in the top-k), averaged over all queries."""
    rows = retrieved_ids if k is None else retrieved_ids[:, :k]
    rrs = []
    for qi, row in zip(query_ids, rows):
        rel = qrel_by_query.get(int(qi), set())
        rr = 0.0
        for i, e in enumerate(row):
            if e >= 0 and int(e) in rel:
                rr = 1.0 / (i + 1.0)
                break
        rrs.append(rr)
    return float(np.mean(rrs)) if rrs else 0.0


def qrel_set(query_ids, entity_ids, valid) -> set:
    q = np.asarray(query_ids)[np.asarray(valid)]
    e = np.asarray(entity_ids)[np.asarray(valid)]
    return set(zip(q.tolist(), e.tolist()))


def qrel_dict(query_ids, entity_ids, valid) -> dict:
    out: dict = {}
    q = np.asarray(query_ids)[np.asarray(valid)]
    e = np.asarray(entity_ids)[np.asarray(valid)]
    for qi, ei in zip(q.tolist(), e.tolist()):
        out.setdefault(qi, set()).add(ei)
    return out
