"""Retrieval-engine registry (DESIGN.md §8).

The experiment grid compares sampling methods across *retrieval systems*, so
each vector index is a first-class registered object behind one protocol —
the same pluggable-component pattern as the label-prop registry in
``core/engines.py`` — rather than a string branch inside the runner.  The
registry lives here, below both of its consumers (``retrieval/experiment.py``
and the ``repro.eval`` grid subsystem, which re-exports it), so neither
package depends upward on the other; chunked multi-query search, backend
selection and global-id mapping live one layer up in
``retrieval/search_core.SearchSession``.

An engine implements the :class:`RetrievalEngine` protocol:

  * ``build(key, vecs)`` — one-time index construction over the corpus
    vectors (f32[N, D]); returns an engine-private index pytree.
  * ``search(index, queries, k)`` — ANN/exact top-k; returns i32[Q, k] ids
    into the ``vecs`` the index was built from (−1 padding for misses).
  * ``search_scored(index, queries, k)`` — the scored variant ``search``
    slices: (scores f32[Q, k], ids i32[Q, k]).  Scores are inner products
    (for lsh: only when ``rerank > 0`` — the no-rerank path returns
    positive Hamming distances), which is what lets the serving tier's
    live append buffers merge engine results with a fresh exact scan
    (serve/ingest.py) by comparing scores across the two sources.

Registered engines:

  * ``exact``   — blocked brute-force inner product (the oracle).
  * ``ivfflat`` — k-means inverted lists, the paper's pgvector index;
                  ``n_lists`` auto-shrinks for small sampled corpora.
  * ``lsh``     — sign-random-projection Hamming search with exact rerank
                  (the paper cites LSH [3] as an index option).
  * ``tfidf``   — IDF-reweighted exact search: dimensions active in few
                  corpus vectors are up-weighted by log1p(N/df).  Over the
                  bag-of-words ``tfidf_vectors`` embedder this is classic
                  tf-idf ranking; over dense encoder vectors df ≈ N, the
                  weights flatten, and it degrades gracefully to ``exact``.

Engines are frozen dataclasses so callers can tune hyper-parameters with
``dataclasses.replace`` without mutating the registry's shared instance.
Every engine carries a ``backend`` field naming a scoring backend from
``retrieval/backends.py`` (``jnp`` reference or ``pallas`` kernels); the
search core sets it uniformly, so the kernel path is a config string, not a
per-index fork.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Protocol, runtime_checkable

import jax.numpy as jnp

from repro.retrieval.backends import get_backend
from repro.retrieval.exact import exact_topk
from repro.retrieval.ivfflat import build_ivfflat, search_ivfflat
from repro.retrieval.lsh import build_lsh, search_lsh


@runtime_checkable
class RetrievalEngine(Protocol):
    """A vector index behind a uniform build/search interface."""

    name: str

    def build(self, key, vecs: jnp.ndarray) -> Any:
        """Corpus vectors f32[N, D] -> engine-private index."""
        ...

    def search(self, index: Any, queries: jnp.ndarray, *,
               k: int) -> jnp.ndarray:
        """Queries f32[Q, D] -> top-k ids i32[Q, k] into the built corpus."""
        ...

    def search_scored(self, index: Any, queries: jnp.ndarray, *,
                      k: int) -> Any:
        """Queries f32[Q, D] -> (scores f32[Q, k], ids i32[Q, k])."""
        ...


_REGISTRY: Dict[str, RetrievalEngine] = {}


def register_retrieval_engine(cls):
    """Class decorator: instantiate and register an engine under its name."""
    engine = cls()
    _REGISTRY[engine.name] = engine
    return cls


def get_retrieval_engine(name: str) -> RetrievalEngine:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown retrieval engine {name!r}; registered engines: "
            f"{', '.join(available_retrieval_engines())}") from None


def available_retrieval_engines() -> tuple:
    return tuple(sorted(_REGISTRY))


@register_retrieval_engine
@dataclasses.dataclass(frozen=True)
class ExactEngine:
    """Blocked brute-force top-k — the recall oracle for the ANN engines."""

    block: int = 2048
    backend: str = "jnp"
    name: str = "exact"

    def build(self, key, vecs):
        del key  # deterministic
        # build-time backend hook: int8 quantizes the corpus once here
        return get_backend(self.backend).prepare_corpus(vecs)

    def search(self, index, queries, *, k: int):
        return self.search_scored(index, queries, k=k)[1]

    def search_scored(self, index, queries, *, k: int):
        return exact_topk(queries, index, k=k, block=self.block,
                          backend=self.backend)


@register_retrieval_engine
@dataclasses.dataclass(frozen=True)
class IVFFlatEngine:
    """k-means inverted lists (pgvector ``ivfflat``).  ``n_lists`` shrinks to
    N//8 on small sampled corpora so every list keeps enough members."""

    n_lists: int = 64
    nprobe: int = 8
    cap_factor: float = 2.0
    backend: str = "jnp"
    name: str = "ivfflat"

    def build(self, key, vecs):
        n_lists = min(self.n_lists, max(1, vecs.shape[0] // 8))
        return build_ivfflat(key, vecs, n_lists=n_lists,
                             cap_factor=self.cap_factor)

    def search(self, index, queries, *, k: int):
        return self.search_scored(index, queries, k=k)[1]

    def search_scored(self, index, queries, *, k: int):
        nprobe = min(self.nprobe, index.centroids.shape[0])
        return search_ivfflat(index, queries, k=k, nprobe=nprobe,
                              backend=self.backend)


@register_retrieval_engine
@dataclasses.dataclass(frozen=True)
class LSHEngine:
    """Sign-random-projection Hamming search with exact rerank of the top
    ``rerank`` Hamming candidates (clamped to [k, N])."""

    n_bits: int = 128
    rerank: int = 64
    backend: str = "jnp"
    name: str = "lsh"

    def build(self, key, vecs):
        return build_lsh(key, vecs, n_bits=self.n_bits)

    def search(self, index, queries, *, k: int):
        return self.search_scored(index, queries, k=k)[1]

    def search_scored(self, index, queries, *, k: int):
        n = index.codes.shape[0]
        rerank = min(max(self.rerank, k), n) if self.rerank > 0 else 0
        return search_lsh(index, queries, k=k, rerank=rerank,
                          backend=self.backend)


class TfIdfIndex(NamedTuple):
    vecs: Any              # (N, D) IDF-weighted corpus, backend-prepared
                           # (QuantizedCorpus under the int8 backend)
    weights: jnp.ndarray   # (D,) per-dimension log1p(N/df)


@register_retrieval_engine
@dataclasses.dataclass(frozen=True)
class TfIdfEngine:
    """IDF-reweighted exact search: df_j = |{i : vecs[i, j] > 0}|, corpus
    dimension j scaled by log1p(N/df_j).  The weight is applied on the
    corpus side only, so scores are sum_j w_j q_j d_j (one IDF factor)."""

    block: int = 2048
    backend: str = "jnp"
    name: str = "tfidf"

    def build(self, key, vecs):
        del key  # deterministic
        n = vecs.shape[0]
        df = jnp.sum(vecs > 0, axis=0).astype(jnp.float32) + 1.0
        w = jnp.log1p(n / df)
        # IDF folds in before the backend hook so int8 quantizes the
        # weighted rows the scan will actually score
        return TfIdfIndex(get_backend(self.backend).prepare_corpus(
            vecs * w[None, :]), w)

    def search(self, index, queries, *, k: int):
        return self.search_scored(index, queries, k=k)[1]

    def search_scored(self, index, queries, *, k: int):
        return exact_topk(queries, index.vecs, k=k, block=self.block,
                          backend=self.backend)
