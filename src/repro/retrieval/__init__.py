"""Retrieval substrate — the paper's Fig. 5 semantic-search pipeline as a
three-layer search core (DESIGN.md §9): scoring backends (jnp / pallas
kernels) under pluggable vector indexes (exact / ivfflat / lsh / tfidf),
mesh-sharded search, and the :class:`SearchSession` front door shared by
offline evaluation and online serving.
"""
from repro.retrieval.encoder import (EncoderConfig, init_encoder,
                                     contrastive_loss, embed_tokens)
from repro.retrieval.backends import (ScoringBackend, available_backends,
                                      get_backend, register_backend)
from repro.retrieval.exact import exact_topk
from repro.retrieval.ivfflat import IVFFlatIndex, build_ivfflat, search_ivfflat
from repro.retrieval.lsh import LSHIndex, build_lsh, search_lsh
from repro.retrieval.engines import (RetrievalEngine,
                                     available_retrieval_engines,
                                     get_retrieval_engine,
                                     register_retrieval_engine)
from repro.retrieval.sharded import sharded_search
from repro.retrieval.search_core import SearchConfig, SearchSession
from repro.retrieval.metrics import (mrr, ndcg_at_k, precision_at_k,
                                     qrel_dict, qrel_set, recall_at_k)

__all__ = ["EncoderConfig", "init_encoder", "contrastive_loss",
           "embed_tokens",
           "ScoringBackend", "available_backends", "get_backend",
           "register_backend",
           "exact_topk", "IVFFlatIndex", "build_ivfflat",
           "search_ivfflat", "LSHIndex", "build_lsh", "search_lsh",
           "RetrievalEngine", "available_retrieval_engines",
           "get_retrieval_engine", "register_retrieval_engine",
           "sharded_search", "SearchConfig", "SearchSession",
           "precision_at_k", "recall_at_k", "ndcg_at_k", "mrr",
           "qrel_set", "qrel_dict"]
