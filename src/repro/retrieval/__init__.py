"""Retrieval substrate — the paper's Fig. 5 semantic-search pipeline:
embedding model -> vector index (IVF-Flat like pgvector's ivfflat, or
sign-LSH) -> ANN top-k -> precision@k / query-density evaluation.
"""
from repro.retrieval.encoder import (EncoderConfig, init_encoder,
                                     contrastive_loss, embed_tokens)
from repro.retrieval.exact import exact_topk
from repro.retrieval.ivfflat import IVFFlatIndex, build_ivfflat, search_ivfflat
from repro.retrieval.lsh import LSHIndex, build_lsh, search_lsh
from repro.retrieval.engines import (RetrievalEngine,
                                     available_retrieval_engines,
                                     chunked_search, get_retrieval_engine,
                                     register_retrieval_engine)
from repro.retrieval.metrics import (mrr, ndcg_at_k, precision_at_k,
                                     qrel_dict, qrel_set, recall_at_k)

__all__ = ["EncoderConfig", "init_encoder", "contrastive_loss",
           "embed_tokens", "exact_topk", "IVFFlatIndex", "build_ivfflat",
           "search_ivfflat", "LSHIndex", "build_lsh", "search_lsh",
           "RetrievalEngine", "available_retrieval_engines",
           "get_retrieval_engine", "register_retrieval_engine",
           "chunked_search",
           "precision_at_k", "recall_at_k", "ndcg_at_k", "mrr",
           "qrel_set", "qrel_dict"]
