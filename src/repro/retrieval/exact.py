"""Exact blocked top-k retrieval (brute force oracle for the ANN indexes and
the retrieval_cand serving path).

Candidates are scored block-by-block with a running top-k merge, so the
(n_queries, n_candidates) score matrix is never materialised — the same
streaming structure the Pallas topk_scoring kernel implements in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax


@functools.partial(jax.jit, static_argnames=("k", "block"))
def exact_topk(queries: jnp.ndarray, corpus: jnp.ndarray, *, k: int,
               block: int = 4096):
    """queries (Q, D), corpus (N, D) -> (scores (Q, k), ids (Q, k))."""
    qn, d = queries.shape
    n = corpus.shape[0]
    nb = (n + block - 1) // block
    pad = nb * block - n
    cp = jnp.pad(corpus, ((0, pad), (0, 0)))
    blocks = cp.reshape(nb, block, d)

    def step(carry, xs):
        best_s, best_i = carry
        blk, bi = xs
        s = queries @ blk.T                                   # (Q, block)
        ids = bi * block + jnp.arange(block, dtype=jnp.int32)[None]
        valid = ids < n
        s = jnp.where(valid, s, -jnp.inf)
        cat_s = jnp.concatenate([best_s, s], axis=1)
        cat_i = jnp.concatenate([best_i, jnp.broadcast_to(ids, s.shape)], 1)
        top_s, pos = lax.top_k(cat_s, k)
        top_i = jnp.take_along_axis(cat_i, pos, axis=1)
        return (top_s, top_i), None

    init = (jnp.full((qn, k), -jnp.inf, queries.dtype),
            jnp.full((qn, k), -1, jnp.int32))
    (scores, ids), _ = lax.scan(
        step, init, (blocks, jnp.arange(nb, dtype=jnp.int32)))
    return scores, ids
