"""Exact top-k retrieval (brute force oracle for the ANN indexes and the
retrieval_cand serving path), dispatched through the scoring-backend
registry (retrieval/backends.py): ``jnp`` runs the blocked streaming merge,
``pallas`` the fused kernels/topk_scoring kernel (interpret off-TPU)."""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.retrieval.backends import get_backend


def exact_topk(queries: jnp.ndarray, corpus: jnp.ndarray, *, k: int,
               block: int = 4096, backend: str = "jnp"):
    """queries (Q, D), corpus (N, D) -> (scores (Q, k), ids (Q, k));
    score −inf / id −1 padding when k exceeds the corpus size.  ``block``
    tunes the jnp backend's streaming block (the pallas backend's block
    sizes live on its registry instance / the autotuner table).  ``corpus``
    may be a backend-prepared layout (QuantizedCorpus for int8, plain
    array otherwise) — every backend accepts both."""
    bk = get_backend(backend)
    if backend == "jnp" and block != bk.block:
        bk = dataclasses.replace(bk, block=block)
    return bk.topk(queries, corpus, k=k)
