"""IVF-Flat vector index — the pgvector ``ivfflat`` index of the paper's
experiments, in JAX.

Build: k-means (Lloyd) clusters the corpus into ``n_lists`` inverted lists,
stored as a padded ELL block (n_lists, cap, d) so probing is dense gathers.
Search: score the query against centroids, probe the ``nprobe`` nearest
lists, then score their members through the scoring-backend registry's
``gathered_topk`` primitive (retrieval/backends.py) — pure jnp or the
Pallas per-query candidate kernel. All static-shape and jit-able.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.retrieval.backends import get_backend


class IVFFlatIndex(NamedTuple):
    centroids: jnp.ndarray   # (n_lists, d)
    vecs: jnp.ndarray        # (n_lists, cap, d)
    ids: jnp.ndarray         # (n_lists, cap) original ids, -1 padding
    mask: jnp.ndarray        # (n_lists, cap)


def kmeans(key, data: jnp.ndarray, n_clusters: int, iters: int = 10):
    """Lloyd's algorithm; returns centroids (n_clusters, d)."""
    n = data.shape[0]
    init_idx = jax.random.choice(key, n, (n_clusters,), replace=False)
    cent = data[init_idx]

    def step(cent, _):
        d2 = (jnp.sum(data ** 2, 1)[:, None] - 2.0 * data @ cent.T
              + jnp.sum(cent ** 2, 1)[None])
        assign = jnp.argmin(d2, axis=1)
        sums = jax.ops.segment_sum(data, assign, num_segments=n_clusters)
        cnts = jax.ops.segment_sum(jnp.ones((n, 1), data.dtype), assign,
                                   num_segments=n_clusters)
        new = jnp.where(cnts > 0, sums / jnp.maximum(cnts, 1.0), cent)
        return new, None

    cent, _ = lax.scan(step, cent, None, length=iters)
    return cent


def build_ivfflat(key, corpus: jnp.ndarray, *, n_lists: int,
                  cap_factor: float = 2.0, kmeans_iters: int = 10
                  ) -> IVFFlatIndex:
    n, d = corpus.shape
    cent = kmeans(key, corpus, n_lists, kmeans_iters)
    d2 = (jnp.sum(corpus ** 2, 1)[:, None] - 2.0 * corpus @ cent.T
          + jnp.sum(cent ** 2, 1)[None])
    assign = jnp.argmin(d2, axis=1)                       # (N,)
    cap = int(cap_factor * n / n_lists) + 1
    # rank of each vector within its list (sort-based, static shape)
    order = jnp.argsort(assign, stable=True)
    sorted_assign = assign[order]
    starts = jnp.concatenate([jnp.ones((1,), bool),
                              sorted_assign[1:] != sorted_assign[:-1]])
    iota = jnp.arange(n, dtype=jnp.int32)
    gstart = lax.associative_scan(jnp.maximum, jnp.where(starts, iota, 0))
    rank = iota - gstart
    ok = rank < cap
    row = jnp.where(ok, sorted_assign, n_lists)
    col = jnp.where(ok, rank, 0)
    vecs = jnp.zeros((n_lists, cap, d), corpus.dtype).at[row, col].set(
        corpus[order], mode="drop")
    ids = jnp.full((n_lists, cap), -1, jnp.int32).at[row, col].set(
        order.astype(jnp.int32), mode="drop")
    mask = jnp.zeros((n_lists, cap), bool).at[row, col].set(
        jnp.ones((n,), bool), mode="drop")
    return IVFFlatIndex(cent, vecs, ids, mask)


def probe_candidates(index: IVFFlatIndex, queries: jnp.ndarray, *,
                     nprobe: int):
    """Select the ``nprobe`` nearest lists per query and gather their
    members as a per-query candidate set: (cand_vecs (Q, nprobe·cap, d),
    cand_ids (Q, nprobe·cap) with −1 marking padding slots)."""
    cscore = queries @ index.centroids.T                   # (Q, n_lists)
    _, probe = lax.top_k(cscore, nprobe)                   # (Q, nprobe)
    vecs = index.vecs[probe]                               # (Q, nprobe, cap, d)
    ids = index.ids[probe]                                 # (Q, nprobe, cap)
    mask = index.mask[probe]
    qn, d = queries.shape
    cand_vecs = vecs.reshape(qn, -1, d)
    cand_ids = jnp.where(mask, ids, -1).reshape(qn, -1)
    return cand_vecs, cand_ids


@functools.partial(jax.jit, static_argnames=("k", "nprobe", "backend"))
def search_ivfflat(index: IVFFlatIndex, queries: jnp.ndarray, *, k: int,
                   nprobe: int = 8, backend: str = "jnp"):
    """queries (Q, d) -> (scores (Q, k), ids (Q, k)); inner product metric,
    probe-scoring dispatched through ``backend``."""
    cand_vecs, cand_ids = probe_candidates(index, queries, nprobe=nprobe)
    return get_backend(backend).gathered_topk(queries, cand_vecs, cand_ids,
                                              k=k)
