"""Deterministic tf-idf bag-of-words embedder — the reference embedding
model for fast benchmarks (the trained transformer encoder is the primary
embedder; this one is seed-free, instant, and exhibits the same retrieval
geometry, so Table I/II benchmarks stay cheap and reproducible)."""
from __future__ import annotations

import numpy as np


def tfidf_vectors(tokens: np.ndarray, vocab_size: int,
                  df: np.ndarray | None = None):
    """tokens (N, L) -> L2-normalised tf-idf vectors (N, vocab_size)."""
    n = tokens.shape[0]
    m = np.zeros((n, vocab_size), np.float32)
    np.add.at(m, (np.repeat(np.arange(n), tokens.shape[1]), tokens.ravel()),
              1.0)
    if df is None:
        df = (m > 0).sum(0) + 1
    m *= np.log(max(n, 2) / df)[None]
    m /= np.linalg.norm(m, axis=1, keepdims=True) + 1e-9
    return m, df
