"""End-to-end semantic-search experiment (paper §III-B, Tables I & II).

Pipeline per sample type (full corpus / uniform random / WindTunnel):
  1. restrict the corpus to the sampled entities,
  2. index their embeddings with any registered retrieval engine
     (repro.eval.engines: exact / ivfflat / lsh / tfidf; the default
     ivfflat is the paper's pgvector index),
  3. run the sample's associated queries through ANN top-k,
  4. report precision@3 against the QRels and the query density rho_q.

For (sampler x engine x k x metric) grids with trie-shared stages and the
sample-fidelity report, use repro.eval.runner.run_grid instead.

The embedding model is trained once on (query, passage) pairs — sampling
methods are compared on the SAME embedding geometry, as in the paper.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (WindTunnelConfig, run_windtunnel, QRelTable,
                        query_density, reconstruct, uniform_sample)
from repro.data.batching import TokenBatcher
from repro.data.synthetic import SyntheticCorpus
from repro.retrieval.encoder import (EncoderConfig, contrastive_loss,
                                     embed_corpus, init_encoder)
from repro.retrieval.metrics import precision_at_k, qrel_set
from repro.retrieval.search_core import SearchConfig, SearchSession
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update


def train_encoder(corpus: SyntheticCorpus, cfg: EncoderConfig, *,
                  steps: int = 300, batch_size: int = 64, lr: float = 1e-3,
                  seed: int = 0, log_every: int = 100):
    params = init_encoder(jax.random.PRNGKey(seed), cfg)
    opt_cfg = AdamWConfig(lr=lr, warmup_steps=20, total_steps=steps,
                          weight_decay=0.01)
    state = adamw_init(params)
    batcher = TokenBatcher(corpus, batch_size, seed=seed)

    @jax.jit
    def step_fn(params, state, batch):
        loss, grads = jax.value_and_grad(contrastive_loss)(params, batch, cfg)
        params, state, info = adamw_update(grads, state, params, opt_cfg)
        return params, state, loss

    losses = []
    for step in range(steps):
        batch = {k: jnp.asarray(v) for k, v in
                 batcher.contrastive_batch(step).items()
                 if k in ("query_tokens", "passage_tokens")}
        params, state, loss = step_fn(params, state, batch)
        losses.append(float(loss))
        if log_every and step % log_every == 0:
            print(f"  encoder step {step}: loss {float(loss):.4f}")
    return params, losses


@dataclasses.dataclass
class SearchResult:
    name: str
    p_at_3: float
    rho_q: float
    n_entities: int
    n_queries: int


def evaluate_sample(name: str, corpus: SyntheticCorpus,
                    entity_vecs: np.ndarray, query_vecs: np.ndarray,
                    entity_mask: Optional[np.ndarray], *,
                    k: int = 3, n_lists: int = 64, nprobe: int = 8,
                    max_queries: int = 2048, seed: int = 0,
                    engine: str = "ivfflat",
                    query_chunk: int = 256,
                    search: Optional[SearchConfig] = None) -> SearchResult:
    """entity_mask None -> full corpus; ``engine`` names any registered
    retrieval engine (n_lists/nprobe apply to ivfflat only).  ``search``
    carries backend/shard options into the search core; its engine field is
    overridden by ``engine``."""
    n_ent = corpus.num_entities
    mask = (np.ones(n_ent, bool) if entity_mask is None
            else np.asarray(entity_mask))
    kept_ids = np.nonzero(mask)[0]

    # queries associated with the sample (>=1 relevant kept entity)
    q = np.asarray(corpus.qrels.query_ids)
    e = np.asarray(corpus.qrels.entity_ids)
    v = np.asarray(corpus.qrels.valid)
    assoc = np.zeros(corpus.num_queries, bool)
    assoc_rows = v & mask[np.clip(e, 0, n_ent - 1)]
    assoc[q[assoc_rows]] = True
    qids = np.nonzero(assoc)[0]
    rng = np.random.default_rng(seed)
    if qids.size > max_queries:
        qids = rng.choice(qids, max_queries, replace=False)

    opts = dict((search.engine_opts or {}) if search else {})
    if engine == "ivfflat":  # honour the legacy tuning knobs
        opts.update(n_lists=n_lists, nprobe=nprobe)
    cfg = dataclasses.replace(search or SearchConfig(), engine=engine,
                              query_chunk=query_chunk,
                              engine_opts=opts or None)
    session = SearchSession(entity_vecs[kept_ids], cfg,
                            key=jax.random.PRNGKey(seed), ids_map=kept_ids)
    global_ids = session.search(query_vecs[qids], k=k)

    pairs = qrel_set(q, e, v)
    p3 = precision_at_k(global_ids, qids, pairs, k=k)

    qm = jnp.asarray(assoc)
    rho = float(query_density(
        QRelTable(*(jnp.asarray(x) for x in corpus.qrels)),
        jnp.asarray(mask), qm, num_queries=corpus.num_queries,
        num_entities=n_ent))
    return SearchResult(name, p3, rho, int(kept_ids.size), int(qids.size))


def run_table1_experiment(corpus: SyntheticCorpus, *,
                          encoder_cfg: Optional[EncoderConfig] = None,
                          encoder_steps: int = 300,
                          wt_config: Optional[WindTunnelConfig] = None,
                          sample_size: Optional[int] = None,
                          seed: int = 0,
                          verbose: bool = True) -> Dict[str, SearchResult]:
    """Reproduces Tables I & II: full vs uniform vs WindTunnel."""
    enc_cfg = encoder_cfg or EncoderConfig(vocab_size=corpus.vocab_size)
    if verbose:
        print("training embedding model...")
    params, _ = train_encoder(corpus, enc_cfg, steps=encoder_steps,
                              seed=seed, log_every=100 if verbose else 0)
    if verbose:
        print("embedding corpus + queries...")
    entity_vecs = embed_corpus(params, corpus.passage_tokens, enc_cfg)
    query_vecs = embed_corpus(params, corpus.query_tokens, enc_cfg)

    # --- WindTunnel sample ---
    # The paper's Table I fixes the sample size (100K passages); we default
    # to 15% of the JUDGED corpus via the calibrated |L|/N rule. Both
    # samples draw from the qrel'd (primary) entities — the corpus is
    # 'significantly larger than the set of (query, result) pairs' (§I) and
    # only the full-corpus row keeps the unjudged auxiliary entities.
    if sample_size is None:
        sample_size = int(0.15 * corpus.num_primary)
    wt_cfg = wt_config or WindTunnelConfig(
        tau_quantile=0.5, fanout=16, lp_rounds=5,
        target_size=sample_size, seed=seed)
    qrels = QRelTable(*(jnp.asarray(x) for x in corpus.qrels))
    wt = jax.jit(lambda qr: run_windtunnel(
        qr, num_queries=corpus.num_queries,
        num_entities=corpus.num_entities, config=wt_cfg))(qrels)
    wt_mask = np.asarray(wt.sample.entity_mask)
    wt_size = int(wt_mask.sum())

    # --- uniform sample of the judged entities, same size ---
    rate = wt_size / corpus.num_primary
    rng = np.random.default_rng(seed + 7)
    uni_mask = np.zeros(corpus.num_entities, bool)
    uni_mask[:corpus.num_primary] = rng.random(corpus.num_primary) < rate

    results = {}
    for name, mask in [("full", None), ("uniform", uni_mask),
                       ("windtunnel", wt_mask)]:
        results[name] = evaluate_sample(
            name, corpus, entity_vecs, query_vecs, mask, seed=seed)
        if verbose:
            r = results[name]
            print(f"  {name:12s} p@3={r.p_at_3:.3f} rho_q={r.rho_q:.3f} "
                  f"entities={r.n_entities} queries={r.n_queries}")
    return results
