"""Embedding model of the semantic-search pipeline (paper Fig. 5).

The paper uses a fine-tuned MPNet; offline we train our own bidirectional
transformer encoder (models/transformer with causal=False) with an in-batch
InfoNCE contrastive loss on (query, passage) pairs — the standard
dense-retrieval recipe. The encoder IS the indexing cost the paper wants to
avoid re-running on the full corpus, so it is first-class and sharded.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import (TransformerConfig, encode,
                                      init_transformer)


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    vocab_size: int = 4096
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    d_ff: int = 512
    dtype: Any = jnp.float32

    def transformer(self) -> TransformerConfig:
        return TransformerConfig(
            vocab_size=self.vocab_size, d_model=self.d_model,
            n_layers=self.n_layers, n_heads=self.n_heads,
            n_kv_heads=self.n_heads, d_ff=self.d_ff, causal=False,
            tie_embeddings=True, activation="geglu", dtype=self.dtype)


def init_encoder(key, cfg: EncoderConfig):
    return init_transformer(key, cfg.transformer())


def embed_tokens(params, tokens, cfg: EncoderConfig):
    """tokens (B, S) -> L2-normalised embeddings (B, D)."""
    return encode(params, tokens, cfg.transformer())


def contrastive_loss(params, batch, cfg: EncoderConfig,
                     temperature: float = 0.05):
    """InfoNCE with in-batch negatives + optional mined same-community hard
    negatives (``negative_tokens``) — the margin Table I actually measures
    is relevant-vs-community-distractor, which in-batch (cross-community)
    negatives alone never train."""
    q = embed_tokens(params, batch["query_tokens"], cfg)     # (B, D)
    p = embed_tokens(params, batch["passage_tokens"], cfg)   # (B, D)
    logits = (q @ p.T) / temperature                          # (B, B)
    if "negative_tokens" in batch:
        n = embed_tokens(params, batch["negative_tokens"], cfg)
        hard = jnp.sum(q * n, axis=-1, keepdims=True) / temperature
        logits_q = jnp.concatenate([logits, hard], axis=1)    # (B, B+1)
    else:
        logits_q = logits
    labels = jnp.arange(q.shape[0])
    logq = jax.nn.log_softmax(logits_q, axis=-1)
    logp = jax.nn.log_softmax(logits, axis=0)
    nll = -(jnp.take_along_axis(logq, labels[:, None], 1).mean()
            + jnp.take_along_axis(logp, labels[None, :].T, 1).mean()) / 2
    return nll


def embed_corpus(params, tokens: np.ndarray, cfg: EncoderConfig,
                 batch_size: int = 256) -> np.ndarray:
    """Host-side batched embedding of a full corpus (the offline indexing
    stage of Fig. 5)."""
    fn = jax.jit(lambda t: embed_tokens(params, t, cfg))
    out = []
    n = tokens.shape[0]
    for i in range(0, n, batch_size):
        blk = tokens[i:i + batch_size]
        if blk.shape[0] < batch_size:  # pad to avoid recompilation
            pad = batch_size - blk.shape[0]
            blk = np.concatenate([blk, np.zeros((pad,) + blk.shape[1:],
                                                blk.dtype)])
            out.append(np.asarray(fn(jnp.asarray(blk)))[:-pad])
        else:
            out.append(np.asarray(fn(jnp.asarray(blk))))
    return np.concatenate(out, axis=0)
