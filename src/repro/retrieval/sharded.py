"""Sharded search — Layer 2 of the search core (DESIGN.md §9, §13).

Two generations of sharding live here:

**Sharded-from-birth (preferred).**  :func:`sharded_build` constructs the
index *per shard* from a :class:`~repro.distributed.sharded_corpus.
ShardedCorpus` whose rows were streamed straight into per-device buffers —
nothing proportional to the global corpus is ever resident on one device.
Shard-local exact/tfidf rows, shard-local LSH codes, shard-local int8
quantization (per-shard scales + float rerank), and IVF lists refined from
shard-local partial sums converged by a per-iteration all-reduce instead
of a global k-means.  The born index types (``Sharded*Index``) route
:func:`sharded_search` to shard-local query plans automatically.  On a
1-device mesh every born build/search is operation-for-operation the
single-device program (bit-consistent); on larger meshes results are
set-equal under the backend tie policy.

**Build-globally-then-partition (deprecated).**  The original layer built
the index once on a single device and only sharded the *scoring*: each
shard runs the engine's backend over its slice of the replicated index,
and partials merge with one tiled all-gather + ``lax.top_k``.  This path
is capped by single-device memory — exactly what the birth path removes —
and is kept only for pre-built ``engine.build`` indexes; new callers
should construct a ``ShardedCorpus`` (or ``SearchConfig(streamed=True)``)
instead.

Partition plans per engine (both generations share the merge):

  * ``exact`` / ``tfidf`` — corpus rows over the mesh; per-shard dense
    top-k via ``backend.topk``; global ids recovered from the shard's row
    offset.  Born tfidf reduces the document-frequency vector with an
    integer ``psum`` (bit-identical IDF weights on any mesh).
  * ``lsh``   — packed codes row-sharded; per-shard Hamming top-rerank via
    ``backend.hamming_topk``.  Born rerank never replicates the vectors:
    each shard scores the merged candidates it owns in f32 and the partial
    score rows merge with ``lax.pmax``.
  * ``ivfflat`` — centroids replicate, so every shard selects the SAME
    global top-``nprobe`` probe set.  Born lists are partitioned by row
    *origin* shard — each shard keeps a (n_lists, cap_local) ELL of its
    own rows per global list — so the union of per-shard candidates is the
    global probe membership.
  * ``int8`` (born only) — per-shard quantized scan over shard-local
    codes/scales (ranking is scale-invariant within a shard), candidate
    ids all-gathered, then the float rerank runs distributed as in lsh.
    The deprecated global-partition path still rejects int8: its −1e30
    padding sentinel would destroy the single global quantization scale.

Padding invariants: rows/lists pad to a multiple of the shard count; padded
rows mask to −inf/−1 before the merge and can never displace a real
candidate.  Born pads are born masked: LSH pad rows carry W+1 all-ones
extra code words, IVF pad rows assign to a dummy list that is never
probed, int8 widens the local candidate pool by the global pad count so a
zero-code pad row can never push a real candidate out of the pool.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.distributed import collectives as coll
from repro.distributed.compression import quantize_int8
from repro.distributed.sharded_corpus import ShardedCorpus
from repro.distributed.sharding import RETRIEVAL_RULES, partition_axes
from repro.kernels.topk_scoring import ops as topk_ops
from repro.kernels.topk_scoring.ref import pad_topk as _pad_topk
from repro.retrieval.backends import get_backend, rerank_candidates
from repro.retrieval.lsh import encode


def _resolve_axes(mesh: Mesh, axes: Optional[tuple]) -> tuple:
    if axes is None:
        axes = partition_axes(mesh, "corpus", RETRIEVAL_RULES)
    axes = tuple(axes) if axes else ()
    if not axes:
        raise ValueError(
            f"mesh {mesh} has none of the retrieval corpus axes "
            f"({RETRIEVAL_RULES['corpus']})")
    return axes


def _axis_count(mesh: Mesh, axes: tuple) -> int:
    d = 1
    for a in axes:
        d *= mesh.shape[a]
    return d


def _row_spec(axes: tuple, ndim: int) -> P:
    lead = axes if len(axes) > 1 else axes[0]
    return P(lead, *([None] * (ndim - 1)))


def _merge(s: jnp.ndarray, i: jnp.ndarray, axes: tuple, k: int):
    """All-gather per-shard (scores, ids) partials along the k axis and
    reduce to the global top-k (replicated on every shard)."""
    s = lax.all_gather(s, axes, axis=1, tiled=True)
    i = lax.all_gather(i, axes, axis=1, tiled=True)
    top_s, pos = lax.top_k(s, min(k, s.shape[1]))
    return top_s, jnp.take_along_axis(i, pos, axis=1)


def _rowwise_topk(backend, vecs: jnp.ndarray, queries: jnp.ndarray, *,
                  k: int, mesh: Mesh, axes: tuple):
    """Row-sharded dense top-k: the shared plan for exact and tfidf.

    .. deprecated:: part of the build-globally-then-partition path — the
       full index is resident on every device before the scan.  Prefer a
       sharded-from-birth build (:func:`sharded_build`)."""
    n, dim = vecs.shape
    d = _axis_count(mesh, axes)
    rows = -(-n // d)
    k_l = min(k, rows)
    pad = rows * d - n
    if pad:
        # sentinel coordinate (the kernels/topk_scoring/ops.py trick):
        # queries get 1.0, real rows 0.0, padded rows -BIG, so a padded row
        # scores -BIG and can never displace a real candidate from the
        # LOCAL top-k (a zero-padded row would score 0 and beat genuinely
        # negative candidates before the post-hoc validity mask)
        queries = jnp.pad(queries, ((0, 0), (0, 1)), constant_values=1.0)
        vp = jnp.pad(vecs, ((0, pad), (0, 1)))
        vp = vp.at[n:, dim].set(-1e30)
    else:
        vp = vecs

    def shard_fn(v_l, q):
        row0 = coll.flat_axis_index(axes) * rows
        s, i = backend.topk(q, v_l, k=k_l)
        gid = row0 + i
        ok = (i >= 0) & (gid < n)
        return _merge(jnp.where(ok, s, -jnp.inf),
                      jnp.where(ok, gid, -1), axes, k)

    fn = shard_map(shard_fn, mesh=mesh,
                   in_specs=(_row_spec(axes, 2), P(None, None)),
                   out_specs=(P(), P()), check_rep=False)
    return _pad_topk(*fn(vp, queries), k)


def _sharded_exact(engine, index, queries, *, k, mesh, axes):
    return _rowwise_topk(get_backend(engine.backend), index, queries,
                         k=k, mesh=mesh, axes=axes)


def _sharded_tfidf(engine, index, queries, *, k, mesh, axes):
    # IDF weights were folded into index.vecs at (global) build time, so the
    # sharded scan is the exact engine's plan over the weighted rows.
    return _rowwise_topk(get_backend(engine.backend), index.vecs, queries,
                         k=k, mesh=mesh, axes=axes)


def _sharded_lsh(engine, index, queries, *, k, mesh, axes):
    backend = get_backend(engine.backend)
    n = index.codes.shape[0]
    d = _axis_count(mesh, axes)
    rows = -(-n // d)
    rerank = min(max(engine.rerank, k), n) if engine.rerank > 0 else 0
    target = rerank if rerank > 0 else k
    t_l = min(target, rows)
    qc = encode(index.proj, queries)
    pad = rows * d - n
    if pad:
        # a zero-padded code row would get a REAL Hamming distance and
        # could evict a true candidate from the local top-k, so padded rows
        # get W+1 extra all-ones words (queries and real rows get zeros):
        # their distance grows by 32·(W+1) > 32·W ≥ any real distance,
        # strictly below every real row — exact integer arithmetic, and
        # real-row distances are untouched
        w = index.codes.shape[1]
        cp = jnp.pad(index.codes, ((0, pad), (0, w + 1)))
        cp = cp.at[n:, w:].set(-1)
        qc = jnp.pad(qc, ((0, 0), (0, w + 1)))
    else:
        cp = index.codes

    def shard_fn(c_l, qc_):
        row0 = coll.flat_axis_index(axes) * rows
        s, i = backend.hamming_topk(qc_, c_l, k=t_l)
        gid = row0 + i
        ok = (i >= 0) & (gid < n)
        return _merge(jnp.where(ok, s, -jnp.inf),
                      jnp.where(ok, gid, -1), axes, target)

    fn = shard_map(shard_fn, mesh=mesh,
                   in_specs=(_row_spec(axes, 2), P(None, None)),
                   out_specs=(P(), P()), check_rep=False)
    neg, cand = fn(cp, qc)
    if rerank <= 0:
        # match search_lsh's historical no-rerank API: positive Hamming
        # distance, lower = better (+inf for misses)
        neg, cand = _pad_topk(neg, cand, k)
        return (-neg).astype(queries.dtype), cand
    # exact rerank of the merged global candidates — identical math to the
    # single-device search_lsh rerank step, on the replicated vectors
    return rerank_candidates(index.vecs, queries, cand, k=k)


def _sharded_ivfflat(engine, index, queries, *, k, mesh, axes):
    backend = get_backend(engine.backend)
    n_lists, cap, dim = index.vecs.shape
    nprobe = min(engine.nprobe, n_lists)
    d = _axis_count(mesh, axes)
    ll = -(-n_lists // d)
    pad = ll * d - n_lists
    vecs = jnp.pad(index.vecs, ((0, pad), (0, 0), (0, 0)))
    ids = jnp.pad(index.ids, ((0, pad), (0, 0)), constant_values=-1)
    mask = jnp.pad(index.mask, ((0, pad), (0, 0)))
    k_l = min(k, nprobe * cap)

    def shard_fn(v_l, i_l, m_l, cent, q):
        l0 = coll.flat_axis_index(axes) * ll
        cscore = q @ cent.T                          # (Q, n_lists) global
        _, probe = lax.top_k(cscore, nprobe)         # same probes everywhere
        own = (probe >= l0) & (probe < l0 + ll)
        lp = jnp.clip(probe - l0, 0, ll - 1)
        v = v_l[lp]                                  # (Q, nprobe, cap, dim)
        cid = jnp.where(m_l[lp] & own[..., None], i_l[lp], -1)
        qn = q.shape[0]
        s, gid = backend.gathered_topk(q, v.reshape(qn, -1, dim),
                                       cid.reshape(qn, -1), k=k_l)
        return _merge(s, gid, axes, k)

    fn = shard_map(shard_fn, mesh=mesh,
                   in_specs=(_row_spec(axes, 3), _row_spec(axes, 2),
                             _row_spec(axes, 2), P(None, None),
                             P(None, None)),
                   out_specs=(P(), P()), check_rep=False)
    return _pad_topk(*fn(vecs, ids, mask, index.centroids, queries), k)


_SHARDED_IMPLS: Dict[str, Callable] = {
    "exact": _sharded_exact,
    "tfidf": _sharded_tfidf,
    "lsh": _sharded_lsh,
    "ivfflat": _sharded_ivfflat,
}


# ---------------------------------------------------------------------------
# Sharded-from-birth: per-shard index construction + shard-local search.
# The index never exists globally — every field below is a row-sharded
# jax.Array whose shards were built on the device that owns them.
# ---------------------------------------------------------------------------


class ShardedFlatIndex(NamedTuple):
    """Born-sharded dense rows (exact engine).  ``aug`` marks the padding
    sentinel column (present only when the corpus needed tail padding, so a
    1-device build stays bit-identical to the global build)."""

    vecs: Any        # f32[rows·d, D(+1)] row-sharded
    n: int
    aug: bool


class ShardedTfIdfIndex(NamedTuple):
    """Born-sharded IDF-weighted rows; ``weights`` replicate (they are an
    O(D) statistic reduced with an integer psum — bit-identical on any
    mesh)."""

    vecs: Any        # f32[rows·d, D(+1)] row-sharded, IDF-weighted
    weights: Any     # f32[D] replicated
    n: int
    aug: bool


class ShardedQuantIndex(NamedTuple):
    """Born-sharded int8 corpus: per-shard codes with shard-local scales
    (PR 5's rejection lifted — ranking within a shard is scale-invariant,
    and cross-shard merging happens after the float rerank, so no global
    scale is ever needed).  ``vecs`` keeps the float rows (IDF-weighted for
    tfidf) sharded for the distributed rerank tail."""

    codes: Any       # i8[rows·d, D] row-sharded
    scales: Any      # f32[d] one max-abs scale per shard
    vecs: Any        # f32[rows·d, D] row-sharded
    n: int


class ShardedLSHIndex(NamedTuple):
    """Born-sharded LSH: codes encoded shard-locally from the replicated
    projection; ``aug`` marks the W+1 all-ones pad-sentinel words."""

    proj: Any        # f32[D, n_bits] replicated
    codes: Any       # i32[rows·d, W(+W+1)] row-sharded
    vecs: Any        # f32[rows·d, D] row-sharded (rerank)
    n: int
    aug: bool


class ShardedIVFIndex(NamedTuple):
    """Born-sharded IVF: lists partitioned by row ORIGIN shard — each shard
    holds a (n_lists, cap_local) ELL of its own rows per global list, so
    no row ever moves between shards at build time.  Centroids replicate
    (they are refined from shard-local partial sums converged by a
    per-iteration all-reduce), so all shards compute identical probe
    sets and the union of per-shard candidates is the global probe
    membership."""

    centroids: Any   # f32[n_lists, D] replicated
    vecs: Any        # f32[d·n_lists, cap_local, D] row-sharded by origin
    ids: Any         # i32[d·n_lists, cap_local] global ids, −1 pad
    mask: Any        # bool[d·n_lists, cap_local]
    n: int


_BORN_INDEX_TYPES = (ShardedFlatIndex, ShardedTfIdfIndex, ShardedQuantIndex,
                     ShardedLSHIndex, ShardedIVFIndex)


def _shard_geometry(corpus: ShardedCorpus):
    axes = corpus.axes
    d = corpus.num_shards
    rows = corpus.rows_per_shard
    return axes, d, rows, rows * d - corpus.n


def _local_valid(row0, rows: int, n: int):
    return (row0 + jnp.arange(rows, dtype=jnp.int32)) < n


def _augment_rows(corpus: ShardedCorpus, row_vecs):
    """Append the −1e30/0.0 pad-sentinel column shard-locally (only when
    the corpus has pad rows — a pad-free build adds nothing, preserving
    1-device bit parity with the global build)."""
    axes, d, rows, pad = _shard_geometry(corpus)
    if not pad:
        return row_vecs, False
    n = corpus.n

    def f(v_l):
        row0 = coll.flat_axis_index(axes) * rows
        sent = jnp.where(_local_valid(row0, rows, n), 0.0,
                         -1e30).astype(v_l.dtype)
        return jnp.concatenate([v_l, sent[:, None]], axis=1)

    fn = shard_map(f, mesh=corpus.mesh, in_specs=(_row_spec(axes, 2),),
                   out_specs=_row_spec(axes, 2), check_rep=False)
    return fn(row_vecs), True


def _quant_build(corpus: ShardedCorpus, row_vecs) -> ShardedQuantIndex:
    """Per-shard int8 quantization: each shard derives its own max-abs
    scale from its local rows only (zero pad rows cannot perturb it)."""
    axes = corpus.axes

    def f(v_l):
        codes, scale = quantize_int8(v_l)
        return codes, scale[None]

    fn = shard_map(f, mesh=corpus.mesh, in_specs=(_row_spec(axes, 2),),
                   out_specs=(_row_spec(axes, 2), P(_lead_axes(axes))),
                   check_rep=False)
    codes, scales = fn(row_vecs)
    return ShardedQuantIndex(codes, scales, row_vecs, corpus.n)


def _lead_axes(axes: tuple):
    return axes if len(axes) > 1 else axes[0]


def _build_born_exact(engine, corpus: ShardedCorpus, key):
    del key  # deterministic
    if engine.backend == "int8":
        return _quant_build(corpus, corpus.vecs)
    vecs, aug = _augment_rows(corpus, corpus.vecs)
    return ShardedFlatIndex(vecs, corpus.n, aug)


def _build_born_tfidf(engine, corpus: ShardedCorpus, key):
    del key  # deterministic
    axes = corpus.axes
    n = corpus.n

    def f(v_l):
        # integer document frequencies psum exactly -> IDF weights are
        # bit-identical to the global build on any mesh (pad rows are
        # all-zero, so (v > 0) contributes nothing)
        df = lax.psum(jnp.sum(v_l > 0, axis=0), axes).astype(
            jnp.float32) + 1.0
        w = jnp.log1p(n / df)
        return v_l * w[None, :], w

    fn = shard_map(f, mesh=corpus.mesh, in_specs=(_row_spec(axes, 2),),
                   out_specs=(_row_spec(axes, 2), P(None)), check_rep=False)
    weighted, w = fn(corpus.vecs)
    if engine.backend == "int8":
        quant = _quant_build(corpus, weighted)
        return ShardedTfIdfIndex(quant, w, corpus.n, False)
    weighted, aug = _augment_rows(corpus, weighted)
    return ShardedTfIdfIndex(weighted, w, corpus.n, aug)


def _build_born_lsh(engine, corpus: ShardedCorpus, key):
    axes, d, rows, pad = _shard_geometry(corpus)
    n = corpus.n
    proj = jax.random.normal(key, (corpus.dim, engine.n_bits),
                             corpus.vecs.dtype)

    def f(v_l, proj_):
        row0 = coll.flat_axis_index(axes) * rows
        codes = encode(proj_, v_l)
        if pad:
            # the legacy path's pad sentinel, applied at birth: pad rows
            # get W+1 extra all-ones words (real rows and queries zeros),
            # growing their Hamming distance past any real row's
            w = codes.shape[1]
            extra = jnp.where(_local_valid(row0, rows, n)[:, None],
                              jnp.int32(0), jnp.int32(-1))
            codes = jnp.concatenate(
                [codes, jnp.broadcast_to(extra, (rows, w + 1))], axis=1)
        return codes

    fn = shard_map(f, mesh=corpus.mesh,
                   in_specs=(_row_spec(axes, 2), P(None, None)),
                   out_specs=_row_spec(axes, 2), check_rep=False)
    return ShardedLSHIndex(proj, fn(corpus.vecs, proj), corpus.vecs,
                           n, bool(pad))


def _build_born_ivfflat(engine, corpus: ShardedCorpus, key,
                        kmeans_iters: int = 10):
    """IVF build with shard-local centroid refinement: Lloyd iterations
    compute per-shard (sum, count) partials over local rows and converge
    them with one ``psum`` all-reduce per iteration — no device ever sees
    another shard's rows.  List fill is shard-local too: each shard packs
    its own rows into a (n_lists, cap_local) ELL keyed by the replicated
    centroids."""
    axes, d, rows, pad = _shard_geometry(corpus)
    n, dim = corpus.n, corpus.dim
    n_lists = min(engine.n_lists, max(1, n // 8))
    cap_l = int(engine.cap_factor * rows / n_lists) + 1
    # same init selection as ivfflat.kmeans (replicated): global row ids
    init_idx = jax.random.choice(key, n, (n_lists,), replace=False)

    def f(v_l, init_g):
        row0 = coll.flat_axis_index(axes) * rows
        valid = _local_valid(row0, rows, n)

        # replicated init centroids: each shard contributes the init rows
        # it owns; the psum assembles the same gather kmeans() does
        lidx = init_g - row0
        own = (lidx >= 0) & (lidx < rows)
        cand = v_l[jnp.clip(lidx, 0, rows - 1)]
        cent0 = lax.psum(jnp.where(own[:, None], cand, 0.0), axes)

        def assign_of(cent):
            d2 = (jnp.sum(v_l ** 2, 1)[:, None] - 2.0 * v_l @ cent.T
                  + jnp.sum(cent ** 2, 1)[None])
            return jnp.argmin(d2, axis=1)

        # pad rows route to a dummy segment so they never pull a centroid;
        # the dummy is only materialised when pads exist (1-device parity)
        nseg = n_lists + 1 if pad else n_lists
        seg = ((lambda a: jnp.where(valid, a, n_lists)) if pad
               else (lambda a: a))

        def step(cent, _):
            a = seg(assign_of(cent))
            sums = jax.ops.segment_sum(v_l, a,
                                       num_segments=nseg)[:n_lists]
            cnts = jax.ops.segment_sum(jnp.ones((rows, 1), v_l.dtype), a,
                                       num_segments=nseg)[:n_lists]
            sums, cnts = lax.psum((sums, cnts), axes)
            new = jnp.where(cnts > 0, sums / jnp.maximum(cnts, 1.0), cent)
            return new, None

        cent, _ = lax.scan(step, cent0, None, length=kmeans_iters)

        # shard-local ELL list fill (build_ivfflat's fill over local rows)
        a = seg(assign_of(cent))
        order = jnp.argsort(a, stable=True)
        sa = a[order]
        starts = jnp.concatenate([jnp.ones((1,), bool),
                                  sa[1:] != sa[:-1]])
        iota = jnp.arange(rows, dtype=jnp.int32)
        gstart = lax.associative_scan(jnp.maximum,
                                      jnp.where(starts, iota, 0))
        rank = iota - gstart
        ok = rank < cap_l
        row = jnp.where(ok, sa, n_lists)
        col = jnp.where(ok, rank, 0)
        lvecs = jnp.zeros((n_lists, cap_l, dim), v_l.dtype).at[
            row, col].set(v_l[order], mode="drop")
        lids = jnp.full((n_lists, cap_l), -1, jnp.int32).at[row, col].set(
            (row0 + order).astype(jnp.int32), mode="drop")
        lmask = jnp.zeros((n_lists, cap_l), bool).at[row, col].set(
            jnp.ones((rows,), bool), mode="drop")
        return cent, lvecs, lids, lmask

    fn = shard_map(f, mesh=corpus.mesh,
                   in_specs=(_row_spec(axes, 2), P(None)),
                   out_specs=(P(None, None), _row_spec(axes, 3),
                              _row_spec(axes, 2), _row_spec(axes, 2)),
                   check_rep=False)
    cent, lvecs, lids, lmask = fn(corpus.vecs, init_idx)
    return ShardedIVFIndex(cent, lvecs, lids, lmask, n)


_BORN_BUILDS: Dict[str, Callable] = {
    "exact": _build_born_exact,
    "tfidf": _build_born_tfidf,
    "lsh": _build_born_lsh,
    "ivfflat": _build_born_ivfflat,
}


def sharded_build(engine, corpus: ShardedCorpus, key=None):
    """Per-shard index construction over a sharded-from-birth corpus.

    Returns a born index (``Sharded*Index``) whose corpus-proportional
    fields are row-sharded jax.Arrays; :func:`sharded_search` routes them
    to the shard-local query plans.  On a 1-device mesh the built index
    is bit-identical to ``engine.build`` on the gathered rows."""
    try:
        impl = _BORN_BUILDS[engine.name]
    except KeyError:
        raise ValueError(
            f"no shard-local build plan for engine {engine.name!r}; "
            f"engines with plans: {', '.join(sorted(_BORN_BUILDS))}"
        ) from None
    if key is None:
        key = jax.random.PRNGKey(0)
    return impl(engine, corpus, key)


def _distributed_rerank(v_l, q, cand, row0, rows: int, k: int, axes):
    """Float rerank of replicated candidate ids against row-sharded
    vectors: each shard scores the candidates it owns (−inf elsewhere) and
    the partial score rows merge with ``pmax`` — every real candidate is
    owned by exactly one shard, so the merged row equals
    ``rerank_candidates`` on the gathered vectors, bit for bit on one
    device and value-equal on any mesh."""
    lid = cand - row0
    own = (cand >= 0) & (lid >= 0) & (lid < rows)
    cv = v_l[jnp.clip(lid, 0, rows - 1)]
    s = jnp.einsum("qd,qrd->qr", q, cv)
    s = jnp.where(own, s, -jnp.inf)
    s = lax.pmax(s, axes)
    s = jnp.where(cand >= 0, s, -jnp.inf)
    top_s, pos = lax.top_k(s, min(k, cand.shape[1]))
    top_i = jnp.take_along_axis(cand, pos, axis=1)
    top_i = jnp.where(jnp.isfinite(top_s), top_i, -1)
    return _pad_topk(top_s, top_i, k)


def _search_born_rows(backend, index_vecs, n: int, aug: bool, queries, *,
                      k: int, mesh, axes):
    """Shard-local dense scan over born rows (exact / tfidf): the sentinel
    column was appended at build time, so this is ``_rowwise_topk`` minus
    the global pad step."""
    d = _axis_count(mesh, axes)
    rows = index_vecs.shape[0] // d
    k_l = min(k, rows)
    if aug:
        queries = jnp.pad(queries, ((0, 0), (0, 1)), constant_values=1.0)

    def f(v_l, q):
        row0 = coll.flat_axis_index(axes) * rows
        s, i = backend.topk(q, v_l, k=k_l)
        gid = row0 + i
        ok = (i >= 0) & (gid < n)
        return _merge(jnp.where(ok, s, -jnp.inf),
                      jnp.where(ok, gid, -1), axes, k)

    fn = shard_map(f, mesh=mesh,
                   in_specs=(_row_spec(axes, 2), P(None, None)),
                   out_specs=(P(), P()), check_rep=False)
    return _pad_topk(*fn(index_vecs, queries), k)


def _search_born_quant(backend, index: ShardedQuantIndex, queries, *,
                       k: int, mesh, axes):
    """Born int8 plan: per-shard quantized scan (shard-local codes — the
    integer ranking is invariant to the shard's own scale), candidate ids
    all-gathered, float rerank distributed over the sharded rows.  The
    local pool widens by the global pad count so zero-code pad rows can
    never displace a real candidate (they score 0, which beats genuinely
    negative rows before the validity mask)."""
    d = _axis_count(mesh, axes)
    rows = index.codes.shape[0] // d
    n = index.n
    pad = rows * d - n
    pool = min(max(backend.rerank_factor * k, k), n)
    pool_l = min(pool + pad, rows)
    q_codes, _ = quantize_int8(jnp.asarray(queries, jnp.float32))

    def f(c_l, v_l, qc, q):
        row0 = coll.flat_axis_index(axes) * rows
        _, i = topk_ops.topk_scores_int8(qc, c_l, k=pool_l,
                                         block_q=backend.block_q,
                                         block_n=backend.block_n)
        gid = jnp.where((i >= 0) & (row0 + i < n), row0 + i, -1)
        cand = lax.all_gather(gid, axes, axis=1, tiled=True)
        return _distributed_rerank(v_l, q, cand, row0, rows, k, axes)

    fn = shard_map(f, mesh=mesh,
                   in_specs=(_row_spec(axes, 2), _row_spec(axes, 2),
                             P(None, None), P(None, None)),
                   out_specs=(P(), P()), check_rep=False)
    return fn(index.codes, index.vecs, q_codes, queries)


def _search_born_lsh(engine, index: ShardedLSHIndex, queries, *, k: int,
                     mesh, axes):
    backend = get_backend(engine.backend)
    n = index.n
    d = _axis_count(mesh, axes)
    rows = index.codes.shape[0] // d
    rerank = min(max(engine.rerank, k), n) if engine.rerank > 0 else 0
    target = rerank if rerank > 0 else k
    t_l = min(target, rows)
    qc = encode(index.proj, queries)
    if index.aug:
        qc = jnp.pad(qc, ((0, 0), (0, index.codes.shape[1] - qc.shape[1])))

    def f(c_l, v_l, qc_, q):
        row0 = coll.flat_axis_index(axes) * rows
        s, i = backend.hamming_topk(qc_, c_l, k=t_l)
        gid = row0 + i
        ok = (i >= 0) & (gid < n)
        neg, cand = _merge(jnp.where(ok, s, -jnp.inf),
                           jnp.where(ok, gid, -1), axes, target)
        if rerank <= 0:
            return _pad_topk(neg, cand, k)
        return _distributed_rerank(v_l, q, cand, row0, rows, k, axes)

    fn = shard_map(f, mesh=mesh,
                   in_specs=(_row_spec(axes, 2), _row_spec(axes, 2),
                             P(None, None), P(None, None)),
                   out_specs=(P(), P()), check_rep=False)
    s, ids = fn(index.codes, index.vecs, qc, queries)
    if rerank <= 0:
        # positive Hamming distance, matching search_lsh's no-rerank API
        return (-s).astype(queries.dtype), ids
    return s, ids


def _search_born_ivf(engine, index: ShardedIVFIndex, queries, *, k: int,
                     mesh, axes):
    backend = get_backend(engine.backend)
    n_lists = index.centroids.shape[0]
    cap_l, dim = index.vecs.shape[1], index.vecs.shape[2]
    nprobe = min(engine.nprobe, n_lists)
    k_l = min(k, nprobe * cap_l)

    def f(v_l, i_l, m_l, cent, q):
        cscore = q @ cent.T                      # replicated centroids:
        _, probe = lax.top_k(cscore, nprobe)     # same probes on all shards
        v = v_l[probe]                           # (Q, nprobe, cap_l, dim)
        cid = jnp.where(m_l[probe], i_l[probe], -1)
        qn = q.shape[0]
        s, gid = backend.gathered_topk(q, v.reshape(qn, -1, dim),
                                       cid.reshape(qn, -1), k=k_l)
        return _merge(s, gid, axes, k)

    fn = shard_map(f, mesh=mesh,
                   in_specs=(_row_spec(axes, 3), _row_spec(axes, 2),
                             _row_spec(axes, 2), P(None, None),
                             P(None, None)),
                   out_specs=(P(), P()), check_rep=False)
    return _pad_topk(*fn(index.vecs, index.ids, index.mask,
                         index.centroids, queries), k)


def sharded_buffer_topk(buf_vecs, n_valid, queries, *, k: int, mesh: Mesh,
                        axes: Optional[tuple] = None, id_base: int = 0):
    """Dense exact top-k over a fixed-capacity row-sharded append buffer
    (the serving tier's live-ingest structure, DESIGN.md §14).

    ``buf_vecs`` is a row-sharded f32[cap·d, D] buffer (rows at global
    position ≥ ``n_valid`` are unused capacity); ``n_valid`` is a DYNAMIC
    scalar — appends grow it without changing any traced shape, so the
    steady-state serve loop never recompiles as rows land.  Scores are
    plain f32 inner products (buffers are small; quantization is a
    bandwidth optimisation for the big frozen index, not the tail), ids
    come back offset by ``id_base`` (the frozen corpus size), and the
    per-shard partials merge through the same all-gather + ``lax.top_k``
    path every sharded engine plan uses."""
    axes = _resolve_axes(mesh, axes)
    d = _axis_count(mesh, axes)
    rows = buf_vecs.shape[0] // d
    k_l = min(k, rows)

    def f(v_l, q, nv):
        row0 = coll.flat_axis_index(axes) * rows
        gid = row0 + jnp.arange(rows, dtype=jnp.int32)
        s = (q @ v_l.T).astype(jnp.float32)
        s = jnp.where((gid < nv)[None, :], s, -jnp.inf)
        top_s, pos = lax.top_k(s, k_l)
        top_i = jnp.where(jnp.isfinite(top_s), id_base + row0 + pos, -1)
        return _merge(top_s, top_i, axes, k)

    fn = shard_map(f, mesh=mesh,
                   in_specs=(_row_spec(axes, 2), P(None, None), P()),
                   out_specs=(P(), P()), check_rep=False)
    return _pad_topk(*fn(buf_vecs, queries, jnp.int32(n_valid)), k)


def _born_search(engine, index, queries, *, k: int, mesh, axes):
    if isinstance(index, ShardedFlatIndex):
        return _search_born_rows(get_backend(engine.backend), index.vecs,
                                 index.n, index.aug, queries, k=k,
                                 mesh=mesh, axes=axes)
    if isinstance(index, ShardedTfIdfIndex):
        if isinstance(index.vecs, ShardedQuantIndex):
            return _search_born_quant(get_backend(engine.backend),
                                      index.vecs, queries, k=k, mesh=mesh,
                                      axes=axes)
        return _search_born_rows(get_backend(engine.backend), index.vecs,
                                 index.n, index.aug, queries, k=k,
                                 mesh=mesh, axes=axes)
    if isinstance(index, ShardedQuantIndex):
        return _search_born_quant(get_backend(engine.backend), index,
                                  queries, k=k, mesh=mesh, axes=axes)
    if isinstance(index, ShardedLSHIndex):
        return _search_born_lsh(engine, index, queries, k=k, mesh=mesh,
                                axes=axes)
    if isinstance(index, ShardedIVFIndex):
        return _search_born_ivf(engine, index, queries, k=k, mesh=mesh,
                                axes=axes)
    raise TypeError(f"not a born-sharded index: {type(index).__name__}")


def sharded_search(engine, index, queries: jnp.ndarray, *, k: int,
                   mesh: Mesh, axes: Optional[tuple] = None
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Mesh-partitioned ``engine.search`` with equivalent semantics:
    (scores f32[Q, k], ids i32[Q, k]) into the corpus the index was built
    from, −inf/−1 padding for misses.  Bit-consistent with single-device
    search on a 1-device mesh; set-equal under the backend tie policy on
    larger meshes.

    Born indexes from :func:`sharded_build` route to the shard-local
    plans (including int8); a pre-built global index falls through to the
    deprecated build-globally-then-partition plans below."""
    if isinstance(index, _BORN_INDEX_TYPES):
        return _born_search(engine, index, queries, k=k, mesh=mesh,
                            axes=_resolve_axes(mesh, axes))
    if getattr(engine, "backend", None) == "int8":
        # the row-shard padding sentinel (−1e30 coordinate) would destroy
        # the int8 corpus scale on THIS (deprecated, global-partition)
        # path; the born path supports int8 via per-shard scales + float
        # rerank — build with ``sharded_build`` instead (DESIGN.md §13)
        raise ValueError(
            "sharded search does not support the 'int8' backend; use "
            "backend='jnp' or 'pallas' for sharded meshes")
    try:
        impl = _SHARDED_IMPLS[engine.name]
    except KeyError:
        raise ValueError(
            f"no sharded search plan for engine {engine.name!r}; engines "
            f"with plans: {', '.join(sorted(_SHARDED_IMPLS))}") from None
    return impl(engine, index, queries, k=k, mesh=mesh,
                axes=_resolve_axes(mesh, axes))
