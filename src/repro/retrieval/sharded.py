"""Sharded search — Layer 2 of the search core (DESIGN.md §9).

The corpus side of a built index is partitioned across a device mesh with
``shard_map``: each shard runs the engine's scoring backend over its local
rows (per-shard top-k), then the per-shard partial results are merged with
one tiled all-gather + ``lax.top_k`` — the same gather/merge collectives the
sharded WindTunnel pipeline uses (distributed/collectives.py).

What is sharded is the *work the index was built to do*, never the index
construction itself: the index is built once, globally (same key, same
k-means / projection / IDF statistics as the single-device path), and the
sharded layer only distributes the scoring.  That is what makes the result
equivalent to single-device search — on a 1-device mesh every stage is
operation-for-operation the single-device program (bit-consistent), and on
larger meshes the merged candidate set is exactly the single-device
candidate set, so results are set-equal under the backend tie policy
(retrieval/backends.py: ties break toward the first candidate in layout
order — lower ids for the row-sharded scans, probe position for ivfflat;
the cross-shard merge scans shards in ascending row/list order,
preserving it).

Partition plans per engine:

  * ``exact`` / ``tfidf`` — corpus rows over the mesh; per-shard dense
    top-k via ``backend.topk``; global ids recovered from the shard's row
    offset.
  * ``lsh``   — packed codes row-sharded; per-shard Hamming top-rerank via
    ``backend.hamming_topk``; merged candidates exact-reranked on the
    replicated vectors (the rerank set is tiny — ≤ rerank ids per query).
  * ``ivfflat`` — inverted lists sharded; centroids replicate, so every
    shard selects the SAME global top-``nprobe`` probe set and scores only
    the probed lists it owns via ``backend.gathered_topk`` — the union of
    per-shard candidates is exactly the single-device probe gather.

Padding invariants: rows/lists pad to a multiple of the shard count; padded
rows mask to −inf/−1 before the merge and can never displace a real
candidate.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.distributed import collectives as coll
from repro.distributed.sharding import RETRIEVAL_RULES, partition_axes
from repro.kernels.topk_scoring.ref import pad_topk as _pad_topk
from repro.retrieval.backends import get_backend, rerank_candidates
from repro.retrieval.lsh import encode


def _resolve_axes(mesh: Mesh, axes: Optional[tuple]) -> tuple:
    if axes is None:
        axes = partition_axes(mesh, "corpus", RETRIEVAL_RULES)
    axes = tuple(axes) if axes else ()
    if not axes:
        raise ValueError(
            f"mesh {mesh} has none of the retrieval corpus axes "
            f"({RETRIEVAL_RULES['corpus']})")
    return axes


def _axis_count(mesh: Mesh, axes: tuple) -> int:
    d = 1
    for a in axes:
        d *= mesh.shape[a]
    return d


def _row_spec(axes: tuple, ndim: int) -> P:
    lead = axes if len(axes) > 1 else axes[0]
    return P(lead, *([None] * (ndim - 1)))


def _merge(s: jnp.ndarray, i: jnp.ndarray, axes: tuple, k: int):
    """All-gather per-shard (scores, ids) partials along the k axis and
    reduce to the global top-k (replicated on every shard)."""
    s = lax.all_gather(s, axes, axis=1, tiled=True)
    i = lax.all_gather(i, axes, axis=1, tiled=True)
    top_s, pos = lax.top_k(s, min(k, s.shape[1]))
    return top_s, jnp.take_along_axis(i, pos, axis=1)


def _rowwise_topk(backend, vecs: jnp.ndarray, queries: jnp.ndarray, *,
                  k: int, mesh: Mesh, axes: tuple):
    """Row-sharded dense top-k: the shared plan for exact and tfidf."""
    n, dim = vecs.shape
    d = _axis_count(mesh, axes)
    rows = -(-n // d)
    k_l = min(k, rows)
    pad = rows * d - n
    if pad:
        # sentinel coordinate (the kernels/topk_scoring/ops.py trick):
        # queries get 1.0, real rows 0.0, padded rows -BIG, so a padded row
        # scores -BIG and can never displace a real candidate from the
        # LOCAL top-k (a zero-padded row would score 0 and beat genuinely
        # negative candidates before the post-hoc validity mask)
        queries = jnp.pad(queries, ((0, 0), (0, 1)), constant_values=1.0)
        vp = jnp.pad(vecs, ((0, pad), (0, 1)))
        vp = vp.at[n:, dim].set(-1e30)
    else:
        vp = vecs

    def shard_fn(v_l, q):
        row0 = coll.flat_axis_index(axes) * rows
        s, i = backend.topk(q, v_l, k=k_l)
        gid = row0 + i
        ok = (i >= 0) & (gid < n)
        return _merge(jnp.where(ok, s, -jnp.inf),
                      jnp.where(ok, gid, -1), axes, k)

    fn = shard_map(shard_fn, mesh=mesh,
                   in_specs=(_row_spec(axes, 2), P(None, None)),
                   out_specs=(P(), P()), check_rep=False)
    return _pad_topk(*fn(vp, queries), k)


def _sharded_exact(engine, index, queries, *, k, mesh, axes):
    return _rowwise_topk(get_backend(engine.backend), index, queries,
                         k=k, mesh=mesh, axes=axes)


def _sharded_tfidf(engine, index, queries, *, k, mesh, axes):
    # IDF weights were folded into index.vecs at (global) build time, so the
    # sharded scan is the exact engine's plan over the weighted rows.
    return _rowwise_topk(get_backend(engine.backend), index.vecs, queries,
                         k=k, mesh=mesh, axes=axes)


def _sharded_lsh(engine, index, queries, *, k, mesh, axes):
    backend = get_backend(engine.backend)
    n = index.codes.shape[0]
    d = _axis_count(mesh, axes)
    rows = -(-n // d)
    rerank = min(max(engine.rerank, k), n) if engine.rerank > 0 else 0
    target = rerank if rerank > 0 else k
    t_l = min(target, rows)
    qc = encode(index.proj, queries)
    pad = rows * d - n
    if pad:
        # a zero-padded code row would get a REAL Hamming distance and
        # could evict a true candidate from the local top-k, so padded rows
        # get W+1 extra all-ones words (queries and real rows get zeros):
        # their distance grows by 32·(W+1) > 32·W ≥ any real distance,
        # strictly below every real row — exact integer arithmetic, and
        # real-row distances are untouched
        w = index.codes.shape[1]
        cp = jnp.pad(index.codes, ((0, pad), (0, w + 1)))
        cp = cp.at[n:, w:].set(-1)
        qc = jnp.pad(qc, ((0, 0), (0, w + 1)))
    else:
        cp = index.codes

    def shard_fn(c_l, qc_):
        row0 = coll.flat_axis_index(axes) * rows
        s, i = backend.hamming_topk(qc_, c_l, k=t_l)
        gid = row0 + i
        ok = (i >= 0) & (gid < n)
        return _merge(jnp.where(ok, s, -jnp.inf),
                      jnp.where(ok, gid, -1), axes, target)

    fn = shard_map(shard_fn, mesh=mesh,
                   in_specs=(_row_spec(axes, 2), P(None, None)),
                   out_specs=(P(), P()), check_rep=False)
    neg, cand = fn(cp, qc)
    if rerank <= 0:
        # match search_lsh's historical no-rerank API: positive Hamming
        # distance, lower = better (+inf for misses)
        neg, cand = _pad_topk(neg, cand, k)
        return (-neg).astype(queries.dtype), cand
    # exact rerank of the merged global candidates — identical math to the
    # single-device search_lsh rerank step, on the replicated vectors
    return rerank_candidates(index.vecs, queries, cand, k=k)


def _sharded_ivfflat(engine, index, queries, *, k, mesh, axes):
    backend = get_backend(engine.backend)
    n_lists, cap, dim = index.vecs.shape
    nprobe = min(engine.nprobe, n_lists)
    d = _axis_count(mesh, axes)
    ll = -(-n_lists // d)
    pad = ll * d - n_lists
    vecs = jnp.pad(index.vecs, ((0, pad), (0, 0), (0, 0)))
    ids = jnp.pad(index.ids, ((0, pad), (0, 0)), constant_values=-1)
    mask = jnp.pad(index.mask, ((0, pad), (0, 0)))
    k_l = min(k, nprobe * cap)

    def shard_fn(v_l, i_l, m_l, cent, q):
        l0 = coll.flat_axis_index(axes) * ll
        cscore = q @ cent.T                          # (Q, n_lists) global
        _, probe = lax.top_k(cscore, nprobe)         # same probes everywhere
        own = (probe >= l0) & (probe < l0 + ll)
        lp = jnp.clip(probe - l0, 0, ll - 1)
        v = v_l[lp]                                  # (Q, nprobe, cap, dim)
        cid = jnp.where(m_l[lp] & own[..., None], i_l[lp], -1)
        qn = q.shape[0]
        s, gid = backend.gathered_topk(q, v.reshape(qn, -1, dim),
                                       cid.reshape(qn, -1), k=k_l)
        return _merge(s, gid, axes, k)

    fn = shard_map(shard_fn, mesh=mesh,
                   in_specs=(_row_spec(axes, 3), _row_spec(axes, 2),
                             _row_spec(axes, 2), P(None, None),
                             P(None, None)),
                   out_specs=(P(), P()), check_rep=False)
    return _pad_topk(*fn(vecs, ids, mask, index.centroids, queries), k)


_SHARDED_IMPLS: Dict[str, Callable] = {
    "exact": _sharded_exact,
    "tfidf": _sharded_tfidf,
    "lsh": _sharded_lsh,
    "ivfflat": _sharded_ivfflat,
}


def sharded_search(engine, index, queries: jnp.ndarray, *, k: int,
                   mesh: Mesh, axes: Optional[tuple] = None
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Mesh-partitioned ``engine.search`` with equivalent semantics:
    (scores f32[Q, k], ids i32[Q, k]) into the corpus the index was built
    from, −inf/−1 padding for misses.  Bit-consistent with single-device
    search on a 1-device mesh; set-equal under the backend tie policy on
    larger meshes."""
    if getattr(engine, "backend", None) == "int8":
        # the row-shard padding sentinel (−1e30 coordinate) would destroy
        # the int8 corpus scale, and shard-local quantization changes the
        # candidate ranking; quantized sharded scoring is future work
        raise ValueError(
            "sharded search does not support the 'int8' backend; use "
            "backend='jnp' or 'pallas' for sharded meshes")
    try:
        impl = _SHARDED_IMPLS[engine.name]
    except KeyError:
        raise ValueError(
            f"no sharded search plan for engine {engine.name!r}; engines "
            f"with plans: {', '.join(sorted(_SHARDED_IMPLS))}") from None
    return impl(engine, index, queries, k=k, mesh=mesh,
                axes=_resolve_axes(mesh, axes))
