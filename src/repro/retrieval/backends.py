"""Scoring-backend registry — Layer 1 of the search core (DESIGN.md §9).

Every retrieval engine bottoms out in one of three scoring primitives:

  * ``topk``          — dense inner-product top-k against a shared corpus
                        (exact / tfidf search, lsh rerank oracle);
  * ``hamming_topk``  — packed sign-code Hamming top-k (the lsh scan);
  * ``gathered_topk`` — per-query candidate-set top-k (the ivfflat probe
                        scoring, where each query scores its own gathered
                        lists).

A backend is a registered implementation of all three behind one protocol —
the same pluggable-component pattern as ``core/engines.py`` — so the choice
of execution strategy (pure-XLA jnp vs the Pallas kernels) is a config
string on any engine rather than a fork in each index.  Registered:

  * ``jnp``    — pure-jnp reference: blocked streaming top-k for the dense
                 scan (the (Q, N) score matrix is never materialised),
                 the kernel oracles for Hamming and gathered scoring.
  * ``pallas`` — the fused Pallas kernels (kernels/topk_scoring,
                 kernels/lsh_hamming); interpret mode off-TPU, so the
                 backend is selectable everywhere.  Block sizes default to
                 ``None`` = resolved per call through the autotuner table
                 (kernels/tuning.py, DESIGN.md §11).
  * ``int8``   — quantized dense scan + float rerank tail: the corpus is
                 quantized ONCE at index build (``prepare_corpus`` →
                 :class:`QuantizedCorpus`, via
                 ``distributed/compression.quantize_int8``), queries are
                 quantized per call, the int8 Pallas kernel scans for the
                 top ``rerank_factor*k`` candidates on the raw integer dot
                 (ranking is invariant to the two global scales), and the
                 winners are exact-reranked in f32 — so results are
                 exact-at-k whenever the true top-k survives into the int8
                 top-``rerank_factor*k`` pool (DESIGN.md §11 for the
                 argument).  Hamming scoring delegates to the pallas
                 kernel (codes are already 1-bit); gathered scoring
                 delegates to the float pallas kernel (the ivfflat probe
                 gather has already shrunk the candidate set, so int8
                 would re-quantize per call for no bandwidth win).

``prepare_corpus`` is the build-time hook: engines pass their corpus-side
matrix through it so a backend can transform the layout once per index
(identity for jnp/pallas, quantization for int8).

Tie policy (both backends, verified by tests/test_search_core.py): results
are score-descending; equal scores break toward the FIRST candidate in the
input layout (``lax.top_k`` takes the first occurrence, and the kernels'
per-block extraction + ascending-block merge preserve the same order).
For ``topk`` and ``hamming_topk`` the layout is id-ascending, so ties
break toward the lower candidate id; for ``gathered_topk`` the layout is
the caller's candidate order (for ivfflat: probe rank × slot), so ties
break by candidate *position*, not id.  Misses — k larger than the
candidate count, or invalid slots — come back as score −inf / id −1.

Backends are frozen dataclasses so callers can tune block sizes with
``dataclasses.replace`` without mutating the registry's shared instance.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, NamedTuple, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.compression import quantize_int8
from repro.kernels.lsh_hamming import ops as lsh_ops
from repro.kernels.lsh_hamming.ref import hamming_topk_ref
from repro.kernels.topk_scoring import ops as topk_ops
from repro.kernels.topk_scoring.ref import gathered_topk_ref
from repro.kernels.topk_scoring.ref import pad_topk as _pad_topk


@runtime_checkable
class ScoringBackend(Protocol):
    """Execution strategy for the three scoring primitives."""

    name: str

    def prepare_corpus(self, vecs: jnp.ndarray):
        """Build-time hook: corpus f32[N, D] -> whatever layout ``topk``
        consumes (identity for float backends)."""
        ...

    def topk(self, queries: jnp.ndarray, corpus, *, k: int):
        """(Q, D) x prepared corpus -> (scores f32[Q, k], ids i32[Q, k])."""
        ...

    def hamming_topk(self, q_codes: jnp.ndarray, c_codes: jnp.ndarray, *,
                     k: int):
        """Packed codes (Q, W) x (N, W) -> (−distance f32[Q, k], ids)."""
        ...

    def gathered_topk(self, queries: jnp.ndarray, cand_vecs: jnp.ndarray,
                      cand_ids: jnp.ndarray, *, k: int):
        """(Q, D) x (Q, C, D) with ids (Q, C), −1 = invalid slot."""
        ...


_REGISTRY: Dict[str, ScoringBackend] = {}


def register_backend(cls):
    """Class decorator: instantiate and register a backend under its name."""
    backend = cls()
    _REGISTRY[backend.name] = backend
    return cls


def get_backend(name: str) -> ScoringBackend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown scoring backend {name!r}; registered backends: "
            f"{', '.join(available_backends())}") from None


def available_backends() -> tuple:
    return tuple(sorted(_REGISTRY))


class QuantizedCorpus(NamedTuple):
    """Int8-quantized corpus built once per index (``prepare_corpus``):
    codes for the kernel scan, the global scale, and the original float
    vectors kept for the exact rerank tail."""

    codes: jnp.ndarray   # (N, D) int8
    scale: jnp.ndarray   # () f32 global max-abs scale
    vecs: jnp.ndarray    # (N, D) f32 originals (rerank + float fallback)


def _float_corpus(corpus) -> jnp.ndarray:
    """Float view of a prepared corpus — lets the float backends search an
    index an int8-backed engine built (cross-backend ``dataclasses.replace``
    swaps stay valid)."""
    return corpus.vecs if isinstance(corpus, QuantizedCorpus) else corpus


def rerank_candidates(vecs: jnp.ndarray, queries: jnp.ndarray,
                      cand: jnp.ndarray, *, k: int):
    """Exact inner-product rerank of per-query candidate ids (−1 = miss):
    (Q, R) -> top-k (scores, ids).  Shared by the single-device and sharded
    lsh search paths and the int8 backend's float tail, so all rank
    identically."""
    cvecs = vecs[jnp.maximum(cand, 0)]                    # (Q, R, d)
    s = jnp.einsum("qd,qrd->qr", queries, cvecs)
    s = jnp.where(cand >= 0, s, -jnp.inf)
    top_s, pos = lax.top_k(s, min(k, cand.shape[1]))
    top_i = jnp.take_along_axis(cand, pos, axis=1)
    top_i = jnp.where(jnp.isfinite(top_s), top_i, -1)
    return _pad_topk(top_s, top_i, k)


@functools.partial(jax.jit, static_argnames=("k", "block"))
def _blocked_topk(queries: jnp.ndarray, corpus: jnp.ndarray, *, k: int,
                  block: int = 4096):
    """Streaming blocked top-k: candidates scored block-by-block with a
    running merge, so the (Q, N) score matrix never materialises — the same
    structure the Pallas topk_scoring kernel implements in VMEM.  Handles
    k > N natively (the −inf/−1 init survives into the output)."""
    qn, d = queries.shape
    n = corpus.shape[0]
    nb = (n + block - 1) // block
    pad = nb * block - n
    cp = jnp.pad(corpus, ((0, pad), (0, 0)))
    blocks = cp.reshape(nb, block, d)

    def step(carry, xs):
        best_s, best_i = carry
        blk, bi = xs
        s = (queries @ blk.T).astype(jnp.float32)             # (Q, block)
        ids = bi * block + jnp.arange(block, dtype=jnp.int32)[None]
        valid = ids < n
        s = jnp.where(valid, s, -jnp.inf)
        cat_s = jnp.concatenate([best_s, s], axis=1)
        cat_i = jnp.concatenate([best_i, jnp.broadcast_to(ids, s.shape)], 1)
        top_s, pos = lax.top_k(cat_s, k)
        top_i = jnp.take_along_axis(cat_i, pos, axis=1)
        return (top_s, top_i), None

    init = (jnp.full((qn, k), -jnp.inf, jnp.float32),
            jnp.full((qn, k), -1, jnp.int32))
    (scores, ids), _ = lax.scan(
        step, init, (blocks, jnp.arange(nb, dtype=jnp.int32)))
    return scores, ids


@register_backend
@dataclasses.dataclass(frozen=True)
class JnpBackend:
    """Pure-XLA reference backend (the oracle the pallas backend is tested
    against)."""

    block: int = 4096
    name: str = "jnp"

    def prepare_corpus(self, vecs):
        return vecs

    def topk(self, queries, corpus, *, k: int):
        return _blocked_topk(queries, _float_corpus(corpus), k=k,
                             block=self.block)

    def hamming_topk(self, q_codes, c_codes, *, k: int):
        k_eff = min(k, c_codes.shape[0])
        return _pad_topk(*hamming_topk_ref(q_codes, c_codes, k=k_eff), k)

    def gathered_topk(self, queries, cand_vecs, cand_ids, *, k: int):
        k_eff = min(k, cand_ids.shape[1])
        return _pad_topk(
            *gathered_topk_ref(queries, cand_vecs, cand_ids, k=k_eff), k)


@register_backend
@dataclasses.dataclass(frozen=True)
class PallasBackend:
    """Fused Pallas kernels (interpret mode off-TPU); the dispatch wrappers
    in kernels/*/ops.py own padding, k-clamping and the k > 32 fallback.

    ``None`` block fields defer to the autotuner table (kernels/tuning.py):
    explicit kwarg > tuned entry for the corpus-size bucket > hard-coded
    default.  ``dataclasses.replace`` with concrete ints pins blocks."""

    block_q: Optional[int] = None
    block_n: Optional[int] = None
    block_c: Optional[int] = None
    name: str = "pallas"

    def prepare_corpus(self, vecs):
        return vecs

    def topk(self, queries, corpus, *, k: int):
        return topk_ops.topk_scores(queries, _float_corpus(corpus), k=k,
                                    block_q=self.block_q,
                                    block_n=self.block_n)

    def hamming_topk(self, q_codes, c_codes, *, k: int):
        return lsh_ops.hamming_topk(q_codes, c_codes, k=k,
                                    block_q=self.block_q,
                                    block_n=self.block_n)

    def gathered_topk(self, queries, cand_vecs, cand_ids, *, k: int):
        return topk_ops.gathered_topk(queries, cand_vecs, cand_ids, k=k,
                                      block_c=self.block_c)


@register_backend
@dataclasses.dataclass(frozen=True)
class Int8Backend:
    """Quantized dense scan + float rerank tail.

    The int8 kernel scans the quantized corpus for the top
    ``rerank_factor*k`` candidates on the raw integer dot (scale-invariant
    ranking: both scales are global positive constants), then
    :func:`rerank_candidates` rescores those candidates with the original
    f32 vectors — exact-at-k whenever the true top-k survives into the
    int8 candidate pool (rerank_factor trades recall against scan width;
    ``eval/fidelity.backend_recall_curve`` measures the trade).

    Hamming/gathered scoring delegate to the pallas kernels — codes are
    already 1-bit, and the ivfflat probe gather has already shrunk the
    candidate set, so a per-call re-quantization buys no bandwidth."""

    rerank_factor: int = 4
    block_q: Optional[int] = None
    block_n: Optional[int] = None
    name: str = "int8"

    def prepare_corpus(self, vecs):
        vecs = jnp.asarray(vecs)
        codes, scale = quantize_int8(vecs)
        return QuantizedCorpus(codes, scale, vecs)

    def topk(self, queries, corpus, *, k: int):
        qc = (corpus if isinstance(corpus, QuantizedCorpus)
              else self.prepare_corpus(corpus))
        n = qc.codes.shape[0]
        pool = min(max(self.rerank_factor * k, k), n)
        q_codes, _ = quantize_int8(jnp.asarray(queries, jnp.float32))
        _, cand = topk_ops.topk_scores_int8(q_codes, qc.codes, k=pool,
                                            block_q=self.block_q,
                                            block_n=self.block_n)
        return rerank_candidates(qc.vecs, queries, cand, k=k)

    def hamming_topk(self, q_codes, c_codes, *, k: int):
        return lsh_ops.hamming_topk(q_codes, c_codes, k=k,
                                    block_q=self.block_q,
                                    block_n=self.block_n)

    def gathered_topk(self, queries, cand_vecs, cand_ids, *, k: int):
        return topk_ops.gathered_topk(queries, cand_vecs, cand_ids, k=k)
