"""Scoring-backend registry — Layer 1 of the search core (DESIGN.md §9).

Every retrieval engine bottoms out in one of three scoring primitives:

  * ``topk``          — dense inner-product top-k against a shared corpus
                        (exact / tfidf search, lsh rerank oracle);
  * ``hamming_topk``  — packed sign-code Hamming top-k (the lsh scan);
  * ``gathered_topk`` — per-query candidate-set top-k (the ivfflat probe
                        scoring, where each query scores its own gathered
                        lists).

A backend is a registered implementation of all three behind one protocol —
the same pluggable-component pattern as ``core/engines.py`` — so the choice
of execution strategy (pure-XLA jnp vs the Pallas kernels) is a config
string on any engine rather than a fork in each index.  Registered:

  * ``jnp``    — pure-jnp reference: blocked streaming top-k for the dense
                 scan (the (Q, N) score matrix is never materialised),
                 the kernel oracles for Hamming and gathered scoring.
  * ``pallas`` — the fused Pallas kernels (kernels/topk_scoring,
                 kernels/lsh_hamming); interpret mode off-TPU, so the
                 backend is selectable everywhere.

Tie policy (both backends, verified by tests/test_search_core.py): results
are score-descending; equal scores break toward the FIRST candidate in the
input layout (``lax.top_k`` takes the first occurrence, and the kernels'
per-block extraction + ascending-block merge preserve the same order).
For ``topk`` and ``hamming_topk`` the layout is id-ascending, so ties
break toward the lower candidate id; for ``gathered_topk`` the layout is
the caller's candidate order (for ivfflat: probe rank × slot), so ties
break by candidate *position*, not id.  Misses — k larger than the
candidate count, or invalid slots — come back as score −inf / id −1.

Backends are frozen dataclasses so callers can tune block sizes with
``dataclasses.replace`` without mutating the registry's shared instance.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels.lsh_hamming import ops as lsh_ops
from repro.kernels.lsh_hamming.ref import hamming_topk_ref
from repro.kernels.topk_scoring import ops as topk_ops
from repro.kernels.topk_scoring.ref import gathered_topk_ref
from repro.kernels.topk_scoring.ref import pad_topk as _pad_topk


@runtime_checkable
class ScoringBackend(Protocol):
    """Execution strategy for the three scoring primitives."""

    name: str

    def topk(self, queries: jnp.ndarray, corpus: jnp.ndarray, *,
             k: int):
        """(Q, D) x (N, D) -> (scores f32[Q, k], ids i32[Q, k])."""
        ...

    def hamming_topk(self, q_codes: jnp.ndarray, c_codes: jnp.ndarray, *,
                     k: int):
        """Packed codes (Q, W) x (N, W) -> (−distance f32[Q, k], ids)."""
        ...

    def gathered_topk(self, queries: jnp.ndarray, cand_vecs: jnp.ndarray,
                      cand_ids: jnp.ndarray, *, k: int):
        """(Q, D) x (Q, C, D) with ids (Q, C), −1 = invalid slot."""
        ...


_REGISTRY: Dict[str, ScoringBackend] = {}


def register_backend(cls):
    """Class decorator: instantiate and register a backend under its name."""
    backend = cls()
    _REGISTRY[backend.name] = backend
    return cls


def get_backend(name: str) -> ScoringBackend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown scoring backend {name!r}; registered backends: "
            f"{', '.join(available_backends())}") from None


def available_backends() -> tuple:
    return tuple(sorted(_REGISTRY))


@functools.partial(jax.jit, static_argnames=("k", "block"))
def _blocked_topk(queries: jnp.ndarray, corpus: jnp.ndarray, *, k: int,
                  block: int = 4096):
    """Streaming blocked top-k: candidates scored block-by-block with a
    running merge, so the (Q, N) score matrix never materialises — the same
    structure the Pallas topk_scoring kernel implements in VMEM.  Handles
    k > N natively (the −inf/−1 init survives into the output)."""
    qn, d = queries.shape
    n = corpus.shape[0]
    nb = (n + block - 1) // block
    pad = nb * block - n
    cp = jnp.pad(corpus, ((0, pad), (0, 0)))
    blocks = cp.reshape(nb, block, d)

    def step(carry, xs):
        best_s, best_i = carry
        blk, bi = xs
        s = (queries @ blk.T).astype(jnp.float32)             # (Q, block)
        ids = bi * block + jnp.arange(block, dtype=jnp.int32)[None]
        valid = ids < n
        s = jnp.where(valid, s, -jnp.inf)
        cat_s = jnp.concatenate([best_s, s], axis=1)
        cat_i = jnp.concatenate([best_i, jnp.broadcast_to(ids, s.shape)], 1)
        top_s, pos = lax.top_k(cat_s, k)
        top_i = jnp.take_along_axis(cat_i, pos, axis=1)
        return (top_s, top_i), None

    init = (jnp.full((qn, k), -jnp.inf, jnp.float32),
            jnp.full((qn, k), -1, jnp.int32))
    (scores, ids), _ = lax.scan(
        step, init, (blocks, jnp.arange(nb, dtype=jnp.int32)))
    return scores, ids


@register_backend
@dataclasses.dataclass(frozen=True)
class JnpBackend:
    """Pure-XLA reference backend (the oracle the pallas backend is tested
    against)."""

    block: int = 4096
    name: str = "jnp"

    def topk(self, queries, corpus, *, k: int):
        return _blocked_topk(queries, corpus, k=k, block=self.block)

    def hamming_topk(self, q_codes, c_codes, *, k: int):
        k_eff = min(k, c_codes.shape[0])
        return _pad_topk(*hamming_topk_ref(q_codes, c_codes, k=k_eff), k)

    def gathered_topk(self, queries, cand_vecs, cand_ids, *, k: int):
        k_eff = min(k, cand_ids.shape[1])
        return _pad_topk(
            *gathered_topk_ref(queries, cand_vecs, cand_ids, k=k_eff), k)


@register_backend
@dataclasses.dataclass(frozen=True)
class PallasBackend:
    """Fused Pallas kernels (interpret mode off-TPU); the dispatch wrappers
    in kernels/*/ops.py own padding, k-clamping and the k > 32 fallback."""

    block_q: int = 128
    block_n: int = 1024
    block_c: int = 256
    name: str = "pallas"

    def topk(self, queries, corpus, *, k: int):
        return topk_ops.topk_scores(queries, corpus, k=k,
                                    block_q=self.block_q,
                                    block_n=self.block_n)

    def hamming_topk(self, q_codes, c_codes, *, k: int):
        return lsh_ops.hamming_topk(q_codes, c_codes, k=k,
                                    block_q=self.block_q,
                                    block_n=self.block_n)

    def gathered_topk(self, queries, cand_vecs, cand_ids, *, k: int):
        return topk_ops.gathered_topk(queries, cand_vecs, cand_ids, k=k,
                                      block_c=self.block_c)
