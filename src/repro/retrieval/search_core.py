"""Search core front door — Layer 3 (DESIGN.md §9).

One :class:`SearchSession` is the single implementation of "build an index
once, answer many queries" that every consumer routes through: the
experiment grid (``eval/runner.py``), the Table I/II experiment
(``retrieval/experiment.py``), the evaluation CLI (``launch/evaluate.py``)
and the online serving path (``serve/engine.py`` — the paper's Fig. 5
query → embed → ANN component).  Offline eval and online serving therefore
share one code path, so a backend or sharding change benchmarked in the
grid is exactly what serves traffic.

Configuration is one declarative :class:`SearchConfig`:

  * ``engine``  — a registered retrieval engine (retrieval/engines.py);
  * ``backend`` — a registered scoring backend (retrieval/backends.py,
    Layer 1): ``jnp`` reference, ``pallas`` kernels, or ``int8``
    quantized scan + float rerank (applied before ``engine.build`` so
    build-time hooks like int8 corpus quantization see it);
  * ``sharded``/``mesh`` — route searches through the mesh-partitioned
    Layer 2 (retrieval/sharded.py);
  * ``query_chunk`` — chunked multi-query batching, so the probe gather
    stays O(chunk · cand · d) regardless of the query load;
  * ``engine_opts`` — hyper-parameter overrides applied with
    ``dataclasses.replace`` (e.g. ``{"n_lists": 16}``).

Unknown engine/backend names fail fast with the registry's error message
(the ``core/engines.py`` UX).  ``k`` is clamped to the indexed corpus size
and padded back with −1 ids, so tiny sampled corpora never crash a search.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharded_corpus import ShardedCorpus
from repro.kernels import tuning
from repro.obs import trace
from repro.obs import memory as obs_memory
from repro.retrieval.backends import get_backend
from repro.retrieval.engines import get_retrieval_engine
from repro.retrieval.sharded import sharded_build, sharded_search


@dataclasses.dataclass(frozen=True)
class SearchConfig:
    """Declarative search-core configuration (engine × backend × shard).

    ``streamed=True`` shards the corpus from birth: the host array is
    streamed chunk-wise into per-device buffers
    (distributed/sharded_corpus.ShardedCorpus) and the index is built
    per shard (retrieval/sharded.sharded_build) — no device ever holds
    the global corpus or the global index.  Passing a ``ShardedCorpus``
    directly as ``corpus_vecs`` has the same effect; both imply
    ``sharded=True``.
    """

    engine: str = "exact"
    backend: str = "jnp"
    sharded: bool = False
    mesh: Any = None              # jax.sharding.Mesh when sharded
    streamed: bool = False        # shard-local build from birth
    stream_chunk: int = 65536     # host->device streaming chunk rows
    query_chunk: int = 256
    engine_opts: Optional[Mapping[str, Any]] = None


class SearchSession:
    """Build-once, chunked multi-query search over one corpus.

    ``corpus_vecs`` f32[N, D] are indexed once at construction (globally —
    sharding distributes scoring, never index statistics); ``search`` then
    answers any number of query batches.  When ``ids_map`` is given (the
    sample's kept entity ids), results map from index-local rows back to
    global ids, with −1 for misses — the contract the eval grid's metric
    stages consume.
    """

    def __init__(self, corpus_vecs, config: Optional[SearchConfig] = None,
                 *, key: Optional[jax.Array] = None,
                 ids_map: Optional[np.ndarray] = None, **overrides):
        cfg = config or SearchConfig()
        if overrides:
            cfg = dataclasses.replace(cfg, **overrides)
        engine = get_retrieval_engine(cfg.engine)   # registry error UX
        get_backend(cfg.backend)                    # fail fast, same UX
        born = corpus_vecs if isinstance(corpus_vecs, ShardedCorpus) else None
        if born is None and cfg.streamed:
            if cfg.mesh is None:
                raise ValueError("streamed build needs a mesh; pass "
                                 "SearchConfig(mesh=...) (launch.mesh "
                                 "helpers)")
            born = ShardedCorpus.from_host(corpus_vecs, mesh=cfg.mesh,
                                           chunk_rows=cfg.stream_chunk)
        if born is not None:
            # a sharded-from-birth corpus forces the sharded query plans
            cfg = dataclasses.replace(cfg, sharded=True, streamed=True,
                                      mesh=born.mesh)
        if cfg.sharded and cfg.mesh is None:
            raise ValueError("sharded search needs a mesh; pass "
                             "SearchConfig(mesh=...) (launch.mesh helpers)")
        if cfg.sharded and cfg.backend == "int8" and born is None:
            # lifted on the born path (per-shard scales + float rerank);
            # the global-partition path keeps the rejection (DESIGN.md §13)
            raise ValueError(
                "sharded search does not support the 'int8' backend (the "
                "row-shard padding sentinel would destroy the quantization "
                "scale); use backend='jnp' or 'pallas'")
        if cfg.engine_opts:
            engine = dataclasses.replace(engine, **dict(cfg.engine_opts))
        self.config = cfg
        self.engine = dataclasses.replace(engine, backend=cfg.backend)
        self._born = born
        if born is not None:
            self.corpus_size = born.n
        else:
            vecs = jnp.asarray(corpus_vecs)
            self.corpus_size = int(vecs.shape[0])
        self.ids_map = None if ids_map is None else np.asarray(ids_map)
        if self.ids_map is not None and self.ids_map.size != self.corpus_size:
            raise ValueError(
                f"ids_map has {self.ids_map.size} entries for a corpus of "
                f"{self.corpus_size} vectors")
        with trace.jax_span(
                "search.build",
                compile_key=f"search.build/{cfg.engine}/{cfg.backend}",
                engine=cfg.engine, backend=cfg.backend,
                n=self.corpus_size, streamed=born is not None,
                shards=born.num_shards if born is not None else 1) as sp:
            bkey = key if key is not None else jax.random.PRNGKey(0)
            if born is not None:
                self.index = sharded_build(self.engine, born, bkey)
            else:
                self.index = self.engine.build(bkey, vecs)
            sp.declare(self.index)
        obs_memory.record_build_peak()

    def _search_chunk(self, queries: jnp.ndarray, k: int):
        cfg = self.config
        mark = tuning.resolution_mark() if trace.is_enabled() else 0
        with trace.jax_span(
                "search.chunk",
                compile_key=(f"search.chunk/{cfg.engine}/{cfg.backend}/"
                             f"{self.corpus_size}/{queries.shape[0]}/{k}"),
                engine=cfg.engine, backend=cfg.backend,
                n=self.corpus_size, q=int(queries.shape[0]), k=k,
                sharded=cfg.sharded) as sp:
            if cfg.sharded:
                scores, ids = sharded_search(self.engine, self.index,
                                             queries, k=k, mesh=cfg.mesh)
            else:
                scores, ids = self.engine.search_scored(self.index, queries,
                                                        k=k)
            sp.declare(ids)
            blocks = tuning.resolutions_since(mark)
            if blocks:
                # block choice per kernel dispatched inside this chunk
                # (resolution happens at trace time, so steady-state calls
                # that hit a cached jit trace carry no tuned_blocks attr)
                sp.set(tuned_blocks=[
                    {"kernel": b["kernel"], "params": b["params"],
                     "tuned": b["tuned"]} for b in blocks])
        return np.asarray(scores), np.asarray(ids)

    def search_scored(self, queries, *, k: int):
        """(scores f32[Q, k], ids i32[Q, k]) for a query batch — −inf/−1
        padding for misses, chunked by ``query_chunk``, ids mapped through
        ``ids_map`` when set.  Scores are the engine's final ranking scores
        (inner products for every engine except no-rerank lsh, which ranks
        by positive Hamming distance), which is what the serving tier's
        live-ingest merge (serve/ingest.py) compares against its append
        buffer's exact scan."""
        q = np.asarray(queries)
        k_eff = max(1, min(k, self.corpus_size))
        chunk = self.config.query_chunk
        parts = [self._search_chunk(jnp.asarray(q[i:i + chunk]), k_eff)
                 for i in range(0, q.shape[0], chunk)]
        if parts:
            scores = np.concatenate([p[0] for p in parts], 0)
            local = np.concatenate([p[1] for p in parts], 0)
        else:
            scores = np.full((0, k_eff), -np.inf, np.float32)
            local = np.zeros((0, k_eff), np.int32)
        if k_eff < k:
            scores = np.pad(scores, ((0, 0), (0, k - k_eff)),
                            constant_values=-np.inf)
            local = np.pad(local, ((0, 0), (0, k - k_eff)),
                           constant_values=-1)
        if self.ids_map is not None:
            local = np.where(local >= 0,
                             self.ids_map[np.clip(local, 0, None)], -1)
        return scores, local

    def search(self, queries, *, k: int) -> np.ndarray:
        """Top-k ids i32[Q, k] for a query batch (−1 padding for misses);
        chunked by ``query_chunk``, mapped through ``ids_map`` when set."""
        return self.search_scored(queries, k=k)[1]
