"""Real neighbour sampler for sampled-training GNN shapes (minibatch_lg:
fanout 15-10 over a 233k-node / 115M-edge graph).

Host-side CSR + per-layer uniform fanout sampling (GraphSAGE style), emitting
statically-shaped, locally-indexed subgraph blocks that the JAX model
consumes directly. Padding uses self-loops on node 0 with zero mask.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import numpy as np


class SubgraphBlock(NamedTuple):
    """One message-passing layer block (dst nodes aggregate from src)."""
    src_nodes: np.ndarray   # i32[n_src] global ids (dst nodes come first)
    edge_src: np.ndarray    # i32[n_edges] local index into src_nodes
    edge_dst: np.ndarray    # i32[n_edges] local index into dst (0..n_dst-1)
    edge_mask: np.ndarray   # bool[n_edges]
    n_dst: int


class NeighborSampler:
    def __init__(self, src: np.ndarray, dst: np.ndarray, num_nodes: int,
                 seed: int = 0):
        order = np.argsort(dst, kind="stable")
        self._src = src[order].astype(np.int64)
        dsts = dst[order]
        self._indptr = np.zeros(num_nodes + 1, np.int64)
        np.add.at(self._indptr, dsts + 1, 1)
        self._indptr = np.cumsum(self._indptr)
        self.num_nodes = num_nodes
        self._rng = np.random.default_rng(seed)

    def _sample_layer(self, dst_nodes: np.ndarray, fanout: int) -> SubgraphBlock:
        n_dst = dst_nodes.shape[0]
        starts = self._indptr[dst_nodes]
        degs = self._indptr[dst_nodes + 1] - starts
        # uniform with replacement up to fanout; mask out degree-0 nodes
        offs = (self._rng.random((n_dst, fanout)) *
                np.maximum(degs, 1)[:, None]).astype(np.int64)
        nbrs = self._src[starts[:, None] + offs]
        mask = np.repeat(degs > 0, fanout)
        # local re-indexing: dst nodes first, then new unique srcs
        uniq, inv = np.unique(np.concatenate([dst_nodes, nbrs.ravel()]),
                              return_inverse=True)
        # remap so dst nodes occupy 0..n_dst-1
        lut = np.full(uniq.shape[0], -1, np.int64)
        lut[inv[:n_dst]] = np.arange(n_dst)
        extra = np.setdiff1d(np.arange(uniq.shape[0]), inv[:n_dst],
                             assume_unique=False)
        lut[extra] = n_dst + np.arange(extra.shape[0])
        src_nodes = np.empty(uniq.shape[0], np.int64)
        src_nodes[lut] = uniq
        edge_src = lut[inv[n_dst:]]
        edge_dst = np.repeat(np.arange(n_dst), fanout)
        return SubgraphBlock(src_nodes.astype(np.int32),
                             edge_src.astype(np.int32),
                             edge_dst.astype(np.int32),
                             mask, n_dst)

    def sample(self, batch_nodes: np.ndarray,
               fanouts: Sequence[int]) -> list[SubgraphBlock]:
        """Multi-layer blocks, outermost layer last (message flow order)."""
        blocks = []
        frontier = batch_nodes.astype(np.int64)
        for f in fanouts:
            blk = self._sample_layer(frontier, f)
            blocks.append(blk)
            frontier = blk.src_nodes.astype(np.int64)
        return list(reversed(blocks))
