"""Data substrate: synthetic corpora/QRel generation, batching, neighbour
sampling. MSMarco is unavailable offline; data/synthetic.py generates a
corpus whose QRel graph is calibrated to the paper's measured statistics
(Yule-Simon degree law, gamma ~ 3) so Fig. 4 / Tables I-II reproduce
directionally (DESIGN.md §6).
"""
from repro.data.synthetic import (SyntheticCorpus, generate_qrels,
                                  generate_corpus)
from repro.data.batching import TokenBatcher
from repro.data.neighbor_sampler import NeighborSampler

__all__ = ["SyntheticCorpus", "generate_qrels", "generate_corpus",
           "TokenBatcher", "NeighborSampler"]
