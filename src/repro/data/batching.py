"""Deterministic batching for training pipelines.

Batches are a pure function of (seed, step): restarts resume mid-epoch with
no iterator state to checkpoint — only the step counter (train/checkpoint.py
stores exactly that). This is the fault-tolerance-friendly data-order design
used by large-scale LM stacks.
"""
from __future__ import annotations

import numpy as np


class TokenBatcher:
    """Contrastive (query, positive-passage) batches from a SyntheticCorpus,
    plus plain LM token batches for decoder training."""

    def __init__(self, corpus, batch_size: int, seed: int = 0):
        self.corpus = corpus
        self.batch_size = batch_size
        self.seed = seed
        q = np.asarray(corpus.qrels.query_ids)
        e = np.asarray(corpus.qrels.entity_ids)
        v = np.asarray(corpus.qrels.valid)
        self._pairs = np.stack([q[v], e[v]], axis=1)
        # same-community hard negatives: topic -> entity list (the in-batch
        # negatives are cross-topic; the within-community margin — exactly
        # what Table I measures — must be trained explicitly)
        topics = np.asarray(corpus.entity_topic)
        order = np.argsort(topics, kind="stable")
        self._ents_by_topic = order
        n_topics = topics.max() + 1
        self._topic_lo = np.searchsorted(topics[order], np.arange(n_topics))
        self._topic_hi = np.searchsorted(topics[order], np.arange(n_topics),
                                         side="right")
        self._rel_set = set(map(tuple, self._pairs.tolist()))

    def _perm(self, step: int) -> np.ndarray:
        epoch = (step * self.batch_size) // self._pairs.shape[0]
        rng = np.random.default_rng(self.seed * 1_000_003 + epoch)
        return rng.permutation(self._pairs.shape[0])

    def contrastive_batch(self, step: int):
        n = self._pairs.shape[0]
        perm = self._perm(step)
        start = (step * self.batch_size) % n
        idx = perm[(start + np.arange(self.batch_size)) % n]
        qi, ei = self._pairs[idx, 0], self._pairs[idx, 1]
        # hard negative: same-topic entity that is not relevant to the query
        rng = np.random.default_rng(self.seed * 11_000_003 + step)
        t = np.asarray(self.corpus.query_topic)[qi]
        lo, hi = self._topic_lo[t], self._topic_hi[t]
        ni = np.empty_like(ei)
        for j in range(self.batch_size):
            cand = -1
            for _ in range(8):
                c = self._ents_by_topic[rng.integers(lo[j], max(hi[j], lo[j] + 1))]
                if (int(qi[j]), int(c)) not in self._rel_set:
                    cand = c
                    break
            ni[j] = cand if cand >= 0 else rng.integers(
                0, self.corpus.num_entities)
        return {
            "query_tokens": self.corpus.query_tokens[qi],
            "passage_tokens": self.corpus.passage_tokens[ei],
            "negative_tokens": self.corpus.passage_tokens[ni],
            "query_ids": qi.astype(np.int32),
            "entity_ids": ei.astype(np.int32),
        }

    def lm_batch(self, step: int, seq_len: int):
        """Concatenate passages into fixed-length LM training rows."""
        rng = np.random.default_rng(self.seed * 7_000_003 + step)
        n_ent, plen = self.corpus.passage_tokens.shape
        per_row = (seq_len + plen - 1) // plen
        ids = rng.integers(0, n_ent, size=(self.batch_size, per_row))
        toks = self.corpus.passage_tokens[ids].reshape(self.batch_size, -1)
        toks = toks[:, :seq_len]
        return {"tokens": toks.astype(np.int32)}
