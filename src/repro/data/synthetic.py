"""Synthetic MSMarco-like corpus generation.

The container is offline, so the benchmark corpus is synthesized with the
statistics the paper measures on MSMarco:

* Entity qrel-multiplicities follow a **Yule-Simon power law** with
  gamma = 1 + 1/(1 - alpha): queries arrive and attach to entities by a
  Simon preferential-attachment (copy) process. alpha = 0.5 -> gamma = 3,
  matching the paper's fitted gamma = 2.94.
* **Planted community structure**: the copy process runs *within topics*, so
  entities sharing queries share topics — exactly the latent communities
  WindTunnel must preserve (paper Fig. 1/2: thematically consistent
  communities).
* Text: each topic owns a boosted word subset over a Zipfian background
  vocabulary; passages/queries sample from their topic's mixture. An
  embedding model trained on (query, passage) pairs therefore embeds
  communities as clusters — giving the distractor geometry of paper Fig. 1.
* **Auxiliary entities** (paper §I-A): a configurable fraction of corpus
  entities appear in no QRel; they act as distractors in indexing only.

Generation is host-side numpy (data pipeline), downstream consumption is JAX.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.graph_builder import QRelTable


@dataclasses.dataclass
class SyntheticCorpus:
    qrels: QRelTable                # padded table (numpy arrays)
    num_queries: int
    num_entities: int               # includes auxiliary entities
    num_primary: int                # entities that appear in QRels
    passage_tokens: np.ndarray      # i32[num_entities, passage_len]
    query_tokens: np.ndarray        # i32[num_queries, query_len]
    entity_topic: np.ndarray        # i32[num_entities] ground-truth community
    query_topic: np.ndarray         # i32[num_queries]
    vocab_size: int


def _simon_block(n_slots: int, alpha: float, rng: np.random.Generator):
    """One Simon preferential-attachment process over ``n_slots`` qrel slots.

    Returns local entity ids per slot (0..n_new-1). Vectorized via pointer
    jumping: slot t either mints a new entity (prob alpha) or copies the
    entity of a uniformly random earlier slot.
    """
    if n_slots == 0:
        return np.zeros((0,), np.int64)
    is_new = rng.random(n_slots) < alpha
    is_new[0] = True
    # copy target: uniform over strictly earlier slots
    copy_src = (rng.random(n_slots) * np.arange(n_slots)).astype(np.int64)
    ptr = np.where(is_new, np.arange(n_slots), copy_src)
    # pointer jumping: after ceil(log2 n) rounds every slot points at a minter
    rounds = max(1, int(np.ceil(np.log2(max(n_slots, 2)))) + 1)
    for _ in range(rounds):
        ptr = ptr[ptr]
    local_ids = np.cumsum(is_new) - 1
    return local_ids[ptr]


def generate_qrels(*, num_queries: int, qrels_per_query: int = 8,
                   alpha: float = 0.5, num_topics: int = 64,
                   topic_concentration: float = 1.2,
                   seed: int = 0):
    """Bipartite (query, entity, score) table with Yule-Simon entity degrees
    and planted topic communities.

    Returns (q_ids, e_ids, scores, entity_topic, query_topic, num_entities),
    all numpy, un-padded.
    """
    rng = np.random.default_rng(seed)
    # power-law-ish topic sizes (Zipf over topics)
    topic_w = 1.0 / np.arange(1, num_topics + 1) ** topic_concentration
    topic_w /= topic_w.sum()
    query_topic = rng.choice(num_topics, size=num_queries, p=topic_w)

    q_ids, e_ids, topics = [], [], []
    entity_topic = []
    offset = 0
    for t in range(num_topics):
        qs = np.nonzero(query_topic == t)[0]
        n_slots = qs.size * qrels_per_query
        local = _simon_block(n_slots, alpha, rng)
        n_new = int(local.max()) + 1 if n_slots else 0
        q_ids.append(np.repeat(qs, qrels_per_query))
        e_ids.append(local + offset)
        entity_topic.append(np.full(n_new, t, np.int64))
        offset += n_new
    q_ids = np.concatenate(q_ids)
    e_ids = np.concatenate(e_ids)
    entity_topic = np.concatenate(entity_topic)
    scores = rng.random(q_ids.shape[0]).astype(np.float32)
    return (q_ids.astype(np.int32), e_ids.astype(np.int32), scores,
            entity_topic.astype(np.int32), query_topic.astype(np.int32),
            offset)


def _query_words(query_ids: np.ndarray, k: np.ndarray,
                 vocab_size: int) -> np.ndarray:
    """Deterministic per-query intent-word set hashed into the vocab.
    Hash collisions across queries are intentional: at full-corpus scale
    they are the lexically-similar-but-irrelevant matches that drive the
    paper's low full-corpus precision (Table I: 0.105)."""
    return ((query_ids * 7919 + k * 104729 + 13) % vocab_size).astype(np.int32)


class _TokenModel:
    """Token model giving the embedding geometry the paper measures.

    Every QUERY owns a small intent-word set. A PASSAGE mixes the intent
    words of the queries it answers + its community's topic words + Zipf
    background (real passages answer several intents). A QUERY's text is
    drawn from its own intent words plus its two-hop neighbourhood (the
    intent words of queries sharing a relevant passage) — real queries are
    fragments of their relevant documents. Consequences:

    * relevant passages embed closest to the query (shared intent words);
    * passages of co-community queries are the strong distractors —
      preserved by WindTunnel sampling, thinned by uniform sampling, which
      is exactly why uniform sampling inflates precision (paper §IV);
    * auxiliary entities borrow intent words of random same-topic queries:
      strong community distractors invisible to shared-query edges — the
      paper's own explanation of why even the WindTunnel sample's
      precision sits above the full corpus.
    """

    def __init__(self, vocab_size, num_topics, topic_words, rng,
                 intent_words: int = 8):
        self.vocab = vocab_size
        self.iw = intent_words
        self.rng = rng
        bg = 1.0 / np.arange(1, vocab_size + 1) ** 1.1
        self.bg = bg / bg.sum()
        self.owned = rng.integers(0, vocab_size, size=(num_topics, topic_words))
        self.topic_words = topic_words

    def _mix(self, topic_ids, length, qsrc, p_intent, p_topic):
        """qsrc: (n, R) query ids (pad -1) to borrow intent words from."""
        n = topic_ids.shape[0]
        rng = self.rng
        u = rng.random((n, length))
        out = rng.choice(self.vocab, size=(n, length), p=self.bg).astype(np.int32)
        topic_tok = self.owned[topic_ids][
            np.arange(n)[:, None],
            rng.integers(0, self.topic_words, size=(n, length))]
        out = np.where(u < p_intent + p_topic, topic_tok, out)
        pick = rng.integers(0, qsrc.shape[1], size=(n, length))
        chosen = qsrc[np.arange(n)[:, None], pick]
        intent = _query_words(np.maximum(chosen, 0),
                              rng.integers(0, self.iw, size=(n, length)),
                              self.vocab)
        out = np.where((u < p_intent) & (chosen >= 0), intent, out)
        return out

    def passages(self, topic_ids, length, entity_queries):
        """entity_queries: (n, M) ids of queries each passage answers."""
        return self._mix(topic_ids, length, entity_queries,
                         p_intent=0.35, p_topic=0.30)

    def queries(self, topic_ids, length, own_and_neighbors):
        """own_and_neighbors: (n, R) = own id (repeated for weight) + co-
        community query ids (two-hop via shared passages)."""
        return self._mix(topic_ids, length, own_and_neighbors,
                         p_intent=0.55, p_topic=0.25)


def generate_corpus(*, num_queries: int = 2048, qrels_per_query: int = 8,
                    alpha: float = 0.5, num_topics: int = 64,
                    aux_fraction: float = 0.3, vocab_size: int = 4096,
                    passage_len: int = 64, query_len: int = 16,
                    topic_words: int = 64, seed: int = 0,
                    pad_multiple: int = 1024) -> SyntheticCorpus:
    rng = np.random.default_rng(seed + 1)
    (q_ids, e_ids, scores, entity_topic, query_topic,
     num_primary) = generate_qrels(
        num_queries=num_queries, qrels_per_query=qrels_per_query,
        alpha=alpha, num_topics=num_topics, seed=seed)

    # auxiliary entities: indexed but never relevant (paper §I-A)
    num_aux = int(num_primary * aux_fraction)
    aux_topics = rng.choice(num_topics, size=num_aux,
                            p=np.bincount(entity_topic,
                                          minlength=num_topics) /
                              max(entity_topic.size, 1))
    entity_topic = np.concatenate([entity_topic, aux_topics.astype(np.int32)])
    num_entities = num_primary + num_aux

    tm = _TokenModel(vocab_size, num_topics, topic_words, rng)

    # entity -> answered-queries table (padded -1), capped at M per entity
    M = 4
    ent_q = np.full((num_entities, M), -1, np.int64)
    order = np.argsort(e_ids, kind="stable")
    es, qs = e_ids[order], q_ids[order]
    starts = np.concatenate([[True], es[1:] != es[:-1]])
    rank = np.arange(es.size) - np.maximum.accumulate(
        np.where(starts, np.arange(es.size), 0))
    sel = rank < M
    ent_q[es[sel], rank[sel]] = qs[sel]

    # aux entities: strong same-topic distractors — each borrows the intent
    # words of ONE random query of its topic at full strength (unjudged
    # near-duplicates, invisible to shared-query edges). These are what
    # drags full-corpus precision down to the paper's 0.105 regime.
    if num_aux:
        qt_order = np.argsort(query_topic, kind="stable")
        sorted_qt = query_topic[qt_order]
        t_lo = np.searchsorted(sorted_qt, entity_topic[num_primary:])
        t_hi = np.searchsorted(sorted_qt, entity_topic[num_primary:],
                               side="right")
        has_q = t_hi > t_lo
        pick = t_lo + (rng.random(num_aux) * np.maximum(t_hi - t_lo, 1)
                       ).astype(np.int64)
        ent_q[num_primary:, 0] = np.where(
            has_q, qt_order[np.minimum(pick, qt_order.size - 1)], -1)

    passage_tokens = tm.passages(entity_topic, passage_len, ent_q)

    # query -> relevant entities (for two-hop neighbour intent sampling)
    rel = np.full((num_queries, qrels_per_query), -1, np.int64)
    order = np.argsort(q_ids, kind="stable")
    qs2, es2 = q_ids[order], e_ids[order]
    starts = np.concatenate([[True], qs2[1:] != qs2[:-1]])
    rank = np.arange(qs2.size) - np.maximum.accumulate(
        np.where(starts, np.arange(qs2.size), 0))
    sel = rank < qrels_per_query
    rel[qs2[sel], rank[sel]] = es2[sel]

    # neighbour queries: random query of a random relevant entity
    R2 = 6
    re_pick = rel[np.arange(num_queries)[:, None],
                  rng.integers(0, rel.shape[1], (num_queries, R2))]
    nb = np.where(re_pick >= 0,
                  ent_q[np.maximum(re_pick, 0),
                        rng.integers(0, M, (num_queries, R2))], -1)
    own = np.repeat(np.arange(num_queries, dtype=np.int64)[:, None], 4, 1)
    qsrc = np.concatenate([own, nb], axis=1)     # half own, half two-hop
    query_tokens = tm.queries(query_topic, query_len, qsrc)

    # pad the relational table to a static length
    n = q_ids.shape[0]
    n_pad = ((n + pad_multiple - 1) // pad_multiple) * pad_multiple
    pad = n_pad - n
    qrels = QRelTable(
        query_ids=np.concatenate([q_ids, np.zeros(pad, np.int32)]),
        entity_ids=np.concatenate([e_ids, np.zeros(pad, np.int32)]),
        scores=np.concatenate([scores, np.zeros(pad, np.float32)]),
        valid=np.concatenate([np.ones(n, bool), np.zeros(pad, bool)]),
    )
    return SyntheticCorpus(qrels, num_queries, num_entities, num_primary,
                           passage_tokens, query_tokens,
                           entity_topic, query_topic, vocab_size)
