"""Zero-dependency structured span tracer (DESIGN.md §12).

One process-global tracer produces nested, attributed spans:

    from repro.obs import trace

    with trace.span("eval.sample", sampler="windtunnel") as sp:
        ...
        sp.set(n_entities=int(mask.sum()))

Spans record wall time (``perf_counter``), a span/parent id pair (so a
reader can reconstruct the nesting), and free-form JSON attributes, and are
appended to a JSONL sink — one JSON object per line, written as each span
closes.

The JAX-aware variant understands asynchronous dispatch: a plain timer
around a jitted call measures dispatch, not execution.  ``jax_span``
lets the caller *declare* the outputs whose completion the span should
cover; on exit the tracer calls ``jax.block_until_ready`` on them and
records the blocked tail separately (``block_s``), so the span's duration
is the true wall time of the computation:

    with trace.jax_span("sampling.labels", engine="ell") as sp:
        labels, changes = _labels_stage(...)
        sp.declare(labels, changes)

Compile vs execute: the first call of a jitted function pays tracing +
XLA compilation; steady-state calls do not.  ``jax_span`` tags each span
with ``first`` — True the first time its compile key (span name by
default, override with ``compile_key=``) is seen in the process — so a
reader can split compile-dominated first calls from steady-state
execution (``launch/trace.py`` reports the per-stage compile share).

Disabled is the default and is a strict no-op fast path: ``span()`` /
``jax_span()`` return one shared :data:`NOOP` singleton — no span object
is allocated, nothing is retained, nothing is written (enforced by
tests/test_obs.py).  Enable with the ``REPRO_TRACE=<path>`` environment
variable (honoured at import) or programmatically / via the CLIs'
``--trace <path>`` flag through :func:`enable`.
"""
from __future__ import annotations

import atexit
import itertools
import json
import os
import threading
import time
from typing import Any, Dict, Optional

ENV_VAR = "REPRO_TRACE"

__all__ = ["ENV_VAR", "NOOP", "Span", "configure_from_env", "disable",
           "enable", "enabled_path", "is_enabled", "jax_span", "span"]


class _NoopSpan:
    """Shared do-nothing span: the disabled tracer's entire surface."""

    __slots__ = ()

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **attrs) -> "_NoopSpan":
        return self

    def declare(self, *outputs) -> "_NoopSpan":
        return self


NOOP = _NoopSpan()


class _State:
    """Process-global tracer state (one sink, one span-id sequence)."""

    def __init__(self) -> None:
        self.enabled = False
        self.path: Optional[str] = None
        self.sink = None                  # open file handle when enabled
        self.lock = threading.Lock()
        self.ids = itertools.count(1)
        self.local = threading.local()    # .stack: per-thread open span ids
        self.seen_first: set = set()      # compile keys already traced
        self.records_written = 0          # testability: sink write count


_STATE = _State()


def _stack() -> list:
    stack = getattr(_STATE.local, "stack", None)
    if stack is None:
        stack = _STATE.local.stack = []
    return stack


def enable(path: str) -> None:
    """Open ``path`` as the process-global JSONL sink and start tracing.
    Parent directories are created; re-enabling to the same path appends."""
    disable()
    dirname = os.path.dirname(path)
    if dirname:
        os.makedirs(dirname, exist_ok=True)
    _STATE.sink = open(path, "a", encoding="utf-8")
    _STATE.path = path
    _STATE.enabled = True


def disable() -> None:
    """Stop tracing and close the sink (idempotent)."""
    _STATE.enabled = False
    sink, _STATE.sink, _STATE.path = _STATE.sink, None, None
    if sink is not None:
        try:
            sink.close()
        except OSError:
            pass


def is_enabled() -> bool:
    return _STATE.enabled


def enabled_path() -> Optional[str]:
    return _STATE.path


def _write(record: Dict[str, Any]) -> None:
    with _STATE.lock:
        sink = _STATE.sink
        if sink is None:
            return
        sink.write(json.dumps(record, default=str) + "\n")
        sink.flush()
        _STATE.records_written += 1


class Span:
    """One live span; created only while tracing is enabled."""

    __slots__ = ("name", "attrs", "span_id", "parent_id", "_jax",
                 "_compile_key", "_outputs", "_t0", "_wall0")

    def __init__(self, name: str, attrs: Dict[str, Any], *,
                 jax_aware: bool = False,
                 compile_key: Optional[str] = None):
        self.name = name
        self.attrs = attrs
        self._jax = jax_aware
        self._compile_key = compile_key if compile_key is not None else name
        self._outputs: list = []
        self.span_id = 0
        self.parent_id: Optional[int] = None

    def __enter__(self) -> "Span":
        stack = _stack()
        self.parent_id = stack[-1] if stack else None
        self.span_id = next(_STATE.ids)
        stack.append(self.span_id)
        self._wall0 = time.time()
        self._t0 = time.perf_counter()
        return self

    def set(self, **attrs) -> "Span":
        """Attach/overwrite attributes on the open span."""
        self.attrs.update(attrs)
        return self

    def declare(self, *outputs) -> "Span":
        """Declare JAX outputs the span must wait for on exit
        (``jax_span`` only; a plain span ignores the block step)."""
        self._outputs.extend(outputs)
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        block_s = None
        if self._jax and self._outputs and exc_type is None:
            import jax
            t_block = time.perf_counter()
            jax.block_until_ready(self._outputs)
            block_s = time.perf_counter() - t_block
        dur_s = time.perf_counter() - self._t0
        stack = _stack()
        if stack and stack[-1] == self.span_id:
            stack.pop()
        record: Dict[str, Any] = {
            "name": self.name, "id": self.span_id,
            "parent": self.parent_id, "t0": self._wall0,
            "dur_s": dur_s,
        }
        if self._jax:
            first = self._compile_key not in _STATE.seen_first
            _STATE.seen_first.add(self._compile_key)
            record["first"] = first
            if block_s is not None:
                record["block_s"] = block_s
        if exc_type is not None:
            record["error"] = f"{exc_type.__name__}: {exc}"
        if self.attrs:
            record["attrs"] = self.attrs
        _write(record)
        return False


def span(name: str, **attrs):
    """Start a structured span; a shared no-op when tracing is disabled."""
    if not _STATE.enabled:
        return NOOP
    return Span(name, attrs)


def jax_span(name: str, *, compile_key: Optional[str] = None, **attrs):
    """JAX-aware span: ``declare(*outputs)`` inside the block and the span
    blocks on them at exit (``block_s``), tagging the record with ``first``
    (compile) vs steady-state per ``compile_key`` (default: the name)."""
    if not _STATE.enabled:
        return NOOP
    return Span(name, attrs, jax_aware=True, compile_key=compile_key)


def configure_from_env() -> None:
    """Enable tracing when ``REPRO_TRACE`` names a sink path (import-time
    hook; a blank / ``off`` / ``0`` value keeps the tracer disabled)."""
    path = os.environ.get(ENV_VAR, "").strip()
    if path and path.lower() not in ("0", "off", "none"):
        enable(path)


configure_from_env()
atexit.register(disable)
