"""Shared timing + provenance helpers for the benchmark harness.

``timeit`` is the one benchmark timer (DESIGN.md §12): warm up once with
``block_until_ready`` so compilation is fully retired before t0, then
report the mean wall microseconds of n fully-retired calls — the contract
``benchmarks/run.py`` rows have always used, now owned by the obs layer so
every bench and the autotuner measure the same way.

``provenance`` stamps the host/device/toolchain identity (platform, JAX
version, backend, device kind/count, git SHA) into bench and trace
artifacts — perf trajectories across machines are uninterpretable
without it.
"""
from __future__ import annotations

import os
import platform
import subprocess
import time
from typing import Callable, Optional

__all__ = ["git_sha", "provenance", "timeit"]


def timeit(fn: Callable, n: int = 3) -> float:
    """Mean wall microseconds of ``fn()`` over ``n`` fully-retired calls,
    after one warmup call (compile + dispatch retired before timing)."""
    import jax
    jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / n * 1e6


def git_sha(cwd: Optional[str] = None) -> Optional[str]:
    """Short git SHA of the working tree (CI env fallback), else None."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=cwd,
            capture_output=True, text=True, timeout=5)
        if out.returncode == 0 and out.stdout.strip():
            return out.stdout.strip()
    except (OSError, subprocess.SubprocessError):
        pass
    sha = os.environ.get("GITHUB_SHA")
    return sha[:12] if sha else None


def provenance() -> dict:
    """Host/device/toolchain identity for bench + trace artifacts."""
    import jax
    dev = jax.devices()[0]
    return {
        "platform": platform.platform(),
        "python": platform.python_version(),
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "device_kind": dev.device_kind,
        "device_count": jax.device_count(),
        "git_sha": git_sha(os.path.dirname(os.path.abspath(__file__))),
    }
