"""Instrumented debug locks (DESIGN.md §15): runtime complement to the
``conc-lock-order`` static rule.

:func:`make_lock` / :func:`make_rlock` are what ``serve/`` uses to create
its locks.  In production they return plain ``threading`` primitives —
zero overhead.  With ``REPRO_DEBUG_LOCKS=1`` (or after :func:`enable`)
they return :class:`DebugLock` wrappers that record, per acquisition:

  * the **acquisition-order edge** held-lock -> new-lock, into a global
    edge set; :func:`inversions` reports every pair of locks observed in
    both orders — the dynamic witness of a potential deadlock the static
    lock-order graph can only approximate;
  * a per-lock **acquire count** (:func:`acquire_counts`), which is what
    the regression tests assert — e.g. "reading ``LiveIndex.pending_rows``
    acquires the index lock" becomes a counted fact instead of a comment.

State is process-global and lock-protected; :func:`reset` clears it
between tests.  The wrapper is context-manager compatible with the plain
primitives (``with lock:``, ``acquire(timeout=...)``, ``release``), so
enabling debug mode changes observability, never semantics.
"""
from __future__ import annotations

import os
import threading
from typing import Dict, List, Set, Tuple

__all__ = ["DebugLock", "make_lock", "make_rlock", "enable", "disable",
           "is_enabled", "edges", "inversions", "acquire_counts", "reset"]


class _Tracker:
    def __init__(self) -> None:
        self.lock = threading.Lock()
        self.enabled = os.environ.get("REPRO_DEBUG_LOCKS", "") not in (
            "", "0", "false")
        self.edges: Set[Tuple[str, str]] = set()
        self.acquires: Dict[str, int] = {}
        self.local = threading.local()


_TRACKER = _Tracker()


def enable() -> None:
    """Hand out DebugLock wrappers from make_lock()/make_rlock()."""
    _TRACKER.enabled = True


def disable() -> None:
    _TRACKER.enabled = False


def is_enabled() -> bool:
    return _TRACKER.enabled


def _held_stack() -> List[str]:
    stack = getattr(_TRACKER.local, "held", None)
    if stack is None:
        stack = _TRACKER.local.held = []
    return stack


class DebugLock:
    """A named lock recording acquisition order and counts.

    Wraps ``threading.Lock`` or ``threading.RLock``; re-entrant acquires
    of an RLock are counted but add no self-edges.
    """

    def __init__(self, name: str, reentrant: bool = False):
        self.name = name
        self._inner = threading.RLock() if reentrant else threading.Lock()

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        ok = self._inner.acquire(blocking, timeout)
        if ok:
            held = _held_stack()
            with _TRACKER.lock:
                _TRACKER.acquires[self.name] = \
                    _TRACKER.acquires.get(self.name, 0) + 1
                for h in held:
                    if h != self.name:
                        _TRACKER.edges.add((h, self.name))
            held.append(self.name)
        return ok

    def release(self) -> None:
        held = _held_stack()
        # remove the innermost occurrence (RLocks release in any depth)
        for i in range(len(held) - 1, -1, -1):
            if held[i] == self.name:
                del held[i]
                break
        self._inner.release()

    def __enter__(self) -> "DebugLock":
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()


def make_lock(name: str):
    """A mutex for ``serve``-tier state: plain ``threading.Lock`` in
    production, :class:`DebugLock` under REPRO_DEBUG_LOCKS."""
    return DebugLock(name) if _TRACKER.enabled else threading.Lock()


def make_rlock(name: str):
    """Re-entrant variant of :func:`make_lock`."""
    return DebugLock(name, reentrant=True) if _TRACKER.enabled \
        else threading.RLock()


def edges() -> Set[Tuple[str, str]]:
    """Observed acquisition-order edges (held -> acquired)."""
    with _TRACKER.lock:
        return set(_TRACKER.edges)


def inversions() -> List[Tuple[str, str]]:
    """Lock pairs observed in both orders — each is a latent deadlock."""
    with _TRACKER.lock:
        return sorted({(a, b) for (a, b) in _TRACKER.edges
                       if a < b and (b, a) in _TRACKER.edges})


def acquire_counts() -> Dict[str, int]:
    """Acquisitions per lock name since reset()."""
    with _TRACKER.lock:
        return dict(_TRACKER.acquires)


def reset() -> None:
    """Clear edges and counts (tests); leaves enablement untouched."""
    with _TRACKER.lock:
        _TRACKER.edges.clear()
        _TRACKER.acquires.clear()
