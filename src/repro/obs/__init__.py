"""Unified observability layer (DESIGN.md §12): structured span tracing,
a metrics registry, and the shared benchmark timer.

  * :mod:`repro.obs.trace`   — nested spans -> JSONL sink; strict no-op
    when disabled (the default); ``REPRO_TRACE=<path>`` or ``--trace``
    enables it.  Read traces back with ``python -m repro.launch.trace``.
  * :mod:`repro.obs.metrics` — counters / gauges / fixed-bucket
    histograms with p50/p90/p99, snapshot-to-dict for JSON export.
  * :mod:`repro.obs.timing`  — ``timeit`` (the bench timer) and
    ``provenance`` (host/device/git identity for artifacts).
  * :mod:`repro.obs.memory`  — per-device resident-bytes accounting and
    the ``build.peak_bytes_per_device`` gauge for the streaming build path.
  * :mod:`repro.obs.recompile` — XLA recompile sentinel: per-region
    compilation counts, asserted zero in steady state by serve-smoke CI.
  * :mod:`repro.obs.locks`   — instrumented debug locks recording
    acquisition order and counts (``REPRO_DEBUG_LOCKS=1``).
"""
from repro.obs import locks, memory, recompile, trace
from repro.obs.locks import make_lock, make_rlock
from repro.obs.metrics import (DEFAULT_BUCKETS, REGISTRY, Counter, Gauge,
                               Histogram, Registry)
from repro.obs.timing import git_sha, provenance, timeit

__all__ = ["locks", "memory", "recompile", "trace", "make_lock",
           "make_rlock", "DEFAULT_BUCKETS", "REGISTRY", "Counter", "Gauge",
           "Histogram", "Registry", "git_sha", "provenance", "timeit"]
