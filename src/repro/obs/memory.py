"""Per-device memory accounting for the streaming build path (DESIGN.md
§13).

The sharded-from-birth corpus machinery exists to keep per-device memory
O(corpus / n_shards + chunk); this module is how that claim is *observed*
rather than asserted.  :func:`bytes_per_device` reads the allocator's
high-water mark where the platform exposes one (``device.memory_stats()``
on TPU/GPU), and falls back to summing the addressable shards of every
live ``jax.Array`` per device elsewhere (the CPU backend reports no
allocator stats) — the fallback is an instantaneous residency figure, not
a true peak, but it is exactly what the build keeps resident, which is the
quantity the streaming path bounds.

:func:`record_build_peak` publishes the worst device as the
``build.peak_bytes_per_device`` gauge; the session front doors call it
after every index / graph build so the figure lands in ``--metrics-json``
exports and the benchmark rows (benchmarks/run.py ``peak_bytes_per_device``
column).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax

from repro.obs.metrics import REGISTRY, Registry

__all__ = ["PEAK_GAUGE", "bytes_per_device", "record_build_peak"]

#: gauge name for the per-device build high-water mark
PEAK_GAUGE = "build.peak_bytes_per_device"


def _allocator_stats(device) -> Optional[int]:
    try:
        stats = device.memory_stats()
    except Exception:  # platform without allocator stats (CPU)
        return None
    if not stats:
        return None
    for key in ("peak_bytes_in_use", "bytes_in_use"):
        if key in stats:
            return int(stats[key])
    return None


def bytes_per_device() -> Dict[str, int]:
    """device -> resident bytes: allocator peak where available, live-array
    shard accounting otherwise."""
    devices = jax.local_devices()
    per = {}
    for dev in devices:
        val = _allocator_stats(dev)
        if val is None:
            break
        per[str(dev)] = val
    else:
        return per
    # fallback: sum the addressable shards of every live array per device
    per = {str(dev): 0 for dev in devices}
    for arr in jax.live_arrays():
        try:
            shards = arr.addressable_shards
        except Exception:
            continue
        for sh in shards:
            key = str(sh.device)
            if key in per and sh.data is not None:
                per[key] += int(sh.data.nbytes)
    return per


def record_build_peak(registry: Registry = REGISTRY) -> int:
    """Publish max-over-devices resident bytes as the build gauge."""
    per = bytes_per_device()
    peak = max(per.values(), default=0)
    registry.gauge(PEAK_GAUGE).set(peak)
    return int(peak)
