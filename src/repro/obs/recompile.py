"""Recompile sentinel (DESIGN.md §15): count XLA compilations per
compile-key at runtime.

The static analyzer flags retrace *amplifiers* (unbounded static args);
this module catches the retraces that actually happen.  It hooks
``jax.monitoring`` — XLA fires ``/jax/core/compile/backend_compile_duration``
once per backend compilation — and attributes each compilation to the
innermost active :func:`region` on the calling thread (compilation runs
synchronously on the thread that triggered the trace, so thread-local
attribution is exact).

The serving contract this enforces: after the scheduler's warmup pass has
touched every (bucket, k) shape, **steady state never recompiles**.
``launch/serve.py --recompile-check N`` runs warmup, calls :func:`mark`,
ticks N more times, and fails the process when :func:`since` is nonzero —
CI's serve-smoke job asserts exactly that.

Usage::

    from repro.obs import recompile
    recompile.enable()
    with recompile.region("serve.tick"):
        session.search_scored(q, k=k)
    recompile.counts()   # {"serve.tick": 1} on the cold call, then stable

Counting is disabled by default and costs one thread-local read per
compilation event when enabled — nothing on the dispatch fast path.  The
listener itself is registered at most once per process (JAX offers no
per-listener unregistration), gated by the enabled flag.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Iterator, Optional

from repro.obs.metrics import REGISTRY

__all__ = ["enable", "disable", "is_enabled", "region", "counts", "total",
           "mark", "since", "reset", "UNATTRIBUTED", "COMPILE_EVENT"]

#: the jax.monitoring event fired once per backend (XLA) compilation
COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"

#: key for compilations that happen outside any region()
UNATTRIBUTED = "unattributed"


class _State:
    def __init__(self) -> None:
        self.lock = threading.Lock()
        self.enabled = False
        self.listener_registered = False
        self.counts: Dict[str, int] = {}
        self.marked: Dict[str, int] = {}
        self.local = threading.local()


_STATE = _State()


def _region_key() -> str:
    stack = getattr(_STATE.local, "stack", None)
    return stack[-1] if stack else UNATTRIBUTED


def _on_event(event: str, duration: float, **kwargs) -> None:
    if not _STATE.enabled or not event.startswith(COMPILE_EVENT):
        return
    key = _region_key()
    with _STATE.lock:
        _STATE.counts[key] = _STATE.counts.get(key, 0) + 1
    REGISTRY.counter(f"recompile.{key}").inc()


def _ensure_listener() -> None:
    if _STATE.listener_registered:
        return
    import jax.monitoring
    jax.monitoring.register_event_duration_secs_listener(_on_event)
    _STATE.listener_registered = True


def enable() -> None:
    """Start counting compilations (registers the JAX listener once)."""
    _ensure_listener()
    _STATE.enabled = True


def disable() -> None:
    """Stop counting (the listener stays registered but inert)."""
    _STATE.enabled = False


def is_enabled() -> bool:
    return _STATE.enabled


@contextlib.contextmanager
def region(key: str) -> Iterator[None]:
    """Attribute compilations on this thread to ``key`` while active.
    Regions nest; the innermost wins."""
    stack = getattr(_STATE.local, "stack", None)
    if stack is None:
        stack = _STATE.local.stack = []
    stack.append(key)
    try:
        yield
    finally:
        stack.pop()


def counts() -> Dict[str, int]:
    """Compilations per region key since enable()/reset()."""
    with _STATE.lock:
        return dict(_STATE.counts)


def total(key: Optional[str] = None) -> int:
    """Total compilations (or for one key) since enable()/reset()."""
    with _STATE.lock:
        if key is not None:
            return _STATE.counts.get(key, 0)
        return sum(_STATE.counts.values())


def mark() -> None:
    """Snapshot the current counts — the end-of-warmup waterline."""
    with _STATE.lock:
        _STATE.marked = dict(_STATE.counts)


def since(key: Optional[str] = None) -> int:
    """Compilations since the last mark() (all keys, or one)."""
    with _STATE.lock:
        if key is not None:
            return _STATE.counts.get(key, 0) - _STATE.marked.get(key, 0)
        return (sum(_STATE.counts.values())
                - sum(_STATE.marked.values()))


def reset() -> None:
    """Zero all counts and the mark (tests)."""
    with _STATE.lock:
        _STATE.counts.clear()
        _STATE.marked.clear()
