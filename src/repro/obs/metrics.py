"""Lightweight metrics registry: counters, gauges, fixed-bucket histograms
(DESIGN.md §12).

A :class:`Registry` is a named bag of instruments with get-or-create
semantics (`registry.counter("sampling.draw.miss").inc()`) and a
``snapshot()`` that renders every instrument to plain JSON-able values —
the export surface the CLIs' ``--metrics-json`` flag and the CI artifacts
consume.  One process-global :data:`REGISTRY` serves the instrumented
subsystems (serve latency, draw-cache hits, tuned-table hits); components
that need isolated counters (e.g. one :class:`~repro.eval.plans.PlanTrie`
per grid run) construct their own Registry.

Histograms use fixed upper-bound buckets (default: a latency ladder from
100 µs to 60 s) so ``observe()`` is O(log B) with constant memory, and
``percentile(p)`` reads p50/p90/p99 back out by linear interpolation
inside the covering bucket — exact at bucket edges, bounded error inside
(tested against hand-computed fixtures in tests/test_obs.py).  Values
above the last bucket land in an overflow bucket whose percentile
estimate is the observed maximum.

Naming convention: dot-separated ``<subsystem>.<thing>[.<qualifier>]``,
units suffixed when ambiguous (``_s`` seconds, ``_bytes``) — e.g.
``serve.request_latency_s``, ``tuning.resolve.hit``, ``plan.executions.
sample``.
"""
from __future__ import annotations

import bisect
import math
import threading
from typing import Dict, Iterable, Optional, Sequence, Tuple

__all__ = ["Counter", "Gauge", "Histogram", "Registry", "REGISTRY",
           "DEFAULT_BUCKETS"]

#: default histogram upper bounds (seconds): 1 µs .. 60 s latency ladder.
#: The sub-100 µs rungs exist for the serving tier — a warm microbatched
#: search on a small tenant completes in tens of microseconds, and a
#: ladder that starts at 100 µs reports every such request as "< 1e-4",
#: making p50 vs p99 indistinguishable exactly where the scheduler's
#: batching decisions show up.
DEFAULT_BUCKETS: Tuple[float, ...] = (
    1e-6, 2.5e-6, 5e-6, 1e-5, 2.5e-5, 5e-5,
    1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0)


class Counter:
    """Monotonically increasing count."""

    __slots__ = ("name", "_value")

    def __init__(self, name: str):
        self.name = name
        self._value = 0

    def inc(self, n: int = 1) -> None:
        self._value += n

    @property
    def value(self) -> int:
        return self._value


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("name", "_value")

    def __init__(self, name: str):
        self.name = name
        self._value = 0.0

    def set(self, value: float) -> None:
        self._value = float(value)

    @property
    def value(self) -> float:
        return self._value


class Histogram:
    """Fixed-bucket histogram with interpolated percentiles.

    ``buckets`` are ascending upper bounds; an implicit overflow bucket
    catches everything above the last bound.
    """

    __slots__ = ("name", "uppers", "counts", "count", "sum", "_min", "_max")

    def __init__(self, name: str,
                 buckets: Optional[Sequence[float]] = None):
        self.name = name
        self.uppers = tuple(sorted(
            DEFAULT_BUCKETS if buckets is None else buckets))
        if not self.uppers:
            raise ValueError("histogram needs at least one bucket bound")
        self.counts = [0] * (len(self.uppers) + 1)   # +1: overflow
        self.count = 0
        self.sum = 0.0
        self._min = math.inf
        self._max = -math.inf

    def observe(self, value: float) -> None:
        value = float(value)
        self.counts[bisect.bisect_left(self.uppers, value)] += 1
        self.count += 1
        self.sum += value
        self._min = min(self._min, value)
        self._max = max(self._max, value)

    @property
    def min(self) -> float:
        return self._min if self.count else 0.0

    @property
    def max(self) -> float:
        return self._max if self.count else 0.0

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def percentile(self, p: float) -> float:
        """Estimated p-th percentile (p in [0, 100]) by linear
        interpolation inside the covering bucket; 0.0 when empty."""
        if not 0 <= p <= 100:
            raise ValueError(f"percentile {p!r} outside [0, 100]")
        if self.count == 0:
            return 0.0
        rank = p / 100.0 * self.count
        cum = 0
        for i, n in enumerate(self.counts):
            if n == 0:
                continue
            if cum + n >= rank:
                if i == len(self.uppers):          # overflow bucket
                    return self._max
                lo = 0.0 if i == 0 else self.uppers[i - 1]
                hi = self.uppers[i]
                frac = (rank - cum) / n
                # clamp into the actually observed range
                return min(max(lo + frac * (hi - lo), self._min), self._max)
            cum += n
        return self._max

    def percentiles(self) -> Dict[str, float]:
        return {"p50": self.percentile(50), "p90": self.percentile(90),
                "p99": self.percentile(99)}

    def to_dict(self) -> dict:
        return {"count": self.count, "sum": self.sum, "mean": self.mean,
                "min": self.min, "max": self.max, **self.percentiles()}


class Registry:
    """Get-or-create instrument store with a JSON-able snapshot."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        with self._lock:
            inst = self._counters.get(name)
            if inst is None:
                inst = self._counters[name] = Counter(name)
            return inst

    def gauge(self, name: str) -> Gauge:
        with self._lock:
            inst = self._gauges.get(name)
            if inst is None:
                inst = self._gauges[name] = Gauge(name)
            return inst

    def histogram(self, name: str,
                  buckets: Optional[Sequence[float]] = None) -> Histogram:
        with self._lock:
            inst = self._histograms.get(name)
            if inst is None:
                inst = self._histograms[name] = Histogram(name, buckets)
            return inst

    def counters(self) -> Iterable[Counter]:
        with self._lock:
            return list(self._counters.values())

    def snapshot(self) -> dict:
        """Every instrument rendered to plain values (the JSON export)."""
        with self._lock:
            return {
                "counters": {n: c.value
                             for n, c in sorted(self._counters.items())},
                "gauges": {n: g.value
                           for n, g in sorted(self._gauges.items())},
                "histograms": {n: h.to_dict()
                               for n, h in sorted(self._histograms.items())},
            }

    def reset(self) -> None:
        """Drop every instrument (tests; never called on the hot path)."""
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._histograms.clear()


#: process-global default registry — what the instrumented subsystems use
REGISTRY = Registry()
