"""Per-tenant session cache for the serving tier (DESIGN.md §14).

A multi-tenant server holds one built index per (tenant, search
configuration) — each is a :class:`~repro.serve.ingest.LiveIndex` (or a
bare :class:`~repro.retrieval.search_core.SearchSession`) whose device
buffers are the dominant memory cost.  :class:`TenantCache` bounds that
cost with an LRU over live sessions: a hit returns the resident session,
a miss builds one through the caller's provider, and eviction drops the
session reference so its device buffers free with the last in-flight
search.  Eviction is safe-by-construction: a session is pure state plus
pure compute, so an evicted tenant's next request just rebuilds (a cold
``search.build``, visible in the trace), and results are identical.

Observability (the shared registry): ``serve.tenant.hit`` /
``serve.tenant.miss`` / ``serve.tenant.evict`` counters and a
``serve.tenant.resident_bytes`` gauge sampled from
``obs/memory.bytes_per_device`` after every build/evict — the same
device-buffer accounting the build paths record
(``build.peak_bytes_per_device``).

The generic :class:`LRUCache` is also what bounds the RAG frontend's
context cache (serve/engine.py) — one eviction policy, two tiers.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Any, Callable, Hashable, Optional, Tuple

from repro.obs import REGISTRY
from repro.obs import memory as obs_memory
from repro.obs.locks import make_lock
from repro.obs.metrics import Registry

__all__ = ["LRUCache", "TenantCache", "RESIDENT_GAUGE"]

RESIDENT_GAUGE = "serve.tenant.resident_bytes"


class LRUCache:
    """Minimal thread-safe LRU: ``get`` promotes, ``put`` evicts the least
    recently used entry past ``capacity`` and hands it to ``on_evict``
    (called outside the lock — evict handlers may do real work)."""

    def __init__(self, capacity: int,
                 on_evict: Optional[Callable[[Hashable, Any], None]] = None):
        if capacity < 1:
            raise ValueError(f"cache capacity must be >= 1; got {capacity}")
        self.capacity = capacity
        self._on_evict = on_evict
        self._items: "OrderedDict[Hashable, Any]" = OrderedDict()
        self._lock = make_lock("lru-cache")

    def __len__(self) -> int:
        with self._lock:
            return len(self._items)

    def __contains__(self, key: Hashable) -> bool:
        with self._lock:
            return key in self._items

    def get(self, key: Hashable, default: Any = None) -> Any:
        with self._lock:
            if key not in self._items:
                return default
            self._items.move_to_end(key)
            return self._items[key]

    def put(self, key: Hashable, value: Any) -> None:
        evicted = []
        with self._lock:
            self._items[key] = value
            self._items.move_to_end(key)
            while len(self._items) > self.capacity:
                evicted.append(self._items.popitem(last=False))
        for ekey, evalue in evicted:
            if self._on_evict is not None:
                self._on_evict(ekey, evalue)

    def pop(self, key: Hashable, default: Any = None) -> Any:
        with self._lock:
            return self._items.pop(key, default)

    def keys(self) -> Tuple[Hashable, ...]:
        with self._lock:
            return tuple(self._items)


class TenantCache:
    """LRU of per-tenant live search sessions.

    ``provider(tenant)`` builds the session for a tenant on a miss — the
    server owns corpus loading and configuration; the cache owns residency.
    ``capacity`` bounds how many tenants hold device buffers at once."""

    def __init__(self, provider: Callable[[str], Any], *, capacity: int = 8,
                 registry: Registry = REGISTRY):
        self._provider = provider
        self._registry = registry
        self._build_lock = make_lock("tenant-build")
        self._lru = LRUCache(capacity, on_evict=self._evicted)

    def _sample_resident(self) -> None:
        self._registry.gauge(RESIDENT_GAUGE).set(
            float(max(obs_memory.bytes_per_device().values(), default=0.0)))

    def _evicted(self, tenant: Hashable, session: Any) -> None:
        self._registry.counter("serve.tenant.evict").inc()
        flush = getattr(session, "flush", None)
        if callable(flush):
            flush()    # let an in-flight compaction land before the drop
        self._sample_resident()

    def get(self, tenant: str) -> Any:
        """The tenant's resident session, building (and possibly evicting)
        on a miss."""
        session = self._lru.get(tenant)
        if session is not None:
            self._registry.counter("serve.tenant.hit").inc()
            return session
        # one build at a time: concurrent misses for the same tenant must
        # not build twice (device memory spike), and provider builds are
        # the expensive path anyway
        with self._build_lock:
            session = self._lru.get(tenant)
            if session is not None:
                self._registry.counter("serve.tenant.hit").inc()
                return session
            self._registry.counter("serve.tenant.miss").inc()
            session = self._provider(tenant)
            self._lru.put(tenant, session)
            self._sample_resident()
            return session

    def evict(self, tenant: str) -> bool:
        """Explicitly drop one tenant's session (admin path)."""
        session = self._lru.pop(tenant)
        if session is None:
            return False
        self._evicted(tenant, session)
        return True

    @property
    def resident(self) -> Tuple[Hashable, ...]:
        return self._lru.keys()

    def __len__(self) -> int:
        return len(self._lru)
