"""Incremental corpus ingestion for the serving tier (DESIGN.md §14).

A :class:`LiveIndex` is a :class:`~repro.retrieval.search_core.
SearchSession` that accepts new documents while it serves: ``append(docs)``
lands rows in a fixed-capacity append buffer that every search scans
alongside the frozen index, and a compaction threshold triggers a
background rebuild through the normal session build path (``sharded_build``
on the streamed path) — serving never stops for a reindex.

Dataflow per search::

    queries ──> frozen SearchSession.search_scored ──┐
            └─> append-buffer exact scan ────────────┴─> score merge, top-k

The two sides merge by score, which works because every engine's
``search_scored`` returns its FINAL ranking scores as inner products
(lsh must therefore run with ``rerank > 0`` — enforced at construction;
the no-rerank Hamming scale is not comparable to a dot product).  The
buffer is scanned in f32 regardless of the session backend: buffers are
small, and quantization is a bandwidth optimisation for the big frozen
index, not its tail.

tf-idf is the one engine whose index statistics go stale under appends:
the frozen rows have ``w = log1p(n/df)`` folded in at build time.  Rather
than rebuilding per append, the O(D) document-frequency vector is
maintained incrementally and the refreshed weights fold into the QUERY:
``q ⊙ (w_live / w_frozen)`` scores the frozen rows exactly as a rebuild
would (``(q ⊙ w'/w) · (v ⊙ w) = q · (v ⊙ w')``), and ``q ⊙ w_live``
scores the raw buffer rows — so append-then-search stays set-equal to a
from-scratch rebuild without touching the index.

Buffer mechanics: capacity is fixed per compiled shape and grows
geometrically (so steady-state appends and searches never retrace — the
live-row count is a dynamic scalar), rows land via a jitted
``dynamic_update_slice`` (NOT donated: an in-flight search may still hold
the previous buffer), and on the sharded path the buffer is one more
shard-local structure built with the ``distributed/sharded_corpus.py``
streaming geometry and merged through the same all-gather + top-k path as
every sharded engine plan (``retrieval/sharded.sharded_buffer_topk``).

Compaction: when pending rows reach ``compact_threshold``, the pending
prefix is folded into a NEW session built on a worker thread from the host
mirror while searches keep hitting the old (session, buffer) snapshot;
the swap happens under the lock, rows appended mid-build stay pending, and
ids are stable across compactions (append order is the global id order).
"""
from __future__ import annotations

import dataclasses
import functools
import threading
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.distributed.sharded_corpus import sharded_row_buffer
from repro.obs import REGISTRY, trace
from repro.obs.locks import make_rlock
from repro.obs.metrics import Registry
from repro.retrieval.search_core import SearchConfig, SearchSession
from repro.retrieval.sharded import sharded_buffer_topk

__all__ = ["IngestConfig", "LiveIndex"]


@dataclasses.dataclass(frozen=True)
class IngestConfig:
    """Live-ingest knobs.

    ``append_cap`` is the initial device-buffer capacity in rows (grows by
    doubling — each growth is one new compiled shape, so leave headroom);
    ``compact_threshold`` is the pending-row count that triggers a rebuild;
    ``background=False`` compacts inline (deterministic; tests and
    single-threaded drivers)."""

    append_cap: int = 256
    compact_threshold: int = 4096
    background: bool = True


@functools.partial(jax.jit, static_argnames=("k", "id_base"))
def _buffer_topk(queries, buf, n_valid, *, k: int, id_base: int):
    """Exact top-k over the (single-device) append buffer: rows at position
    ≥ ``n_valid`` (a dynamic scalar — appends never retrace) mask to −inf
    and can never displace a live row; ids offset by the frozen size."""
    s = (queries @ buf.T).astype(jnp.float32)
    pos = jnp.arange(buf.shape[0], dtype=jnp.int32)
    s = jnp.where((pos < n_valid)[None, :], s, -jnp.inf)
    top_s, top_p = lax.top_k(s, k)
    top_i = jnp.where(jnp.isfinite(top_s), id_base + top_p, -1)
    return top_s, top_i


@functools.partial(jax.jit, donate_argnums=())
def _buffer_write(buf, rows, start):
    # deliberately NOT donated: a concurrent search may still hold the
    # previous buffer array (the lock covers the swap, not the compute)
    return lax.dynamic_update_slice(buf, rows, (start, jnp.int32(0)))


def _df_counts(rows: np.ndarray) -> np.ndarray:
    return (np.asarray(rows) > 0).sum(axis=0).astype(np.int64)


class LiveIndex:
    """Build-once-append-forever search target: a frozen
    :class:`SearchSession` plus a live append buffer, one ``search``/
    ``search_scored`` contract (scores f32[Q, k], ids i32[Q, k], −inf/−1
    padding), ids stable across compactions.

    Metrics (DESIGN.md §12, the shared registry): ``serve.ingest.appended``
    rows counter, ``serve.ingest.pending`` gauge, ``serve.ingest.
    compactions`` counter, ``serve.ingest.searches`` counter; compactions
    run under a ``serve.compact`` span.
    """

    def __init__(self, corpus_vecs, config: Optional[SearchConfig] = None,
                 *, key: Optional[jax.Array] = None,
                 ingest: Optional[IngestConfig] = None,
                 registry: Registry = REGISTRY, **overrides):
        self._host = np.ascontiguousarray(
            np.asarray(corpus_vecs, np.float32))
        if self._host.ndim != 2:
            raise ValueError(
                f"live corpus must be 2-D (N, D); got {self._host.shape}")
        self._lock = make_rlock("live-index")
        self.ingest = ingest or IngestConfig()
        if self.ingest.append_cap < 1 or self.ingest.compact_threshold < 1:
            raise ValueError("append_cap and compact_threshold must be >= 1")
        self._key = key if key is not None else jax.random.PRNGKey(0)
        self._registry = registry
        self._session = SearchSession(self._host, config, key=self._key,
                                      **overrides)
        cfg = self._session.config
        if cfg.engine == "lsh" and self._session.engine.rerank <= 0:
            raise ValueError(
                "live ingest needs score-comparable results to merge the "
                "append buffer; the lsh engine must run with rerank > 0 "
                "(no-rerank lsh ranks by Hamming distance, which cannot "
                "merge with the buffer's inner products)")
        self._tfidf = cfg.engine == "tfidf"
        self._frozen_df = (_df_counts(self._host) if self._tfidf else None)
        self._pending = np.zeros((0, self.dim), np.float32)
        self._cap = 0
        self._buf = None
        self._compactor: Optional[threading.Thread] = None
        self._compacting = False
        self._compact_error: Optional[BaseException] = None

    # -- geometry ----------------------------------------------------------
    # Every property below reads state the compactor swaps under the lock;
    # the RLock is re-entrant, so holders of the lock can use them freely.

    @property
    def dim(self) -> int:
        with self._lock:
            return int(self._host.shape[1])

    @property
    def frozen_n(self) -> int:
        """Rows covered by the frozen index (grows at each compaction)."""
        with self._lock:
            return self._session.corpus_size

    @property
    def pending_rows(self) -> int:
        with self._lock:
            return int(self._pending.shape[0])

    @property
    def n(self) -> int:
        """Total searchable rows (frozen + pending)."""
        with self._lock:
            return self.frozen_n + self.pending_rows

    @property
    def config(self) -> SearchConfig:
        with self._lock:
            return self._session.config

    # -- ingest ------------------------------------------------------------

    def _rebuild_buffer(self) -> None:
        """Re-materialise the device buffer from the pending host rows
        (capacity growth, post-compaction shrink, or any sharded append —
        the sharded buffer re-streams; it is small by construction).
        Takes the (re-entrant) lock itself: callers already hold it, but
        the buffer swap must never run bare."""
        with self._lock:
            cfg = self._session.config
            need = max(self.pending_rows, 1)
            cap = max(self._cap, self.ingest.append_cap)
            while cap < need:
                cap *= 2
            self._cap = cap
            if cfg.sharded:
                self._buf = sharded_row_buffer(
                    self._pending, capacity=cap, dim=self.dim,
                    mesh=cfg.mesh, chunk_rows=cfg.stream_chunk)
            else:
                padded = np.zeros((cap, self.dim), np.float32)
                padded[:self.pending_rows] = self._pending
                self._buf = jnp.asarray(padded)

    def append(self, docs) -> Tuple[int, int]:
        """Land new document vectors f32[m, D]; returns their global id
        range [start, stop) — stable across compactions (append order is
        the id order).  May trigger a (background) compaction."""
        rows = np.asarray(docs, np.float32).reshape(-1, self.dim)
        if rows.shape[0] == 0:
            return self.n, self.n
        self._raise_pending_error()
        with self._lock, trace.span("serve.ingest.append",
                                    rows=int(rows.shape[0])):
            start = self.frozen_n + self.pending_rows
            old = self.pending_rows
            self._pending = np.concatenate([self._pending, rows], axis=0)
            cfg = self._session.config
            if cfg.sharded or self._buf is None \
                    or self.pending_rows > self._cap:
                self._rebuild_buffer()
            else:
                self._buf = _buffer_write(self._buf, jnp.asarray(rows),
                                          jnp.int32(old))
            self._registry.counter("serve.ingest.appended").inc(
                int(rows.shape[0]))
            self._registry.gauge("serve.ingest.pending").set(
                self.pending_rows)
            stop = start + int(rows.shape[0])
            if self.pending_rows >= self.ingest.compact_threshold:
                self.compact(background=self.ingest.background)
        return start, stop

    # -- search ------------------------------------------------------------

    def _weights(self, frozen_n: int, frozen_df, pending: np.ndarray):
        """(w_frozen, w_live) for the tf-idf query-side refresh: the df
        vector is O(D) and maintained exactly (integer counts), so the live
        weights equal what a from-scratch rebuild over frozen+pending rows
        would fold into the corpus."""
        total = frozen_n + pending.shape[0]
        df_frozen = frozen_df.astype(np.float32) + 1.0
        df_live = (frozen_df + _df_counts(pending)).astype(np.float32) + 1.0
        w_frozen = np.log1p(np.float32(frozen_n) / df_frozen)
        w_live = np.log1p(np.float32(total) / df_live)
        return w_frozen, w_live

    def search_scored(self, queries, *, k: int):
        """(scores f32[Q, k], ids i32[Q, k]) over frozen + pending rows —
        one consistent snapshot: every row appended before this call is
        visible, during a compaction included (the swap is atomic under
        the lock, so there is never a stale-index window)."""
        self._raise_pending_error()
        with self._lock:
            session = self._session
            buf, n_pend, cap = self._buf, self.pending_rows, self._cap
            frozen_n = session.corpus_size
            frozen_df = self._frozen_df
            pending = self._pending
        self._registry.counter("serve.ingest.searches").inc()
        q = np.asarray(queries, np.float32)
        total = frozen_n + n_pend
        k_eff = max(1, min(k, total))
        q_frozen = q
        if self._tfidf and n_pend:
            w_frozen, w_live = self._weights(frozen_n, frozen_df, pending)
            q_frozen = q * (w_live / np.maximum(w_frozen, 1e-30))[None, :]
            q_buf = q * w_live[None, :]
        else:
            q_buf = q
        fs, fi = session.search_scored(q_frozen, k=k_eff)
        if n_pend == 0:
            if k_eff < k:
                fs = np.pad(fs, ((0, 0), (0, k - k_eff)),
                            constant_values=-np.inf)
                fi = np.pad(fi, ((0, 0), (0, k - k_eff)),
                            constant_values=-1)
            return fs, fi
        cfg = session.config
        k_buf = min(k_eff, cap)   # cap from the snapshot: matches buf's shape
        if cfg.sharded:
            bs, bi = sharded_buffer_topk(buf, n_pend, jnp.asarray(q_buf),
                                         k=k_buf, mesh=cfg.mesh,
                                         id_base=frozen_n)
        else:
            bs, bi = _buffer_topk(jnp.asarray(q_buf), buf,
                                  jnp.int32(n_pend), k=k_buf,
                                  id_base=frozen_n)
        scores = np.concatenate([fs, np.asarray(bs)], axis=1)
        ids = np.concatenate([fi, np.asarray(bi)], axis=1)
        # stable descending merge: ties break toward the frozen side (the
        # backend tie policy's lower-id-first, since pending ids are ≥
        # frozen ids)
        order = np.argsort(-scores, axis=1, kind="stable")[:, :k_eff]
        scores = np.take_along_axis(scores, order, axis=1)
        ids = np.take_along_axis(ids, order, axis=1)
        ids = np.where(np.isfinite(scores), ids, -1)
        if k_eff < k:
            scores = np.pad(scores, ((0, 0), (0, k - k_eff)),
                            constant_values=-np.inf)
            ids = np.pad(ids, ((0, 0), (0, k - k_eff)),
                         constant_values=-1)
        return scores, ids

    def search(self, queries, *, k: int) -> np.ndarray:
        """Top-k ids i32[Q, k] (−1 padding), frozen + pending rows."""
        return self.search_scored(queries, k=k)[1]

    # -- compaction --------------------------------------------------------

    def _raise_pending_error(self) -> None:
        with self._lock:
            err, self._compact_error = self._compact_error, None
        if err is not None:
            raise RuntimeError("background compaction failed") from err

    def compact(self, *, background: Optional[bool] = None,
                wait: bool = False) -> bool:
        """Fold the current pending rows into a fresh frozen index.

        The rebuild runs on a worker thread (``background=True``) while
        searches keep hitting the old snapshot; rows appended mid-build
        stay pending and remain searchable throughout.  Returns False when
        a compaction is already in flight (or nothing is pending)."""
        background = (self.ingest.background if background is None
                      else background)
        with self._lock:
            # the in-flight flag (not Thread.is_alive(), which is False
            # until start() and leaves a window where two compactions both
            # pass the check) — set here, cleared in the worker's finally
            if self._compacting:
                in_flight = True
            else:
                in_flight = False
                m = self.pending_rows
                if m == 0:
                    return False
                host_new = np.concatenate([self._host, self._pending[:m]],
                                          axis=0)
                cfg = self._session.config
                self._compacting = True
        if in_flight:
            if wait:
                self._join_compactor()
            return False

        def build():
            with trace.span("serve.compact", rows=int(host_new.shape[0]),
                            folded=m):
                session = SearchSession(host_new, cfg, key=self._key)
                df_new = _df_counts(host_new) if self._tfidf else None
                with self._lock:
                    self._host = host_new
                    self._session = session
                    self._frozen_df = df_new
                    self._pending = self._pending[m:]
                    self._rebuild_buffer()
                    self._registry.gauge("serve.ingest.pending").set(
                        self.pending_rows)
                self._registry.counter("serve.ingest.compactions").inc()

        if not background:
            try:
                build()
            finally:
                with self._lock:
                    self._compacting = False
            return True

        def guarded():
            try:
                build()
            except BaseException as e:   # surfaced on the next call
                with self._lock:
                    self._compact_error = e
            finally:
                with self._lock:
                    self._compacting = False

        t = threading.Thread(target=guarded, name="live-index-compact",
                             daemon=True)
        with self._lock:
            self._compactor = t
        t.start()
        if wait:
            self._join_compactor()
        return True

    def _join_compactor(self) -> None:
        # snapshot under the lock, join OUTSIDE it: the build thread needs
        # the lock to land its swap, so joining while holding it deadlocks
        with self._lock:
            t = self._compactor
        if t is not None:
            t.join()
        self._raise_pending_error()

    def flush(self) -> None:
        """Block until any in-flight compaction lands (tests, shutdown)."""
        self._join_compactor()
