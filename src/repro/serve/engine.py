"""Batched serving engine with continuous batching over a shared KV cache.

The paper's Fig. 5 online component (query -> embed -> ANN) plus a
generative RAG path: :class:`RetrievalFrontend` embeds incoming queries and
answers them through the SAME :class:`~repro.retrieval.search_core.
SearchSession` the offline experiment grid uses (engine/backend/shard are
one config, DESIGN.md §9), and :class:`RagEngine` feeds the retrieved
passages into the continuous-batching decoder. Requests join a fixed-slot
batch; finished slots are refilled without stalling in-flight requests
(continuous batching). Slot state lives in the rolling KV cache; prefill
for a joining request runs token-by-token through decode_step (simple,
correct; chunked prefill is a §Perf extension).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import (TransformerConfig, decode_step,
                                      init_kv_cache)
from repro.retrieval.search_core import SearchConfig, SearchSession


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 8
    max_seq: int = 512
    max_new_tokens: int = 32
    temperature: float = 0.0      # 0 -> greedy


@dataclasses.dataclass
class Request:
    prompt: np.ndarray            # i32[prompt_len]
    out: list = dataclasses.field(default_factory=list)
    remaining_prompt: int = 0
    new_tokens: int = 0
    done: bool = False


class ServeEngine:
    def __init__(self, params, model_cfg: TransformerConfig,
                 cfg: ServeConfig):
        self.params = params
        self.mcfg = model_cfg
        self.cfg = cfg
        self.cache = init_kv_cache(model_cfg, cfg.max_batch, cfg.max_seq)
        self.slots: List[Optional[Request]] = [None] * cfg.max_batch
        self._step = jax.jit(
            lambda p, c, t: decode_step(p, c, t, model_cfg))

    def submit(self, prompt: np.ndarray) -> Optional[Request]:
        for i, s in enumerate(self.slots):
            if s is None:
                req = Request(prompt=prompt, remaining_prompt=len(prompt))
                self.slots[i] = req
                # joining slot restarts its cache position
                self.cache["pos"] = self.cache["pos"].at[i].set(0)
                return req
        return None

    def _next_tokens(self) -> np.ndarray:
        toks = np.zeros((self.cfg.max_batch, 1), np.int32)
        for i, req in enumerate(self.slots):
            if req is None or req.done:
                continue
            if req.remaining_prompt > 0:
                toks[i, 0] = req.prompt[len(req.prompt) - req.remaining_prompt]
            elif req.out:
                toks[i, 0] = req.out[-1]
        return toks

    def step(self, key: Optional[jax.Array] = None) -> int:
        """One engine step: feeds every active slot one token. Returns the
        number of active requests."""
        active = [i for i, r in enumerate(self.slots)
                  if r is not None and not r.done]
        if not active:
            return 0
        toks = jnp.asarray(self._next_tokens())
        logits, self.cache = self._step(self.params, self.cache, toks)
        if self.cfg.temperature > 0 and key is not None:
            nxt = jax.random.categorical(
                key, logits[:, 0] / self.cfg.temperature, axis=-1)
        else:
            nxt = jnp.argmax(logits[:, 0], axis=-1)
        nxt = np.asarray(nxt)
        for i in active:
            req = self.slots[i]
            if req.remaining_prompt > 0:
                req.remaining_prompt -= 1
                if req.remaining_prompt == 0 and req.new_tokens == 0:
                    req.out.append(int(nxt[i]))   # first generated token
                    req.new_tokens = 1
            else:
                req.out.append(int(nxt[i]))
                req.new_tokens += 1
            if req.new_tokens >= self.cfg.max_new_tokens:
                req.done = True
                self.slots[i] = None if req.done else req
        return len(active)

    def drain(self, key: Optional[jax.Array] = None):
        while self.step(key):
            pass


class RetrievalFrontend:
    """Fig. 5 online path: query -> embed -> ANN, through the search core.

    ``embed_fn`` maps a batch of raw queries (token arrays, text — whatever
    the deployment embeds) to f32[Q, D] vectors on the same geometry the
    ``corpus_vecs`` were embedded with; retrieval itself is one
    :class:`SearchSession`, so the online path and the offline grid share
    one implementation (and one benchmark surface).
    """

    def __init__(self, corpus_vecs, embed_fn: Callable[..., Any], *,
                 config: Optional[SearchConfig] = None,
                 key: Optional[jax.Array] = None,
                 ids_map: Optional[np.ndarray] = None, **overrides):
        self.embed_fn = embed_fn
        self.session = SearchSession(corpus_vecs, config, key=key,
                                     ids_map=ids_map, **overrides)

    def retrieve(self, raw_queries, *, k: int = 3) -> np.ndarray:
        """Raw queries -> top-k ids i32[Q, k] (−1 padding for misses)."""
        return self.session.search(self.embed_fn(raw_queries), k=k)


class RagEngine:
    """Retrieval-augmented serving: the frontend's top passage is prepended
    to the prompt and decoded through the continuous-batching engine."""

    def __init__(self, frontend: RetrievalFrontend, engine: ServeEngine,
                 passage_tokens: Callable[[int], np.ndarray], *,
                 ctx_tokens: int = 24):
        self.frontend = frontend
        self.engine = engine
        self.passage_tokens = passage_tokens   # global id -> i32[tokens]
        self.ctx_tokens = ctx_tokens

    def submit_query(self, raw_query, query_tokens: np.ndarray, *,
                     k: int = 1):
        """Retrieve for one query and enqueue its RAG prompt; returns
        (request-or-None, retrieved ids i32[k])."""
        ids = self.frontend.retrieve([raw_query], k=k)[0]
        ctx = (self.passage_tokens(int(ids[0]))[:self.ctx_tokens]
               if ids.size and ids[0] >= 0 else
               np.zeros((0,), np.int32))
        prompt = np.concatenate([np.asarray(query_tokens, np.int32),
                                 np.asarray(ctx, np.int32)])
        return self.engine.submit(prompt), ids
