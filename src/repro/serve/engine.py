"""Batched serving engine with continuous batching over a shared KV cache.

The paper's Fig. 5 online component (query -> embed -> ANN) plus a
generative RAG path: :class:`RetrievalFrontend` embeds incoming queries and
answers them through the SAME :class:`~repro.retrieval.search_core.
SearchSession` the offline experiment grid uses (engine/backend/shard are
one config, DESIGN.md §9), and :class:`RagEngine` feeds the retrieved
passages into the continuous-batching decoder. Requests join a fixed-slot
batch; finished slots are refilled without stalling in-flight requests
(continuous batching). Slot state lives in the rolling KV cache; prefill
for a joining request runs token-by-token through decode_step (simple,
correct; chunked prefill is a §Perf extension).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import (TransformerConfig, decode_step,
                                      init_kv_cache)
from repro.obs import REGISTRY, trace
from repro.retrieval.search_core import SearchConfig, SearchSession
from repro.serve.ingest import IngestConfig, LiveIndex
from repro.serve.scheduler import (MicrobatchScheduler, PendingResult,
                                   SchedulerConfig)
from repro.serve.tenants import LRUCache, TenantCache


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 8
    max_seq: int = 512
    max_new_tokens: int = 32
    temperature: float = 0.0      # 0 -> greedy


@dataclasses.dataclass
class Request:
    prompt: np.ndarray            # i32[prompt_len]
    out: list = dataclasses.field(default_factory=list)
    remaining_prompt: int = 0
    new_tokens: int = 0
    done: bool = False
    t_submit: float = 0.0         # perf_counter at submit (latency metrics)
    t_done: float = 0.0           # perf_counter at completion


class ServeEngine:
    """Metrics (DESIGN.md §12, always on — the global obs registry):
    ``serve.request_latency_s`` submit→complete histogram (p50/p99),
    ``serve.tokens_per_step`` histogram + ``serve.tokens`` counter,
    ``serve.slot_occupancy`` gauge (active/max_batch per step), and
    ``serve.submitted`` / ``serve.completed`` / ``serve.rejected``
    request counters."""

    def __init__(self, params, model_cfg: TransformerConfig,
                 cfg: ServeConfig):
        self.params = params
        self.mcfg = model_cfg
        self.cfg = cfg
        self.cache = init_kv_cache(model_cfg, cfg.max_batch, cfg.max_seq)
        self.slots: List[Optional[Request]] = [None] * cfg.max_batch
        self._step = jax.jit(
            lambda p, c, t: decode_step(p, c, t, model_cfg))

    def submit(self, prompt: np.ndarray) -> Optional[Request]:
        for i, s in enumerate(self.slots):
            if s is None:
                req = Request(prompt=prompt, remaining_prompt=len(prompt),
                              t_submit=time.perf_counter())
                self.slots[i] = req
                # joining slot restarts its cache position
                self.cache["pos"] = self.cache["pos"].at[i].set(0)
                REGISTRY.counter("serve.submitted").inc()
                return req
        REGISTRY.counter("serve.rejected").inc()   # batch full
        return None

    def _next_tokens(self) -> np.ndarray:
        toks = np.zeros((self.cfg.max_batch, 1), np.int32)
        for i, req in enumerate(self.slots):
            if req is None or req.done:
                continue
            if req.remaining_prompt > 0:
                toks[i, 0] = req.prompt[len(req.prompt) - req.remaining_prompt]
            elif req.out:
                toks[i, 0] = req.out[-1]
        return toks

    def step(self, key: Optional[jax.Array] = None) -> int:
        """One engine step: feeds every active slot one token. Returns the
        number of active requests."""
        active = [i for i, r in enumerate(self.slots)
                  if r is not None and not r.done]
        REGISTRY.gauge("serve.slot_occupancy").set(
            len(active) / max(self.cfg.max_batch, 1))
        if not active:
            return 0
        with trace.jax_span("serve.step", active=len(active)) as sp:
            toks = jnp.asarray(self._next_tokens())
            logits, self.cache = self._step(self.params, self.cache, toks)
            if self.cfg.temperature > 0 and key is not None:
                nxt = jax.random.categorical(
                    key, logits[:, 0] / self.cfg.temperature, axis=-1)
            else:
                nxt = jnp.argmax(logits[:, 0], axis=-1)
            nxt = np.asarray(nxt)
            sp.declare(nxt)
        REGISTRY.counter("serve.tokens").inc(len(active))
        REGISTRY.histogram("serve.tokens_per_step",
                           buckets=tuple(range(1, 257))).observe(len(active))
        now = time.perf_counter()
        for i in active:
            req = self.slots[i]
            if req.remaining_prompt > 0:
                req.remaining_prompt -= 1
                if req.remaining_prompt == 0 and req.new_tokens == 0:
                    req.out.append(int(nxt[i]))   # first generated token
                    req.new_tokens = 1
            else:
                req.out.append(int(nxt[i]))
                req.new_tokens += 1
            if req.new_tokens >= self.cfg.max_new_tokens:
                req.done = True
                req.t_done = now
                REGISTRY.counter("serve.completed").inc()
                REGISTRY.histogram("serve.request_latency_s").observe(
                    now - req.t_submit)
                self.slots[i] = None if req.done else req
        return len(active)

    def state_summary(self) -> Dict[str, Any]:
        """Engine state for diagnostics (attached to the drain guard's
        error): per-slot progress plus the serving config bounds."""
        return {
            "max_batch": self.cfg.max_batch,
            "max_new_tokens": self.cfg.max_new_tokens,
            "slots": [None if r is None else
                      {"remaining_prompt": r.remaining_prompt,
                       "new_tokens": r.new_tokens, "done": r.done,
                       "out_len": len(r.out)}
                      for r in self.slots],
        }

    def drain(self, key: Optional[jax.Array] = None,
              max_steps: Optional[int] = None) -> int:
        """Step until every request completes; returns the step count.

        Guarded against hanging: by default ``max_steps`` is derived from
        the pending work — each active request needs at most
        ``remaining_prompt + (max_new_tokens - new_tokens)`` steps, and no
        new work can join mid-drain, so the sum over pending requests is a
        hard upper bound.  Exceeding the bound raises ``RuntimeError``
        with the engine state attached (``.engine_state``) instead of
        looping forever (e.g. on a corrupted slot or a non-positive
        ``max_new_tokens``)."""
        if max_steps is None:
            pending = [r for r in self.slots
                       if r is not None and not r.done]
            max_steps = sum(
                r.remaining_prompt +
                max(self.cfg.max_new_tokens - r.new_tokens, 1)
                for r in pending)
        steps = 0
        with trace.span("serve.drain", max_steps=max_steps) as sp:
            while self.step(key):
                steps += 1
                if steps > max_steps:
                    state = self.state_summary()
                    err = RuntimeError(
                        f"ServeEngine.drain exceeded its step bound "
                        f"({max_steps} steps for the pending work) without "
                        f"completing every request — engine state: {state}")
                    err.engine_state = state
                    raise err
            sp.set(steps=steps)
        return steps


class RetrievalFrontend:
    """Fig. 5 online path: query -> embed -> ANN, through the search core.

    ``embed_fn`` maps a batch of raw queries (token arrays, text — whatever
    the deployment embeds) to f32[Q, D] vectors on the same geometry the
    ``corpus_vecs`` were embedded with; retrieval itself is one
    :class:`SearchSession`, so the online path and the offline grid share
    one implementation (and one benchmark surface).

    Retrieved contexts are memoised in a BOUNDED per-query LRU (keyed by
    the embedded vector bytes + k): repeat queries skip the session
    entirely, the cache can never grow past ``ctx_cache_size`` entries
    (eviction is observable as ``serve.ctx.evict``), and an evicted
    query's re-retrieval recomputes the identical ids — the session is
    deterministic, so the cache is purely a latency/VRAM bound, never a
    correctness surface.  ``ingest=IngestConfig(...)`` swaps the frozen
    session for a :class:`~repro.serve.ingest.LiveIndex`, adding
    ``append`` (the cache is flushed per append — stale top-k would
    otherwise hide new documents).
    """

    def __init__(self, corpus_vecs, embed_fn: Callable[..., Any], *,
                 config: Optional[SearchConfig] = None,
                 key: Optional[jax.Array] = None,
                 ids_map: Optional[np.ndarray] = None,
                 ctx_cache_size: int = 1024,
                 ingest: Optional[IngestConfig] = None, **overrides):
        self.embed_fn = embed_fn
        if ingest is not None:
            if ids_map is not None:
                raise ValueError("live ingest keeps its own global id "
                                 "space; ids_map is not supported")
            self.session = LiveIndex(corpus_vecs, config, key=key,
                                     ingest=ingest, **overrides)
        else:
            self.session = SearchSession(corpus_vecs, config, key=key,
                                         ids_map=ids_map, **overrides)
        self._ctx_cache = LRUCache(
            ctx_cache_size,
            on_evict=lambda *_: REGISTRY.counter("serve.ctx.evict").inc())

    def append(self, docs):
        """Land new documents into a live-ingest session (and invalidate
        the context cache — cached top-k predates the new rows)."""
        if not isinstance(self.session, LiveIndex):
            raise ValueError("frontend was built without ingest=; pass "
                             "IngestConfig(...) to enable appends")
        out = self.session.append(docs)
        self._ctx_cache = LRUCache(self._ctx_cache.capacity,
                                   on_evict=self._ctx_cache._on_evict)
        return out

    def retrieve(self, raw_queries, *, k: int = 3) -> np.ndarray:
        """Raw queries -> top-k ids i32[Q, k] (−1 padding for misses)."""
        t0 = time.perf_counter()
        vecs = np.asarray(self.embed_fn(raw_queries), np.float32)
        if vecs.shape[0] == 0:
            return np.zeros((0, k), np.int32)
        keys = [(q.tobytes(), k) for q in vecs]
        cached = [self._ctx_cache.get(key) for key in keys]
        misses = [i for i, c in enumerate(cached) if c is None]
        REGISTRY.counter("serve.ctx.hit").inc(len(keys) - len(misses))
        REGISTRY.counter("serve.ctx.miss").inc(len(misses))
        if misses:
            fresh = self.session.search(vecs[misses], k=k)
            for j, i in enumerate(misses):
                cached[i] = fresh[j]
                self._ctx_cache.put(keys[i], fresh[j])
        ids = np.stack(cached, axis=0).astype(np.int32)
        REGISTRY.counter("serve.retrieve.queries").inc(len(ids))
        REGISTRY.histogram("serve.retrieve_latency_s").observe(
            time.perf_counter() - t0)
        return ids


class RagEngine:
    """Retrieval-augmented serving: the frontend's top passage is prepended
    to the prompt and decoded through the continuous-batching engine."""

    def __init__(self, frontend: RetrievalFrontend, engine: ServeEngine,
                 passage_tokens: Callable[[int], np.ndarray], *,
                 ctx_tokens: int = 24):
        self.frontend = frontend
        self.engine = engine
        self.passage_tokens = passage_tokens   # global id -> i32[tokens]
        self.ctx_tokens = ctx_tokens

    def submit_query(self, raw_query, query_tokens: np.ndarray, *,
                     k: int = 1):
        """Retrieve for one query and enqueue its RAG prompt; returns
        (request-or-None, retrieved ids i32[k])."""
        ids = self.frontend.retrieve([raw_query], k=k)[0]
        hit = bool(ids.size and ids[0] >= 0)
        REGISTRY.counter("serve.rag.ctx_hit" if hit
                         else "serve.rag.ctx_miss").inc()
        ctx = (self.passage_tokens(int(ids[0]))[:self.ctx_tokens]
               if hit else np.zeros((0,), np.int32))
        prompt = np.concatenate([np.asarray(query_tokens, np.int32),
                                 np.asarray(ctx, np.int32)])
        return self.engine.submit(prompt), ids


class SearchServer:
    """The serving tier, assembled (DESIGN.md §14): a bounded-queue
    :class:`~repro.serve.scheduler.MicrobatchScheduler` dispatching into a
    :class:`~repro.serve.tenants.TenantCache` of per-tenant
    :class:`~repro.serve.ingest.LiveIndex` sessions.

    ``corpus_provider(tenant)`` returns the tenant's corpus vectors
    f32[N, D] — called on cache miss (first request, or re-admission after
    eviction), so tenant state is always reconstructible and eviction is
    safe.  ``submit``/``tick``/``drain`` are the scheduler's;
    ``append(tenant, docs)`` lands documents in that tenant's live index
    (building it if cold).
    """

    def __init__(self, corpus_provider: Callable[[str], Any], *,
                 config: Optional[SearchConfig] = None,
                 scheduler: Optional[SchedulerConfig] = None,
                 ingest: Optional[IngestConfig] = None,
                 max_tenants: int = 8,
                 key: Optional[jax.Array] = None):
        search_cfg = config or SearchConfig()
        ingest_cfg = ingest or IngestConfig()

        def build(tenant: str) -> LiveIndex:
            return LiveIndex(corpus_provider(tenant), search_cfg, key=key,
                             ingest=ingest_cfg)

        self.tenants = TenantCache(build, capacity=max_tenants)
        self.scheduler = MicrobatchScheduler(self.tenants.get, scheduler)

    def submit(self, query, *, k: Optional[int] = None,
               tenant: str = "default") -> Optional[PendingResult]:
        return self.scheduler.submit(query, k=k, tenant=tenant)

    def tick(self) -> int:
        return self.scheduler.tick()

    def drain(self, max_ticks: Optional[int] = None) -> int:
        return self.scheduler.drain(max_ticks)

    def append(self, tenant: str, docs):
        """Ingest new documents for one tenant (cold tenants build first)."""
        return self.tenants.get(tenant).append(docs)
