"""Open-loop load generator for the serving tier (bench + CLI driver).

Drives a :class:`~repro.serve.scheduler.MicrobatchScheduler` with a
synthetic arrival process and reports what the capacity-planning
quickstart wants to read: sustained **throughput** (completed requests /
wall time) and **p50/p99 latency** (per-request queue wait + compute,
straight off each request's completion future) as functions of offered
load, microbatch size and tenant count.

Open loop with backpressure shedding: arrivals fire on their schedule
regardless of completions (``rate=inf`` collapses to "as fast as
possible"); a full queue rejects the arrival, the generator counts the
shed and moves on — so overload shows up as rejections plus saturated
throughput, not as a generator stall that would hide it.  Tenants
round-robin over arrivals.  The scheduler's cooperative ``tick`` runs in
the generator loop between submissions — one thread, deterministic
per-seed, nothing to join.

``benchmarks/run.py --only serve`` and ``launch/serve.py --bench`` both
route here; the BENCH_serve.json columns come from :class:`LoadReport`.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import numpy as np

from repro.serve.scheduler import MicrobatchScheduler, PendingResult

__all__ = ["LoadSpec", "LoadReport", "run_load"]


@dataclasses.dataclass(frozen=True)
class LoadSpec:
    """One load-generation run: ``n_requests`` arrivals at ``rate``
    requests/s (inf = back-to-back), spread round-robin over ``tenants``
    tenant ids (``tenant-0`` … ``tenant-{n-1}``), each asking top-``k``."""

    n_requests: int = 256
    rate: float = float("inf")
    tenants: int = 1
    k: int = 10
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class LoadReport:
    """What one run measured (BENCH_serve.json row material)."""

    completed: int
    rejected: int
    wall_s: float
    throughput_rps: float
    p50_s: float
    p99_s: float
    ticks: int
    mean_batch: float

    def to_row(self) -> dict:
        return {"throughput_rps": round(self.throughput_rps, 2),
                "p50_s": self.p50_s, "p99_s": self.p99_s,
                "completed": self.completed, "rejected": self.rejected,
                "ticks": self.ticks,
                "mean_batch": round(self.mean_batch, 2)}


def _percentile(xs: List[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs, np.float64), q)) if xs \
        else float("nan")


def run_load(scheduler: MicrobatchScheduler, queries: np.ndarray,
             spec: Optional[LoadSpec] = None) -> LoadReport:
    """Run one open-loop load test; ``queries`` f32[Q, D] are cycled to
    fill ``spec.n_requests`` arrivals."""
    spec = spec or LoadSpec()
    q = np.asarray(queries, np.float32)
    if q.ndim != 2 or q.shape[0] == 0:
        raise ValueError(f"queries must be non-empty f32[Q, D]; got "
                         f"{q.shape}")
    interval = 0.0 if not np.isfinite(spec.rate) else 1.0 / spec.rate
    pending: List[PendingResult] = []
    rejected = 0
    ticks0 = scheduler.ticks
    start = time.perf_counter()
    for i in range(spec.n_requests):
        due = start + i * interval
        # hold the arrival to its schedule, ticking while we wait so the
        # queue keeps draining between arrivals
        while True:
            now = time.perf_counter()
            if now >= due:
                break
            if scheduler.tick() == 0:
                time.sleep(min(due - now, 1e-4))
        req = scheduler.submit(q[i % q.shape[0]], k=spec.k,
                               tenant=f"tenant-{i % spec.tenants}")
        if req is None:
            rejected += 1
        else:
            pending.append(req)
        # tick once a full microbatch is waiting — ticking per arrival
        # would pin every batch at size 1 and measure the serial path
        if scheduler.depth >= scheduler.config.max_batch:
            scheduler.tick()
    while scheduler.tick():
        pass
    wall = time.perf_counter() - start
    lat = [r.completed_at - r.submitted_at for r in pending if r.done]
    ticks = scheduler.ticks - ticks0
    return LoadReport(
        completed=len(lat), rejected=rejected, wall_s=wall,
        throughput_rps=len(lat) / wall if wall > 0 else 0.0,
        p50_s=_percentile(lat, 50), p99_s=_percentile(lat, 99),
        ticks=ticks, mean_batch=(len(lat) / ticks if ticks else 0.0))
