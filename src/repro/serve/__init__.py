from repro.serve.engine import (RagEngine, RetrievalFrontend, SearchServer,
                                ServeConfig, ServeEngine)
from repro.serve.ingest import IngestConfig, LiveIndex
from repro.serve.loadgen import LoadReport, LoadSpec, run_load
from repro.serve.scheduler import (MicrobatchScheduler, PendingResult,
                                   SchedulerConfig)
from repro.serve.tenants import LRUCache, TenantCache

__all__ = ["ServeEngine", "ServeConfig", "RetrievalFrontend", "RagEngine",
           "SearchServer", "IngestConfig", "LiveIndex", "LoadSpec",
           "LoadReport", "run_load", "MicrobatchScheduler", "PendingResult",
           "SchedulerConfig", "LRUCache", "TenantCache"]
