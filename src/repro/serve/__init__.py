from repro.serve.engine import (RagEngine, RetrievalFrontend, ServeConfig,
                                ServeEngine)

__all__ = ["ServeEngine", "ServeConfig", "RetrievalFrontend", "RagEngine"]
