"""Continuous-batching microbatch scheduler for search serving (§14).

The serving tier's throughput problem: one query per ``search`` call
leaves the accelerator idle between dispatches, but naive batching makes
p99 hostage to the slowest co-batched request *and* — worse on XLA — every
distinct batch shape is a recompile.  The scheduler solves both with the
decode-slot playbook from :class:`~repro.serve.engine.ServeEngine`
adapted to retrieval:

  * a bounded FIFO queue admits requests (``submit``) and rejects with
    backpressure when full — callers see ``None`` immediately, never an
    unbounded wait;
  * each ``tick()`` pops the head-of-line tenant's requests (up to
    ``max_batch``, in arrival order), pads them to the smallest shape in
    a fixed **bucket set** (powers of two up to ``max_batch``) and runs
    ONE shared ``search_scored`` at the fixed ``k_max`` — so after the
    bucket set is warm, steady state never recompiles regardless of
    offered load;
  * results slice back to per-request completion futures
    (:class:`PendingResult`) that callers block on independently — a
    request's latency is its own queue wait + its tick, not the tail of
    an epoch barrier.

Ticks are cooperative (the caller's serving loop invokes ``tick`` /
``drain``), matching ``ServeEngine.step`` — no scheduler threads to
drain on shutdown, and tests drive it deterministically.

Observability: ``serve.tick`` and ``serve.batch`` spans (the batch span
carries tenant, bucket and fill), the existing ``serve.request_latency_s``
histogram (queue wait + compute, per request), ``serve.queue.depth``
gauge, ``serve.queue.rejected`` counter, and a ``serve.batch.fill``
histogram exposing padding waste.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Any, Callable, Deque, Optional, Tuple

import numpy as np

from repro.obs import REGISTRY, recompile, trace
from repro.obs.locks import make_lock
from repro.obs.metrics import Registry

__all__ = ["SchedulerConfig", "PendingResult", "MicrobatchScheduler"]


def _buckets(max_batch: int) -> Tuple[int, ...]:
    out = []
    b = 1
    while b < max_batch:
        out.append(b)
        b *= 2
    out.append(max_batch)
    return tuple(out)


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """Admission and batching knobs.

    ``k_max`` fixes the top-k width of every dispatched search (requests
    ask for any ``k <= k_max`` and get a slice) — one more shape held
    constant so the compile cache stays at |buckets| entries."""

    max_queue: int = 256
    max_batch: int = 32
    k_max: int = 16
    buckets: Optional[Tuple[int, ...]] = None   # default: powers of two

    def bucket_set(self) -> Tuple[int, ...]:
        return tuple(sorted(self.buckets)) if self.buckets \
            else _buckets(self.max_batch)


class PendingResult:
    """Completion future for one submitted query: ``result()`` blocks for
    (scores f32[k], ids i32[k]) — or re-raises the tick's failure."""

    def __init__(self, tenant: str, query: np.ndarray, k: int):
        self.tenant = tenant
        self.query = query
        self.k = k
        self.submitted_at = time.perf_counter()
        self.completed_at: Optional[float] = None
        self._done = threading.Event()
        self._scores: Optional[np.ndarray] = None
        self._ids: Optional[np.ndarray] = None
        self._error: Optional[BaseException] = None

    @property
    def done(self) -> bool:
        return self._done.is_set()

    def _complete(self, scores: np.ndarray, ids: np.ndarray) -> None:
        self._scores, self._ids = scores, ids
        self.completed_at = time.perf_counter()
        self._done.set()

    def _fail(self, error: BaseException) -> None:
        self._error = error
        self.completed_at = time.perf_counter()
        self._done.set()

    def result(self, timeout: Optional[float] = None):
        if not self._done.wait(timeout):
            raise TimeoutError("result not ready; drive the scheduler "
                               "(tick()/drain()) or raise the timeout")
        if self._error is not None:
            raise self._error
        return self._scores, self._ids


class MicrobatchScheduler:
    """Bounded-queue continuous batching over per-tenant search sessions.

    ``sessions(tenant)`` resolves a search target exposing
    ``search_scored(queries, k=...)`` — a :class:`~repro.serve.tenants.
    TenantCache` bound method, a :class:`~repro.serve.ingest.LiveIndex`,
    or a bare :class:`~repro.retrieval.search_core.SearchSession` wrapped
    in a lambda."""

    def __init__(self, sessions: Callable[[str], Any],
                 config: Optional[SchedulerConfig] = None,
                 *, registry: Registry = REGISTRY):
        self.config = config or SchedulerConfig()
        if self.config.max_queue < 1 or self.config.max_batch < 1:
            raise ValueError("max_queue and max_batch must be >= 1")
        if max(self.config.bucket_set()) < self.config.max_batch:
            raise ValueError("bucket set must cover max_batch")
        self._sessions = sessions
        self._registry = registry
        self._queue: Deque[PendingResult] = deque()
        self._lock = make_lock("scheduler-queue")
        self.ticks = 0

    # -- admission ---------------------------------------------------------

    @property
    def depth(self) -> int:
        with self._lock:
            return len(self._queue)

    def submit(self, query, *, k: Optional[int] = None,
               tenant: str = "default") -> Optional[PendingResult]:
        """Admit one query (f32[D]); returns its future, or None when the
        queue is full (backpressure — the caller retries or sheds)."""
        cfg = self.config
        k = cfg.k_max if k is None else k
        if not 1 <= k <= cfg.k_max:
            raise ValueError(f"k={k} outside [1, k_max={cfg.k_max}]; "
                             "raise SchedulerConfig.k_max")
        q = np.asarray(query, np.float32).reshape(-1)
        req = PendingResult(tenant, q, k)
        with self._lock:
            if len(self._queue) >= cfg.max_queue:
                self._registry.counter("serve.queue.rejected").inc()
                return None
            self._queue.append(req)
            depth = len(self._queue)
        self._registry.counter("serve.queue.submitted").inc()
        self._registry.gauge("serve.queue.depth").set(depth)
        return req

    # -- batching ----------------------------------------------------------

    def _take_batch(self) -> list:
        """Pop the head-of-line tenant's requests in arrival order (up to
        ``max_batch``); other tenants keep their queue positions, so
        admission order is served order within every tenant."""
        with self._lock:
            if not self._queue:
                return []
            tenant = self._queue[0].tenant
            batch, keep = [], deque()
            while self._queue:
                req = self._queue.popleft()
                if req.tenant == tenant and len(batch) < \
                        self.config.max_batch:
                    batch.append(req)
                else:
                    keep.append(req)
            self._queue = keep
            self._registry.gauge("serve.queue.depth").set(len(keep))
        return batch

    def _bucket(self, n: int) -> int:
        for b in self.config.bucket_set():
            if b >= n:
                return b
        return max(self.config.bucket_set())

    def tick(self) -> int:
        """Serve one microbatch; returns the number of requests completed
        (0 when idle).  One shared search per tick, fixed shapes."""
        batch = self._take_batch()
        if not batch:
            return 0
        self.ticks += 1
        cfg = self.config
        tenant = batch[0].tenant
        bucket = self._bucket(len(batch))
        with trace.span("serve.tick", requests=len(batch), bucket=bucket), \
                recompile.region("serve.tick"):
            try:
                session = self._sessions(tenant)
                dim = batch[0].query.shape[0]
                padded = np.zeros((bucket, dim), np.float32)
                for i, req in enumerate(batch):
                    padded[i] = req.query
                with trace.span("serve.batch", tenant=tenant, bucket=bucket,
                                fill=len(batch)):
                    scores, ids = session.search_scored(padded, k=cfg.k_max)
                scores, ids = np.asarray(scores), np.asarray(ids)
            except BaseException as e:
                for req in batch:
                    req._fail(e)
                    self._observe(req)
                return len(batch)
            for i, req in enumerate(batch):
                req._complete(scores[i, :req.k].copy(),
                              ids[i, :req.k].copy())
                self._observe(req)
        self._registry.histogram("serve.batch.fill").observe(
            len(batch) / bucket)
        return len(batch)

    def _observe(self, req: PendingResult) -> None:
        self._registry.histogram("serve.request_latency_s").observe(
            req.completed_at - req.submitted_at)
        self._registry.counter("serve.queue.completed").inc()

    def drain(self, max_ticks: Optional[int] = None) -> int:
        """Tick until the queue empties; returns requests completed.  The
        bound defaults to the depth (every tick serves >= 1 request, so
        depth ticks always suffice) — a guard, like ServeEngine.drain."""
        bound = max_ticks if max_ticks is not None else max(self.depth, 1)
        total = 0
        for _ in range(bound):
            done = self.tick()
            if done == 0:
                break
            total += done
        return total
