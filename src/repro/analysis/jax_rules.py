"""JAX contract rules (DESIGN.md §15): trace hazards and donation safety.

Rule family 1 — trace hazards inside ``jit`` / ``shard_map`` bodies:

  * ``jax-host-cast``       (error)   ``float()``/``int()``/``bool()``/
    ``.item()``/``np.asarray()`` applied to a traced value forces a device
    sync inside the trace (or a ``TracerConversionError`` at runtime).
  * ``jax-traced-branch``   (error)   Python ``if``/``while``/ternary on a
    traced value — a concretization error at trace time; use ``jnp.where``
    / ``lax.cond``.
  * ``jax-unbounded-static`` (warning) a call site of a jitted function
    passes a *static* argument whose value set is not provably bounded —
    every distinct value is a fresh trace + XLA compile (the retrace
    amplifier the scheduler's bucket set exists to prevent).  Values are
    known-static when they are literals, ALL_CAPS constants, shapes/dims,
    ``min(...)`` clamps, bucket lookups (anything resolved through the
    ``kernels/tuning.py`` size buckets), or the tuned block kwargs
    (``block_q``/``block_n``/... — ``tuning.resolve`` draws them from a
    finite table keyed by the SIZE_BUCKETS boundaries).

Rule family 2 — donation/aliasing safety:

  * ``jax-donated-reuse``   (error)   an argument passed at a donated
    position is read again after the call: XLA may have reused its buffer,
    so the read observes garbage.
  * ``serve-donated-append`` (error)  the LiveIndex contract: in ``serve/``
    modules, a jitted buffer-update function (``dynamic_update_slice``
    writes) must NOT donate — an in-flight search on another thread may
    still hold the previous buffer (serve/ingest.py documents this; the
    lock covers the swap, not the compute).

Tracedness is a forward, lexical dataflow over each traced function body:
parameters (minus the declared static ones) seed the traced set; names
assigned from traced expressions join it; ``.shape``/``.ndim``/``.dtype``
and ``len()`` projections are static and leave it.  The analysis is
deliberately intraprocedural — precise enough for the kernels/serve idioms
in this repo, with ``# lint: disable=`` as the reviewed escape hatch.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis.core import (Finding, Module, Project, arg_names,
                                 call_name, iter_functions, register_rule)

__all__ = ["JitInfo", "traced_functions", "TUNED_BLOCK_KWARGS"]


def _tuned_block_kwargs() -> frozenset:
    """Block-kwarg names the autotuner dispatches (cross-referenced from
    kernels/tuning.py so tuned kwargs are known-static: resolve() draws
    them from a finite table keyed by the SIZE_BUCKETS boundaries)."""
    from repro.kernels.tuning import DEFAULTS
    return frozenset(k for params in DEFAULTS.values() for k in params)


TUNED_BLOCK_KWARGS = _tuned_block_kwargs()

#: attribute projections of an array that are static under tracing
_STATIC_ATTRS = frozenset({"shape", "ndim", "dtype", "size", "sharding"})

_HOST_CASTS = frozenset({"float", "int", "bool", "complex"})
_HOST_CALLS = frozenset({"np.asarray", "np.array", "numpy.asarray",
                         "numpy.array", "onp.asarray"})
_HOST_METHODS = frozenset({"item", "tolist", "__bool__", "__float__"})


@dataclasses.dataclass(frozen=True)
class JitInfo:
    """What a jit/shard_map wrapping declares about its function."""

    kind: str                      # "jit" | "shard_map"
    static_argnames: Tuple[str, ...] = ()
    static_argnums: Tuple[int, ...] = ()
    donate_argnums: Tuple[int, ...] = ()


def _const_strings(node: ast.AST) -> Tuple[str, ...]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        return tuple(e.value for e in node.elts
                     if isinstance(e, ast.Constant)
                     and isinstance(e.value, str))
    return ()


def _const_ints(node: ast.AST) -> Tuple[int, ...]:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        return tuple(e.value for e in node.elts
                     if isinstance(e, ast.Constant)
                     and isinstance(e.value, int))
    return ()


def _jit_call_info(call: ast.Call) -> Optional[JitInfo]:
    """JitInfo when ``call`` is jax.jit(...)/jit(...) or a
    functools.partial(jax.jit, ...) wrapping; None otherwise."""
    name = call_name(call)
    if name is None:
        return None
    base = name.split(".")[-1]
    if base == "partial" and call.args:
        inner = call.args[0]
        inner_name = (inner.id if isinstance(inner, ast.Name)
                      else inner.attr if isinstance(inner, ast.Attribute)
                      else None)
        if inner_name not in ("jit", "shard_map"):
            return None
        kind = "jit" if inner_name == "jit" else "shard_map"
        kw = {k.arg: k.value for k in call.keywords if k.arg}
        return JitInfo(
            kind=kind,
            static_argnames=_const_strings(kw.get("static_argnames",
                                                  ast.Constant(None))),
            static_argnums=_const_ints(kw.get("static_argnums",
                                              ast.Constant(None))),
            donate_argnums=_const_ints(kw.get("donate_argnums",
                                              ast.Constant(None))))
    if base in ("jit", "shard_map"):
        kw = {k.arg: k.value for k in call.keywords if k.arg}
        return JitInfo(
            kind="jit" if base == "jit" else "shard_map",
            static_argnames=_const_strings(kw.get("static_argnames",
                                                  ast.Constant(None))),
            static_argnums=_const_ints(kw.get("static_argnums",
                                              ast.Constant(None))),
            donate_argnums=_const_ints(kw.get("donate_argnums",
                                              ast.Constant(None))))
    return None


def traced_functions(module: Module) -> Dict[str, Tuple[ast.AST, JitInfo]]:
    """qualname -> (funcdef, JitInfo) for every function this module puts
    under a trace: decorated defs, defs passed to jit()/shard_map() calls,
    and ``g = jax.jit(f, ...)`` module-level wrappings (keyed by the
    *wrapper* name too, for call-site rules)."""
    out: Dict[str, Tuple[ast.AST, JitInfo]] = {}
    defs: Dict[str, List[Tuple[str, ast.AST]]] = {}
    for qual, fn, _cls in iter_functions(module.tree):
        defs.setdefault(fn.name, []).append((qual, fn))
        for dec in fn.decorator_list:
            info = None
            if isinstance(dec, ast.Call):
                info = _jit_call_info(dec)
            elif (name := (dec.id if isinstance(dec, ast.Name)
                           else dec.attr if isinstance(dec, ast.Attribute)
                           else None)) in ("jit", "shard_map"):
                info = JitInfo(kind="jit" if name == "jit" else "shard_map")
            if info is not None:
                out[qual] = (fn, info)
    # functions passed into jit(f, ...) / shard_map(f, ...) call sites,
    # and wrapper bindings `g = jax.jit(f, ...)`
    for node in ast.walk(module.tree):
        if not isinstance(node, ast.Call):
            continue
        name = call_name(node)
        if name is None or name.split(".")[-1] not in ("jit", "shard_map"):
            continue
        info = _jit_call_info(node)
        if info is None or not node.args:
            continue
        target = node.args[0]
        if isinstance(target, ast.Name) and target.id in defs:
            for qual, fn in defs[target.id]:
                out.setdefault(qual, (fn, info))
    # wrapper name bindings: g = jax.jit(f, ...) at any assignment
    for node in ast.walk(module.tree):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            info = _jit_call_info(node.value)
            if info is None or not node.value.args:
                continue
            inner = node.value.args[0]
            if not isinstance(inner, ast.Name) or inner.id not in defs:
                continue
            _, fn = defs[inner.id][0]
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    out.setdefault(tgt.name if hasattr(tgt, "name")
                                   else tgt.id, (fn, info))
    return out


def _static_params(fn: ast.AST, info: JitInfo) -> Set[str]:
    names = arg_names(fn)
    static = set(info.static_argnames)
    for i in info.static_argnums:
        if 0 <= i < len(names):
            static.add(names[i])
    return static


class _Tracedness:
    """Forward lexical dataflow: which names hold traced values."""

    def __init__(self, fn: ast.AST, info: JitInfo):
        self.traced: Set[str] = set(arg_names(fn)) - _static_params(fn, info)

    def expr_traced(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Constant):
            return False
        if isinstance(node, ast.Name):
            return node.id in self.traced
        if isinstance(node, ast.Attribute):
            if node.attr in _STATIC_ATTRS:
                return False
            return self.expr_traced(node.value)
        if isinstance(node, ast.Call):
            name = call_name(node) or ""
            if name == "len" or name.split(".")[-1] in ("range", "zip",
                                                        "enumerate"):
                return False
            # method calls propagate the receiver: x.sum() is traced iff x is
            recv = (self.expr_traced(node.func.value)
                    if isinstance(node.func, ast.Attribute) else False)
            return recv or \
                any(self.expr_traced(a) for a in node.args) or \
                any(self.expr_traced(k.value) for k in node.keywords)
        if isinstance(node, (ast.BinOp, ast.BoolOp, ast.Compare,
                             ast.UnaryOp, ast.Subscript, ast.IfExp,
                             ast.Tuple, ast.List, ast.Starred)):
            return any(self.expr_traced(c) for c in ast.iter_child_nodes(node)
                       if isinstance(c, ast.expr))
        return False

    def feed(self, stmt: ast.stmt) -> None:
        """Propagate through one assignment statement."""
        if isinstance(stmt, ast.Assign) and self.expr_traced(stmt.value):
            for tgt in stmt.targets:
                for n in ast.walk(tgt):
                    if isinstance(n, ast.Name):
                        self.traced.add(n.id)
        elif isinstance(stmt, ast.AugAssign) and \
                self.expr_traced(stmt.value):
            if isinstance(stmt.target, ast.Name):
                self.traced.add(stmt.target.id)


def _is_none_check(test: ast.AST) -> bool:
    return (isinstance(test, ast.Compare)
            and any(isinstance(op, (ast.Is, ast.IsNot))
                    for op in test.ops))


def _body_statements(fn: ast.AST) -> Iterable[ast.stmt]:
    """Statements of a def in source order, skipping nested defs (they
    trace separately if jitted)."""

    def walk(stmts):
        for s in stmts:
            if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.ClassDef)):
                continue
            yield s
            for field in ("body", "orelse", "finalbody", "handlers"):
                sub = getattr(s, field, None)
                if sub:
                    for item in sub:
                        if isinstance(item, ast.ExceptHandler):
                            yield from walk(item.body)
                        elif isinstance(item, ast.stmt):
                            yield from walk([item])

    yield from walk(getattr(fn, "body", []))


@register_rule
class HostCastRule:
    """float()/int()/bool()/.item()/np.asarray() on traced values."""

    id = "jax-host-cast"
    severity = "error"

    def check(self, project: Project) -> Iterable[Finding]:
        for module in project.modules:
            for qual, (fn, info) in traced_functions(module).items():
                if not hasattr(fn, "body"):
                    continue
                flow = _Tracedness(fn, info)
                for stmt in _body_statements(fn):
                    for node in ast.walk(stmt):
                        if not isinstance(node, ast.Call):
                            continue
                        name = call_name(node) or ""
                        is_cast = (name in _HOST_CASTS
                                   or name in _HOST_CALLS)
                        is_method = (isinstance(node.func, ast.Attribute)
                                     and node.func.attr in _HOST_METHODS)
                        if not (is_cast or is_method):
                            continue
                        target = (node.func.value if is_method
                                  else node.args[0] if node.args else None)
                        if target is not None and \
                                flow.expr_traced(target):
                            what = (f".{node.func.attr}()" if is_method
                                    else f"{name}()")
                            yield Finding(
                                self.id, self.severity, module.path,
                                node.lineno, symbol=qual,
                                message=(
                                    f"{what} on a traced value inside a "
                                    f"{info.kind} body forces a host sync "
                                    f"(or fails to trace); keep it in jnp "
                                    f"or hoist the cast out of the trace"))
                    flow.feed(stmt)


@register_rule
class TracedBranchRule:
    """Python control flow on traced values inside a trace."""

    id = "jax-traced-branch"
    severity = "error"

    def check(self, project: Project) -> Iterable[Finding]:
        for module in project.modules:
            for qual, (fn, info) in traced_functions(module).items():
                if not hasattr(fn, "body"):
                    continue
                flow = _Tracedness(fn, info)
                for stmt in _body_statements(fn):
                    tests = []
                    if isinstance(stmt, (ast.If, ast.While)):
                        tests.append(stmt.test)
                    for node in ast.walk(stmt):
                        if isinstance(node, ast.IfExp):
                            tests.append(node.test)
                    for test in tests:
                        if _is_none_check(test):
                            continue
                        if flow.expr_traced(test):
                            yield Finding(
                                self.id, self.severity, module.path,
                                test.lineno, symbol=qual,
                                message=(
                                    "Python branch on a traced value "
                                    f"inside a {info.kind} body — this "
                                    "concretizes the tracer; use "
                                    "jnp.where / lax.cond / lax.select"))
                    flow.feed(stmt)


def _single_assignments(fn: ast.AST) -> Dict[str, ast.AST]:
    """name -> value expr for names assigned exactly once within ``fn``."""
    assigns: Dict[str, List[ast.AST]] = {}
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    assigns.setdefault(tgt.id, []).append(node.value)
        elif isinstance(node, ast.AugAssign) and \
                isinstance(node.target, ast.Name):
            assigns.setdefault(node.target.id, []).append(node)
    return {n: vals[0] for n, vals in assigns.items() if len(vals) == 1}


def _bounded(node: ast.AST, env: Dict[str, ast.AST],
             stack: Optional[Set[str]] = None) -> bool:
    """Value set provably finite across the process lifetime.  ``env``
    maps single-assigned local names to their value exprs (resolved
    recursively: ``k = min(user_k, K_MAX)`` makes ``k`` bounded)."""
    stack = stack if stack is not None else set()
    if isinstance(node, ast.Constant):
        return True
    if isinstance(node, ast.Name):
        if node.id.isupper():
            return True
        if node.id in env and node.id not in stack:
            return _bounded(env[node.id], env, stack | {node.id})
        return False
    if isinstance(node, ast.Attribute):
        # shapes/dims are static per trace; ALL_CAPS module constants
        return node.attr in _STATIC_ATTRS or node.attr.isupper()
    if isinstance(node, ast.Subscript):
        return _bounded(node.value, env, stack)
    if isinstance(node, ast.UnaryOp):
        return _bounded(node.operand, env, stack)
    if isinstance(node, ast.BinOp):
        return _bounded(node.left, env, stack) and \
            _bounded(node.right, env, stack)
    if isinstance(node, ast.IfExp):
        return _bounded(node.body, env, stack) and \
            _bounded(node.orelse, env, stack)
    if isinstance(node, ast.Call):
        name = (call_name(node) or "").split(".")[-1]
        if name == "len":
            return True
        if name == "min":   # a clamp: bounded if ANY bound is bounded
            return any(_bounded(a, env, stack) for a in node.args)
        if name == "max":
            return all(_bounded(a, env, stack) for a in node.args)
        # bucket lookups quantize to the finite kernels/tuning.py ladder
        if "bucket" in name or name in ("size_bucket", "resolve"):
            return True
    return False


@register_rule
class UnboundedStaticRule:
    """Static args at jitted call sites drawn from unbounded value sets."""

    id = "jax-unbounded-static"
    severity = "warning"

    def check(self, project: Project) -> Iterable[Finding]:
        for module in project.modules:
            jitted = {qual.split(".")[-1]: (fn, info)
                      for qual, (fn, info) in
                      traced_functions(module).items()
                      if info.static_argnames or info.static_argnums}
            if not jitted:
                continue
            for qual, fn, _cls in iter_functions(module.tree):
                consts = _single_assignments(fn)
                for node in ast.walk(fn):
                    if not isinstance(node, ast.Call) or \
                            not isinstance(node.func, ast.Name):
                        continue
                    entry = jitted.get(node.func.id)
                    if entry is None:
                        continue
                    target_fn, info = entry
                    if target_fn is fn:       # the def itself, not a site
                        continue
                    static = _static_params(target_fn, info)
                    annotations = {
                        a.arg: ast.dump(a.annotation)
                        for a in (list(target_fn.args.args)
                                  + list(target_fn.args.kwonlyargs))
                        if a.annotation is not None}
                    for kw in node.keywords:
                        if kw.arg is None or kw.arg not in static:
                            continue
                        if kw.arg in TUNED_BLOCK_KWARGS:
                            continue          # finite tuned table
                        if "'bool'" in annotations.get(kw.arg, ""):
                            continue          # two-valued: bounded by type
                        if not _bounded(kw.value, consts):
                            yield Finding(
                                self.id, self.severity, module.path,
                                node.lineno, symbol=qual,
                                message=(
                                    f"static arg {kw.arg!r} to jitted "
                                    f"{node.func.id}() may take unboundedly "
                                    "many values — each distinct value is a "
                                    "fresh trace + XLA compile; clamp to a "
                                    "bucket (kernels/tuning.size_bucket) or "
                                    "pass it dynamically"))


def _donating_functions(module: Module) -> Dict[str, Tuple[ast.AST, JitInfo]]:
    return {qual.split(".")[-1]: (fn, info)
            for qual, (fn, info) in traced_functions(module).items()
            if info.donate_argnums}


@register_rule
class DonatedReuseRule:
    """Reads of an argument after it was passed at a donated position."""

    id = "jax-donated-reuse"
    severity = "error"

    def check(self, project: Project) -> Iterable[Finding]:
        for module in project.modules:
            donating = _donating_functions(module)
            if not donating:
                continue
            for qual, fn, _cls in iter_functions(module.tree):
                yield from self._check_function(module, qual, fn, donating)

    def _check_function(self, module: Module, qual: str, fn: ast.AST,
                        donating) -> Iterable[Finding]:
        # call line -> donated argument names
        donated_at: List[Tuple[int, str, str]] = []
        assigns: Dict[str, List[int]] = {}
        loads: Dict[str, List[int]] = {}
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign):
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        assigns.setdefault(tgt.id, []).append(node.lineno)
            elif isinstance(node, ast.Name) and \
                    isinstance(node.ctx, ast.Load):
                loads.setdefault(node.id, []).append(node.lineno)
            if not isinstance(node, ast.Call) or \
                    not isinstance(node.func, ast.Name):
                continue
            entry = donating.get(node.func.id)
            if entry is None:
                continue
            _, info = entry
            for i in info.donate_argnums:
                if i < len(node.args) and \
                        isinstance(node.args[i], ast.Name):
                    donated_at.append((node.lineno, node.args[i].id,
                                       node.func.id))
        for call_line, name, callee in donated_at:
            # a read after the call, before any reassignment, is a
            # use-after-donation (the common `x = f(x)` rebind is fine:
            # the reassignment shares the call line)
            rebinds = [ln for ln in assigns.get(name, ()) if ln >= call_line]
            horizon = min(rebinds) if rebinds else float("inf")
            for load_line in loads.get(name, ()):
                if call_line < load_line and load_line > horizon:
                    break
                if call_line < load_line <= horizon:
                    yield Finding(
                        self.id, self.severity, module.path, load_line,
                        symbol=qual,
                        message=(
                            f"{name!r} is read after being donated to "
                            f"{callee}() on line {call_line} — XLA may "
                            "have reused its buffer; rebind the result "
                            "or drop the donation"))
                    break


@register_rule
class ServeDonatedAppendRule:
    """LiveIndex contract: serve-tier buffer writes must not donate."""

    id = "serve-donated-append"
    severity = "error"

    def check(self, project: Project) -> Iterable[Finding]:
        for module in project.modules:
            if module.package != "serve" and \
                    ".serve." not in f".{module.name}.":
                continue
            for qual, (fn, info) in traced_functions(module).items():
                if not info.donate_argnums or not hasattr(fn, "body"):
                    continue
                for node in ast.walk(fn):
                    if isinstance(node, ast.Call) and \
                            (call_name(node) or "").endswith(
                                "dynamic_update_slice"):
                        yield Finding(
                            self.id, self.severity, module.path,
                            fn.lineno, symbol=qual,
                            message=(
                                "serve-tier append buffers must not be "
                                "donated: an in-flight search on another "
                                "thread may still hold the previous buffer "
                                "(the lock covers the swap, not the "
                                "compute) — use donate_argnums=()"))
                        break
