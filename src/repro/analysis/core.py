"""Contract analyzer core (DESIGN.md §15): findings, rule registry,
project loader, suppressions, baseline.

Every layer added since the search/sampling cores rests on invariants that
used to live only in prose — "steady state never recompiles" (scheduler
bucket set), "append-path buffers are never donated" (LiveIndex), "lock
discipline across serve/" — and on registry protocols whose violations
surface as runtime ``AttributeError``.  This package machine-checks those
contracts the same way the engine registries made execution strategies
first-class: each contract family is a registered :class:`LintRule` behind
one ``check(project)`` protocol (mirroring ``core/engines.py`` /
``core/samplers.py``), and ``launch/lint.py`` runs the registry over a
parsed :class:`Project`.

Rule families (each in its own module, imported by :func:`load_default_rules`):

  * ``analysis/jax_rules.py``         — JAX trace hazards + donation safety.
  * ``analysis/concurrency_rules.py`` — lock discipline, lock-order graph,
                                        thread failure surfacing.
  * ``analysis/registry_rules.py``    — registered classes implement their
                                        Protocol (signatures included).
  * ``analysis/imports.py``           — package import cycles + layering.

Suppression: a finding is silenced by ``# lint: disable=<rule-id>`` (or a
bare ``# lint: disable``) on the flagged line or the line directly above.
Suppressions are for *reviewed* exceptions — the analyzer is advisory about
idioms it cannot prove safe, and the comment is the audit trail.

Baseline: :func:`save_baseline` persists finding fingerprints (rule + path
+ symbol + message — line numbers excluded, so unrelated edits do not churn
it); :func:`new_findings` filters a run against it.  CI fails on any
error-severity finding not in the committed baseline.
"""
from __future__ import annotations

import ast
import dataclasses
import hashlib
import json
import os
import re
from typing import Dict, Iterable, List, Optional, Protocol, Sequence, \
    Tuple, runtime_checkable

__all__ = [
    "SEVERITIES", "Finding", "Module", "Project", "LintRule",
    "register_rule", "get_rule", "available_rules", "analyze",
    "load_default_rules", "load_baseline", "save_baseline", "new_findings",
    "dotted_name", "call_name",
]

#: severity rank — exit-code policy and report ordering
SEVERITIES: Dict[str, int] = {"info": 0, "warning": 1, "error": 2}

_SUPPRESS_RE = re.compile(r"#\s*lint:\s*disable(?:=([\w\-, ]+))?")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One analyzer finding, anchored to ``path:line``."""

    rule: str
    severity: str
    path: str
    line: int
    message: str
    symbol: str = ""   # enclosing def/class qualname, for stable baselines

    @property
    def fingerprint(self) -> str:
        """Line-number-free identity (baseline key).  The path is keyed
        by its trailing package-relative form so absolute and relative
        invocations agree on the same fingerprint."""
        path = self.path.replace(os.sep, "/")
        for marker in ("/src/", "/tests/"):
            if marker in path:
                path = path.split(marker, 1)[1]
                path = marker.strip("/") + "/" + path
                break
        else:
            path = path.lstrip("/")
        raw = "|".join((self.rule, path, self.symbol, self.message))
        return hashlib.sha1(raw.encode()).hexdigest()[:12]

    def to_dict(self) -> dict:
        return {"rule": self.rule, "severity": self.severity,
                "path": self.path, "line": self.line,
                "symbol": self.symbol, "message": self.message,
                "fingerprint": self.fingerprint}

    def format(self) -> str:
        sym = f" [{self.symbol}]" if self.symbol else ""
        return (f"{self.path}:{self.line}: {self.severity}: "
                f"{self.rule}: {self.message}{sym}")


@dataclasses.dataclass
class Module:
    """One parsed source file."""

    path: str             # filesystem path, as discovered
    name: str             # dotted module name (repro.serve.ingest, ...)
    tree: ast.Module
    lines: List[str]      # raw source lines, 0-indexed

    @property
    def package(self) -> str:
        """Top-level subpackage under ``repro`` ('' for root modules),
        else the first dotted component (fixture trees)."""
        parts = self.name.split(".")
        if parts[0] == "repro":
            return parts[1] if len(parts) > 1 else ""
        return parts[0]

    def suppressed(self, line: int, rule_id: str) -> bool:
        """True when ``# lint: disable[=rule[,rule]]`` covers ``line``."""
        for lineno in (line, line - 1):
            if not 1 <= lineno <= len(self.lines):
                continue
            m = _SUPPRESS_RE.search(self.lines[lineno - 1])
            if m is None:
                continue
            if m.group(1) is None:
                return True
            rules = {r.strip() for r in m.group(1).split(",")}
            if rule_id in rules:
                return True
        return False


def _module_name(path: str) -> str:
    """Dotted name by walking up through ``__init__.py`` package dirs; the
    first directory without one is the import root (``src`` for the repo,
    a tmp dir for test fixtures)."""
    path = os.path.abspath(path)
    parts = [os.path.splitext(os.path.basename(path))[0]]
    d = os.path.dirname(path)
    while os.path.isfile(os.path.join(d, "__init__.py")):
        parts.append(os.path.basename(d))
        d = os.path.dirname(d)
    if parts[0] == "__init__":
        parts = parts[1:] or [os.path.basename(os.path.dirname(path))]
    return ".".join(reversed(parts))


class Project:
    """A set of parsed modules the rules run over."""

    def __init__(self, modules: Sequence[Module]):
        self.modules: List[Module] = sorted(modules, key=lambda m: m.path)
        self.by_name: Dict[str, Module] = {m.name: m for m in self.modules}

    @classmethod
    def load(cls, paths: Sequence[str]) -> "Project":
        """Parse every ``.py`` under the given files/directories."""
        files: List[str] = []
        for p in paths:
            if os.path.isdir(p):
                for dirpath, dirnames, names in os.walk(p):
                    dirnames[:] = [d for d in dirnames
                                   if d != "__pycache__"]
                    files.extend(os.path.join(dirpath, n)
                                 for n in names if n.endswith(".py"))
            elif p.endswith(".py"):
                files.append(p)
            else:
                raise ValueError(f"not a python file or directory: {p!r}")
        modules = []
        for f in sorted(set(files)):
            with open(f, encoding="utf-8") as fh:
                src = fh.read()
            modules.append(Module(path=f, name=_module_name(f),
                                  tree=ast.parse(src, filename=f),
                                  lines=src.splitlines()))
        return cls(modules)


# ---------------------------------------------------------------------------
# Rule registry (the core/engines.py pattern)
# ---------------------------------------------------------------------------


@runtime_checkable
class LintRule(Protocol):
    """One contract checker: scans a project, yields findings."""

    id: str
    severity: str

    def check(self, project: Project) -> Iterable[Finding]:
        ...


_RULES: Dict[str, LintRule] = {}


def register_rule(cls):
    """Class decorator: instantiate and register a rule under its id."""
    rule = cls()
    _RULES[rule.id] = rule
    return cls


def get_rule(rule_id: str) -> LintRule:
    try:
        return _RULES[rule_id]
    except KeyError:
        raise ValueError(
            f"unknown lint rule {rule_id!r}; registered rules: "
            f"{', '.join(available_rules())}") from None


def available_rules() -> tuple:
    return tuple(sorted(_RULES))


def load_default_rules() -> tuple:
    """Import the built-in rule modules (their decorators register) and
    return the registered rule ids."""
    from repro.analysis import concurrency_rules  # noqa: F401
    from repro.analysis import imports            # noqa: F401
    from repro.analysis import jax_rules          # noqa: F401
    from repro.analysis import registry_rules     # noqa: F401
    return available_rules()


def analyze(project: Project,
            rules: Optional[Sequence[str]] = None) -> List[Finding]:
    """Run rules over the project; suppression comments applied; findings
    ordered (path, line, rule)."""
    if not _RULES:
        load_default_rules()
    ids = list(rules) if rules is not None else list(available_rules())
    by_path = {m.path: m for m in project.modules}
    findings: List[Finding] = []
    for rule_id in ids:
        rule = get_rule(rule_id)
        for f in rule.check(project):
            mod = by_path.get(f.path)
            if mod is not None and mod.suppressed(f.line, f.rule):
                continue
            findings.append(f)
    return sorted(findings, key=lambda f: (f.path, f.line, f.rule))


# ---------------------------------------------------------------------------
# Baseline
# ---------------------------------------------------------------------------


def save_baseline(path: str, findings: Sequence[Finding]) -> None:
    """Persist finding fingerprints (sorted, line-free) as the accepted set."""
    payload = {
        "version": 1,
        "findings": sorted(
            ({"fingerprint": f.fingerprint, "rule": f.rule, "path": f.path,
              "severity": f.severity, "message": f.message}
             for f in findings), key=lambda d: d["fingerprint"]),
    }
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=2)
        fh.write("\n")


def load_baseline(path: str) -> frozenset:
    """Accepted fingerprints (empty set when the file does not exist)."""
    if not os.path.exists(path):
        return frozenset()
    with open(path) as fh:
        payload = json.load(fh)
    return frozenset(d["fingerprint"] for d in payload.get("findings", ()))


def new_findings(findings: Sequence[Finding],
                 baseline: frozenset) -> List[Finding]:
    return [f for f in findings if f.fingerprint not in baseline]


# ---------------------------------------------------------------------------
# Shared AST helpers
# ---------------------------------------------------------------------------


def dotted_name(node: ast.AST) -> Optional[str]:
    """'a.b.c' for a Name/Attribute chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def call_name(call: ast.Call) -> Optional[str]:
    """Dotted name of a call target ('functools.partial', 'jax.jit', ...)."""
    return dotted_name(call.func)


def iter_functions(tree: ast.AST
                   ) -> Iterable[Tuple[str, ast.AST, Optional[ast.ClassDef]]]:
    """Yield (qualname, funcdef, enclosing_class) for every def, including
    nested ones (nested defs carry the outer qualname prefix)."""

    def walk(node: ast.AST, prefix: str, cls: Optional[ast.ClassDef]):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qual = f"{prefix}{child.name}"
                yield qual, child, cls
                yield from walk(child, qual + ".", cls)
            elif isinstance(child, ast.ClassDef):
                yield from walk(child, f"{prefix}{child.name}.", child)
            else:
                yield from walk(child, prefix, cls)

    yield from walk(tree, "", None)


def arg_names(fn: ast.AST) -> List[str]:
    """Positional + kw-only parameter names of a def or lambda."""
    a = fn.args
    return [x.arg for x in
            list(a.posonlyargs) + list(a.args) + list(a.kwonlyargs)]
