"""Concurrency rules (DESIGN.md §15): lock discipline across the serving
tier.

The serve/obs threading model is lock-per-object (``self._lock`` guarding
instance state) plus short-lived worker threads (ingest compactor, async
checkpointer).  Three things go wrong in that model, and each is a rule:

  * ``conc-unguarded-write`` (error) / ``conc-unguarded-read`` (warning) —
    an attribute is *guarded* when some non-``__init__`` method assigns it
    inside a ``with self.<lock>`` block; any other method touching it bare
    is racing the guarded writers.  Writes are errors (lost updates /
    torn state); reads are warnings (many are benign monotonic probes,
    but each deserves a look or a ``# lint: disable``).
  * ``conc-lock-order`` (error) — the lock-acquisition-order graph: class
    methods may acquire their own lock and, through attribute calls, the
    locks of objects they hold; a cycle in that graph is a deadlock
    waiting for the right interleaving.
  * ``conc-thread-no-surface`` (error) — a ``threading.Thread`` whose
    target's failure is never surfaced: no ``join()`` anywhere in the
    class and no try/except in the worker that stores the error for a
    caller to re-raise (the AsyncCheckpointer ``_err`` idiom).

Scope: rules apply to classes in ``serve`` and ``obs`` packages (plus
``train``, which owns the checkpoint worker) — the packages with real
cross-thread traffic — and to any fixture tree handed to them directly.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis.core import (Finding, Module, Project, call_name,
                                 dotted_name, register_rule)

__all__ = ["ClassLocks", "class_locks", "lock_order_graph", "graph_cycle"]

#: packages whose classes are subject to the concurrency rules
_CONCURRENT_PACKAGES = frozenset({"serve", "obs", "train"})

#: self-attribute names treated as locks when used as context managers
_LOCK_HINT = "lock"

#: container methods that mutate their receiver — ``self.x.append(...)``
#: is a write to the guarded structure, not a read
_MUTATORS = frozenset({
    "append", "appendleft", "extend", "insert", "add", "discard", "remove",
    "pop", "popleft", "popitem", "clear", "update", "setdefault",
    "move_to_end", "sort", "reverse"})


def _applies(module: Module) -> bool:
    return module.package in _CONCURRENT_PACKAGES or \
        not module.name.startswith("repro.")


def _self_attr(node: ast.AST) -> Optional[str]:
    """'x' for a ``self.x`` expression, else None."""
    if isinstance(node, ast.Attribute) and \
            isinstance(node.value, ast.Name) and node.value.id == "self":
        return node.attr
    return None


def _is_lock_attr(name: Optional[str]) -> bool:
    return name is not None and _LOCK_HINT in name.lower()


def _with_lock_name(stmt: ast.With) -> Optional[str]:
    """Lock attr name when ``stmt`` is ``with self.<lock>: ...``."""
    for item in stmt.items:
        ctx = item.context_expr
        # allow `with self._lock:` and `with self._lock, other:`
        name = _self_attr(ctx)
        if _is_lock_attr(name):
            return name
        # `with self._lock.acquire_timeout(...)`-style wrappers
        if isinstance(ctx, ast.Call):
            inner = _self_attr(ctx.func.value) \
                if isinstance(ctx.func, ast.Attribute) else None
            if _is_lock_attr(inner):
                return inner
    return None


@dataclasses.dataclass
class ClassLocks:
    """Lock discipline facts for one class."""

    name: str
    module: Module
    node: ast.ClassDef
    locks: Set[str]                      # lock attrs ever used in `with`
    guarded: Dict[str, Set[str]]         # attr -> lock names guarding writes
    # (method, attr, line, inside_lock, is_write) access records
    accesses: List[Tuple[str, str, int, bool, bool]]


def _mutation_writes(fn: ast.AST) -> Set[int]:
    """``id()`` of self-attr Attribute nodes written *through*: subscript
    stores (``self.x[k] = v``) and mutator calls (``self.x.append(v)``)."""
    out: Set[int] = set()
    for node in ast.walk(fn):
        if isinstance(node, (ast.Assign, ast.AugAssign)):
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            for tgt in targets:
                for sub in ast.walk(tgt):
                    if isinstance(sub, ast.Subscript) and \
                            _self_attr(sub.value) is not None:
                        out.add(id(sub.value))
        elif isinstance(node, ast.Call) and \
                isinstance(node.func, ast.Attribute) and \
                node.func.attr in _MUTATORS and \
                _self_attr(node.func.value) is not None:
            out.add(id(node.func.value))
    return out


def _method_accesses(fn: ast.AST) -> Iterable[Tuple[str, int, bool, bool]]:
    """(attr, line, inside_lock, is_write) for every self.attr touch."""
    mutated = _mutation_writes(fn)

    def walk(node: ast.AST, inside: bool):
        if isinstance(node, ast.With):
            lock = _with_lock_name(node)
            for child in node.body:
                yield from walk(child, inside or lock is not None)
            for item in node.items:
                yield from walk(item.context_expr, inside)
            return
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            return  # nested defs audited on their own
        attr = _self_attr(node)
        if attr is not None and not _is_lock_attr(attr):
            is_write = id(node) in mutated or (
                isinstance(node.ctx, (ast.Store, ast.Del))
                if hasattr(node, "ctx") else False)
            yield attr, node.lineno, inside, is_write
        for child in ast.iter_child_nodes(node):
            yield from walk(child, inside)

    for stmt in getattr(fn, "body", []):
        yield from walk(stmt, False)


def class_locks(module: Module, cls: ast.ClassDef) -> ClassLocks:
    """Collect lock facts for one class body."""
    locks: Set[str] = set()
    guarded: Dict[str, Set[str]] = {}
    accesses: List[Tuple[str, str, int, bool, bool]] = []
    for item in cls.body:
        if not isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        mutated = _mutation_writes(item)
        # record which locks each `with` in this method names
        for node in ast.walk(item):
            if isinstance(node, ast.With):
                lock = _with_lock_name(node)
                if lock is not None:
                    locks.add(lock)
                    if item.name != "__init__":
                        for sub in node.body:
                            for n in ast.walk(sub):
                                attr = _self_attr(n)
                                if attr and not _is_lock_attr(attr) and (
                                        id(n) in mutated or
                                        (hasattr(n, "ctx") and isinstance(
                                            n.ctx, ast.Store))):
                                    guarded.setdefault(attr,
                                                       set()).add(lock)
        if item.name == "__init__":
            continue  # construction is single-threaded
        for attr, line, inside, is_write in _method_accesses(item):
            accesses.append((item.name, attr, line, inside, is_write))
    return ClassLocks(name=cls.name, module=module, node=cls,
                      locks=locks, guarded=guarded, accesses=accesses)


def _iter_classes(module: Module) -> Iterable[ast.ClassDef]:
    for node in module.tree.body:
        if isinstance(node, ast.ClassDef):
            yield node


@register_rule
class UnguardedWriteRule:
    """Bare writes to attributes that are elsewhere lock-guarded."""

    id = "conc-unguarded-write"
    severity = "error"

    def check(self, project: Project) -> Iterable[Finding]:
        for module in project.modules:
            if not _applies(module):
                continue
            for cls in _iter_classes(module):
                facts = class_locks(module, cls)
                for method, attr, line, inside, is_write in facts.accesses:
                    if not is_write or inside or attr not in facts.guarded:
                        continue
                    locks = "/".join(sorted(facts.guarded[attr]))
                    yield Finding(
                        self.id, self.severity, module.path, line,
                        symbol=f"{cls.name}.{method}",
                        message=(
                            f"write to self.{attr} outside self.{locks} — "
                            f"other methods only write it under the lock; "
                            f"a bare write races them (lost update / torn "
                            f"state)"))


@register_rule
class UnguardedReadRule:
    """Bare reads of attributes that are elsewhere lock-guarded."""

    id = "conc-unguarded-read"
    severity = "warning"

    def check(self, project: Project) -> Iterable[Finding]:
        for module in project.modules:
            if not _applies(module):
                continue
            for cls in _iter_classes(module):
                facts = class_locks(module, cls)
                for method, attr, line, inside, is_write in facts.accesses:
                    if is_write or inside or attr not in facts.guarded:
                        continue
                    locks = "/".join(sorted(facts.guarded[attr]))
                    yield Finding(
                        self.id, self.severity, module.path, line,
                        symbol=f"{cls.name}.{method}",
                        message=(
                            f"read of self.{attr} outside self.{locks} — "
                            f"writers hold the lock; take it (or annotate "
                            f"why a stale/torn read is safe)"))


# ---------------------------------------------------------------------------
# Lock-order graph
# ---------------------------------------------------------------------------


def _init_fn(cls: ast.ClassDef) -> Optional[ast.FunctionDef]:
    for item in cls.body:
        if isinstance(item, ast.FunctionDef) and item.name == "__init__":
            return item
    return None


def _self_param_flow(classes: Dict[str, Tuple["Module", ast.ClassDef]]
                     ) -> Dict[Tuple[str, str], str]:
    """(callee_class, param) -> caller class, from ``Callee(self, ...)``
    call sites anywhere inside a class body — the caller's type flows
    into the callee's constructor parameter."""
    params: Dict[str, List[str]] = {}
    for name, (_, cls) in classes.items():
        init = _init_fn(cls)
        if init is not None:
            params[name] = [a.arg for a in init.args.args[1:]]
    flow: Dict[Tuple[str, str], str] = {}
    for caller, (_, cls) in classes.items():
        for node in ast.walk(cls):
            if not isinstance(node, ast.Call):
                continue
            callee = (call_name(node) or "").split(".")[-1]
            if callee not in params:
                continue
            for i, arg in enumerate(node.args):
                if isinstance(arg, ast.Name) and arg.id == "self" \
                        and i < len(params[callee]):
                    flow[(callee, params[callee][i])] = caller
            for kw in node.keywords:
                if isinstance(kw.value, ast.Name) and \
                        kw.value.id == "self" and kw.arg in params[callee]:
                    flow[(callee, kw.arg)] = caller
    return flow


def _init_attr_classes(cls: ast.ClassDef, known: Set[str],
                       param_flow: Optional[Dict[Tuple[str, str], str]] = None
                       ) -> Dict[str, str]:
    """attr -> class name, from ``self.attr = ClassName(...)`` in
    __init__, ``self.attr = param`` with a class-typed annotation, or a
    param another class passed ``self`` into (``param_flow``)."""
    out: Dict[str, str] = {}
    init = _init_fn(cls)
    if init is None:
        return out
    param_cls: Dict[str, str] = {}          # __init__ param -> class name
    for a in init.args.args[1:] + init.args.kwonlyargs:
        ann = a.annotation
        if isinstance(ann, ast.Constant) and isinstance(ann.value, str):
            ann_name = ann.value.split(".")[-1].strip("'\" ")
        else:
            ann_name = (dotted_name(ann) or "").split(".")[-1] if ann else ""
        if ann_name in known:
            param_cls[a.arg] = ann_name
        elif param_flow and (cls.name, a.arg) in param_flow:
            param_cls[a.arg] = param_flow[(cls.name, a.arg)]
    for node in ast.walk(init):
        if not isinstance(node, ast.Assign):
            continue
        callee = None
        if isinstance(node.value, ast.Call):
            callee = (call_name(node.value) or "").split(".")[-1]
        elif isinstance(node.value, ast.Name):
            callee = param_cls.get(node.value.id)
        if callee not in known:
            continue
        for tgt in node.targets:
            attr = _self_attr(tgt)
            if attr is not None:
                out[attr] = callee
    return out


def lock_order_graph(project: Project) -> Dict[str, Set[str]]:
    """Directed edges ``ClassA.lock -> ClassB.lock`` meaning: some method
    may acquire A's lock and, while holding it, reach code that acquires
    B's lock (a direct nested ``with``, or a call on an attribute whose
    class takes its own lock in that method)."""
    classes: Dict[str, Tuple[Module, ast.ClassDef]] = {}
    for module in project.modules:
        if not _applies(module):
            continue
        for cls in _iter_classes(module):
            classes[cls.name] = (module, cls)

    # which methods of each class acquire that class's own lock
    acquiring: Dict[str, Set[str]] = {}
    for name, (module, cls) in classes.items():
        facts = class_locks(module, cls)
        methods = set()
        for item in cls.body:
            if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for node in ast.walk(item):
                    if isinstance(node, ast.With) and \
                            _with_lock_name(node) is not None:
                        methods.add(item.name)
                        break
        if facts.locks:
            acquiring[name] = methods

    edges: Dict[str, Set[str]] = {}
    param_flow = _self_param_flow(classes)
    for name, (module, cls) in classes.items():
        if name not in acquiring:
            continue
        attr_cls = _init_attr_classes(cls, set(classes), param_flow)
        for item in cls.body:
            if not isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            for node in ast.walk(item):
                if not isinstance(node, ast.With) or \
                        _with_lock_name(node) is None:
                    continue
                # inside this class's lock: find calls into held objects
                for sub in node.body:
                    for n in ast.walk(sub):
                        if not isinstance(n, ast.Call) or \
                                not isinstance(n.func, ast.Attribute):
                            continue
                        owner = _self_attr(n.func.value)
                        if owner is None or owner not in attr_cls:
                            continue
                        callee_cls = attr_cls[owner]
                        if n.func.attr in acquiring.get(callee_cls, ()):
                            edges.setdefault(name, set()).add(callee_cls)
    return edges


def graph_cycle(edges: Dict[str, Set[str]]) -> Optional[List[str]]:
    """One cycle as a node list (closed), or None."""
    WHITE, GREY, BLACK = 0, 1, 2
    color = {n: WHITE for n in
             set(edges) | {v for vs in edges.values() for v in vs}}
    stack: List[str] = []

    def visit(n: str) -> Optional[List[str]]:
        color[n] = GREY
        stack.append(n)
        for m in sorted(edges.get(n, ())):
            if color[m] == GREY:
                i = stack.index(m)
                return stack[i:] + [m]
            if color[m] == WHITE:
                found = visit(m)
                if found:
                    return found
        stack.pop()
        color[n] = BLACK
        return None

    for n in sorted(color):
        if color[n] == WHITE:
            found = visit(n)
            if found:
                return found
    return None


@register_rule
class LockOrderRule:
    """Cycles in the cross-class lock-acquisition-order graph."""

    id = "conc-lock-order"
    severity = "error"

    def check(self, project: Project) -> Iterable[Finding]:
        edges = lock_order_graph(project)
        cycle = graph_cycle(edges)
        if cycle is None:
            return
        # anchor the finding at the first class in the cycle
        first = cycle[0]
        for module in project.modules:
            for cls in _iter_classes(module):
                if cls.name == first:
                    yield Finding(
                        self.id, self.severity, module.path, cls.lineno,
                        symbol=first,
                        message=(
                            "lock-acquisition-order cycle: "
                            + " -> ".join(cycle)
                            + " — two threads taking these locks in "
                              "opposite orders deadlock; impose a single "
                              "acquisition order or drop to one lock"))
                    return


# ---------------------------------------------------------------------------
# Thread failure surfacing
# ---------------------------------------------------------------------------


def _thread_targets(cls: ast.ClassDef) -> List[Tuple[str, int, Optional[str]]]:
    """(creating_method, line, target_method) per Thread(...) construction."""
    out = []
    for item in cls.body:
        if not isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        for node in ast.walk(item):
            if not isinstance(node, ast.Call):
                continue
            callee = (call_name(node) or "").split(".")[-1]
            if callee != "Thread":
                continue
            target = None
            for kw in node.keywords:
                if kw.arg == "target":
                    t = _self_attr(kw.value)
                    if t is not None:
                        target = t
                    elif isinstance(kw.value, ast.Name):
                        target = kw.value.id
            out.append((item.name, node.lineno, target))
    return out


def _has_join(cls: ast.ClassDef) -> bool:
    for node in ast.walk(cls):
        if isinstance(node, ast.Call) and \
                isinstance(node.func, ast.Attribute) and \
                node.func.attr == "join":
            return True
    return False


def _worker_surfaces(cls: ast.ClassDef, target: Optional[str]) -> bool:
    """True when the worker stores/raises failures: its body has a
    try/except whose handler assigns to self.* or re-raises/logs."""
    if target is None:
        return False
    for item in cls.body:
        if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)) and \
                item.name == target:
            for node in ast.walk(item):
                if isinstance(node, ast.Try) and node.handlers:
                    for handler in node.handlers:
                        for n in ast.walk(handler):
                            if _self_attr(n) is not None and \
                                    hasattr(n, "ctx") and \
                                    isinstance(n.ctx, ast.Store):
                                return True
                            if isinstance(n, (ast.Raise,)):
                                return True
                            if isinstance(n, ast.Call) and \
                                    (call_name(n) or "").split(".")[-1] in (
                                        "error", "exception", "critical"):
                                return True
    return False


@register_rule
class ThreadNoSurfaceRule:
    """Threads whose failures vanish: no join and no error capture."""

    id = "conc-thread-no-surface"
    severity = "error"

    def check(self, project: Project) -> Iterable[Finding]:
        for module in project.modules:
            if not _applies(module):
                continue
            for cls in _iter_classes(module):
                for method, line, target in _thread_targets(cls):
                    if _has_join(cls) or _worker_surfaces(cls, target):
                        continue
                    yield Finding(
                        self.id, self.severity, module.path, line,
                        symbol=f"{cls.name}.{method}",
                        message=(
                            "thread started without failure surfacing: the "
                            "class never join()s it and the worker has no "
                            "try/except storing the error — a crash here "
                            "is silent; keep the AsyncCheckpointer idiom "
                            "(store exc in the worker, re-raise on "
                            "join/close)"))
