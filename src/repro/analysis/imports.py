"""Import hygiene rules (DESIGN.md §15): package cycles and layering.

The PR 2 layering rule ("eval/ sits above core/data and below nothing
that matters"; obs at the bottom, launch at the top) has been prose until
now, and two deferred-import cycles crept in under it.  Two rules enforce
it mechanically:

  * ``import-cycle`` (error) — a cycle between ``repro.*`` packages (or
    between top-level packages of a fixture tree) at module import time.
    Function-level (deferred) imports that *would* close a cycle are a
    warning: the cycle is latent — invisible until someone hoists the
    import, at which point the failure is an ImportError at a distance.
  * ``import-layering`` (error) — each package has a declared rank
    (:data:`LAYERS`); an import must point strictly *down* the ranks.
    This is what makes "eval importing upward" (serve, launch, configs)
    a finding rather than a review comment.

Both rules look only at ``repro.*``-rooted module names (fixture trees in
tests emulate this by creating a ``repro/`` package dir), so vendored or
stdlib imports never trip them.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis.concurrency_rules import graph_cycle
from repro.analysis.core import Finding, Module, Project, register_rule

__all__ = ["LAYERS", "ImportEdge", "import_edges"]

#: Package ranks: an import must point to a strictly lower rank.
#: obs is the foundation (everything may trace/count); launch the roof.
LAYERS: Dict[str, int] = {
    "obs": 0,
    "kernels": 1,
    "distributed": 2,
    "models": 2,
    "core": 3,
    "data": 4,
    "train": 4,
    "retrieval": 5,
    "eval": 6,
    "serve": 6,
    "configs": 7,
    "analysis": 7,
    "launch": 8,
}


@dataclasses.dataclass(frozen=True)
class ImportEdge:
    """One package->package import with its first witnessing statement."""

    src: str            # importing package
    dst: str            # imported package
    path: str
    line: int
    deferred: bool      # inside a function body (imported lazily)


def _target_packages(node: ast.AST, module: Module) -> List[str]:
    """repro-subpackage names a single import statement reaches."""
    root = module.name.split(".")[0]
    out: List[str] = []
    if isinstance(node, ast.Import):
        for alias in node.names:
            parts = alias.name.split(".")
            if parts[0] == root and len(parts) > 1:
                out.append(parts[1])
    elif isinstance(node, ast.ImportFrom):
        if node.level:  # relative: resolve against this module's name
            base = module.name.split(".")[:-node.level]
            parts = base + (node.module.split(".") if node.module else [])
        else:
            parts = (node.module or "").split(".")
        if parts and parts[0] == root and len(parts) > 1:
            out.append(parts[1])
    return out


def import_edges(project: Project) -> List[ImportEdge]:
    """Package-level import graph of the project, deduplicated to the
    first witness per (src, dst, deferred)."""
    seen: Dict[Tuple[str, str, bool], ImportEdge] = {}
    for module in project.modules:
        src = module.package
        if not src:
            continue

        def visit(node: ast.AST, deferred: bool):
            for child in ast.iter_child_nodes(node):
                inner = deferred or isinstance(
                    child, (ast.FunctionDef, ast.AsyncFunctionDef))
                if isinstance(child, (ast.Import, ast.ImportFrom)):
                    for dst in _target_packages(child, module):
                        if dst == src:
                            continue
                        key = (src, dst, deferred)
                        if key not in seen:
                            seen[key] = ImportEdge(
                                src=src, dst=dst, path=module.path,
                                line=child.lineno, deferred=deferred)
                visit(child, inner)

        visit(module.tree, False)
    return sorted(seen.values(),
                  key=lambda e: (e.src, e.dst, e.deferred))


def _graph(edges: Iterable[ImportEdge]) -> Dict[str, Set[str]]:
    g: Dict[str, Set[str]] = {}
    for e in edges:
        g.setdefault(e.src, set()).add(e.dst)
    return g


def _witness(edges: List[ImportEdge], src: str,
             dst: str) -> Optional[ImportEdge]:
    hard = [e for e in edges if e.src == src and e.dst == dst]
    hard.sort(key=lambda e: e.deferred)  # prefer module-level witness
    return hard[0] if hard else None


@register_rule
class ImportCycleRule:
    """Cycles between repro.* packages (latent deferred cycles warn)."""

    id = "import-cycle"
    severity = "error"

    def check(self, project: Project) -> Iterable[Finding]:
        edges = import_edges(project)
        hard = [e for e in edges if not e.deferred]
        cycle = graph_cycle(_graph(hard))
        if cycle is not None:
            e = _witness(hard, cycle[0], cycle[1])
            yield Finding(
                self.id, "error", e.path if e else "<project>",
                e.line if e else 1, symbol=cycle[0],
                message=("package import cycle: " + " -> ".join(cycle)
                         + " — importing any member fails or silently "
                           "half-initializes depending on entry order"))
            return
        # latent: deferred imports would close a cycle if hoisted
        cycle = graph_cycle(_graph(edges))
        if cycle is not None:
            soft = [e for e in edges if e.deferred
                    and (e.src, e.dst) in zip(cycle, cycle[1:])]
            e = soft[0] if soft else None
            yield Finding(
                self.id, "warning", e.path if e else "<project>",
                e.line if e else 1, symbol=cycle[0],
                message=(
                    "latent package cycle (closed by a function-level "
                    "import): " + " -> ".join(cycle)
                    + " — hoisting the deferred import breaks the build; "
                      "move the shared symbol down the layering instead"))


@register_rule
class ImportLayeringRule:
    """Imports must point strictly down the declared package ranks."""

    id = "import-layering"
    severity = "error"

    def check(self, project: Project) -> Iterable[Finding]:
        for e in import_edges(project):
            src_rank = LAYERS.get(e.src)
            dst_rank = LAYERS.get(e.dst)
            if src_rank is None or dst_rank is None:
                continue  # unranked package (fixtures name their own)
            if dst_rank >= src_rank:
                direction = ("sideways"
                             if dst_rank == src_rank else "upward")
                yield Finding(
                    self.id, self.severity, e.path, e.line, symbol=e.src,
                    message=(
                        f"{e.src} (rank {src_rank}) imports {e.dst} "
                        f"(rank {dst_rank}) — {direction} against the "
                        f"declared layering; move the shared code into a "
                        f"lower-ranked package"
                        + (" (deferred import: still a layering hole)"
                           if e.deferred else "")))
