"""Registry conformance rule (DESIGN.md §15): registered implementations
satisfy their Protocol, statically.

The repo's extension points all share one shape (``core/engines.py``,
``core/samplers.py``, ``retrieval/engines.py``, ``retrieval/backends.py``,
``analysis/core.py``): a ``typing.Protocol`` class declaring the contract,
a module-level ``register*`` function whose body subscript-assigns into a
``*REGISTRY*`` dict, and implementations registered by decorator (often
stacked with ``@dataclasses.dataclass``).  A non-conforming implementation
today surfaces as an ``AttributeError``/``TypeError`` deep inside a run;
this rule finds the same defect at lint time:

  * a protocol method the implementation never defines (and no base class
    in the module defines);
  * an implementation method whose signature cannot accept the protocol's
    calls — fewer positionals, missing kw-only names, or extra required
    parameters without defaults;
  * a protocol attribute (``name: str`` / ``needs_graph: bool`` …) the
    implementation declares neither at class level (AnnAssign *or* plain
    Assign — sampler strategies use both), nor in ``__init__`` via
    ``self.attr = …``, nor as a property.

Discovery is per-module and purely syntactic: the protocol/register-fn
pairing is inferred, so the rule automatically covers new registries —
including this package's own ``LintRule``/``register_rule``.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis.core import (Finding, Module, Project, call_name,
                                 register_rule)

__all__ = ["Registry", "find_registries", "conformance_findings"]


@dataclasses.dataclass
class Registry:
    """One protocol + register-function pairing in a module."""

    module: Module
    protocol: ast.ClassDef
    register_fn: str
    implementations: List[ast.ClassDef]


def _is_protocol(cls: ast.ClassDef) -> bool:
    for base in cls.bases:
        name = base.attr if isinstance(base, ast.Attribute) else \
            base.id if isinstance(base, ast.Name) else None
        if name == "Protocol":
            return True
    return False


def _is_register_fn(fn: ast.FunctionDef) -> bool:
    """Module-level def that subscript-assigns into a *REGISTRY* dict."""
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign):
            for tgt in node.targets:
                if isinstance(tgt, ast.Subscript) and \
                        isinstance(tgt.value, ast.Name) and \
                        "registry" in tgt.value.id.lower():
                    return True
    return False


def _decorator_names(cls: ast.ClassDef) -> Set[str]:
    out: Set[str] = set()
    for dec in cls.decorator_list:
        node = dec.func if isinstance(dec, ast.Call) else dec
        name = node.attr if isinstance(node, ast.Attribute) else \
            node.id if isinstance(node, ast.Name) else None
        if name:
            out.add(name)
    return out


def find_registries(project: Project) -> List[Registry]:
    """Protocol/register-fn pairs, with their registered implementations
    gathered project-wide (implementations often live in other modules)."""
    registries: List[Registry] = []
    for module in project.modules:
        protocols = [n for n in module.tree.body
                     if isinstance(n, ast.ClassDef) and _is_protocol(n)]
        register_fns = [n.name for n in module.tree.body
                        if isinstance(n, ast.FunctionDef)
                        and _is_register_fn(n)]
        if not protocols or not register_fns:
            continue
        # one protocol per register fn in this codebase; pair them in
        # source order when a module declares several
        for proto, fn_name in zip(protocols, register_fns):
            registries.append(Registry(module=module, protocol=proto,
                                       register_fn=fn_name,
                                       implementations=[]))
    by_fn = {r.register_fn: r for r in registries}
    for module in project.modules:
        for node in ast.walk(module.tree):
            if isinstance(node, ast.ClassDef):
                for dec in _decorator_names(node):
                    if dec in by_fn:
                        by_fn[dec].implementations.append(node)
            elif isinstance(node, ast.Call):
                # register(MyClass) call form
                name = (call_name(node) or "").split(".")[-1]
                if name in by_fn and node.args and \
                        isinstance(node.args[0], ast.Name):
                    reg = by_fn[name]
                    target = node.args[0].id
                    for n in ast.walk(module.tree):
                        if isinstance(n, ast.ClassDef) and \
                                n.name == target and \
                                n not in reg.implementations:
                            reg.implementations.append(n)
    return registries


def _protocol_members(proto: ast.ClassDef
                      ) -> Tuple[Dict[str, ast.FunctionDef], Set[str]]:
    """(methods, attrs) the protocol declares."""
    methods: Dict[str, ast.FunctionDef] = {}
    attrs: Set[str] = set()
    for item in proto.body:
        if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if not item.name.startswith("__"):
                methods[item.name] = item
        elif isinstance(item, ast.AnnAssign) and \
                isinstance(item.target, ast.Name):
            attrs.add(item.target.id)
        elif isinstance(item, ast.Assign):
            for tgt in item.targets:
                if isinstance(tgt, ast.Name):
                    attrs.add(tgt.id)
    return methods, attrs


def _class_members(cls: ast.ClassDef,
                   classes: Dict[str, ast.ClassDef],
                   seen: Optional[Set[str]] = None
                   ) -> Tuple[Dict[str, ast.FunctionDef], Set[str]]:
    """(methods, attrs) of a class, following same-project base classes.

    Attrs count when declared at class level (AnnAssign or plain Assign —
    sampler strategies use both), assigned to ``self`` in ``__init__``, or
    defined as a property."""
    seen = seen or set()
    seen.add(cls.name)
    methods: Dict[str, ast.FunctionDef] = {}
    attrs: Set[str] = set()
    for base in cls.bases:
        bname = base.attr if isinstance(base, ast.Attribute) else \
            base.id if isinstance(base, ast.Name) else None
        if bname in classes and bname not in seen:
            bm, ba = _class_members(classes[bname], classes, seen)
            methods.update(bm)
            attrs.update(ba)
    for item in cls.body:
        if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
            decs = {d.id for d in item.decorator_list
                    if isinstance(d, ast.Name)}
            if "property" in decs or "cached_property" in decs:
                attrs.add(item.name)
            else:
                methods[item.name] = item
            if item.name == "__init__":
                for node in ast.walk(item):
                    if isinstance(node, ast.Attribute) and \
                            isinstance(node.value, ast.Name) and \
                            node.value.id == "self" and \
                            hasattr(node, "ctx") and \
                            isinstance(node.ctx, ast.Store):
                        attrs.add(node.attr)
        elif isinstance(item, ast.AnnAssign) and \
                isinstance(item.target, ast.Name):
            attrs.add(item.target.id)
        elif isinstance(item, ast.Assign):
            for tgt in item.targets:
                if isinstance(tgt, ast.Name):
                    attrs.add(tgt.id)
    return methods, attrs


def _sig(fn: ast.FunctionDef
         ) -> Tuple[List[str], int, Set[str], bool, bool, Set[str]]:
    """(positional names sans self, n_required_positional, kwonly names,
    has_vararg, has_kwarg, required kwonly names)."""
    a = fn.args
    pos = [x.arg for x in list(a.posonlyargs) + list(a.args)]
    if pos and pos[0] in ("self", "cls"):
        pos = pos[1:]
    n_defaults = len(a.defaults)
    n_required = max(0, len(pos) - n_defaults)
    kwonly = {x.arg for x in a.kwonlyargs}
    required_kwonly = {x.arg for x, d in zip(a.kwonlyargs, a.kw_defaults)
                       if d is None}
    return pos, n_required, kwonly, a.vararg is not None, \
        a.kwarg is not None, required_kwonly


def _signature_problem(proto_fn: ast.FunctionDef,
                       impl_fn: ast.FunctionDef) -> Optional[str]:
    """Human-readable incompatibility, or None when compatible."""
    p_pos, _, p_kw, _, _, _ = _sig(proto_fn)
    i_pos, i_req, i_kw, i_var, i_kwarg, i_req_kw = _sig(impl_fn)
    if len(i_pos) < len(p_pos) and not i_var:
        return (f"takes {len(i_pos)} positional args where the protocol "
                f"passes {len(p_pos)} ({', '.join(p_pos)})")
    if i_req > len(p_pos):
        extra = i_pos[len(p_pos):i_req]
        return ("requires extra positional args without defaults: "
                + ", ".join(extra))
    missing_kw = p_kw - i_kw
    if missing_kw and not i_kwarg:
        return ("missing keyword-only args the protocol declares: "
                + ", ".join(sorted(missing_kw)))
    extra_required = i_req_kw - p_kw
    if extra_required:
        return ("requires keyword-only args the protocol never passes: "
                + ", ".join(sorted(extra_required)))
    return None


def conformance_findings(project: Project, rule_id: str,
                         severity: str) -> Iterable[Finding]:
    for reg in find_registries(project):
        proto_methods, proto_attrs = _protocol_members(reg.protocol)
        for impl in reg.implementations:
            impl_module = next(m for m in project.modules
                               if impl in ast.walk(m.tree))
            local_classes = {n.name: n for n in ast.walk(impl_module.tree)
                             if isinstance(n, ast.ClassDef)}
            methods, attrs = _class_members(impl, local_classes)
            for name, proto_fn in proto_methods.items():
                if name not in methods:
                    yield Finding(
                        rule_id, severity, impl_module.path, impl.lineno,
                        symbol=impl.name,
                        message=(
                            f"registered via {reg.register_fn}() but does "
                            f"not implement {reg.protocol.name}.{name}() — "
                            f"this is a runtime AttributeError on first "
                            f"dispatch"))
                    continue
                problem = _signature_problem(proto_fn, methods[name])
                if problem:
                    yield Finding(
                        rule_id, severity, impl_module.path,
                        methods[name].lineno,
                        symbol=f"{impl.name}.{name}",
                        message=(
                            f"signature incompatible with "
                            f"{reg.protocol.name}.{name}: {problem}"))
            for attr in sorted(proto_attrs - attrs - set(methods)):
                yield Finding(
                    rule_id, severity, impl_module.path, impl.lineno,
                    symbol=impl.name,
                    message=(
                        f"missing protocol attribute "
                        f"{reg.protocol.name}.{attr} — declare it at class "
                        f"level or assign it in __init__"))


@register_rule
class RegistryConformanceRule:
    """Every registered implementation satisfies its Protocol."""

    id = "reg-conformance"
    severity = "error"

    def check(self, project: Project) -> Iterable[Finding]:
        yield from conformance_findings(project, self.id, self.severity)
