"""AST-based contract analyzer (DESIGN.md §15).

Machine-checks the invariants the runtime layers rely on: JAX trace /
retrace hazards, buffer-donation safety, lock discipline across the
serving tier, and registry-protocol conformance.  Run it with::

    PYTHONPATH=src python -m repro.launch.lint src/repro
    PYTHONPATH=src python -m repro.launch.lint --imports
"""
from repro.analysis.core import (Finding, LintRule, Module, Project,
                                 analyze, available_rules, get_rule,
                                 load_baseline, load_default_rules,
                                 new_findings, register_rule, save_baseline)

__all__ = ["Finding", "LintRule", "Module", "Project", "analyze",
           "available_rules", "get_rule", "load_baseline",
           "load_default_rules", "new_findings", "register_rule",
           "save_baseline"]
