"""Sharded WindTunnel pipeline — the single-device dataflow of pipeline.py
partitioned across a device mesh with ``shard_map`` (DESIGN.md §5).

Dataflow (one XLA program, one ``shard_map`` region):

  1. **Query-partitioned GraphBuilder.**  The (tau-filtered) QRel table is
     routed so that each device owns a contiguous block of query ids, then
     each device builds its per-shard ELL table and enumerates affinity
     pairs locally — the reduce-by-query self-join never leaves the shard
     because a query's rows are never split.
  2. **Edge merge.**  The per-shard pair lists are concatenated with a tiled
     all-gather and deduplicated with the same sort + segment-max reduction
     the single-device path uses (collectives.all_concat + gb.dedup_edges):
     an all-gather + segment-max merge.
  3. **Node-partitioned label propagation.**  The merged edge list is packed
     into ELL adjacency rows for the local node block only (adjacency stays
     sharded, O(N·K/d) per device); the i32[N] label vector is the cheap
     replicated carry, refreshed by one label all-gather per round — the
     communication lower bound for bounded-degree distributed LP.
  4. **Sampling + reconstruction** run on the replicated outputs outside the
     shard_map region.  The cluster-sampling Bernoulli draw is keyed per
     label id (sampler.cluster_sample), so the sampled mask is a pure
     function of (seed, labels) — bit-identical to the single-device path
     on a 1-device mesh, and independent of the mesh shape given equal
     labels.

The LP round body follows ``config.engine``: ``ell`` (default) runs the
dense XLA round, ``pallas`` runs the Pallas kernel on the local node block
(interpret mode off-TPU).  The ``sort`` engine has no sharded formulation
(its per-round global sort is exactly the shuffle this path removes) —
selecting it here raises.

Padding invariants: queries are padded to a multiple of the shard count
(padded queries have no QRel rows), nodes to a multiple of the shard count
(padded nodes have no edges, keep their own label, and are sliced off
before sampling).  On a 1-device mesh both paddings are empty and every
stage is operation-for-operation the single-device program.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core import graph_builder as gb
from repro.core import label_prop as lp
from repro.core import segment_utils as su
from repro.core.pipeline import WindTunnelConfig, WindTunnelResult
from repro.distributed import collectives as coll
from repro.distributed.sharded_corpus import ShardedQRels
from repro.distributed.sharding import GNN_RULES, partition_axes


def _mesh_axis_count(mesh: Mesh, axes: tuple) -> int:
    d = 1
    for a in axes:
        d *= mesh.shape[a]
    return d


def _route_by_query(qrels: gb.QRelTable, *, num_shards: int,
                    queries_per_shard: int) -> gb.QRelTable:
    """Partition QRel rows into per-shard buffers of shape (d, n): shard
    ``q // queries_per_shard`` owns every row of query q.  The stable sort
    preserves original row order within a shard, so each shard's local
    table is the compaction of its rows — downstream stable sorts see the
    same tie order as the single-device path."""
    n = qrels.query_ids.shape[0]
    shard = jnp.where(qrels.valid, qrels.query_ids // queries_per_shard,
                      num_shards)  # invalid rows route to the drop bucket
    (ss,), (q, e, s, v) = su.sort_by(
        (shard,), (qrels.query_ids, qrels.entity_ids, qrels.scores,
                   qrels.valid.astype(jnp.int32)))
    rank = su.group_rank(su.run_starts(ss))
    row = jnp.where(ss < num_shards, ss, num_shards)
    buf = lambda fill, dtype: jnp.full((num_shards, n), fill, dtype)
    q_b = buf(0, jnp.int32).at[row, rank].set(q.astype(jnp.int32), mode="drop")
    e_b = buf(0, jnp.int32).at[row, rank].set(e.astype(jnp.int32), mode="drop")
    s_b = buf(0.0, jnp.float32).at[row, rank].set(s, mode="drop")
    v_b = buf(0, jnp.int32).at[row, rank].set(v, mode="drop")
    return gb.QRelTable(q_b, e_b, s_b, v_b)


def _local_lp_round(nbr_labels, wgt, own, *, use_kernel: bool):
    """One LP round on a local node block with pre-gathered neighbour
    labels — either the jnp reference or the Pallas kernel (hot-loop
    winner), both bit-identical to label_prop.ell_round."""
    if not use_kernel:
        from repro.kernels.label_prop.ref import label_prop_round_ref
        return label_prop_round_ref(nbr_labels, wgt, own)
    from repro.kernels.label_prop.ops import pallas_round_padded
    return pallas_round_padded(nbr_labels, wgt, own)


def sharded_graph_and_labels(qrels, *, num_queries: int,
                             num_entities: int, config: WindTunnelConfig,
                             mesh: Mesh, axes: tuple = None) -> tuple:
    """Mesh-partitioned graph build + label propagation (stages 1-3 above):
    one ``shard_map`` region, returning replicated ``(edges, labels,
    changes_per_round)``.

    ``qrels`` is either a global :class:`~repro.core.graph_builder.
    QRelTable` (tau-filtered and query-routed on device — the legacy flow,
    which materialises the full table on one device first) or a
    sharded-from-birth :class:`~repro.distributed.sharded_corpus.
    ShardedQRels` whose buffers were routed host-side and streamed straight
    to their shards.  On the born path tau is computed *inside* the mesh
    from an all-gather of the score column only (O(rows) scalars, never
    the table) — ``nanquantile`` is permutation-invariant, so the
    threshold is bit-identical to the global ``threshold_tau``.

    This is the expensive staged state of the sampling core
    (``sampling_core.SamplerSession``): sampling + reconstruction are cheap
    per-draw stages on the replicated outputs, identical to the
    single-device path.  ``axes`` defaults to the GNN sharding rule for
    node/query arrays filtered to the mesh (production: ('data', 'model');
    host mesh: the same names with total size 1).
    """
    if config.engine not in ("ell", "pallas"):
        raise ValueError(
            f"sharded pipeline requires an ELL-family engine ('ell' or "
            f"'pallas'); got {config.engine!r} — the sort engine's global "
            f"per-round shuffle is exactly what this path eliminates")
    born = isinstance(qrels, ShardedQRels)
    if born and axes is None:
        axes = qrels.axes
    if axes is None:
        axes = partition_axes(mesh, "nodes", GNN_RULES)
    axes = tuple(axes) if axes else ()
    if not axes:
        raise ValueError(f"mesh {mesh} has none of the GNN node axes")
    d = _mesh_axis_count(mesh, axes)

    qps = -(-num_queries // d)          # queries per shard (ceil)
    rows_n = -(-num_entities // d)      # nodes per shard (ceil)
    n_pad = rows_n * d
    if born:
        if qrels.num_shards != d or qrels.queries_per_shard != qps:
            raise ValueError(
                f"ShardedQRels routed for {qrels.num_shards} shards × "
                f"{qrels.queries_per_shard} queries/shard, but the mesh "
                f"needs {d} × {qps}")
        routed = gb.QRelTable(qrels.query_ids, qrels.entity_ids,
                              qrels.scores, qrels.valid)
    else:
        # Global tau: the only stage needing the full score distribution —
        # a scalar quantile, computed replicated before partitioning.
        tau = gb.threshold_tau(qrels, config.tau_quantile)
        kept = gb.filter_qrels(qrels, tau)
        routed = _route_by_query(kept, num_shards=d, queries_per_shard=qps)
    use_kernel = config.engine == "pallas"

    def shard_fn(q_b, e_b, s_b, v_b):
        # ---- local QRel block: (1, n) shard -> (n,) local table ----
        idx = coll.flat_axis_index(axes)
        valid = v_b[0].astype(bool)
        if born:
            # in-mesh tau over the gathered score COLUMN (scores only:
            # the table itself never leaves its shards); invalid/pad rows
            # mark NaN, which nanquantile ignores — same sorted valid
            # multiset as the global path, so tau is bit-identical
            marked = jnp.where(valid, s_b[0], jnp.nan)
            tau_l = jnp.nanquantile(
                lax.all_gather(marked, axes, axis=0, tiled=True),
                config.tau_quantile)
            valid = valid & (s_b[0] > tau_l)
        q_local = jnp.where(valid, q_b[0] - idx * qps, 0).astype(jnp.int32)
        local = gb.QRelTable(q_local, e_b[0], s_b[0], valid)

        # ---- Alg. 1 on the shard: ELL group-by + pair enumeration ----
        ell_e, ell_s = gb.build_ell(local, qps, config.fanout)
        pairs = gb.affinity_pairs(ell_e, ell_s)

        # ---- merge: all-gather pair lists, dedup with segment-max ----
        gathered = coll.all_concat(pairs, axes)
        edges = gb.dedup_edges(gathered)
        src, dst, w, e_valid = gb.symmetrize(edges)

        # ---- node-partitioned ELL adjacency (local rows only) ----
        row0 = idx * rows_n
        dst_local = dst - row0
        mine = e_valid & (dst_local >= 0) & (dst_local < rows_n)
        nbr_l, wgt_l = lp.edges_to_ell(
            src, jnp.where(mine, dst_local, rows_n), w, mine,
            num_nodes=rows_n, max_degree=config.max_degree)

        # ---- LP rounds: sharded adjacency, replicated label carry ----
        def one(labels, _):
            own = lax.dynamic_slice(labels, (row0,), (rows_n,))
            lab = jnp.where(nbr_l >= 0, labels[jnp.maximum(nbr_l, 0)], -1)
            new = _local_lp_round(lab, wgt_l, own, use_kernel=use_kernel)
            changed = lax.psum(jnp.sum((new != own).astype(jnp.int32)), axes)
            return lax.all_gather(new, axes, tiled=True), changed

        labels0 = coll.pvary_compat(jnp.arange(n_pad, dtype=jnp.int32), axes)
        labels, changes = lax.scan(one, labels0, None,
                                   length=config.lp_rounds)
        labels = coll.unvary_compat(labels, axes)
        if born:
            # Born outputs stay row-sharded: every shard computed the SAME
            # replicated edge/label values (dedup of an identical gather;
            # all-gathered label carry), so each keeps only its slice and
            # the assembled global array is bit-identical to the
            # replicated one — per-device residency drops from O(E + N)
            # to O((E + N) / d), which is what keeps the sampling bench's
            # peak_bytes_per_device flat under weak scaling.
            e_len = edges.u.shape[0] // d
            sl = lambda a: lax.dynamic_slice(a, (idx * e_len,), (e_len,))
            edges = gb.EdgeList(sl(edges.u), sl(edges.v),
                                sl(edges.w), sl(edges.valid))
            labels = lax.dynamic_slice(labels, (idx * rows_n,), (rows_n,))
        return edges, labels, changes

    shard_spec = P(axes if len(axes) > 1 else axes[0], None)
    row_spec = P(axes if len(axes) > 1 else axes[0])
    out_edge = (gb.EdgeList(*(row_spec,) * 4) if born
                else gb.EdgeList(P(), P(), P(), P()))
    fn = shard_map(shard_fn, mesh=mesh,
                   in_specs=(shard_spec,) * 4,
                   out_specs=(out_edge, row_spec if born else P(), P()),
                   check_rep=False)
    edges, labels, changes = fn(routed.query_ids, routed.entity_ids,
                                routed.scores, routed.valid)
    return edges, labels[:num_entities], changes


def run_windtunnel_sharded(qrels: gb.QRelTable, *, num_queries: int,
                           num_entities: int, config: WindTunnelConfig,
                           mesh: Mesh, axes: tuple = None
                           ) -> WindTunnelResult:
    """Mesh-partitioned ``run_windtunnel`` with identical semantics.

    .. deprecated:: next release — thin wrapper over
       ``sampling_core.SamplerSession`` (``SamplerSpec(sharded=True,
       mesh=...)``), kept one release for existing callers.  The session
       amortizes the shard_map graph + LP stages across many draws; this
       wrapper re-stages them on every call.

    Sampling + reconstruction run on the replicated outputs (keyed per
    label id -> mesh-shape independent given equal labels), so a 1-device
    mesh is bit-identical to ``run_windtunnel``.
    """
    from repro.core.pipeline import note_deprecated
    from repro.core.sampling_core import SamplerSession, SamplerSpec
    note_deprecated("run_windtunnel_sharded",
                    "SamplerSession with SamplerSpec(sharded=True, mesh=...)")
    session = SamplerSession(
        qrels, num_queries=num_queries, num_entities=num_entities,
        spec=SamplerSpec.from_config(config, strategy="windtunnel",
                                     sharded=True, mesh=mesh, axes=axes))
    return session.result()
