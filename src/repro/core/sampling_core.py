"""Sampling core front door (DESIGN.md §10) — build once, draw many.

:class:`SamplerSession` is the sampling-side twin of the search core's
:class:`~repro.retrieval.search_core.SearchSession`: one session pays the
expensive staged state — affinity-graph construction (Alg. 1) and label
propagation (Alg. 2 steps 1-3) — exactly once, and every subsequent
``draw(target_size, seed)`` runs only the cheap cluster-sampling +
reconstruction tail.  A size/seed :meth:`~SamplerSession.sweep` therefore
costs one LP run instead of |sizes| × |seeds| of them.

Configuration is one declarative :class:`SamplerSpec`:

  * ``strategy``      — a registered sampling strategy (core/samplers.py:
    ``windtunnel`` / ``uniform`` / ``full`` / ``degree_stratified``);
  * ``engine``        — a registered LP engine (core/engines.py);
  * backend knobs     — ``tau_quantile`` / ``fanout`` / ``lp_rounds`` /
    ``max_degree``, exactly the legacy :class:`WindTunnelConfig` fields;
  * ``sharded``/``mesh`` — route the graph + LP stages through the
    mesh-partitioned path (core/sharded_pipeline.py); draws always run on
    the replicated outputs, so a 1-device mesh is bit-identical to the
    single-device session;
  * ``streamed``/``stream_chunk`` — shard the QRel table from birth
    (distributed/sharded_corpus.ShardedQRels): rows are routed host-side
    and streamed straight to their shards, so no device ever holds the
    global table; a :class:`ShardedQRels` may also be passed directly as
    ``qrels`` (both imply ``sharded=True``);
  * ``target_size``/``seed`` — per-draw defaults; ``target_size`` in (0, 1]
    is a fraction of the strategy's eligible universe, > 1 an absolute
    entity count, ``None`` the strategy default (paper |L|/N rule for
    ``windtunnel``).

Stages execute lazily and exactly once per session, with ``executions`` /
``requests`` counters mirroring :meth:`repro.eval.plans.PlanTrie.stage_counts`
so the reuse is observable and testable.  Unknown strategy/engine names fail
fast with the registry's error message (the ``core/engines.py`` UX).

The legacy entry points ``run_windtunnel`` / ``run_windtunnel_sharded`` /
``run_uniform_baseline`` are thin wrappers over a session and remain
bit-compatible; new code should construct the session directly.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Mapping, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import engines as eng
from repro.core import graph_builder as gb
from repro.core import reconstructor as rc
from repro.core import sampler as sm
from repro.core.pipeline import WindTunnelConfig, WindTunnelResult
from repro.core.samplers import DrawState, get_sampler
from repro.core.sharded_pipeline import sharded_graph_and_labels
from repro.distributed.sharded_corpus import ShardedQRels
from repro.obs import REGISTRY, trace
from repro.obs import memory as obs_memory


@dataclasses.dataclass(frozen=True)
class SamplerSpec:
    """Declarative sampling-core configuration (strategy × engine × mesh)."""

    strategy: str = "windtunnel"
    engine: str = "sort"          # any name in engines.available_engines()
    tau_quantile: float = 0.5
    fanout: int = 16
    lp_rounds: int = 5
    max_degree: int = 32
    target_size: Optional[float] = None   # default draw target (None = paper)
    seed: int = 0                         # default draw seed
    sharded: bool = False
    mesh: Any = None                      # jax.sharding.Mesh when sharded
    axes: Any = None                      # mesh axes override (sharded path)
    streamed: bool = False                # route the QRel table shard-local
    stream_chunk: int = 65536             # host->device streaming chunk rows
    strategy_opts: Optional[Mapping[str, Any]] = None

    def to_config(self) -> WindTunnelConfig:
        """The backend-knob subset as the legacy pipeline config."""
        return WindTunnelConfig(
            tau_quantile=self.tau_quantile, fanout=self.fanout,
            lp_rounds=self.lp_rounds, max_degree=self.max_degree,
            target_size=self.target_size, engine=self.engine, seed=self.seed)

    @classmethod
    def from_config(cls, config: WindTunnelConfig, **overrides) -> "SamplerSpec":
        fields = {f.name: getattr(config, f.name)
                  for f in dataclasses.fields(config)}
        fields.update(overrides)
        return cls(**fields)


class SamplerDraw(NamedTuple):
    """One draw: the mask, cluster-sampling diagnostics (windtunnel only),
    and the reconstructed (Queries, Corpus, QRels) sample."""

    entity_mask: jnp.ndarray
    sample: Optional[sm.ClusterSample]
    reconstructed: rc.ReconstructedSample


# ---------------------------------------------------------------------------
# Stage functions: module-level and jitted with static config args, so every
# session (and every legacy-wrapper call) shares one compile cache entry per
# distinct configuration.
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=(
    "num_queries", "num_entities", "tau_quantile", "fanout"))
def _graph_stage(qrels, *, num_queries, num_entities, tau_quantile, fanout):
    edges = gb.build_affinity_graph(qrels, num_queries=num_queries,
                                    tau_quantile=tau_quantile, fanout=fanout)
    return edges, gb.node_degrees(edges, num_entities)


@functools.partial(jax.jit, static_argnames=(
    "engine", "num_entities", "max_degree", "rounds"))
def _labels_stage(edges, *, engine, num_entities, max_degree, rounds):
    src, dst, w, valid = gb.symmetrize(edges)
    res = eng.run_engine(eng.get_engine(engine), src, dst, w, valid,
                         num_nodes=num_entities, max_degree=max_degree,
                         rounds=rounds)
    return res.labels, res.changes_per_round


@functools.partial(jax.jit, static_argnames=(
    "strategy", "opts", "target", "num_queries", "num_entities"))
def _draw_stage(qrels, labels, degrees, seed, *, strategy, opts, target,
                num_queries, num_entities):
    strat = get_sampler(strategy)
    if opts:
        strat = dataclasses.replace(strat, **dict(opts))
    state = DrawState(qrels, num_entities, labels, degrees)
    # per-strategy salt decorrelates same-seed draws across strategies;
    # salt 0 keeps the raw key for legacy bit-parity (see samplers.py)
    key = jax.random.PRNGKey(seed)
    if strat.salt:
        key = jax.random.fold_in(key, strat.salt)
    mask, sample = strat.draw(state, key, target)
    recon = rc.reconstruct(qrels, mask, num_queries=num_queries)
    return SamplerDraw(mask, sample, recon)


@dataclasses.dataclass
class SweepResult:
    """A size × seed sweep: per-draw results plus the stage counters that
    prove graph-build and LP ran once for the whole sweep."""

    strategy: str
    sizes: Tuple[float, ...]
    seeds: Tuple[int, ...]
    draws: Dict[Tuple[float, int], SamplerDraw]
    stage_counts: Dict[str, Tuple[int, int]]

    def to_json(self) -> dict:
        return {
            "strategy": self.strategy,
            "sizes": list(self.sizes),
            "seeds": list(self.seeds),
            "draws": [{"target_size": s, "seed": r,
                       "n_entities": int(d.entity_mask.sum()),
                       "n_queries": int(d.reconstructed.num_queries)}
                      for (s, r), d in sorted(self.draws.items())],
            "stage_counts": {st: {"executions": ex, "requests": rq}
                             for st, (ex, rq) in self.stage_counts.items()},
        }


class SamplerSession:
    """Build-once, draw-many sampling over one QRel table.

    Stages — ``graph`` (Alg. 1 edges + degrees), ``labels`` (Alg. 2 LP),
    ``draw`` (cluster sampling / baseline mask + reconstruction) — execute
    lazily, each at most once per distinct draw key, and only when the
    active strategy declares it needs them (a ``uniform`` session never
    builds the graph).  ``strategy`` can be overridden per draw, so one
    session (one staged graph + LP) serves every registered strategy — the
    eval grid draws ``full`` / ``uniform`` / ``windtunnel`` from a single
    session.
    """

    STAGES = ("graph", "labels", "draw")

    def __init__(self, qrels, *, num_queries: int,
                 num_entities: int, spec: Optional[SamplerSpec] = None,
                 **overrides):
        cfg = spec or SamplerSpec()
        if overrides:
            cfg = dataclasses.replace(cfg, **overrides)
        get_sampler(cfg.strategy)        # registry error UX, fail fast
        eng.get_engine(cfg.engine)       # same UX for the LP engine
        born = qrels if isinstance(qrels, ShardedQRels) else None
        if born is None and cfg.streamed:
            if cfg.mesh is None:
                raise ValueError("streamed sampling needs a mesh; pass "
                                 "SamplerSpec(mesh=...) (launch.mesh "
                                 "helpers)")
            born = ShardedQRels.from_host(
                qrels, num_queries=num_queries, num_entities=num_entities,
                mesh=cfg.mesh, axes=cfg.axes, chunk_rows=cfg.stream_chunk)
        if born is not None:
            # sharded-from-birth tables force the mesh-partitioned stages
            # (the global stages would gather what birth sharding avoids)
            if (born.num_queries, born.num_entities) != (num_queries,
                                                         num_entities):
                raise ValueError(
                    f"ShardedQRels routed for {born.num_queries} queries / "
                    f"{born.num_entities} entities; session asked for "
                    f"{num_queries} / {num_entities}")
            cfg = dataclasses.replace(cfg, sharded=True, streamed=True,
                                      mesh=born.mesh, axes=born.axes)
        if cfg.sharded:
            if cfg.mesh is None:
                raise ValueError("sharded sampling needs a mesh; pass "
                                 "SamplerSpec(mesh=...) (launch.mesh helpers)")
            if cfg.engine not in ("ell", "pallas"):
                raise ValueError(
                    f"sharded pipeline requires an ELL-family engine ('ell' "
                    f"or 'pallas'); got {cfg.engine!r} — the sort engine's "
                    f"global per-round shuffle is exactly what this path "
                    f"eliminates")
        self.spec = cfg
        self._born = born
        # draws run on the (routed) flat table — reconstruction and every
        # registered strategy are row-order-free, so the born permutation
        # is invisible downstream
        self.qrels = born.table() if born is not None else qrels
        self.num_queries = num_queries
        self.num_entities = num_entities
        self._graph = None      # (edges, degrees)
        self._labels = None     # (labels, changes_per_round)
        self._draws: Dict[tuple, SamplerDraw] = {}
        self._counts = {stage: [0, 0] for stage in self.STAGES}

    # -- staged state -------------------------------------------------------

    def _stage_sharded(self) -> None:
        """One shard_map region computes graph AND labels (they share the
        partitioned dataflow); both stage slots fill from it.  The fused
        region is traced as ``sampling.graph`` (where the wall time lives)
        plus a zero-cost ``sampling.labels`` marker with ``fused=True``,
        so per-stage aggregates list both stages on either path."""
        with trace.jax_span("sampling.graph", sharded=True,
                            streamed=self._born is not None,
                            engine=self.spec.engine, n=self.num_entities,
                            q=self.num_queries, fused_labels=True) as sp:
            edges, labels, changes = sharded_graph_and_labels(
                self._born if self._born is not None else self.qrels,
                num_queries=self.num_queries,
                num_entities=self.num_entities, config=self.spec.to_config(),
                mesh=self.spec.mesh, axes=self.spec.axes)
            self._graph = (edges, gb.node_degrees(edges, self.num_entities))
            self._labels = (labels, changes)
            sp.declare(self._graph, self._labels)
        obs_memory.record_build_peak()
        with trace.span("sampling.labels", sharded=True, fused=True,
                        engine=self.spec.engine):
            pass
        self._counts["graph"][0] += 1
        self._counts["labels"][0] += 1

    def graph(self) -> tuple:
        """(EdgeList, degrees i32[N]) — Alg. 1, executed once per session."""
        self._counts["graph"][1] += 1
        if self._graph is None:
            if self.spec.sharded:
                self._stage_sharded()
            else:
                with trace.jax_span("sampling.graph",
                                    n=self.num_entities,
                                    q=self.num_queries,
                                    tau=self.spec.tau_quantile,
                                    fanout=self.spec.fanout) as sp:
                    self._graph = _graph_stage(
                        self.qrels, num_queries=self.num_queries,
                        num_entities=self.num_entities,
                        tau_quantile=self.spec.tau_quantile,
                        fanout=self.spec.fanout)
                    sp.declare(self._graph)
                self._counts["graph"][0] += 1
        return self._graph

    def labels(self) -> tuple:
        """(labels i32[N], changes i32[rounds]) — Alg. 2 LP, executed once."""
        self._counts["labels"][1] += 1
        if self._labels is None:
            if self.spec.sharded:
                self._stage_sharded()
            else:
                edges, _ = self.graph()
                with trace.jax_span("sampling.labels",
                                    engine=self.spec.engine,
                                    n=self.num_entities,
                                    rounds=self.spec.lp_rounds,
                                    max_degree=self.spec.max_degree) as sp:
                    self._labels = _labels_stage(
                        edges, engine=self.spec.engine,
                        num_entities=self.num_entities,
                        max_degree=self.spec.max_degree,
                        rounds=self.spec.lp_rounds)
                    sp.declare(self._labels)
                self._counts["labels"][0] += 1
        return self._labels

    # -- draws --------------------------------------------------------------

    def _strategy(self, name: Optional[str]):
        strat = get_sampler(name or self.spec.strategy)
        opts = ()
        if self.spec.strategy_opts and strat.name == self.spec.strategy:
            opts = tuple(sorted(dict(self.spec.strategy_opts).items()))
            strat = dataclasses.replace(strat, **dict(opts))
        return strat, opts

    def draw(self, target_size: Optional[float] = None,
             seed: Optional[int] = None,
             strategy: Optional[str] = None) -> SamplerDraw:
        """One sample at (target_size, seed); cached per distinct draw key.

        ``target_size`` / ``seed`` default to the spec's; ``strategy``
        overrides the spec's strategy for this draw only (reusing the
        session's staged graph/labels).
        """
        strat, opts = self._strategy(strategy)
        target = self.spec.target_size if target_size is None else target_size
        target = None if target is None else float(target)
        seed = self.spec.seed if seed is None else int(seed)
        key = (strat.name, opts, target, seed)
        self._counts["draw"][1] += 1
        hit = key in self._draws
        REGISTRY.counter(
            "sampling.draw.hit" if hit else "sampling.draw.miss").inc()
        if not hit:
            labels = self.labels()[0] if strat.needs_labels else None
            degrees = self.graph()[1] if strat.needs_graph else None
            with trace.jax_span("sampling.draw",
                                compile_key=f"sampling.draw/{strat.name}",
                                strategy=strat.name, target=target,
                                seed=seed, cache="miss") as sp:
                self._draws[key] = _draw_stage(
                    self.qrels, labels, degrees, seed, strategy=strat.name,
                    opts=opts, target=target, num_queries=self.num_queries,
                    num_entities=self.num_entities)
                sp.declare(self._draws[key])
            self._counts["draw"][0] += 1
        return self._draws[key]

    def result(self, target_size: Optional[float] = None,
               seed: Optional[int] = None) -> WindTunnelResult:
        """Full legacy :class:`WindTunnelResult` (edges, labels, changes,
        sample, reconstruction, degrees) for cluster-sampling strategies —
        what the ``run_windtunnel*`` wrappers return."""
        draw = self.draw(target_size, seed)
        if draw.sample is None:
            raise ValueError(
                f"strategy {self.spec.strategy!r} has no cluster-sample "
                f"diagnostics; use draw() for baseline strategies")
        edges, degrees = self.graph()
        labels, changes = self.labels()
        return WindTunnelResult(edges, labels, changes, draw.sample,
                                draw.reconstructed, degrees)

    def sweep(self, sizes, seeds, *,
              strategy: Optional[str] = None) -> SweepResult:
        """Draw every (target_size, seed) cell; graph + LP run at most once
        for the whole sweep (asserted via the result's ``stage_counts``,
        which record only THIS sweep's executions/requests — a delta over
        the session counters, so repeated sweeps don't inflate the record)."""
        sizes = tuple(float(s) for s in sizes)
        seeds = tuple(int(r) for r in seeds)
        strat, _ = self._strategy(strategy)
        before = self.stage_counts()
        draws = {(s, r): self.draw(target_size=s, seed=r, strategy=strategy)
                 for s in sizes for r in seeds}
        after = self.stage_counts()
        delta = {st: (after[st][0] - before[st][0],
                      after[st][1] - before[st][1]) for st in after}
        return SweepResult(strat.name, sizes, seeds, draws, delta)

    # -- observability ------------------------------------------------------

    def stage_counts(self) -> Dict[str, Tuple[int, int]]:
        """stage -> (executions, requests), mirroring PlanTrie.stage_counts."""
        return {stage: tuple(c) for stage, c in self._counts.items()}

    def summary(self) -> str:
        lines = ["stage      executed  requested  shared"]
        for stage in self.STAGES:
            ex, rq = self._counts[stage]
            lines.append(f"{stage:<10s} {ex:8d} {rq:10d} {rq - ex:7d}")
        return "\n".join(lines)
