"""GraphSampler step 4 — cluster sampling of communities (Algorithm 2).

Paper semantics: after label propagation, 'Emit L with probability |L|/N'
where |L| is the community size and N the total entity count. A kept label
brings ALL of its entities into the sample (cluster sampling), so community
neighbourhoods survive intact — the whole point of WindTunnel.

Beyond-paper addition (flagged in DESIGN.md §6): ``target_size`` calibration.
The paper's Table I uses a '100K passages' sample but |L|/N gives no direct
size control (E[size] = sum |L|^2 / N). We keep the paper rule as default and
optionally scale the keep-probabilities p_L = min(1, c*|L|/N), solving for c
by bisection so E[size] hits the target. c = 1 recovers the paper exactly.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax


class ClusterSample(NamedTuple):
    entity_mask: jnp.ndarray    # bool[num_nodes] kept entities
    label_kept: jnp.ndarray     # bool[num_nodes] per-label keep decision
    community_sizes: jnp.ndarray  # i32[num_nodes] |L| per label id
    keep_prob: jnp.ndarray      # f32[num_nodes] p_L actually used


def community_sizes(labels: jnp.ndarray, num_nodes: int) -> jnp.ndarray:
    return jax.ops.segment_sum(
        jnp.ones_like(labels), labels, num_segments=num_nodes)


def _calibrate_scale(sizes: jnp.ndarray, n_total: jnp.ndarray,
                     target, iters: int = 40) -> jnp.ndarray:
    """Bisection for c with sum_L min(1, c*|L|/N) * |L| == target.

    ``target`` may be a Python float or a traced f32 scalar (the sampling
    core passes fraction-of-universe targets as traced values)."""
    sizes_f = sizes.astype(jnp.float32)

    def expected(c):
        p = jnp.minimum(1.0, c * sizes_f / n_total)
        return jnp.sum(p * sizes_f)

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        too_small = expected(mid) < target
        return jnp.where(too_small, mid, lo), jnp.where(too_small, hi, mid)

    lo, hi = lax.fori_loop(0, iters, body,
                           (jnp.float32(0.0), jnp.float32(n_total)))
    return 0.5 * (lo + hi)


def cluster_sample(labels: jnp.ndarray, key: jax.Array, *,
                   num_nodes: int,
                   target_size: Optional[float] = None,
                   eligible: Optional[jnp.ndarray] = None) -> ClusterSample:
    """Sample communities. ``labels`` from label_prop.propagate.

    Every node whose label is kept is kept. The Bernoulli draw is keyed per
    label id, so the decision for a community is a pure function of
    (key, label) — reproducible regardless of sharding, which is what lets
    the mesh-partitioned pipeline (sharded_pipeline.py, DESIGN.md §5)
    reproduce the single-device mask bit-exactly.

    ``eligible`` restricts the sampling universe to nodes that appear in
    the affinity graph (Alg. 2's input is the GraphBuilder's edge tuples, so
    degree-0 auxiliary entities never enter the GraphSampler).
    """
    if eligible is None:
        eligible = jnp.ones_like(labels, bool)
    lab_e = jnp.where(eligible, labels, num_nodes)
    sizes = jax.ops.segment_sum(jnp.ones_like(labels), lab_e,
                                num_segments=num_nodes + 1)[:num_nodes]
    n_total = jnp.maximum(jnp.sum(eligible.astype(jnp.float32)), 1.0)
    p = sizes.astype(jnp.float32) / n_total          # the paper's |L|/N
    if target_size is not None:
        c = _calibrate_scale(sizes, n_total, target_size)
        p = jnp.minimum(1.0, c * p)
    unif = jax.random.uniform(key, (num_nodes,))
    label_kept = (unif < p) & (sizes > 0)
    entity_mask = label_kept[labels] & eligible
    return ClusterSample(entity_mask, label_kept, sizes, p)


def uniform_sample(num_nodes: int, key: jax.Array, *, rate: float) -> jnp.ndarray:
    """The paper's baseline: uniform random entity sampling (Section I-A),
    which destroys community structure and inflates precision."""
    return jax.random.uniform(key, (num_nodes,)) < rate
