"""Yule-Simon EM fit (paper §III-A, following Roberts & Roberts [10]).

The paper's community-structure evidence: MSMarco passage node degrees follow
a Yule-Simon discrete power law, p(k; rho) = rho * B(k, rho + 1), k >= 1,
with tail exponent gamma = rho + 1 (they fit gamma = 2.94 ~ 3).

EM derivation (latent-exponential representation):
  w_i ~ Exp(rho),  k_i | w_i ~ Geometric(exp(-w_i))
  marginal of k_i is exactly Yule-Simon(rho).
  E-step: w_i | k_i, rho  has  E[w_i] = psi(rho + 1 + k_i) - psi(rho + 1)
          (posterior of exp(-w) is Beta(rho + 1, k_i)).
  M-step: rho <- n / sum_i E[w_i].

Standard error from observed Fisher information of the marginal likelihood:
  l(rho)  = n log rho + sum_i [log B(rho + 1, k_i)]
  I(rho)  = n / rho^2 - sum_i [psi'(rho + 1) - psi'(rho + 1 + k_i)]
  se(rho_hat) = I(rho_hat)^{-1/2};  se(gamma_hat) = se(rho_hat).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.scipy.special import digamma, polygamma


class YuleSimonFit(NamedTuple):
    rho: jnp.ndarray
    gamma: jnp.ndarray      # power-law exponent rho + 1
    stderr: jnp.ndarray
    log_lik: jnp.ndarray
    iters: jnp.ndarray


def log_pmf(k: jnp.ndarray, rho: jnp.ndarray) -> jnp.ndarray:
    """log p(k; rho) = log rho + log B(k, rho + 1)."""
    k = k.astype(jnp.float32)
    return (jnp.log(rho) + jax.scipy.special.gammaln(k)
            + jax.scipy.special.gammaln(rho + 1.0)
            - jax.scipy.special.gammaln(k + rho + 1.0))


def fit_em(degrees: jnp.ndarray, weights: jnp.ndarray | None = None, *,
           rho0: float = 1.0, max_iters: int = 200,
           tol: float = 1e-7) -> YuleSimonFit:
    """EM fit of rho on observed degrees k_i >= 1.

    ``weights`` allows a histogram representation: fit over values
    ``degrees`` with multiplicities ``weights`` (masked entries weight 0).
    """
    k = degrees.astype(jnp.float32)
    wt = jnp.ones_like(k) if weights is None else weights.astype(jnp.float32)
    wt = jnp.where(k >= 1.0, wt, 0.0)
    k = jnp.maximum(k, 1.0)
    n = jnp.sum(wt)

    def em_step(state):
        rho, _, it = state
        e_w = digamma(rho + 1.0 + k) - digamma(rho + 1.0)
        new_rho = n / jnp.sum(wt * e_w)
        return new_rho, jnp.abs(new_rho - rho), it + 1

    def cond(state):
        _, delta, it = state
        return (delta > tol) & (it < max_iters)

    rho, _, iters = lax.while_loop(
        cond, em_step, (jnp.float32(rho0), jnp.float32(jnp.inf), jnp.int32(0)))

    fisher = (n / (rho ** 2)
              - jnp.sum(wt * (polygamma(1, rho + 1.0)
                              - polygamma(1, rho + 1.0 + k))))
    stderr = jnp.where(fisher > 0, 1.0 / jnp.sqrt(fisher), jnp.nan)
    ll = jnp.sum(wt * log_pmf(k, rho))
    return YuleSimonFit(rho, rho + 1.0, stderr, ll, iters)


def degree_histogram(degrees: jnp.ndarray, max_degree: int) -> jnp.ndarray:
    """Histogram of node degrees (Fig. 4 left). Degree-0 nodes excluded —
    the paper's graph only contains passages that share a query."""
    d = jnp.clip(degrees, 0, max_degree)
    hist = jnp.zeros((max_degree + 1,), jnp.int32).at[d].add(1)
    return hist.at[0].set(0)


def theoretical_pmf(ks: jnp.ndarray, rho: jnp.ndarray) -> jnp.ndarray:
    """Yule-Simon pmf for the Fig. 4 right overlay."""
    return jnp.exp(log_pmf(ks, rho))
