"""CorpusReconstructor — joins the sampled entity set back to the relational
inputs, emitting (Queries, Corpus, QRels) with the SAME SCHEMA as the input
(paper §II 'Output'). Pure mask algebra; jit-able.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph_builder import QRelTable


class ReconstructedSample(NamedTuple):
    qrels: QRelTable          # original rows, valid-mask restricted
    entity_mask: jnp.ndarray  # bool[num_entities]
    query_mask: jnp.ndarray   # bool[num_queries] queries with >=1 kept entity

    @property
    def num_entities(self):
        return jnp.sum(self.entity_mask.astype(jnp.int32))

    @property
    def num_queries(self):
        return jnp.sum(self.query_mask.astype(jnp.int32))


def reconstruct(qrels: QRelTable, entity_mask: jnp.ndarray, *,
                num_queries: int) -> ReconstructedSample:
    """Keep QRel rows whose entity survived; keep queries with >=1 kept row."""
    keep_row = qrels.valid & entity_mask[jnp.clip(qrels.entity_ids, 0)]
    qm = jnp.zeros((num_queries,), jnp.int32).at[
        jnp.where(keep_row, qrels.query_ids, num_queries)
    ].add(1, mode="drop")
    query_mask = qm > 0
    sub = QRelTable(qrels.query_ids, qrels.entity_ids, qrels.scores, keep_row)
    return ReconstructedSample(sub, entity_mask, query_mask)


def associated_queries(qrels: QRelTable, entity_mask, *, num_queries: int,
                       max_queries: Optional[int] = None, seed: int = 0):
    """Host-side mirror of :func:`reconstruct`'s query-association rule.

    Returns ``(assoc bool[num_queries], qids i32[<=max_queries])``: queries
    with >=1 relevant kept entity, plus a deterministic subsample of their
    ids capped at ``max_queries`` (the eval grid's per-sample query budget).
    ``assoc`` agrees bit-for-bit with ``reconstruct(...).query_mask``
    (tests/test_sampling_core.py cross-checks the two), so eval-side query
    selection and the reconstructor can never drift apart.
    """
    q = np.asarray(qrels.query_ids)
    e = np.asarray(qrels.entity_ids)
    v = np.asarray(qrels.valid)
    mask = np.asarray(entity_mask)
    num_entities = mask.shape[0]
    assoc = np.zeros(num_queries, bool)
    rows = v & mask[np.clip(e, 0, num_entities - 1)]
    assoc[q[rows]] = True
    qids = np.nonzero(assoc)[0]
    if max_queries is not None and qids.size > max_queries:
        rng = np.random.default_rng(seed)
        qids = np.sort(rng.choice(qids, max_queries, replace=False))
    return assoc, qids


def query_density(qrels: QRelTable, entity_mask: jnp.ndarray,
                  query_mask: jnp.ndarray, *, num_queries: int,
                  num_entities: int) -> jnp.ndarray:
    """rho_q of Table II: mean over sampled queries of the fraction of the
    sampled corpus that is relevant to the query — 'the same passages are
    relevant to multiple queries' compacts communities and raises rho_q.

    rho_q = mean_q |relevant(q) ∩ sample| / |relevant(q) in full corpus|
    measured over kept queries; this matches the paper's reading that a
    higher percentage of passages in the dataset are returned per query.
    """
    keep_row = qrels.valid & entity_mask[jnp.clip(qrels.entity_ids, 0)]
    rel_kept = jnp.zeros((num_queries,), jnp.float32).at[
        jnp.where(keep_row, qrels.query_ids, num_queries)
    ].add(1.0, mode="drop")
    rel_all = jnp.zeros((num_queries,), jnp.float32).at[
        jnp.where(qrels.valid, qrels.query_ids, num_queries)
    ].add(1.0, mode="drop")
    frac = jnp.where(rel_all > 0, rel_kept / jnp.maximum(rel_all, 1.0), 0.0)
    qn = jnp.sum(query_mask.astype(jnp.float32))
    return jnp.sum(jnp.where(query_mask, frac, 0.0)) / jnp.maximum(qn, 1.0)
