"""WindTunnel core — the paper's contribution as a composable JAX module.

GraphBuilder (Alg. 1) -> GraphSampler (Alg. 2, weighted label propagation +
cluster sampling) -> CorpusReconstructor, plus the Yule-Simon community-
structure analysis of §III-A. See DESIGN.md for the MapReduce->JAX mapping,
the label-prop engine registry (§4), the sharded dataflow (§5) and the
sampling-core session / strategy registry (§10).
"""
from repro.core.engines import (LPEngine, available_engines, get_engine,
                                register, run_engine)
from repro.core.graph_builder import (EdgeList, QRelTable,
                                      build_affinity_graph, node_degrees,
                                      symmetrize)
from repro.core.label_prop import (ell_round, propagate, propagate_ell,
                                   edges_to_ell, sort_round)
from repro.core.pipeline import (WindTunnelConfig, WindTunnelResult,
                                 run_uniform_baseline, run_windtunnel)
from repro.core.reconstructor import (associated_queries, query_density,
                                      reconstruct)
from repro.core.sampler import cluster_sample, uniform_sample
from repro.core.samplers import (SamplerStrategy, available_samplers,
                                 get_sampler, register_sampler)
from repro.core.sampling_core import (SamplerDraw, SamplerSession,
                                      SamplerSpec, SweepResult)
from repro.core.sharded_pipeline import (run_windtunnel_sharded,
                                         sharded_graph_and_labels)
from repro.core.yule_simon import YuleSimonFit, fit_em

__all__ = [
    "EdgeList", "QRelTable", "build_affinity_graph", "node_degrees",
    "symmetrize", "propagate", "propagate_ell", "edges_to_ell",
    "sort_round", "ell_round",
    "LPEngine", "available_engines", "get_engine", "register", "run_engine",
    "SamplerStrategy", "available_samplers", "get_sampler",
    "register_sampler",
    "SamplerSpec", "SamplerSession", "SamplerDraw", "SweepResult",
    "WindTunnelConfig", "WindTunnelResult", "run_windtunnel",
    "run_windtunnel_sharded", "sharded_graph_and_labels",
    "run_uniform_baseline", "associated_queries", "query_density",
    "reconstruct", "cluster_sample", "uniform_sample", "YuleSimonFit",
    "fit_em",
]
