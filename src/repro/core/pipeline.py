"""WindTunnel pipeline orchestration: GraphBuilder -> GraphSampler ->
CorpusReconstructor (paper Fig. 3), as one jit-able program.

Two GraphSampler execution paths with identical semantics:
  * ``engine='sort'`` — sort/segment label propagation (reference, unbounded
    degree; the direct MapReduce port).
  * ``engine='ell'``  — degree-capped dense ELL label propagation; this is
    the layout the Pallas TPU kernel consumes (kernels/label_prop) and the
    path the perf work optimizes.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import graph_builder as gb
from repro.core import label_prop as lp
from repro.core import reconstructor as rc
from repro.core import sampler as sm


@dataclasses.dataclass(frozen=True)
class WindTunnelConfig:
    """Configuration of the full sampling pipeline."""
    tau_quantile: float = 0.5     # paper: 'scores in the top 50%'
    fanout: int = 16              # per-query entity cap in Alg. 1 (ELL width)
    lp_rounds: int = 5            # fixed LP round count (Alg. 2 termination)
    max_degree: int = 32          # ELL engine: per-node neighbour cap
    target_size: Optional[float] = None  # None -> paper's exact |L|/N rule
    engine: str = "sort"          # 'sort' | 'ell'
    seed: int = 0


class WindTunnelResult(NamedTuple):
    edges: gb.EdgeList
    labels: jnp.ndarray
    changes_per_round: jnp.ndarray
    sample: sm.ClusterSample
    reconstructed: rc.ReconstructedSample
    degrees: jnp.ndarray


def run_windtunnel(qrels: gb.QRelTable, *, num_queries: int,
                   num_entities: int, config: WindTunnelConfig
                   ) -> WindTunnelResult:
    # --- GraphBuilder (Alg. 1) ---
    edges = gb.build_affinity_graph(
        qrels, num_queries=num_queries,
        tau_quantile=config.tau_quantile, fanout=config.fanout)
    degrees = gb.node_degrees(edges, num_entities)

    # --- GraphSampler steps 1-3 (Alg. 2): label propagation ---
    src, dst, w, valid = gb.symmetrize(edges)
    if config.engine == "ell":
        nbr, wgt = lp.edges_to_ell(src, dst, w, valid,
                                   num_nodes=num_entities,
                                   max_degree=config.max_degree)
        lp_res = lp.propagate_ell(nbr, wgt, rounds=config.lp_rounds)
    else:
        lp_res = lp.propagate(src, dst, w, valid,
                              num_nodes=num_entities,
                              rounds=config.lp_rounds)

    # --- GraphSampler step 4: cluster sampling (universe = graph nodes) ---
    key = jax.random.PRNGKey(config.seed)
    sample = sm.cluster_sample(lp_res.labels, key,
                               num_nodes=num_entities,
                               target_size=config.target_size,
                               eligible=degrees > 0)

    # --- CorpusReconstructor ---
    recon = rc.reconstruct(qrels, sample.entity_mask, num_queries=num_queries)
    return WindTunnelResult(edges, lp_res.labels, lp_res.changes_per_round,
                            sample, recon, degrees)


def run_uniform_baseline(qrels: gb.QRelTable, *, num_queries: int,
                         num_entities: int, rate: float, seed: int = 0
                         ) -> rc.ReconstructedSample:
    """The uniform-random baseline the paper compares against."""
    mask = sm.uniform_sample(num_entities, jax.random.PRNGKey(seed), rate=rate)
    return rc.reconstruct(qrels, mask, num_queries=num_queries)
