"""WindTunnel pipeline orchestration: GraphBuilder -> GraphSampler ->
CorpusReconstructor (paper Fig. 3), as one jit-able program.

The GraphSampler execution strategy is resolved through the engine registry
(engines.py, DESIGN.md §4): ``WindTunnelConfig.engine`` names any registered
``LPEngine`` — ``sort`` (sort/segment reference, unbounded degree), ``ell``
(degree-capped dense ELL) or ``pallas`` (ELL layout with the per-round body
in the Pallas TPU kernel, interpret mode off-TPU).  All engines share the
same prepare → scan(round) → finalize driver, so the whole pipeline stays
one XLA computation regardless of strategy.

For the multi-device path see sharded_pipeline.run_windtunnel_sharded
(DESIGN.md §5), which partitions this same dataflow across a mesh.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import engines as eng
from repro.core import graph_builder as gb
from repro.core import reconstructor as rc
from repro.core import sampler as sm


@dataclasses.dataclass(frozen=True)
class WindTunnelConfig:
    """Configuration of the full sampling pipeline."""
    tau_quantile: float = 0.5     # paper: 'scores in the top 50%'
    fanout: int = 16              # per-query entity cap in Alg. 1 (ELL width)
    lp_rounds: int = 5            # fixed LP round count (Alg. 2 termination)
    max_degree: int = 32          # ELL engine: per-node neighbour cap
    target_size: Optional[float] = None  # None -> paper's exact |L|/N rule
    engine: str = "sort"          # any name in engines.available_engines()
    seed: int = 0


class WindTunnelResult(NamedTuple):
    edges: gb.EdgeList
    labels: jnp.ndarray
    changes_per_round: jnp.ndarray
    sample: sm.ClusterSample
    reconstructed: rc.ReconstructedSample
    degrees: jnp.ndarray


def run_windtunnel(qrels: gb.QRelTable, *, num_queries: int,
                   num_entities: int, config: WindTunnelConfig
                   ) -> WindTunnelResult:
    # --- GraphBuilder (Alg. 1) ---
    edges = gb.build_affinity_graph(
        qrels, num_queries=num_queries,
        tau_quantile=config.tau_quantile, fanout=config.fanout)
    degrees = gb.node_degrees(edges, num_entities)

    # --- GraphSampler steps 1-3 (Alg. 2): label propagation ---
    src, dst, w, valid = gb.symmetrize(edges)
    engine = eng.get_engine(config.engine)
    lp_res = eng.run_engine(engine, src, dst, w, valid,
                            num_nodes=num_entities,
                            max_degree=config.max_degree,
                            rounds=config.lp_rounds)

    # --- GraphSampler step 4: cluster sampling (universe = graph nodes) ---
    key = jax.random.PRNGKey(config.seed)
    sample = sm.cluster_sample(lp_res.labels, key,
                               num_nodes=num_entities,
                               target_size=config.target_size,
                               eligible=degrees > 0)

    # --- CorpusReconstructor ---
    recon = rc.reconstruct(qrels, sample.entity_mask, num_queries=num_queries)
    return WindTunnelResult(edges, lp_res.labels, lp_res.changes_per_round,
                            sample, recon, degrees)


def run_uniform_baseline(qrels: gb.QRelTable, *, num_queries: int,
                         num_entities: int, rate: float, seed: int = 0
                         ) -> rc.ReconstructedSample:
    """The uniform-random baseline the paper compares against."""
    mask = sm.uniform_sample(num_entities, jax.random.PRNGKey(seed), rate=rate)
    return rc.reconstruct(qrels, mask, num_queries=num_queries)
