"""WindTunnel pipeline orchestration: GraphBuilder -> GraphSampler ->
CorpusReconstructor (paper Fig. 3).

The implementation lives in the sampling core (sampling_core.py, DESIGN.md
§10): a ``SamplerSession`` stages graph build -> label propagation once and
draws many samples against the cached labels.  ``run_windtunnel`` and
``run_uniform_baseline`` below are the legacy one-shot entry points, kept
as thin bit-compatible wrappers over a fresh session (one release of
deprecation; see their docstrings).

The GraphSampler execution strategy is resolved through the engine registry
(engines.py, DESIGN.md §4): ``WindTunnelConfig.engine`` names any registered
``LPEngine`` — ``sort`` (sort/segment reference, unbounded degree), ``ell``
(degree-capped dense ELL) or ``pallas`` (ELL layout with the per-round body
in the Pallas TPU kernel, interpret mode off-TPU).

For the multi-device path see sharded_pipeline (DESIGN.md §5) or
``SamplerSpec(sharded=True, mesh=...)``, which partition the same dataflow
across a mesh.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import NamedTuple, Optional

import jax.numpy as jnp

from repro.core import graph_builder as gb
from repro.core import reconstructor as rc
from repro.core import sampler as sm

log = logging.getLogger("repro.core.pipeline")
_DEPRECATION_NOTED: set = set()


def note_deprecated(name: str, replacement: str) -> None:
    """Log a one-per-process deprecation note for a legacy entry point
    through the ``repro.*`` logger hierarchy (shared with
    ``sharded_pipeline``)."""
    if name not in _DEPRECATION_NOTED:
        _DEPRECATION_NOTED.add(name)
        log.warning("%s is deprecated (one release); use %s",
                    name, replacement)


@dataclasses.dataclass(frozen=True)
class WindTunnelConfig:
    """Configuration of the full sampling pipeline."""
    tau_quantile: float = 0.5     # paper: 'scores in the top 50%'
    fanout: int = 16              # per-query entity cap in Alg. 1 (ELL width)
    lp_rounds: int = 5            # fixed LP round count (Alg. 2 termination)
    max_degree: int = 32          # ELL engine: per-node neighbour cap
    target_size: Optional[float] = None  # None -> paper's exact |L|/N rule
    engine: str = "sort"          # any name in engines.available_engines()
    seed: int = 0


class WindTunnelResult(NamedTuple):
    edges: gb.EdgeList
    labels: jnp.ndarray
    changes_per_round: jnp.ndarray
    sample: sm.ClusterSample
    reconstructed: rc.ReconstructedSample
    degrees: jnp.ndarray


def run_windtunnel(qrels: gb.QRelTable, *, num_queries: int,
                   num_entities: int, config: WindTunnelConfig
                   ) -> WindTunnelResult:
    """One-shot GraphBuilder -> GraphSampler -> CorpusReconstructor run.

    .. deprecated:: next release — thin wrapper over
       ``sampling_core.SamplerSession``, kept one release for existing
       callers.  The session amortizes graph build + label propagation
       across many ``draw(target_size, seed)`` calls; this wrapper re-pays
       them on every call.  Bit-compatible with the historical inline
       pipeline (tests/test_sampling_core.py enforces parity).
    """
    from repro.core.sampling_core import SamplerSession, SamplerSpec
    note_deprecated("run_windtunnel",
                    "sampling_core.SamplerSession (build once, draw many)")
    session = SamplerSession(
        qrels, num_queries=num_queries, num_entities=num_entities,
        spec=SamplerSpec.from_config(config, strategy="windtunnel"))
    return session.result()


def run_uniform_baseline(qrels: gb.QRelTable, *, num_queries: int,
                         num_entities: int, rate: float, seed: int = 0
                         ) -> rc.ReconstructedSample:
    """The uniform-random baseline the paper compares against.

    .. deprecated:: next release — thin wrapper over
       ``sampling_core.SamplerSession`` with the registered ``uniform``
       strategy (``universe="all"`` reproduces the legacy whole-corpus
       Bernoulli draw bit-exactly), kept one release for existing callers.
    """
    from repro.core.sampling_core import SamplerSession, SamplerSpec
    note_deprecated("run_uniform_baseline",
                    "SamplerSession with the 'uniform' strategy")
    session = SamplerSession(
        qrels, num_queries=num_queries, num_entities=num_entities,
        spec=SamplerSpec(strategy="uniform", seed=seed,
                         strategy_opts={"universe": "all", "salt": 0}))
    return session.draw(target_size=rate).reconstructed
