"""Sort/segment primitives shared by the WindTunnel core.

All WindTunnel MapReduce stages (Alg. 1 & 2 of the paper) are expressed as
sort-by-key + reduce-over-runs. On TPU, ``jax.lax.sort`` lowers to a bitonic
sort network and ``segment_*`` to scatter-adds, which is the idiomatic XLA
replacement for a MapReduce shuffle (see DESIGN.md §2).

Static-shape convention: every "table" is a fixed-length array bundle with a
``valid`` mask. Masked rows carry sentinel keys that sort to the end and are
dropped on scatter (``mode='drop'``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

I32_MAX = jnp.iinfo(jnp.int32).max


def sort_by(keys: tuple, payloads: tuple = ()):
    """Lexicographic ascending sort by ``keys``, carrying ``payloads``.

    Returns (sorted_keys, sorted_payloads).
    """
    operands = tuple(keys) + tuple(payloads)
    out = lax.sort(operands, num_keys=len(keys), is_stable=True)
    return out[: len(keys)], out[len(keys):]


def run_starts(*keys) -> jnp.ndarray:
    """Boolean mask marking the first element of each run of equal keys.

    ``keys`` must already be sorted (lexicographically).
    """
    n = keys[0].shape[0]
    changed = jnp.zeros((n - 1,), dtype=bool)
    for k in keys:
        changed = changed | (k[1:] != k[:-1])
    return jnp.concatenate([jnp.ones((1,), dtype=bool), changed])


def run_segment_ids(starts: jnp.ndarray) -> jnp.ndarray:
    """Map each position to the index of the run it belongs to."""
    return jnp.cumsum(starts.astype(jnp.int32)) - 1


def group_rank(starts: jnp.ndarray) -> jnp.ndarray:
    """Rank of each element within its run (0-based). ``starts`` from run_starts."""
    n = starts.shape[0]
    iota = jnp.arange(n, dtype=jnp.int32)
    group_start = lax.associative_scan(jnp.maximum, jnp.where(starts, iota, 0))
    return iota - group_start


def masked_min(values: jnp.ndarray, mask: jnp.ndarray, axis=None):
    big = jnp.asarray(jnp.inf if jnp.issubdtype(values.dtype, jnp.floating) else I32_MAX,
                      dtype=values.dtype)
    return jnp.min(jnp.where(mask, values, big), axis=axis)


def segment_sum(data, segment_ids, num_segments):
    return jax.ops.segment_sum(data, segment_ids, num_segments=num_segments)


def segment_max(data, segment_ids, num_segments):
    return jax.ops.segment_max(data, segment_ids, num_segments=num_segments)


def segment_min(data, segment_ids, num_segments):
    return jax.ops.segment_min(data, segment_ids, num_segments=num_segments)


def reduce_by_key_sum(keys: tuple, values: jnp.ndarray, valid: jnp.ndarray):
    """Sum ``values`` over equal-``keys`` groups.

    Returns per-position arrays aligned with the *sorted* order:
      sorted_keys, run_start mask, per-run sum broadcast back to positions,
      segment ids. Masked rows get sentinel keys and zero value.
    """
    skeys = tuple(jnp.where(valid, k, I32_MAX) for k in keys)
    svals = jnp.where(valid, values, jnp.zeros((), values.dtype))
    (sk, sv) = sort_by(skeys, (svals, valid.astype(jnp.int32)))
    sorted_vals, sorted_valid = sv
    starts = run_starts(*sk)
    seg = run_segment_ids(starts)
    sums = segment_sum(sorted_vals, seg, num_segments=values.shape[0])
    return sk, starts, sums[seg], seg, sorted_valid.astype(bool)
