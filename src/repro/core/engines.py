"""Label-propagation engine registry (DESIGN.md §4).

The GraphSampler's hot loop (Alg. 2 steps 1-3) admits several execution
strategies with identical semantics but very different cost models.  Rather
than string-compare an engine name inline in ``pipeline.py``, each strategy
is a first-class registered object — the PyTerrier/Trove pluggable-component
pattern — that the pipeline, the benchmark harness and the experiment
scripts all select uniformly through :func:`get_engine`.

An engine implements the :class:`LPEngine` protocol:

  * ``prepare(src, dst, w, valid, *, num_nodes, max_degree)`` — one-time
    layout transform of the symmetrized edge list into whatever adjacency
    representation the engine's round consumes (edge list, ELL table, ...).
  * ``round(labels, state)`` — one weighted-LP round; pure and jit-able so
    the multi-round loop stays a single ``lax.scan`` inside one XLA program.
  * ``finalize(labels, changes)`` — package the scan result.

Registered engines:

  * ``sort``   — sort/segment reduce-by-key rounds over the raw edge list
                 (the direct MapReduce port; unbounded degree).
  * ``ell``    — dense degree-capped ELL rounds (O(N·K²) VPU work).
  * ``pallas`` — same ELL layout, but the per-round O(K²) score/argmax body
                 runs in the Pallas TPU kernel (kernels/label_prop).  The
                 neighbour-label gather is hoisted out of the kernel and
                 happens once per round in XLA; off-TPU the kernel runs in
                 interpret mode, so the engine is selectable everywhere.

All three produce bit-identical labels on graphs whose maximum degree fits
the ELL cap (tests/test_engines.py enforces this).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Protocol, runtime_checkable

import jax.numpy as jnp
from jax import lax

from repro.core import label_prop as lp


@runtime_checkable
class LPEngine(Protocol):
    """Execution strategy for weighted label propagation."""

    name: str

    def prepare(self, src, dst, w, valid, *, num_nodes: int,
                max_degree: int) -> Any:
        """Edge list -> engine-private adjacency state."""
        ...

    def round(self, labels: jnp.ndarray, state: Any) -> jnp.ndarray:
        """One LP round: labels i32[N] -> new labels i32[N]."""
        ...

    def finalize(self, labels: jnp.ndarray,
                 changes: jnp.ndarray) -> lp.LabelPropResult:
        ...


_REGISTRY: Dict[str, LPEngine] = {}


def register(cls):
    """Class decorator: instantiate and register an engine under its name."""
    engine = cls()
    _REGISTRY[engine.name] = engine
    return cls


def get_engine(name: str) -> LPEngine:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown label-prop engine {name!r}; registered engines: "
            f"{', '.join(available_engines())}") from None


def available_engines() -> tuple:
    return tuple(sorted(_REGISTRY))


def run_engine(engine: LPEngine, src, dst, w, valid, *, num_nodes: int,
               max_degree: int, rounds: int) -> lp.LabelPropResult:
    """Shared multi-round driver: prepare once, scan the engine's round."""
    state = engine.prepare(src, dst, w, valid, num_nodes=num_nodes,
                           max_degree=max_degree)
    init = jnp.arange(num_nodes, dtype=jnp.int32)

    def step(labels, _):
        new = engine.round(labels, state)
        return new, jnp.sum((new != labels).astype(jnp.int32))

    labels, changes = lax.scan(step, init, None, length=rounds)
    return engine.finalize(labels, changes)


class _EdgeListState(NamedTuple):
    src: jnp.ndarray
    dst: jnp.ndarray
    w: jnp.ndarray
    valid: jnp.ndarray
    num_nodes: int


class _EllState(NamedTuple):
    nbr: jnp.ndarray   # i32[N, K] neighbour ids, -1 padding
    wgt: jnp.ndarray   # f32[N, K]


@register
class SortEngine:
    """Reference engine: reduce-by-(dst,label) + reduce-by-dst argmax as
    sort + segment ops per round (DESIGN.md §2). Handles unbounded degree."""

    name = "sort"

    def prepare(self, src, dst, w, valid, *, num_nodes: int,
                max_degree: int) -> _EdgeListState:
        del max_degree  # the sort engine never caps degree
        return _EdgeListState(src, dst, w, valid, num_nodes)

    def round(self, labels, state: _EdgeListState):
        return lp.sort_round(labels, state.src, state.dst, state.w,
                             state.valid, state.num_nodes)

    def finalize(self, labels, changes):
        return lp.LabelPropResult(labels, changes)


@register
class EllEngine:
    """Dense degree-capped engine: the (N, K) ELL layout the Pallas kernel
    consumes, executed as plain XLA einsum/argmax."""

    name = "ell"

    def prepare(self, src, dst, w, valid, *, num_nodes: int,
                max_degree: int) -> _EllState:
        return _EllState(*lp.edges_to_ell(src, dst, w, valid,
                                          num_nodes=num_nodes,
                                          max_degree=max_degree))

    def round(self, labels, state: _EllState):
        return lp.ell_round(labels, state.nbr, state.wgt)

    def finalize(self, labels, changes):
        return lp.LabelPropResult(labels, changes)


@register
class PallasEngine:
    """ELL layout with the per-round O(K²) body in the Pallas TPU kernel.

    The neighbour-label gather (HBM-bound, irregular) is hoisted out of the
    kernel and re-done once per round in XLA; only the dense score/argmax
    block runs in Pallas.  Off-TPU the kernel executes in interpret mode
    (kernels/label_prop/ops.py checks the backend), so CPU tests exercise
    the exact same code path.

    ``block_n = None`` defers the node block to the autotuner table
    (kernels/tuning.py) — set a concrete int to pin it.
    """

    name = "pallas"
    block_n = None

    def prepare(self, src, dst, w, valid, *, num_nodes: int,
                max_degree: int) -> _EllState:
        return _EllState(*lp.edges_to_ell(src, dst, w, valid,
                                          num_nodes=num_nodes,
                                          max_degree=max_degree))

    def round(self, labels, state: _EllState):
        from repro.kernels.label_prop.ops import label_prop_round
        return label_prop_round(labels, state.nbr, state.wgt,
                                block_n=self.block_n)

    def finalize(self, labels, changes):
        return lp.LabelPropResult(labels, changes)
