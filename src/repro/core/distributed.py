"""Multi-device WindTunnel core: shard_map label propagation.

Node-sharded ELL layout: each device owns N/d rows of the (N, K) adjacency;
labels are the replicated carry. One round = local dense LP round (the
Pallas kernel's computation) + all_gather of the new local labels — one
collective per round, which is the distributed-LP communication lower bound
for bounded degree. Spark pays a full cluster shuffle per round; this is
the DESIGN.md §2 port at the multi-pod level.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.label_prop import ell_round
from repro.distributed.collectives import pvary_compat, unvary_compat


def distributed_propagate_ell(mesh: Mesh, nbr: jnp.ndarray, wgt: jnp.ndarray,
                              *, rounds: int, axis: str = "data"):
    """nbr (N, K) i32 / wgt (N, K) f32, N divisible by mesh axis size.
    Returns final labels (N,) i32 (replicated)."""
    n = nbr.shape[0]

    def local_rounds(nbr_l, wgt_l):
        # nbr_l/wgt_l: (N/d, K) local rows; labels: (N,) replicated carry
        idx = lax.axis_index(axis)
        rows = nbr_l.shape[0]
        row0 = idx * rows

        def one(labels, _):
            local_own = lax.dynamic_slice(labels, (row0,), (rows,))
            lab = jnp.where(nbr_l >= 0, labels[jnp.maximum(nbr_l, 0)], -1)
            # same semantics as core.label_prop.ell_round on the local rows
            mask = nbr_l >= 0
            w = jnp.where(mask, wgt_l, 0.0)
            same = (lab[:, :, None] == lab[:, None, :]).astype(jnp.float32)
            scores = jnp.einsum("nkj,nk->nj", same, w)
            scores = jnp.where(mask, scores, -jnp.inf)
            smax = jnp.max(scores, axis=1, keepdims=True)
            cand = jnp.where((scores == smax) & mask, lab,
                             jnp.iinfo(jnp.int32).max)
            best = jnp.min(cand, axis=1)
            has = jnp.any(mask, axis=1)
            new_local = jnp.where(has, best, local_own).astype(jnp.int32)
            new_labels = lax.all_gather(new_local, axis, tiled=True)
            return new_labels, None

        labels0 = jnp.arange(n, dtype=jnp.int32)
        # mark the replicated carry as device-varying (shard_map scan rule;
        # no-op on JAX versions without varying-manual-axes tracking)
        labels0 = pvary_compat(labels0, (axis,))
        labels, _ = lax.scan(one, labels0, None, length=rounds)
        return unvary_compat(labels, (axis,))  # collapse the annotation

    fn = shard_map(local_rounds, mesh=mesh,
                   in_specs=(P(axis, None), P(axis, None)),
                   out_specs=P())
    return fn(nbr, wgt)


def verify_against_single_device(mesh, nbr, wgt, rounds=3):
    """Test helper: distributed result == single-device ELL result."""
    from repro.core.label_prop import propagate_ell
    dist = distributed_propagate_ell(mesh, nbr, wgt, rounds=rounds)
    ref = propagate_ell(nbr, wgt, rounds=rounds).labels
    return jnp.array_equal(dist, ref)
