"""GraphSampler steps 1-3 — weighted label propagation (Algorithm 2).

Paper semantics (Raghavan et al. [9], weighted variant):
  init:   L(v) = v
  round:  for each node v, over incident edges (v, u, w) aggregate
          S(L) = sum of w over neighbours u with label L;
          assign L*(v) = argmax_L S(L).
  stop:   after a fixed number of rounds (LP is not guaranteed to converge).

MapReduce -> JAX mapping: one round = one reduce-by-(dst, label) followed by
one reduce-by-dst argmax. Both are sort + segment ops (DESIGN.md §2); the
whole multi-round loop runs inside a single XLA computation via lax.scan
(Spark pays a cluster-wide shuffle per round; we pay an on-device sort).

Ties are broken toward the smaller label id — the paper leaves this
unspecified; a deterministic rule makes the pipeline reproducible.

``propagate_ell`` is the dense, degree-capped formulation that feeds the
Pallas label_prop kernel (kernels/label_prop) — same semantics, different
data layout (see ref.py there for the oracle correspondence).

The per-round functions here (``sort_round``, ``ell_round``) are the
building blocks the engine registry (engines.py, DESIGN.md §4) wraps into
uniformly selectable execution strategies.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import segment_utils as su


class LabelPropResult(NamedTuple):
    labels: jnp.ndarray           # i32[num_nodes] final community labels
    changes_per_round: jnp.ndarray  # i32[rounds] nodes that changed label


def sort_round(labels, src, dst, w, valid, num_nodes):
    """One LP round over a directed edge list via sort + segment reduce —
    the round the ``sort`` engine (engines.SortEngine) executes."""
    e = src.shape[0]
    lab_src = labels[jnp.where(valid, src, 0)]
    dst_k = jnp.where(valid, dst, num_nodes)           # sentinel sorts last
    lab_k = jnp.where(valid, lab_src, su.I32_MAX)
    w_m = jnp.where(valid, w, 0.0)

    # reduce-by-(dst, label): sum of affinities per candidate label
    (dsts, labs), (ws,) = su.sort_by((dst_k, lab_k), (w_m,))
    starts = su.run_starts(dsts, labs)
    seg = su.run_segment_ids(starts)
    sums = su.segment_sum(ws, seg, num_segments=e)[seg]  # broadcast to rows

    # reduce-by-dst: argmax_L sum, tie -> min label
    dstarts = su.run_starts(dsts)
    dseg = su.run_segment_ids(dstarts)
    smax = su.segment_max(sums, dseg, num_segments=e)[dseg]
    cand = jnp.where(sums == smax, labs, su.I32_MAX)
    best = su.segment_min(cand, dseg, num_segments=e)

    # one representative row per dst-run; scatter back (sentinel rows drop)
    dst_of_seg = su.segment_min(dsts, dseg, num_segments=e)
    new_labels = labels.at[dst_of_seg].set(
        jnp.minimum(best, su.I32_MAX - 1).astype(labels.dtype), mode="drop")
    # runs made only of sentinel rows produce I32_MAX candidates; they were
    # dropped above because their dst is the sentinel num_nodes.
    return new_labels


def propagate(src, dst, w, valid, *, num_nodes: int, rounds: int) -> LabelPropResult:
    """Run ``rounds`` of weighted label propagation over a directed edge list.

    Use graph_builder.symmetrize() first for undirected graphs.
    """
    init = jnp.arange(num_nodes, dtype=jnp.int32)

    def step(labels, _):
        new = sort_round(labels, src, dst, w, valid, num_nodes)
        changed = jnp.sum((new != labels).astype(jnp.int32))
        return new, changed

    labels, changes = lax.scan(step, init, None, length=rounds)
    return LabelPropResult(labels, changes)


# ---------------------------------------------------------------------------
# Dense ELL formulation (feeds the Pallas kernel; also the vmap-able oracle)
# ---------------------------------------------------------------------------

def edges_to_ell(src, dst, w, valid, *, num_nodes: int, max_degree: int):
    """Pack a directed edge list into ELL adjacency:
    nbr i32[num_nodes, max_degree] (pad -1), wgt f32[num_nodes, max_degree].

    Edges beyond ``max_degree`` per dst are dropped deterministically
    (highest-weight edges kept), mirroring the fanout cap of Alg. 1.
    """
    e = src.shape[0]
    dst_k = jnp.where(valid, dst, num_nodes)
    negw = jnp.where(valid, -w, jnp.inf)
    (dsts, _), (srcs, ws) = su.sort_by((dst_k, negw), (src, w))
    starts = su.run_starts(dsts)
    rank = su.group_rank(starts)
    ok = (dsts < num_nodes) & (rank < max_degree)
    row = jnp.where(ok, dsts, num_nodes)
    col = jnp.where(ok, rank, 0)
    nbr = jnp.full((num_nodes, max_degree), -1, jnp.int32)
    nbr = nbr.at[row, col].set(srcs.astype(jnp.int32), mode="drop")
    wgt = jnp.zeros((num_nodes, max_degree), jnp.float32)
    wgt = wgt.at[row, col].set(ws, mode="drop")
    return nbr, wgt


def ell_round(labels, nbr, wgt):
    """One LP round over ELL adjacency. O(N * K^2) but fully dense —
    this is the computation the Pallas kernel implements on TPU.

    For node n with neighbour labels l_k and weights w_k:
      S(l_j) = sum_k w_k [l_k == l_j];  L* = argmax_j (S, -l_j).
    Nodes with no neighbours keep their label.
    """
    mask = nbr >= 0                                        # (N, K)
    lab = jnp.where(mask, labels[jnp.maximum(nbr, 0)], -1)  # (N, K)
    w = jnp.where(mask, wgt, 0.0)
    same = lab[:, :, None] == lab[:, None, :]               # (N, K, K)
    scores = jnp.einsum("nkj,nk->nj", same.astype(w.dtype), w)
    scores = jnp.where(mask, scores, -jnp.inf)
    # argmax with tie -> smaller label: exact two-pass (max score, min label)
    smax = jnp.max(scores, axis=1, keepdims=True)
    cand = jnp.where((scores == smax) & mask, lab, su.I32_MAX)
    new = jnp.min(cand, axis=1)
    has_nbr = jnp.any(mask, axis=1)
    return jnp.where(has_nbr, new, labels).astype(labels.dtype)


def propagate_ell(nbr, wgt, *, rounds: int) -> LabelPropResult:
    num_nodes = nbr.shape[0]
    init = jnp.arange(num_nodes, dtype=jnp.int32)

    def step(labels, _):
        new = ell_round(labels, nbr, wgt)
        return new, jnp.sum((new != labels).astype(jnp.int32))

    labels, changes = lax.scan(step, init, None, length=rounds)
    return LabelPropResult(labels, changes)
