"""GraphBuilder — Algorithm 1 of the WindTunnel paper.

Builds the weighted entity-affinity graph from a QRel table:

  Step 1 (map):    keep (q, e, s) with s > tau.
  Step 1 (reduce): for every query, emit every entity pair (e1 < e2) that
                   shares it, with affinity S = min(qrel(q,e1), qrel(q,e2)).
  Step 2:          dedup pairs keeping the MAX affinity.

MapReduce -> JAX mapping (DESIGN.md §2): the reduce-by-query self-join is a
degree-capped ELL expansion — QRels are sorted by (query, -score), the top
``fanout`` entities per query form a dense (num_queries, fanout) table, and
pair enumeration is a static (fanout choose 2) broadcast. The cap plays the
same role as the paper's top-50%-score filter: it bounds the O(K^2) pair
blow-up. Dedup is sort + segment_max.

Everything is static-shape and jit-able.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
from jax import lax

from repro.core import segment_utils as su


class QRelTable(NamedTuple):
    """Padded relational QRel table: (entity_id, query_id, score) rows."""
    query_ids: jnp.ndarray   # i32[n]
    entity_ids: jnp.ndarray  # i32[n]
    scores: jnp.ndarray      # f32[n]
    valid: jnp.ndarray       # bool[n]


class EdgeList(NamedTuple):
    """Padded undirected weighted edge list (u < v canonical)."""
    u: jnp.ndarray      # i32[m]
    v: jnp.ndarray      # i32[m]
    w: jnp.ndarray      # f32[m]
    valid: jnp.ndarray  # bool[m]

    @property
    def num_valid(self):
        return jnp.sum(self.valid.astype(jnp.int32))


def threshold_tau(qrels: QRelTable, tau_quantile: float) -> jnp.ndarray:
    """Score value such that scores strictly above it survive Step 1.

    The paper filters 'rankings with scores in the top 50%'; we express tau
    as a quantile of the valid scores so the same config works on any corpus.
    """
    s = jnp.where(qrels.valid, qrels.scores, jnp.nan)
    return jnp.nanquantile(s, tau_quantile)


def filter_qrels(qrels: QRelTable, tau: jnp.ndarray) -> QRelTable:
    """Step 1 map phase: Emit (q, (e, s)) if s > tau."""
    keep = qrels.valid & (qrels.scores > tau)
    return QRelTable(qrels.query_ids, qrels.entity_ids, qrels.scores, keep)


def build_ell(qrels: QRelTable, num_queries: int, fanout: int):
    """Group QRels by query into a dense ELL table of the top-``fanout``
    entities per query (by score).

    Returns (ell_e i32[num_queries, fanout] with -1 padding,
             ell_s f32[num_queries, fanout]).
    """
    qk = jnp.where(qrels.valid, qrels.query_ids, su.I32_MAX)
    neg_s = jnp.where(qrels.valid, -qrels.scores, jnp.inf)
    (qs, ss), (es, vs) = su.sort_by(
        (qk, neg_s), (qrels.entity_ids, qrels.valid.astype(jnp.int32)))
    starts = su.run_starts(qs)
    rank = su.group_rank(starts)
    ok = (vs == 1) & (rank < fanout) & (qs < num_queries)
    row = jnp.where(ok, qs, num_queries)  # out-of-bounds rows are dropped
    col = jnp.where(ok, rank, 0)
    ell_e = jnp.full((num_queries, fanout), -1, jnp.int32)
    ell_e = ell_e.at[row, col].set(es.astype(jnp.int32), mode="drop")
    ell_s = jnp.zeros((num_queries, fanout), jnp.float32)
    ell_s = ell_s.at[row, col].set(-ss, mode="drop")
    return ell_e, ell_s


def affinity_pairs(ell_e: jnp.ndarray, ell_s: jnp.ndarray) -> EdgeList:
    """Step 1 reduce phase: enumerate entity pairs sharing a query.

    S_affinity = min(qrel(q, e1), qrel(q, e2)) along the 2-hop path
    (e1 -> q -> e2). Canonical orientation u < v.
    """
    fanout = ell_e.shape[1]
    iu, ju = jnp.triu_indices(fanout, k=1)
    ea, eb = ell_e[:, iu], ell_e[:, ju]           # (Q, P)
    sa, sb = ell_s[:, iu], ell_s[:, ju]
    valid = (ea >= 0) & (eb >= 0) & (ea != eb)
    u = jnp.minimum(ea, eb)
    v = jnp.maximum(ea, eb)
    w = jnp.minimum(sa, sb)
    return EdgeList(u.ravel(), v.ravel(), w.ravel(), valid.ravel())


def dedup_edges(edges: EdgeList) -> EdgeList:
    """Step 2: one edge per (u, v) pair, keeping max affinity.

    Output is aligned to run-starts of the (u, v)-sorted order; non-start
    positions are masked out.
    """
    n = edges.u.shape[0]
    uk = jnp.where(edges.valid, edges.u, su.I32_MAX)
    vk = jnp.where(edges.valid, edges.v, su.I32_MAX)
    (us, vs), (ws, vals) = su.sort_by((uk, vk), (edges.w, edges.valid.astype(jnp.int32)))
    starts = su.run_starts(us, vs)
    seg = su.run_segment_ids(starts)
    # max affinity per unique pair, broadcast back, representative = run start
    wmax = su.segment_max(jnp.where(vals == 1, ws, -jnp.inf), seg, num_segments=n)
    keep = starts & (vals == 1)
    return EdgeList(us, vs, wmax[seg], keep)


def build_affinity_graph(qrels: QRelTable, *, num_queries: int,
                         tau_quantile: float = 0.5, fanout: int = 16) -> EdgeList:
    """Full Algorithm 1: threshold -> ELL group-by -> pair gen -> dedup."""
    tau = threshold_tau(qrels, tau_quantile)
    kept = filter_qrels(qrels, tau)
    ell_e, ell_s = build_ell(kept, num_queries, fanout)
    pairs = affinity_pairs(ell_e, ell_s)
    return dedup_edges(pairs)


def symmetrize(edges: EdgeList) -> tuple:
    """Undirected edge list -> directed (src, dst, w, valid) with both
    orientations, for message passing."""
    src = jnp.concatenate([edges.u, edges.v])
    dst = jnp.concatenate([edges.v, edges.u])
    w = jnp.concatenate([edges.w, edges.w])
    valid = jnp.concatenate([edges.valid, edges.valid])
    return src, dst, w, valid


def node_degrees(edges: EdgeList, num_nodes: int) -> jnp.ndarray:
    """Node degree histogram support (Fig. 4 of the paper): the degree of an
    entity is its number of unique affinity-graph neighbours."""
    src, dst, _, valid = symmetrize(edges)
    ones = valid.astype(jnp.int32)
    deg = jnp.zeros((num_nodes,), jnp.int32).at[
        jnp.where(valid, dst, num_nodes)].add(ones, mode="drop")
    return deg
