"""Sampling-strategy registry (DESIGN.md §10).

The sampling side of WindTunnel mirrors the retrieval side: just as every
vector index is a registered :class:`~repro.retrieval.engines.RetrievalEngine`
behind one ``build``/``search`` protocol, every *sampling strategy* is a
registered :class:`SamplerStrategy` behind one ``draw`` protocol.  The
session front door (``sampling_core.SamplerSession``) stages the expensive
shared state — affinity graph, label propagation — and hands each strategy
only the pieces it declares it needs, so cheap baselines never pay for the
graph and the grid runner / CLIs select strategies uniformly by name.

A strategy implements the :class:`SamplerStrategy` protocol:

  * ``needs_graph`` / ``needs_labels`` — which staged inputs ``draw``
    consumes (node degrees from Alg. 1; LP labels from Alg. 2).  The
    session builds each stage lazily, once, only if some draw needs it.
  * ``draw(state, key, target_size)`` — pure, jit-able: produce the sampled
    entity mask (and, for cluster sampling, the :class:`ClusterSample`
    diagnostics).  ``target_size`` follows one convention everywhere: a
    value in (0, 1] is a *fraction of the strategy's eligible universe*,
    a value > 1 an absolute entity count, ``None`` the strategy default
    (for ``windtunnel`` the paper's exact |L|/N rule).

Registered strategies:

  * ``windtunnel``        — cluster sampling of LP communities (the paper).
  * ``uniform``           — Bernoulli over the judged entities (the paper's
                            community-destroying baseline); ``universe="all"``
                            reproduces the legacy ``run_uniform_baseline``
                            draw over the whole corpus bit-exactly.
  * ``full``              — keep everything (the no-sampling control).
  * ``degree_stratified`` — NEW baseline between uniform and windtunnel:
                            nodes are bucketed by ⌊log2(degree)⌋ and an
                            equal keep *quota* is drawn per bucket, so the
                            sample preserves the degree distribution
                            exactly (not just in expectation) while still
                            ignoring community structure.

Strategies are frozen dataclasses, so callers tune knobs with
``dataclasses.replace`` (or ``SamplerSpec.strategy_opts``) without mutating
the registry's shared instance.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, NamedTuple, Optional, Protocol, Tuple, runtime_checkable

import jax
import jax.numpy as jnp

from repro.core import sampler as sm
from repro.core import segment_utils as su
from repro.core.graph_builder import QRelTable


class DrawState(NamedTuple):
    """Staged session state a strategy may consume in ``draw``.

    ``labels`` / ``degrees`` are only populated when the strategy declares
    ``needs_labels`` / ``needs_graph`` — the session never builds a stage no
    draw asked for.
    """

    qrels: QRelTable
    num_entities: int
    labels: Optional[jnp.ndarray]    # i32[N] LP labels (needs_labels)
    degrees: Optional[jnp.ndarray]   # i32[N] affinity degrees (needs_graph)


@runtime_checkable
class SamplerStrategy(Protocol):
    """A sampling strategy behind a uniform draw interface.

    ``salt`` decorrelates strategies drawn at the same seed: the session
    folds it into the PRNG key (``fold_in``) before ``draw``, so baselines
    compared side-by-side in the eval grid never consume the same uniform
    array (a shared array would make uniform and degree_stratified keep
    near-identical entity sets).  ``salt = 0`` means the raw
    ``PRNGKey(seed)`` — required where legacy entry points promise
    bit-compatible draws (windtunnel; uniform via ``run_uniform_baseline``,
    which pins ``salt=0`` through ``strategy_opts``).
    """

    name: str
    needs_graph: bool
    needs_labels: bool
    salt: int

    def draw(self, state: DrawState, key: jax.Array,
             target_size: Optional[float]
             ) -> Tuple[jnp.ndarray, Optional[sm.ClusterSample]]:
        """(bool[N] entity mask, ClusterSample diagnostics or None)."""
        ...


_REGISTRY: Dict[str, SamplerStrategy] = {}


def register_sampler(cls):
    """Class decorator: instantiate and register a strategy under its name."""
    strategy = cls()
    _REGISTRY[strategy.name] = strategy
    return cls


def get_sampler(name: str) -> SamplerStrategy:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown sampler strategy {name!r}; registered strategies: "
            f"{', '.join(available_samplers())}") from None


def available_samplers() -> tuple:
    return tuple(sorted(_REGISTRY))


def judged_entities(qrels: QRelTable, num_entities: int) -> jnp.ndarray:
    """bool[N]: entities with >=1 valid QRel row (the paper's 'primary'
    entities — the sampling universe of every judged-corpus baseline)."""
    e = jnp.where(qrels.valid, qrels.entity_ids, num_entities)
    cnt = jnp.zeros((num_entities,), jnp.int32).at[e].add(1, mode="drop")
    return cnt > 0


def _resolve_count(target_size, n_eligible):
    """(0, 1) fraction-of-universe -> absolute count; >= 1 passes through
    (strict, so a legacy absolute target of exactly 1 entity keeps its
    historical meaning through the ``run_windtunnel`` wrapper)."""
    if target_size is not None and target_size < 1.0:
        return target_size * n_eligible
    return target_size


@register_sampler
@dataclasses.dataclass(frozen=True)
class FullSampler:
    """Keep the whole corpus — the fidelity report's baseline row."""

    name: str = "full"
    needs_graph = False
    needs_labels = False
    salt = 0

    def draw(self, state, key, target_size):
        del key, target_size
        return jnp.ones((state.num_entities,), bool), None


@register_sampler
@dataclasses.dataclass(frozen=True)
class UniformSampler:
    """Bernoulli entity sampling (paper §I-A) over a configurable universe.

    ``universe="judged"`` (default) draws from the qrel'd entities — the
    size-matched baseline the eval grid compares against.  ``universe="all"``
    with ``salt=0`` draws from every corpus entity, reproducing the legacy
    ``run_uniform_baseline`` mask bit-exactly for the same (rate, seed); the
    registry default salt decorrelates grid draws from the windtunnel /
    degree_stratified strategies at the same seed (the old runner's
    ``seed + 7`` numpy decorrelation, now at the strategy level).
    """

    universe: str = "judged"
    salt: int = 7
    name: str = "uniform"
    needs_graph = False
    needs_labels = False

    def draw(self, state, key, target_size):
        if target_size is None:
            raise ValueError("uniform sampling needs a target_size "
                             "(fraction in (0, 1] or entity count)")
        n = state.num_entities
        if self.universe == "all":
            eligible = None
        elif self.universe == "judged":
            eligible = judged_entities(state.qrels, n)
        else:
            raise ValueError(f"unknown uniform universe {self.universe!r}; "
                             f"known universes: all, judged")
        if target_size <= 1.0:
            rate = target_size            # already a rate — no float detour
        else:
            n_elig = (jnp.float32(n) if eligible is None
                      else jnp.sum(eligible.astype(jnp.float32)))
            rate = target_size / jnp.maximum(n_elig, 1.0)
        mask = jax.random.uniform(key, (n,)) < rate
        if eligible is not None:
            mask = mask & eligible
        return mask, None


@register_sampler
@dataclasses.dataclass(frozen=True)
class WindTunnelSampler:
    """Cluster sampling of LP communities (Alg. 2 step 4) — a kept label
    brings ALL of its entities, so community neighbourhoods survive intact."""

    name: str = "windtunnel"
    needs_graph = True
    needs_labels = True
    salt = 0          # raw PRNGKey(seed): legacy run_windtunnel bit-parity

    def draw(self, state, key, target_size):
        eligible = state.degrees > 0
        target = _resolve_count(target_size,
                                jnp.sum(eligible.astype(jnp.float32)))
        sample = sm.cluster_sample(state.labels, key,
                                   num_nodes=state.num_entities,
                                   target_size=target, eligible=eligible)
        return sample.entity_mask, sample


@register_sampler
@dataclasses.dataclass(frozen=True)
class DegreeStratifiedSampler:
    """Degree-stratified random sampling: nodes are bucketed by
    ⌊log2(degree)⌋ (``num_strata`` buckets, top bucket open) and each bucket
    keeps a ``rate × |bucket|`` quota of uniformly-ranked members.

    Preserves the affinity-graph degree distribution exactly — the Fig. 4
    power law a uniform Bernoulli draw only preserves in expectation — while
    still cutting across communities, isolating how much of WindTunnel's
    fidelity comes from community structure rather than degree structure.
    """

    num_strata: int = 8
    salt: int = 13
    name: str = "degree_stratified"
    needs_graph = True
    needs_labels = False

    def draw(self, state, key, target_size):
        if target_size is None:
            raise ValueError("degree_stratified sampling needs a target_size "
                             "(fraction in (0, 1] or entity count)")
        deg = state.degrees
        n = state.num_entities
        eligible = deg > 0
        n_elig = jnp.maximum(jnp.sum(eligible.astype(jnp.float32)), 1.0)
        if target_size <= 1.0:
            rate = jnp.float32(target_size)
        else:
            rate = jnp.clip(target_size / n_elig, 0.0, 1.0)
        stratum = jnp.floor(
            jnp.log2(jnp.maximum(deg, 1).astype(jnp.float32))).astype(jnp.int32)
        stratum = jnp.clip(stratum, 0, self.num_strata - 1)
        stratum = jnp.where(eligible, stratum, self.num_strata)  # drop bucket
        counts = jax.ops.segment_sum(jnp.ones((n,), jnp.int32), stratum,
                                     num_segments=self.num_strata + 1)
        quota = jnp.round(rate * counts.astype(jnp.float32)).astype(jnp.int32)
        # random rank within each stratum: sort by (stratum, uniform draw)
        u = jax.random.uniform(key, (n,))
        (strat_s, _), (ids_s,) = su.sort_by(
            (stratum, u), (jnp.arange(n, dtype=jnp.int32),))
        rank = su.group_rank(su.run_starts(strat_s))
        keep = (strat_s < self.num_strata) & \
            (rank < quota[jnp.minimum(strat_s, self.num_strata - 1)])
        mask = jnp.zeros((n,), bool).at[ids_s].set(keep)
        return mask, None
