"""Retrieval-engine registry — the eval subsystem's façade over
``repro.retrieval.engines``, where the implementation lives (below both this
package and ``retrieval/experiment.py``, so neither depends upward on the
other).  See that module and DESIGN.md §8 for the protocol and the
registered ``exact`` / ``ivfflat`` / ``lsh`` / ``tfidf`` engines.
"""
from repro.retrieval.engines import (ExactEngine, IVFFlatEngine, LSHEngine,
                                     RetrievalEngine, TfIdfEngine,
                                     TfIdfIndex, available_retrieval_engines,
                                     get_retrieval_engine,
                                     register_retrieval_engine)

__all__ = [
    "RetrievalEngine", "available_retrieval_engines",
    "get_retrieval_engine", "register_retrieval_engine",
    "ExactEngine", "IVFFlatEngine", "LSHEngine", "TfIdfEngine", "TfIdfIndex",
]
