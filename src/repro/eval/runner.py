"""Grid runner — stage implementations for the experiment-plan trie.

``run_grid`` walks every (sampler × engine × k × metric) cell of a
:class:`~repro.eval.plans.GridSpec` through the stage trie over one
:class:`~repro.data.synthetic.SyntheticCorpus`:

  corpus  — qrel lookup structures (pair set + per-query dict), built once.
  embed   — entity + query vectors from a pluggable embedder (default:
            the deterministic tf-idf reference embedder), built once.
  sample  — entity mask from the sampler registry (full / uniform /
            windtunnel), associated queries and query density, once per
            sampler.
  index   — a :class:`~repro.retrieval.search_core.SearchSession` over the
            sample's kept vectors, once per (sampler, engine): build-once
            through the search-core front door, so the grid exercises the
            same engine/backend/shard path the serving engine uses.
  search  — chunked ``SearchSession.search`` mapped back to global entity
            ids, once per (sampler, engine, k) — the built index is reused
            across k values and metrics.
  metric  — scalar from the metric registry, per cell.

``run_grid(..., search=SearchConfig(backend="pallas", sharded=True,
mesh=...))`` re-runs the whole grid on the kernel backend or a device mesh
without touching any stage code.

Samplers and metrics are registries too, so new sampling baselines or IR
measures extend the grid without touching this walker.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (QRelTable, WindTunnelConfig, query_density,
                        run_windtunnel)
from repro.data.synthetic import SyntheticCorpus
from repro.eval.plans import (GridSpec, PlanTrie, RunSpec, execute_plan,
                              expand_grid)
from repro.retrieval.search_core import SearchConfig, SearchSession
from repro.retrieval.metrics import (mrr, ndcg_at_k, precision_at_k,
                                     qrel_dict, qrel_set, recall_at_k)
from repro.retrieval.tfidf import tfidf_vectors

# --------------------------------------------------------------------------
# sampler registry: name -> fn(corpus, spec) -> Optional[bool mask] (None =
# full corpus).  Samplers are independent of one another so the trie can
# compute them in any order.
# --------------------------------------------------------------------------

_SAMPLERS: Dict[str, Callable[[SyntheticCorpus, GridSpec],
                              Optional[np.ndarray]]] = {}


def register_sampler(name: str):
    def deco(fn):
        _SAMPLERS[name] = fn
        return fn
    return deco


def available_samplers() -> tuple:
    return tuple(sorted(_SAMPLERS))


@register_sampler("full")
def _sample_full(corpus: SyntheticCorpus, spec: GridSpec):
    return None


@register_sampler("uniform")
def _sample_uniform(corpus: SyntheticCorpus, spec: GridSpec):
    """Uniform over the judged entities at the grid's sample fraction —
    the paper's community-destroying baseline.

    Samplers are independent trie nodes, so this draws at ``sample_frac``
    rather than at the WindTunnel sample's *realized* rate; the windtunnel
    sampler's target_size calibration aims at the same fraction, keeping
    the two approximately (not exactly) size-matched.  Realized sizes are
    reported per sampler in ``GridResult.sampler_stats`` — check them
    before attributing small metric deltas to the sampling strategy."""
    rng = np.random.default_rng(spec.seed + 7)
    mask = np.zeros(corpus.num_entities, bool)
    mask[:corpus.num_primary] = rng.random(corpus.num_primary) < \
        spec.sample_frac
    return mask


@register_sampler("windtunnel")
def _sample_windtunnel(corpus: SyntheticCorpus, spec: GridSpec):
    cfg = WindTunnelConfig(
        tau_quantile=0.5, fanout=16, lp_rounds=5,
        target_size=spec.sample_frac * corpus.num_primary, seed=spec.seed)
    qrels = QRelTable(*(jnp.asarray(x) for x in corpus.qrels))
    res = jax.jit(lambda q: run_windtunnel(
        q, num_queries=corpus.num_queries,
        num_entities=corpus.num_entities, config=cfg))(qrels)
    return np.asarray(res.sample.entity_mask)


# --------------------------------------------------------------------------
# metric registry: name -> fn(global_ids, qids, ctx, k) -> float, where ctx
# is the corpus-stage value ({"pairs": set, "by_query": dict}).
# --------------------------------------------------------------------------

METRICS: Dict[str, Callable[..., float]] = {
    "precision": lambda ids, qids, ctx, k:
        precision_at_k(ids, qids, ctx["pairs"], k=k),
    "recall": lambda ids, qids, ctx, k:
        recall_at_k(ids, qids, ctx["by_query"], k=k),
    "ndcg": lambda ids, qids, ctx, k:
        ndcg_at_k(ids, qids, ctx["by_query"], k=k),
    "mrr": lambda ids, qids, ctx, k:
        mrr(ids, qids, ctx["by_query"], k=k),
}


def tfidf_embedder(corpus: SyntheticCorpus):
    """Default embedder: deterministic tf-idf bag-of-words vectors for both
    entities and queries (document df reused for the queries)."""
    ev, df = tfidf_vectors(corpus.passage_tokens, corpus.vocab_size)
    qv, _ = tfidf_vectors(corpus.query_tokens, corpus.vocab_size, df=df)
    return ev, qv


def _associated_queries(corpus: SyntheticCorpus, mask: np.ndarray,
                        max_queries: int, seed: int):
    """Queries with >=1 relevant kept entity, subsampled to ``max_queries``
    (the reconstructor's query-association rule, host-side)."""
    q = np.asarray(corpus.qrels.query_ids)
    e = np.asarray(corpus.qrels.entity_ids)
    v = np.asarray(corpus.qrels.valid)
    assoc = np.zeros(corpus.num_queries, bool)
    rows = v & mask[np.clip(e, 0, corpus.num_entities - 1)]
    assoc[q[rows]] = True
    qids = np.nonzero(assoc)[0]
    if qids.size > max_queries:
        rng = np.random.default_rng(seed)
        qids = np.sort(rng.choice(qids, max_queries, replace=False))
    return assoc, qids


@dataclasses.dataclass
class GridResult:
    spec: GridSpec
    cells: Dict[Tuple[str, str, int, str], float]
    sampler_stats: Dict[str, Dict[str, float]]
    trie: PlanTrie

    def to_json(self) -> dict:
        return {
            "spec": dataclasses.asdict(self.spec),
            "cells": [{"sampler": s, "engine": e, "k": k, "metric": m,
                       "value": v}
                      for (s, e, k, m), v in sorted(self.cells.items())],
            "sampler_stats": self.sampler_stats,
            "stage_counts": {st: {"executions": ex, "requests": rq}
                             for st, (ex, rq)
                             in self.trie.stage_counts().items()},
        }


def run_grid(corpus: SyntheticCorpus, spec: GridSpec, *,
             embedder: Optional[Callable] = None, query_chunk: int = 256,
             search: Optional[SearchConfig] = None,
             verbose: bool = False) -> GridResult:
    """Execute every cell of ``spec`` over ``corpus`` via the plan trie.

    ``search`` configures the search core (backend / sharded / mesh) for
    the index+search stages; the engine axis always comes from the grid.
    """
    embedder = embedder or tfidf_embedder
    search = search or SearchConfig()
    sampler_stats: Dict[str, Dict[str, float]] = {}

    def stage_corpus(parent: Any, run: RunSpec) -> dict:
        del parent, run
        qr = corpus.qrels
        return {"pairs": qrel_set(qr.query_ids, qr.entity_ids, qr.valid),
                "by_query": qrel_dict(qr.query_ids, qr.entity_ids, qr.valid)}

    def stage_embed(ctx: dict, run: RunSpec) -> dict:
        del run
        ev, qv = embedder(corpus)
        return {**ctx, "ev": np.asarray(ev), "qv": np.asarray(qv)}

    def stage_sample(ctx: dict, run: RunSpec) -> dict:
        try:
            sampler = _SAMPLERS[run.sampler]
        except KeyError:
            raise ValueError(
                f"unknown sampler {run.sampler!r}; registered samplers: "
                f"{', '.join(available_samplers())}") from None
        mask = sampler(corpus, spec)
        mask = (np.ones(corpus.num_entities, bool) if mask is None
                else np.asarray(mask))
        kept_ids = np.nonzero(mask)[0]
        assoc, qids = _associated_queries(corpus, mask, spec.max_queries,
                                          spec.seed)
        rho = float(query_density(
            QRelTable(*(jnp.asarray(x) for x in corpus.qrels)),
            jnp.asarray(mask), jnp.asarray(assoc),
            num_queries=corpus.num_queries,
            num_entities=corpus.num_entities))
        sampler_stats[run.sampler] = {"n_entities": int(kept_ids.size),
                                      "n_queries": int(qids.size),
                                      "rho_q": rho}
        if verbose:
            print(f"  sample[{run.sampler}]: {kept_ids.size} entities, "
                  f"{qids.size} queries, rho_q={rho:.3f}")
        return {**ctx, "kept_ids": kept_ids, "qids": qids}

    def stage_index(ctx: dict, run: RunSpec) -> dict:
        cfg = dataclasses.replace(search, engine=run.engine,
                                  query_chunk=query_chunk)
        session = SearchSession(ctx["ev"][ctx["kept_ids"]], cfg,
                                key=jax.random.PRNGKey(spec.seed),
                                ids_map=ctx["kept_ids"])
        return {**ctx, "session": session}

    def stage_search(ctx: dict, run: RunSpec) -> dict:
        global_ids = ctx["session"].search(ctx["qv"][ctx["qids"]], k=run.k)
        return {**ctx, "global_ids": global_ids}

    def stage_metric(ctx: dict, run: RunSpec) -> float:
        try:
            metric = METRICS[run.metric]
        except KeyError:
            raise ValueError(
                f"unknown metric {run.metric!r}; registered metrics: "
                f"{', '.join(sorted(METRICS))}") from None
        return float(metric(ctx["global_ids"], ctx["qids"], ctx, run.k))

    cells, trie = execute_plan(expand_grid(spec), {
        "corpus": stage_corpus, "embed": stage_embed,
        "sample": stage_sample, "index": stage_index,
        "search": stage_search, "metric": stage_metric,
    })
    return GridResult(spec, cells, sampler_stats, trie)
