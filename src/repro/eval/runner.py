"""Grid runner — stage implementations for the experiment-plan trie.

``run_grid`` walks every (sampler × engine × k × metric) cell of a
:class:`~repro.eval.plans.GridSpec` through the stage trie over one
:class:`~repro.data.synthetic.SyntheticCorpus`:

  corpus  — qrel lookup structures (pair set + per-query dict), built once.
  embed   — entity + query vectors from a pluggable embedder (default:
            the deterministic tf-idf reference embedder), built once.
  sample  — entity mask from one shared
            :class:`~repro.core.sampling_core.SamplerSession` via the
            strategy registry (core/samplers.py: full / uniform /
            windtunnel / degree_stratified), associated queries and query
            density, once per sampler.  All samplers draw from the SAME
            session, so the affinity graph and label propagation are
            staged at most once for the whole grid — the sampling-side
            analogue of the trie's shared index stage.
  index   — a :class:`~repro.retrieval.search_core.SearchSession` over the
            sample's kept vectors, once per (sampler, engine): build-once
            through the search-core front door, so the grid exercises the
            same engine/backend/shard path the serving engine uses.
  search  — chunked ``SearchSession.search`` mapped back to global entity
            ids, once per (sampler, engine, k) — the built index is reused
            across k values and metrics.
  metric  — scalar from the metric registry, per cell.

``run_grid(..., search=SearchConfig(backend="pallas", sharded=True,
mesh=...))`` re-runs the whole grid on the kernel backend or a device mesh
without touching any stage code; ``run_grid(..., sampler=SamplerSpec(...))``
does the same for the sampling side (LP engine, sharded graph build, knobs).

Samplers and metrics are registries, so new sampling baselines or IR
measures extend the grid without touching this walker.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import QRelTable, associated_queries, query_density
from repro.core.samplers import available_samplers, get_sampler
from repro.core.sampling_core import SamplerSession, SamplerSpec
from repro.data.synthetic import SyntheticCorpus
from repro.eval.plans import (GridSpec, PlanTrie, RunSpec, execute_plan,
                              expand_grid)
from repro.retrieval.search_core import SearchConfig, SearchSession
from repro.retrieval.metrics import (mrr, ndcg_at_k, precision_at_k,
                                     qrel_dict, qrel_set, recall_at_k)
from repro.retrieval.tfidf import tfidf_vectors

__all__ = ["GridResult", "run_grid", "tfidf_embedder", "available_samplers"]

log = logging.getLogger("repro.eval.runner")


# --------------------------------------------------------------------------
# metric registry: name -> fn(global_ids, qids, ctx, k) -> float, where ctx
# is the corpus-stage value ({"pairs": set, "by_query": dict}).
# --------------------------------------------------------------------------

METRICS: Dict[str, Callable[..., float]] = {
    "precision": lambda ids, qids, ctx, k:
        precision_at_k(ids, qids, ctx["pairs"], k=k),
    "recall": lambda ids, qids, ctx, k:
        recall_at_k(ids, qids, ctx["by_query"], k=k),
    "ndcg": lambda ids, qids, ctx, k:
        ndcg_at_k(ids, qids, ctx["by_query"], k=k),
    "mrr": lambda ids, qids, ctx, k:
        mrr(ids, qids, ctx["by_query"], k=k),
}


def tfidf_embedder(corpus: SyntheticCorpus):
    """Default embedder: deterministic tf-idf bag-of-words vectors for both
    entities and queries (document df reused for the queries)."""
    ev, df = tfidf_vectors(corpus.passage_tokens, corpus.vocab_size)
    qv, _ = tfidf_vectors(corpus.query_tokens, corpus.vocab_size, df=df)
    return ev, qv


@dataclasses.dataclass
class GridResult:
    spec: GridSpec
    cells: Dict[Tuple[str, str, int, str], float]
    sampler_stats: Dict[str, Dict[str, float]]
    trie: PlanTrie

    def to_json(self) -> dict:
        return {
            "spec": dataclasses.asdict(self.spec),
            "cells": [{"sampler": s, "engine": e, "k": k, "metric": m,
                       "value": v}
                      for (s, e, k, m), v in sorted(self.cells.items())],
            "sampler_stats": self.sampler_stats,
            "stage_counts": {st: {"executions": ex, "requests": rq}
                             for st, (ex, rq)
                             in self.trie.stage_counts().items()},
        }


def run_grid(corpus: SyntheticCorpus, spec: GridSpec, *,
             embedder: Optional[Callable] = None, query_chunk: int = 256,
             search: Optional[SearchConfig] = None,
             sampler: Optional[SamplerSpec] = None,
             verbose: bool = False) -> GridResult:
    """Execute every cell of ``spec`` over ``corpus`` via the plan trie.

    ``search`` configures the search core (backend / sharded / mesh) for
    the index+search stages; ``sampler`` configures the sampling core (LP
    engine / sharded graph build / knobs) for the sample stage.  The
    engine and sampler axes always come from the grid; the grid's
    ``sample_frac``/``seed`` override the sampler spec's defaults so every
    strategy is size-matched at the same fraction of the judged corpus.
    """
    embedder = embedder or tfidf_embedder
    search = search or SearchConfig()
    sampler_spec = dataclasses.replace(
        sampler or SamplerSpec(),
        target_size=spec.sample_frac * corpus.num_primary, seed=spec.seed)
    sampler_stats: Dict[str, Dict[str, float]] = {}

    session_box: list = []

    def _session() -> SamplerSession:
        """One SamplerSession shared by every sampler in the grid: the
        affinity graph and LP labels are staged at most once per run_grid."""
        if not session_box:
            qrels = QRelTable(*(jnp.asarray(x) for x in corpus.qrels))
            session_box.append(SamplerSession(
                qrels, num_queries=corpus.num_queries,
                num_entities=corpus.num_entities, spec=sampler_spec))
        return session_box[0]

    def stage_corpus(parent: Any, run: RunSpec) -> dict:
        del parent, run
        qr = corpus.qrels
        return {"pairs": qrel_set(qr.query_ids, qr.entity_ids, qr.valid),
                "by_query": qrel_dict(qr.query_ids, qr.entity_ids, qr.valid)}

    def stage_embed(ctx: dict, run: RunSpec) -> dict:
        del run
        ev, qv = embedder(corpus)
        return {**ctx, "ev": np.asarray(ev), "qv": np.asarray(qv)}

    def stage_sample(ctx: dict, run: RunSpec) -> dict:
        get_sampler(run.sampler)   # registry error UX before any staging
        draw = _session().draw(strategy=run.sampler)
        mask = np.asarray(draw.entity_mask)
        kept_ids = np.nonzero(mask)[0]
        assoc, qids = associated_queries(
            corpus.qrels, mask, num_queries=corpus.num_queries,
            max_queries=spec.max_queries, seed=spec.seed)
        rho = float(query_density(
            QRelTable(*(jnp.asarray(x) for x in corpus.qrels)),
            jnp.asarray(mask), jnp.asarray(assoc),
            num_queries=corpus.num_queries,
            num_entities=corpus.num_entities))
        sampler_stats[run.sampler] = {"n_entities": int(kept_ids.size),
                                      "n_queries": int(qids.size),
                                      "rho_q": rho}
        # progress goes through the repro.* logger hierarchy (DESIGN.md
        # §12): verbose=True promotes it to INFO (the CLIs' default level)
        log.log(logging.INFO if verbose else logging.DEBUG,
                "  sample[%s]: %d entities, %d queries, rho_q=%.3f",
                run.sampler, kept_ids.size, qids.size, rho)
        return {**ctx, "kept_ids": kept_ids, "qids": qids}

    def stage_index(ctx: dict, run: RunSpec) -> dict:
        cfg = dataclasses.replace(search, engine=run.engine,
                                  query_chunk=query_chunk)
        session = SearchSession(ctx["ev"][ctx["kept_ids"]], cfg,
                                key=jax.random.PRNGKey(spec.seed),
                                ids_map=ctx["kept_ids"])
        return {**ctx, "session": session}

    def stage_search(ctx: dict, run: RunSpec) -> dict:
        global_ids = ctx["session"].search(ctx["qv"][ctx["qids"]], k=run.k)
        return {**ctx, "global_ids": global_ids}

    def stage_metric(ctx: dict, run: RunSpec) -> float:
        try:
            metric = METRICS[run.metric]
        except KeyError:
            raise ValueError(
                f"unknown metric {run.metric!r}; registered metrics: "
                f"{', '.join(sorted(METRICS))}") from None
        return float(metric(ctx["global_ids"], ctx["qids"], ctx, run.k))

    cells, trie = execute_plan(expand_grid(spec), {
        "corpus": stage_corpus, "embed": stage_embed,
        "sample": stage_sample, "index": stage_index,
        "search": stage_search, "metric": stage_metric,
    })
    return GridResult(spec, cells, sampler_stats, trie)
