"""Declarative experiment plans with trie-shared stage execution.

A grid of runs (sampler × engine × k × metric) is declared as a
:class:`GridSpec` and expanded into :class:`RunSpec` cells.  Each cell names
the same six-stage pipeline

    corpus → embed → sample → index → search → metric

and cells that agree on a prefix share it: the stage trie keys every node by
its full path, so the corpus and its embeddings materialise once, each
sampler's mask once, each (sampler, engine) index once, and each
(sampler, engine, k) search once — only the final metric is per-cell.  This
is the PyTerrier declarative-pipeline pattern (Macdonald 2020) combined with
the trie-based experiment-plan optimisation of Anu & Macdonald: common
pipeline prefixes across a grid of runs execute exactly once.

Per-node ``executions``/``requests`` counters make the saving observable —
``PlanTrie.summary()`` prints, per stage, how many cell walks were served
from cache instead of recomputed.  The counters live in a per-trie
:class:`~repro.obs.metrics.Registry` (``plan.executions.<stage>`` /
``plan.requests.<stage>``; ``stage_counts()`` reads them back in the
legacy shape), and every stage-node execution runs inside an
``eval.<stage>`` span, so a grid's trace shows exactly which nodes ran
and for how long (DESIGN.md §12).

The trie is deliberately generic: stages are supplied as callables by the
runner (``runner.py``), so new stage semantics (a different embedder, a
sharded index build) plug in without touching the plan machinery.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Callable, Dict, List, Mapping, Tuple

from repro.obs import Registry, trace

#: Stage order of the experiment pipeline; also the trie depth order.
STAGES = ("corpus", "embed", "sample", "index", "search", "metric")


@dataclasses.dataclass(frozen=True)
class GridSpec:
    """Declarative (sampler × engine × k × metric) experiment grid."""

    samplers: Tuple[str, ...] = ("full", "uniform", "windtunnel")
    engines: Tuple[str, ...] = ("exact", "ivfflat", "lsh", "tfidf")
    ks: Tuple[int, ...] = (3, 10)
    metrics: Tuple[str, ...] = ("precision", "recall", "ndcg", "mrr")
    sample_frac: float = 0.15     # sample size as a fraction of judged corpus
    max_queries: int = 512        # per-sample query subsample cap
    seed: int = 0

    @property
    def num_cells(self) -> int:
        return (len(self.samplers) * len(self.engines) * len(self.ks)
                * len(self.metrics))


@dataclasses.dataclass(frozen=True)
class RunSpec:
    """One grid cell: a full root-to-leaf path through the stage trie."""

    sampler: str
    engine: str
    k: int
    metric: str

    def path(self) -> Tuple[tuple, ...]:
        """Stage segments in trie order; prefixes shared between cells that
        agree on the leading coordinates."""
        return (("corpus",), ("embed",), ("sample", self.sampler),
                ("index", self.engine), ("search", self.k),
                ("metric", self.metric))

    @property
    def key(self) -> Tuple[str, str, int, str]:
        return (self.sampler, self.engine, self.k, self.metric)


def expand_grid(spec: GridSpec) -> List[RunSpec]:
    """Cross product of the grid axes, in deterministic order."""
    return [RunSpec(s, e, k, m) for s, e, k, m in itertools.product(
        spec.samplers, spec.engines, spec.ks, spec.metrics)]


@dataclasses.dataclass
class PlanNode:
    path: Tuple[tuple, ...]
    stage: str
    value: Any = None
    executions: int = 0   # times the stage fn actually ran (0 or 1)
    requests: int = 0     # times a cell walk touched this node


class PlanTrie:
    """Path-keyed stage cache: each node computes once, later walks hit.

    Counters are kept in a per-trie metrics :class:`Registry` (isolated,
    so parallel tries / repeated grids never cross-count) as
    ``plan.requests.<stage>`` / ``plan.executions.<stage>``;
    ``stage_counts()`` re-exports them in the legacy dict shape (parity
    with the per-node sums is enforced by tests/test_obs.py).
    """

    def __init__(self, metrics: Registry | None = None):
        self.nodes: Dict[Tuple[tuple, ...], PlanNode] = {}
        self._order: List[Tuple[tuple, ...]] = []
        self.metrics = metrics if metrics is not None else Registry()

    @staticmethod
    def _node_label(path: Tuple[tuple, ...]) -> str:
        return "/".join("-".join(str(p) for p in seg) for seg in path)

    def run(self, path: Tuple[tuple, ...], fn: Callable[[], Any]) -> Any:
        node = self.nodes.get(path)
        if node is None:
            node = PlanNode(path=path, stage=path[-1][0])
            self.nodes[path] = node
            self._order.append(path)
        node.requests += 1
        self.metrics.counter(f"plan.requests.{node.stage}").inc()
        if node.executions == 0:
            with trace.span(f"eval.{node.stage}", stage=node.stage,
                            node=self._node_label(path)):
                node.value = fn()
            node.executions = 1
            self.metrics.counter(f"plan.executions.{node.stage}").inc()
        return node.value

    def stage_counts(self) -> Dict[str, Tuple[int, int]]:
        """stage -> (executions, requests), read from the registry
        counters in first-touch stage order (the legacy shape)."""
        counters = self.metrics.snapshot()["counters"]
        out: Dict[str, Tuple[int, int]] = {}
        for path in self._order:
            stage = self.nodes[path].stage
            if stage not in out:
                out[stage] = (counters.get(f"plan.executions.{stage}", 0),
                              counters.get(f"plan.requests.{stage}", 0))
        return out

    def summary(self) -> str:
        lines = ["stage      executed  requested  shared"]
        for stage in STAGES:
            if stage not in self.stage_counts():
                continue
            ex, rq = self.stage_counts()[stage]
            lines.append(f"{stage:<10s} {ex:8d} {rq:10d} {rq - ex:7d}")
        return "\n".join(lines)


def execute_plan(runs: List[RunSpec],
                 stage_fns: Mapping[str, Callable[[Any, RunSpec], Any]],
                 trie: PlanTrie | None = None
                 ) -> Tuple[Dict[Tuple[str, str, int, str], Any], PlanTrie]:
    """Walk every run root-to-leaf through the trie.

    ``stage_fns[stage](parent_value, run)`` computes a node's value from its
    parent's; it runs only on the first walk that reaches the node.  Returns
    the leaf (metric) value per cell key plus the trie with its counters.
    """
    trie = trie if trie is not None else PlanTrie()
    results: Dict[Tuple[str, str, int, str], Any] = {}
    for run in runs:
        value: Any = None
        prefix: Tuple[tuple, ...] = ()
        for seg in run.path():
            prefix = prefix + (seg,)
            fn = stage_fns[seg[0]]
            parent = value
            value = trie.run(
                prefix, lambda fn=fn, parent=parent, run=run: fn(parent, run))
        results[run.key] = value
    return results, trie
