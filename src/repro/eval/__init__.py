"""Experiment-grid evaluation subsystem (DESIGN.md §8).

The paper's value claim is that a community-aware sample preserves the
*conclusions* of end-to-end IR experiments, not just headline numbers.  This
package turns that claim into a measurable artifact:

* ``engines``  — a :class:`RetrievalEngine` registry (exact / ivfflat / lsh /
  tfidf) behind one ``build``/``search`` protocol, mirroring the LP-engine
  registry in ``core/engines.py``.
* ``plans``    — declarative (sampler × engine × k × metric) grids expanded
  into a stage trie (corpus → embed → sample → index → search → metric);
  shared prefixes execute exactly once, with per-node counters.
* ``runner``   — stage implementations walking each grid cell through the
  trie over a :class:`~repro.data.synthetic.SyntheticCorpus`.
* ``fidelity`` — per-metric deltas of each sampler vs the full corpus and
  Kendall-τ preservation of the engine ranking (does the sample pick the
  same winning index as the full corpus? — the question of paper §I).
"""
from repro.eval.engines import (RetrievalEngine, available_retrieval_engines,
                                get_retrieval_engine, register_retrieval_engine)
from repro.core.samplers import get_sampler
from repro.core.sampling_core import SamplerSession, SamplerSpec
from repro.retrieval.backends import available_backends, get_backend
from repro.retrieval.search_core import SearchConfig, SearchSession
from repro.eval.fidelity import (FidelityReport, backend_recall_curve,
                                 build_fidelity_report, format_backend_curve,
                                 format_fidelity_report, kendall_tau)
from repro.eval.plans import (GridSpec, PlanTrie, RunSpec, execute_plan,
                              expand_grid)
from repro.eval.runner import (GridResult, available_samplers, run_grid,
                               tfidf_embedder)

__all__ = [
    "RetrievalEngine", "available_retrieval_engines", "get_retrieval_engine",
    "register_retrieval_engine",
    "get_sampler", "SamplerSpec", "SamplerSession",
    "available_backends", "get_backend", "SearchConfig", "SearchSession",
    "GridSpec", "RunSpec", "PlanTrie", "expand_grid", "execute_plan",
    "GridResult", "run_grid", "tfidf_embedder", "available_samplers",
    "FidelityReport", "build_fidelity_report", "format_fidelity_report",
    "kendall_tau", "backend_recall_curve", "format_backend_curve",
]
