"""Sample-fidelity report — does the sample preserve experimental
conclusions? (paper §I).

Two views on a finished :class:`~repro.eval.runner.GridResult`:

* **Metric deltas** — per cell, value(sampler) − value(baseline) for the
  same (engine, k, metric); aggregated to mean |Δ| per (sampler, metric).
  Small deltas mean absolute numbers survive sampling.
* **System-ranking preservation** — for each (metric, k) the grid induces a
  ranking of retrieval engines; Kendall-τ between each sampler's ranking
  and the full corpus's, plus whether the *winning* engine agrees.  This is
  the question the paper's §I poses: can the cheap sample pick the same
  winning system as the full corpus would?
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Sequence, Tuple

import numpy as np

from repro.eval.plans import GridSpec


def kendall_tau(a: Sequence[float], b: Sequence[float]) -> float:
    """Kendall's τ-b between two score vectors over the same systems
    (tie-corrected; O(n²), fine for system-ranking sized inputs)."""
    a = np.asarray(a, float)
    b = np.asarray(b, float)
    if a.size != b.size:
        raise ValueError(f"score vectors differ in length: {a.size} vs {b.size}")
    conc = disc = ties_a = ties_b = 0
    for i in range(a.size):
        for j in range(i + 1, a.size):
            sa = np.sign(a[i] - a[j])
            sb = np.sign(b[i] - b[j])
            if sa == 0 and sb == 0:
                continue
            elif sa == 0:
                ties_a += 1
            elif sb == 0:
                ties_b += 1
            elif sa == sb:
                conc += 1
            else:
                disc += 1
    denom = np.sqrt(float(conc + disc + ties_a) * float(conc + disc + ties_b))
    return float((conc - disc) / denom) if denom > 0 else 0.0


@dataclasses.dataclass
class FidelityReport:
    baseline: str
    #: (sampler, engine, k, metric) -> value(sampler) - value(baseline)
    cell_deltas: Dict[Tuple[str, str, int, str], float]
    #: (sampler, metric) -> mean |delta| over engines and ks
    mean_abs_delta: Dict[Tuple[str, str], float]
    #: (sampler, metric) -> mean Kendall-tau over ks vs the baseline ranking
    tau: Dict[Tuple[str, str], float]
    #: (sampler, metric) -> engine with the best mean-over-k score
    winners: Dict[Tuple[str, str], str]
    #: (sampler, metric) -> winner matches the baseline's winner
    winner_agreement: Dict[Tuple[str, str], bool]

    def to_json(self) -> dict:
        return {
            "baseline": self.baseline,
            "cell_deltas": [{"sampler": s, "engine": e, "k": k, "metric": m,
                             "delta": d}
                            for (s, e, k, m), d
                            in sorted(self.cell_deltas.items())],
            "mean_abs_delta": [{"sampler": s, "metric": m, "value": v}
                               for (s, m), v
                               in sorted(self.mean_abs_delta.items())],
            "kendall_tau": [{"sampler": s, "metric": m, "value": v}
                            for (s, m), v in sorted(self.tau.items())],
            "winners": [{"sampler": s, "metric": m, "engine": e,
                         "agrees_with_baseline":
                             self.winner_agreement.get((s, m), True)}
                        for (s, m), e in sorted(self.winners.items())],
        }


def _engine_scores(cells, sampler: str, metric: str, k: int,
                   engines: Sequence[str]):
    return [cells[(sampler, e, k, metric)] for e in engines]


def build_fidelity_report(cells: Dict[Tuple[str, str, int, str], float],
                          spec: GridSpec, *, baseline: str = "full"
                          ) -> FidelityReport:
    if baseline not in spec.samplers:
        raise ValueError(f"baseline sampler {baseline!r} not in grid "
                         f"{spec.samplers}")
    others = [s for s in spec.samplers if s != baseline]

    cell_deltas = {}
    for s in others:
        for e in spec.engines:
            for k in spec.ks:
                for m in spec.metrics:
                    cell_deltas[(s, e, k, m)] = (
                        cells[(s, e, k, m)] - cells[(baseline, e, k, m)])

    mean_abs_delta = {}
    for s in others:
        for m in spec.metrics:
            ds = [abs(cell_deltas[(s, e, k, m)])
                  for e in spec.engines for k in spec.ks]
            mean_abs_delta[(s, m)] = float(np.mean(ds))

    tau = {}
    for s in others:
        for m in spec.metrics:
            taus = [kendall_tau(
                _engine_scores(cells, s, m, k, spec.engines),
                _engine_scores(cells, baseline, m, k, spec.engines))
                for k in spec.ks]
            tau[(s, m)] = float(np.mean(taus))

    winners = {}
    for s in spec.samplers:
        for m in spec.metrics:
            mean_over_k = [np.mean([cells[(s, e, k, m)] for k in spec.ks])
                           for e in spec.engines]
            winners[(s, m)] = spec.engines[int(np.argmax(mean_over_k))]
    winner_agreement = {(s, m): winners[(s, m)] == winners[(baseline, m)]
                        for s in others for m in spec.metrics}

    return FidelityReport(baseline, cell_deltas, mean_abs_delta, tau,
                          winners, winner_agreement)


def format_fidelity_report(report: FidelityReport, spec: GridSpec) -> str:
    """Human-readable fidelity table, one block per non-baseline sampler."""
    others = [s for s in spec.samplers if s != report.baseline]
    lines = [f"sample-fidelity report (baseline: {report.baseline})",
             ""]
    for s in others:
        lines.append(f"[{s}]")
        lines.append(f"  {'metric':<10s} {'mean|Δ|':>8s} {'τ(rank)':>8s} "
                     f"{'winner':>8s}  agrees")
        for m in spec.metrics:
            win = report.winners[(s, m)]
            agree = "yes" if report.winner_agreement[(s, m)] else "NO"
            lines.append(f"  {m:<10s} {report.mean_abs_delta[(s, m)]:8.4f} "
                         f"{report.tau[(s, m)]:8.3f} {win:>8s}  {agree}")
        lines.append("")
    base = ", ".join(f"{m}:{report.winners[(report.baseline, m)]}"
                     for m in spec.metrics)
    lines.append(f"baseline winners — {base}")
    return "\n".join(lines)
