"""Sample-fidelity report — does the sample preserve experimental
conclusions? (paper §I).

Two views on a finished :class:`~repro.eval.runner.GridResult`:

* **Metric deltas** — per cell, value(sampler) − value(baseline) for the
  same (engine, k, metric); aggregated to mean |Δ| per (sampler, metric).
  Small deltas mean absolute numbers survive sampling.
* **System-ranking preservation** — for each (metric, k) the grid induces a
  ranking of retrieval engines; Kendall-τ between each sampler's ranking
  and the full corpus's, plus whether the *winning* engine agrees.  This is
  the question the paper's §I poses: can the cheap sample pick the same
  winning system as the full corpus would?

Plus one backend-level view, :func:`backend_recall_curve`: recall@k vs
wall-clock of every scoring backend against the exact ``jnp`` oracle on
the same vectors — for the ``int8`` backend swept over ``rerank_factor``,
so the quantized backend's recall-vs-speed trade is part of the report
(the engine-ranking question, one layer down).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.eval.plans import GridSpec


def kendall_tau(a: Sequence[float], b: Sequence[float]) -> float:
    """Kendall's τ-b between two score vectors over the same systems
    (tie-corrected; O(n²), fine for system-ranking sized inputs)."""
    a = np.asarray(a, float)
    b = np.asarray(b, float)
    if a.size != b.size:
        raise ValueError(f"score vectors differ in length: {a.size} vs {b.size}")
    conc = disc = ties_a = ties_b = 0
    for i in range(a.size):
        for j in range(i + 1, a.size):
            sa = np.sign(a[i] - a[j])
            sb = np.sign(b[i] - b[j])
            if sa == 0 and sb == 0:
                continue
            elif sa == 0:
                ties_a += 1
            elif sb == 0:
                ties_b += 1
            elif sa == sb:
                conc += 1
            else:
                disc += 1
    denom = np.sqrt(float(conc + disc + ties_a) * float(conc + disc + ties_b))
    return float((conc - disc) / denom) if denom > 0 else 0.0


@dataclasses.dataclass
class FidelityReport:
    baseline: str
    #: (sampler, engine, k, metric) -> value(sampler) - value(baseline)
    cell_deltas: Dict[Tuple[str, str, int, str], float]
    #: (sampler, metric) -> mean |delta| over engines and ks
    mean_abs_delta: Dict[Tuple[str, str], float]
    #: (sampler, metric) -> mean Kendall-tau over ks vs the baseline ranking
    tau: Dict[Tuple[str, str], float]
    #: (sampler, metric) -> engine with the best mean-over-k score
    winners: Dict[Tuple[str, str], str]
    #: (sampler, metric) -> winner matches the baseline's winner
    winner_agreement: Dict[Tuple[str, str], bool]

    def to_json(self) -> dict:
        return {
            "baseline": self.baseline,
            "cell_deltas": [{"sampler": s, "engine": e, "k": k, "metric": m,
                             "delta": d}
                            for (s, e, k, m), d
                            in sorted(self.cell_deltas.items())],
            "mean_abs_delta": [{"sampler": s, "metric": m, "value": v}
                               for (s, m), v
                               in sorted(self.mean_abs_delta.items())],
            "kendall_tau": [{"sampler": s, "metric": m, "value": v}
                            for (s, m), v in sorted(self.tau.items())],
            "winners": [{"sampler": s, "metric": m, "engine": e,
                         "agrees_with_baseline":
                             self.winner_agreement.get((s, m), True)}
                        for (s, m), e in sorted(self.winners.items())],
        }


def _engine_scores(cells, sampler: str, metric: str, k: int,
                   engines: Sequence[str]):
    return [cells[(sampler, e, k, metric)] for e in engines]


def build_fidelity_report(cells: Dict[Tuple[str, str, int, str], float],
                          spec: GridSpec, *, baseline: str = "full"
                          ) -> FidelityReport:
    if baseline not in spec.samplers:
        raise ValueError(f"baseline sampler {baseline!r} not in grid "
                         f"{spec.samplers}")
    others = [s for s in spec.samplers if s != baseline]

    cell_deltas = {}
    for s in others:
        for e in spec.engines:
            for k in spec.ks:
                for m in spec.metrics:
                    cell_deltas[(s, e, k, m)] = (
                        cells[(s, e, k, m)] - cells[(baseline, e, k, m)])

    mean_abs_delta = {}
    for s in others:
        for m in spec.metrics:
            ds = [abs(cell_deltas[(s, e, k, m)])
                  for e in spec.engines for k in spec.ks]
            mean_abs_delta[(s, m)] = float(np.mean(ds))

    tau = {}
    for s in others:
        for m in spec.metrics:
            taus = [kendall_tau(
                _engine_scores(cells, s, m, k, spec.engines),
                _engine_scores(cells, baseline, m, k, spec.engines))
                for k in spec.ks]
            tau[(s, m)] = float(np.mean(taus))

    winners = {}
    for s in spec.samplers:
        for m in spec.metrics:
            mean_over_k = [np.mean([cells[(s, e, k, m)] for k in spec.ks])
                           for e in spec.engines]
            winners[(s, m)] = spec.engines[int(np.argmax(mean_over_k))]
    winner_agreement = {(s, m): winners[(s, m)] == winners[(baseline, m)]
                        for s in others for m in spec.metrics}

    return FidelityReport(baseline, cell_deltas, mean_abs_delta, tau,
                          winners, winner_agreement)


def backend_recall_curve(corpus_vecs, queries, *, k: int = 10,
                         rerank_factors: Sequence[int] = (1, 2, 4, 8),
                         timing_iters: int = 3) -> List[dict]:
    """Recall@k + us/query-batch of every scoring backend vs the exact
    ``jnp`` oracle, the ``int8`` backend swept over ``rerank_factor``
    (its recall-vs-speed knob).  Corpus preparation (quantization) is
    excluded from the timing — it is a build-time cost.

    Returns one row dict per point: ``{"backend", "rerank_factor",
    "recall_at_k", "us_per_call"}`` (rerank_factor is None for the float
    backends, whose recall is 1.0 by construction/parity)."""
    import jax
    from repro.retrieval.backends import available_backends, get_backend

    k = min(k, int(corpus_vecs.shape[0]))
    exact = np.asarray(get_backend("jnp").topk(queries, corpus_vecs, k=k)[1])

    def _point(backend, label, rf):
        prepared = backend.prepare_corpus(corpus_vecs)
        ids = np.asarray(backend.topk(queries, prepared, k=k)[1])
        hits = [len(set(a.tolist()) & set(b.tolist())) / max(k, 1)
                for a, b in zip(ids, exact)]
        fn = lambda: backend.topk(queries, prepared, k=k)[1]
        jax.block_until_ready(fn())
        t0 = time.time()
        for _ in range(timing_iters):
            jax.block_until_ready(fn())
        us = (time.time() - t0) / timing_iters * 1e6
        return {"backend": label, "rerank_factor": rf,
                "recall_at_k": float(np.mean(hits)),
                "us_per_call": float(us)}

    rows = []
    for name in available_backends():
        backend = get_backend(name)
        if name == "int8":
            for rf in rerank_factors:
                rows.append(_point(
                    dataclasses.replace(backend, rerank_factor=rf),
                    name, rf))
        else:
            rows.append(_point(backend, name, None))
    return rows


def format_backend_curve(rows: Sequence[dict], *, k: int = 10) -> str:
    """Human-readable recall-vs-speed block for the fidelity output."""
    lines = [f"backend recall-vs-speed (recall@{k} vs jnp exact)",
             f"  {'backend':<10s} {'rerank':>6s} {'recall':>8s} "
             f"{'us/call':>10s}"]
    for r in rows:
        rf = "-" if r["rerank_factor"] is None else str(r["rerank_factor"])
        lines.append(f"  {r['backend']:<10s} {rf:>6s} "
                     f"{r['recall_at_k']:8.4f} {r['us_per_call']:10.1f}")
    return "\n".join(lines)


def format_fidelity_report(report: FidelityReport, spec: GridSpec) -> str:
    """Human-readable fidelity table, one block per non-baseline sampler."""
    others = [s for s in spec.samplers if s != report.baseline]
    lines = [f"sample-fidelity report (baseline: {report.baseline})",
             ""]
    for s in others:
        lines.append(f"[{s}]")
        lines.append(f"  {'metric':<10s} {'mean|Δ|':>8s} {'τ(rank)':>8s} "
                     f"{'winner':>8s}  agrees")
        for m in spec.metrics:
            win = report.winners[(s, m)]
            agree = "yes" if report.winner_agreement[(s, m)] else "NO"
            lines.append(f"  {m:<10s} {report.mean_abs_delta[(s, m)]:8.4f} "
                         f"{report.tau[(s, m)]:8.3f} {win:>8s}  {agree}")
        lines.append("")
    base = ", ".join(f"{m}:{report.winners[(report.baseline, m)]}"
                     for m in spec.metrics)
    lines.append(f"baseline winners — {base}")
    return "\n".join(lines)
