"""Pure-jnp oracle for the flash_attention kernel (naive softmax attention
with GQA + causal/window masks)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def flash_attention_ref(q, k, v, *, causal: bool = True, window=None):
    b, sq, h, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    qh = q.reshape(b, sq, hkv, g, d)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qh, k).astype(jnp.float32)
    s = s / np.sqrt(d)
    qp = jnp.arange(sq)[:, None]
    kp = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kp <= qp
    if window is not None:
        mask &= kp > qp - window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v)
    return out.reshape(b, sq, h, d)
