"""Flash-attention Pallas kernel (GQA, causal / bidirectional / sliding
window), TPU-tiled.

Grid = (batch*kv_heads*group, q_blocks, kv_blocks) with the kv dimension
'arbitrary' (sequential): online-softmax statistics (m, l, acc) persist in
VMEM scratch across kv steps and the output block is written on the last
step. Q/K/V stream through VMEM in (block_q, d) / (block_kv, d) tiles —
(S, S) scores never touch HBM, which is the whole point: at 32k context the
naive score matrix is ~4GB per (batch, head) while VMEM tiles are ~1MB.

MXU alignment: block_q/block_kv default to 128-multiples; d_head is padded
to 128 by ops.py if needed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  block_q: int, block_kv: int, n_kv: int, causal: bool,
                  window, scale: float):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                                   # (bq, d)
    k = k_ref[0]                                   # (bkv, d)
    v = v_ref[0]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    q_pos = qi * block_q + lax.broadcasted_iota(jnp.int32, s.shape, 0)
    k_pos = kj * block_kv + lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, _NEG_INF)

    m_prev, l_prev, acc_prev = m_scr[...], l_scr[...], acc_scr[...]
    m_cur = jnp.max(s, axis=1)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_new = l_prev * alpha + jnp.sum(p, axis=1)
    acc_new = acc_prev * alpha[:, None] + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc_new

    @pl.when(kj == n_kv - 1)
    def _emit():
        out = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "block_q", "block_kv",
                              "interpret", "scale"))
def flash_attention_pallas(q, k, v, *, causal: bool = True, window=None,
                           block_q: int = 128, block_kv: int = 128,
                           interpret: bool = False, scale=None):
    """q: (B, Sq, H, D), k/v: (B, Skv, Hkv, D) with H % Hkv == 0.
    Returns (B, Sq, H, D). Sq % block_q == 0, Skv % block_kv == 0."""
    b, sq, h, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    scale = (1.0 / np.sqrt(d)) if scale is None else float(scale)
    nq, nkv = sq // block_q, skv // block_kv

    # layout: fold (b, hkv, g) into one parallel grid axis
    qf = q.reshape(b, sq, hkv, g, d).transpose(0, 2, 3, 1, 4) \
          .reshape(b * hkv * g, sq, d)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3).reshape(b * hkv, skv, d), g,
                    axis=0)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3).reshape(b * hkv, skv, d), g,
                    axis=0)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, block_q=block_q, block_kv=block_kv,
                          n_kv=nkv, causal=causal, window=window,
                          scale=scale),
        grid=(b * h, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, block_kv, d), lambda bh, i, j: (bh, j, 0)),
            pl.BlockSpec((1, block_kv, d), lambda bh, i, j: (bh, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),        # m
            pltpu.VMEM((block_q,), jnp.float32),        # l
            pltpu.VMEM((block_q, d), jnp.float32),      # acc
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, hkv, g, sq, d).transpose(0, 3, 1, 2, 4) \
              .reshape(b, sq, h, d)
