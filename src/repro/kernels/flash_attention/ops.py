"""Dispatch wrapper for flash_attention: pads seq lengths to block
multiples (with masking via window/causal semantics preserved), pads d_head
to the 128-lane MXU width, interpret mode off-TPU."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_kv"))
def flash_attention(q, k, v, q_pos=None, k_pos=None, *, causal: bool = True,
                    window=None, block_q: int = 128, block_kv: int = 128):
    """Drop-in for models.transformer.attention (self-attention case:
    q_pos == k_pos == arange)."""
    b, sq, h, d = q.shape
    skv = k.shape[1]
    bq = min(block_q, max(16, sq))
    bkv = min(block_kv, max(16, skv))
    pad_q = (-sq) % bq
    pad_kv = (-skv) % bkv
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    if pad_kv and not causal:
        # bidirectional: padded keys must not attend — give them -inf via a
        # sentinel window... simplest correct: fall back to masking by
        # causal=False + explicit slice; padded KEYS only matter if real
        # queries can see them, so zero-vector keys contribute exp(s)=1
        # uniformly. Use the sentinel-dim trick instead:
        kp = jnp.concatenate([kp, jnp.zeros_like(kp[:, :, :, :1])], -1)
        kp = kp.at[:, skv:, :, -1].set(-1e4)
        qp = jnp.concatenate([qp, jnp.ones_like(qp[:, :, :, :1])], -1)
        vp = jnp.pad(vp, ((0, 0), (0, 0), (0, 0), (0, 1)))
    out = flash_attention_pallas(qp, kp, vp, causal=causal, window=window,
                                 block_q=bq, block_kv=bkv,
                                 interpret=not _on_tpu(),
                                 scale=1.0 / (d ** 0.5))
    if pad_kv and not causal:
        out = out[..., :d]
    return out[:, :sq]
