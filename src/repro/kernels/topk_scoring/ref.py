"""Pure-jnp oracle for the topk_scoring kernel."""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def pad_topk(s: jnp.ndarray, i: jnp.ndarray, k: int):
    """Pad (Q, k_eff) top-k results back to (Q, k) with the miss
    convention every scoring path shares: score −inf, id −1.  The single
    definition of that convention — kernel dispatch wrappers, the backend
    registry and the sharded merge all import it."""
    k_eff = s.shape[1]
    if k_eff >= k:
        return s, i
    return (jnp.pad(s, ((0, 0), (0, k - k_eff)),
                    constant_values=-jnp.inf),
            jnp.pad(i, ((0, 0), (0, k - k_eff)), constant_values=-1))


def topk_scores_ref(queries: jnp.ndarray, corpus: jnp.ndarray, *, k: int):
    scores = (queries @ corpus.T).astype(jnp.float32)
    top_s, top_i = lax.top_k(scores, k)
    return top_s, top_i.astype(jnp.int32)


def topk_scores_int8_ref(q_codes: jnp.ndarray, c_codes: jnp.ndarray, *,
                         k: int):
    """int8-code oracle: exact int32 dot (|dot| ≤ 127²·D < 2³¹ for any
    realistic D), ranked as f32 like the kernel's partials."""
    scores = jnp.dot(q_codes.astype(jnp.int32), c_codes.astype(jnp.int32).T)
    top_s, top_i = lax.top_k(scores.astype(jnp.float32), k)
    return top_s, top_i.astype(jnp.int32)


def gathered_topk_ref(queries: jnp.ndarray, cand_vecs: jnp.ndarray,
                      cand_ids: jnp.ndarray, *, k: int):
    """Per-query candidate sets: queries (Q, D), cand_vecs (Q, C, D),
    cand_ids (Q, C) with −1 marking invalid slots -> top-k (scores, ids),
    invalid slots scored −inf and returned as id −1."""
    s = jnp.einsum("qd,qcd->qc", queries, cand_vecs).astype(jnp.float32)
    s = jnp.where(cand_ids >= 0, s, -jnp.inf)
    top_s, pos = lax.top_k(s, k)
    top_i = jnp.take_along_axis(cand_ids, pos, axis=1).astype(jnp.int32)
    return top_s, jnp.where(jnp.isfinite(top_s), top_i, -1)
