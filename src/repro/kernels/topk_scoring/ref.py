"""Pure-jnp oracle for the topk_scoring kernel."""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def topk_scores_ref(queries: jnp.ndarray, corpus: jnp.ndarray, *, k: int):
    scores = (queries @ corpus.T).astype(jnp.float32)
    top_s, top_i = lax.top_k(scores, k)
    return top_s, top_i.astype(jnp.int32)
