"""Fused block scoring + per-block top-k Pallas kernel.

Roofline motivation: brute-force candidate scoring is HBM-bound on the
(Q, N) score matrix. Fusing the top-k selection into the scoring block keeps
scores in VMEM and writes only (Q, n_blocks*k) partials back to HBM — an
N/(n_blocks*k) reduction in output traffic; the final cross-block merge is
negligible. Candidate blocks stream through VMEM sized by BlockSpec.

Top-k inside the kernel is k rounds of (max, argmax, mask) on the VMEM
score block — branch-free VPU code, no sort network needed for the small k
(<=32) used by ANN probes (paper's p@3 needs k=3).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _topk_kernel(q_ref, c_ref, s_out_ref, i_out_ref, *, k: int, block_n: int):
    j = pl.program_id(1)                       # candidate-block index
    q = q_ref[...]                             # (bq, d)
    c = c_ref[...]                             # (bn, d)
    scores = jnp.dot(q, c.T,
                     preferred_element_type=jnp.float32)   # (bq, bn) in VMEM
    bq = scores.shape[0]

    def body(i, carry):
        scores, out_s, out_i = carry
        m = jnp.max(scores, axis=1)                        # (bq,)
        arg = jnp.argmax(scores, axis=1).astype(jnp.int32)  # (bq,)
        out_s = lax.dynamic_update_slice(out_s, m[:, None], (0, i))
        out_i = lax.dynamic_update_slice(
            out_i, (j * block_n + arg)[:, None], (0, i))
        # mask the extracted maximum for the next round
        hit = lax.broadcasted_iota(jnp.int32, scores.shape, 1) == arg[:, None]
        return jnp.where(hit, -jnp.inf, scores), out_s, out_i

    out_s = jnp.full((bq, k), -jnp.inf, jnp.float32)
    out_i = jnp.full((bq, k), -1, jnp.int32)
    _, out_s, out_i = lax.fori_loop(0, k, body, (scores, out_s, out_i))
    s_out_ref[...] = out_s
    i_out_ref[...] = out_i


def _topk_int8_kernel(q_ref, c_ref, s_out_ref, i_out_ref, *, k: int,
                      block_n: int, n_valid: int):
    """int8 variant: codes dot in int8 with an int32 accumulator (the MXU's
    quantized path on TPU), ranking on the raw integer dot — the global
    query/corpus scales are positive constants, so the int32 order equals
    the dequantized order.  Padding is masked by true row count
    (``n_valid``), the lsh kernel's scheme — an int8 sentinel coordinate
    can't work, the widest code is ±127."""
    j = pl.program_id(1)
    q = q_ref[...]                             # (bq, d) int8
    c = c_ref[...]                             # (bn, d) int8
    scores = lax.dot_general(
        q, c, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32).astype(jnp.float32)
    ids = j * block_n + lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    scores = jnp.where(ids < n_valid, scores, -jnp.inf)
    bq = scores.shape[0]

    def body(i, carry):
        scores, out_s, out_i = carry
        m = jnp.max(scores, axis=1)
        arg = jnp.argmax(scores, axis=1).astype(jnp.int32)
        out_s = lax.dynamic_update_slice(out_s, m[:, None], (0, i))
        out_i = lax.dynamic_update_slice(
            out_i, (j * block_n + arg)[:, None], (0, i))
        hit = lax.broadcasted_iota(jnp.int32, scores.shape, 1) == arg[:, None]
        return jnp.where(hit, -jnp.inf, scores), out_s, out_i

    out_s = jnp.full((bq, k), -jnp.inf, jnp.float32)
    out_i = jnp.full((bq, k), -1, jnp.int32)
    _, out_s, out_i = lax.fori_loop(0, k, body, (scores, out_s, out_i))
    s_out_ref[...] = out_s
    i_out_ref[...] = out_i


def _gathered_kernel(q_ref, c_ref, i_ref, s_out_ref, i_out_ref, *, k: int):
    """Per-query candidate scoring: each query row scores ITS OWN candidate
    block (the ivfflat probe gather), so the dot is a batched row-wise
    reduction on the VPU rather than an MXU matmul; the running top-k is the
    same k-round max/mask extraction as _topk_kernel."""
    q = q_ref[...]                              # (bq, d)
    c = c_ref[...]                              # (bq, bc, d)
    ids = i_ref[...]                            # (bq, bc) int32, -1 invalid
    scores = jnp.sum(q[:, None, :] * c, axis=-1,
                     dtype=jnp.float32)         # (bq, bc)
    scores = jnp.where(ids >= 0, scores, -jnp.inf)

    def body(i, carry):
        scores, out_s, out_i = carry
        m = jnp.max(scores, axis=1)
        arg = jnp.argmax(scores, axis=1).astype(jnp.int32)
        col = lax.broadcasted_iota(jnp.int32, scores.shape, 1)
        hit = col == arg[:, None]
        # id extraction without a dynamic gather: mask-select the argmax col
        idv = jnp.sum(jnp.where(hit, ids, 0), axis=1)
        idv = jnp.where(jnp.isfinite(m), idv, -1)
        out_s = lax.dynamic_update_slice(out_s, m[:, None], (0, i))
        out_i = lax.dynamic_update_slice(out_i, idv[:, None], (0, i))
        return jnp.where(hit, -jnp.inf, scores), out_s, out_i

    out_s = jnp.full((q.shape[0], k), -jnp.inf, jnp.float32)
    out_i = jnp.full((q.shape[0], k), -1, jnp.int32)
    _, out_s, out_i = lax.fori_loop(0, k, body, (scores, out_s, out_i))
    s_out_ref[...] = out_s
    i_out_ref[...] = out_i


@functools.partial(jax.jit,
                   static_argnames=("k", "block_q", "block_c", "interpret"))
def gathered_topk_pallas(queries: jnp.ndarray, cand_vecs: jnp.ndarray,
                         cand_ids: jnp.ndarray, *, k: int, block_q: int = 8,
                         block_c: int = 256, interpret: bool = False):
    """queries (Q, D) f32, cand_vecs (Q, C, D) f32, cand_ids (Q, C) i32
    (−1 = invalid slot) -> (scores (Q, k), ids (Q, k)).

    Q must be a multiple of block_q and C of block_c (ops.py pads).
    """
    qn, d = queries.shape
    c = cand_vecs.shape[1]
    nq, nc = qn // block_q, c // block_c

    partial_s, partial_i = pl.pallas_call(
        functools.partial(_gathered_kernel, k=k),
        grid=(nq, nc),
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_q, block_c, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((block_q, block_c), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, k), lambda i, j: (i, j)),
            pl.BlockSpec((block_q, k), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qn, nc * k), jnp.float32),
            jax.ShapeDtypeStruct((qn, nc * k), jnp.int32),
        ],
        interpret=interpret,
    )(queries, cand_vecs, cand_ids)

    top_s, pos = lax.top_k(partial_s, k)
    top_i = jnp.take_along_axis(partial_i, pos, axis=1)
    return top_s, jnp.where(jnp.isfinite(top_s), top_i, -1)


@functools.partial(jax.jit,
                   static_argnames=("k", "block_q", "block_n", "interpret"))
def topk_scores_pallas(queries: jnp.ndarray, corpus: jnp.ndarray, *, k: int,
                       block_q: int = 128, block_n: int = 1024,
                       interpret: bool = False):
    """queries (Q, D) f32, corpus (N, D) f32 ->
    (scores (Q, k), ids (Q, k)), inner-product metric.

    Q must be a multiple of block_q and N of block_n (ops.py pads).
    """
    qn, d = queries.shape
    n = corpus.shape[0]
    nq, nc = qn // block_q, n // block_n

    partial_s, partial_i = pl.pallas_call(
        functools.partial(_topk_kernel, k=k, block_n=block_n),
        grid=(nq, nc),
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, d), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, k), lambda i, j: (i, j)),
            pl.BlockSpec((block_q, k), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qn, nc * k), jnp.float32),
            jax.ShapeDtypeStruct((qn, nc * k), jnp.int32),
        ],
        interpret=interpret,
    )(queries, corpus)

    # cross-block merge of the (nc * k) partials per query
    top_s, pos = lax.top_k(partial_s, k)
    top_i = jnp.take_along_axis(partial_i, pos, axis=1)
    return top_s, top_i


@functools.partial(jax.jit,
                   static_argnames=("k", "block_q", "block_n", "interpret",
                                    "n_valid"))
def topk_scores_int8_pallas(q_codes: jnp.ndarray, c_codes: jnp.ndarray, *,
                            k: int, block_q: int = 128, block_n: int = 1024,
                            interpret: bool = False, n_valid: int = None):
    """q_codes (Q, D) i8, c_codes (N, D) i8 ->
    (int-dot scores as f32 (Q, k), ids (Q, k)).

    Q must be a multiple of block_q and N of block_n (ops.py pads; rows at
    or past ``n_valid`` are masked to −inf/−1 inside the kernel).
    """
    qn, d = q_codes.shape
    n = c_codes.shape[0]
    nq, nc = qn // block_q, n // block_n

    partial_s, partial_i = pl.pallas_call(
        functools.partial(_topk_int8_kernel, k=k, block_n=block_n,
                          n_valid=n if n_valid is None else n_valid),
        grid=(nq, nc),
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, d), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, k), lambda i, j: (i, j)),
            pl.BlockSpec((block_q, k), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qn, nc * k), jnp.float32),
            jax.ShapeDtypeStruct((qn, nc * k), jnp.int32),
        ],
        interpret=interpret,
    )(q_codes, c_codes)

    top_s, pos = lax.top_k(partial_s, k)
    top_i = jnp.take_along_axis(partial_i, pos, axis=1)
    return top_s, top_i
