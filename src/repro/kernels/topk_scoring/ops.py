"""jit'd dispatch wrapper for topk_scoring: pads to block multiples, selects
interpret mode off-TPU, falls back to the jnp oracle for k > 32 (the
repeated-max extraction stops paying for itself)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.topk_scoring import ref
from repro.kernels.topk_scoring.topk_scoring import topk_scores_pallas

_MAX_KERNEL_K = 32


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("k", "block_q", "block_n",
                                             "use_kernel"))
def topk_scores(queries: jnp.ndarray, corpus: jnp.ndarray, *, k: int,
                block_q: int = 128, block_n: int = 1024,
                use_kernel: bool = True):
    """Top-k inner-product search: (Q, D) x (N, D) -> (Q, k) scores/ids."""
    if not use_kernel or k > _MAX_KERNEL_K:
        return ref.topk_scores_ref(queries, corpus, k=k)
    qn, d = queries.shape
    n = corpus.shape[0]
    bq = min(block_q, max(8, qn))
    bn = min(block_n, max(128, n))
    pad_q = (-qn) % bq
    pad_n = (-n) % bn
    # sentinel coordinate: query coord 1, real candidates 0, padding -BIG —
    # padded rows then score -BIG and can never displace real candidates
    qp = jnp.pad(queries.astype(jnp.float32), ((0, pad_q), (0, 1)),
                 constant_values=1.0)
    qp = qp.at[:, d].set(1.0)
    cp = jnp.pad(corpus.astype(jnp.float32), ((0, pad_n), (0, 1)))
    if pad_n:
        cp = cp.at[n:, d].set(-1e30)
    s, i = topk_scores_pallas(qp, cp, k=k, block_q=bq, block_n=bn,
                              interpret=not _on_tpu())
    if pad_n:
        bad = i >= n
        s = jnp.where(bad, -jnp.inf, s)
        i = jnp.where(bad, -1, i)
    return s[:qn], i[:qn]
