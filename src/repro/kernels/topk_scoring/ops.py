"""Dispatch wrappers for topk_scoring: pad to block multiples, select
interpret mode off-TPU, fall back to the jnp oracle for k > 32 (the
repeated-max extraction stops paying for itself).

Shape contract (the engine path depends on it): any Q/N/C/k combination is
accepted — k is clamped to the candidate count, inputs are padded to block
multiples, and missing results come back as score −inf / id −1, so callers
never see a ``lax.top_k`` shape error from an undersized corpus.

Block sizes resolve through the autotuner table (kernels/tuning.py,
DESIGN.md §11): explicit kwarg > tuned entry for the corpus-size bucket >
hard-coded default.  Resolution happens in the plain-python outer wrappers,
BEFORE the inner jitted call — a lookup inside a jitted body would be baked
into the trace and go stale when the active table changes.  Blocks are also
clamped to the padded problem size (``_ceil8``), never floored up to a
128-wide block a small corpus then mostly wastes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import tuning
from repro.kernels.topk_scoring import ref
from repro.kernels.topk_scoring.ref import pad_topk as _pad_topk
from repro.kernels.topk_scoring.topk_scoring import (gathered_topk_pallas,
                                                     topk_scores_int8_pallas,
                                                     topk_scores_pallas)

_MAX_KERNEL_K = 32
# the int8 scan exists to feed a float rerank tail of rerank_factor*k
# candidates (typically 4*k > 32 for the paper's k=10), and its bandwidth
# win dominates the extra extraction rounds, so its kernel cap is higher
_MAX_KERNEL_K_INT8 = 64


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _ceil8(n: int) -> int:
    return max(8, ((n + 7) // 8) * 8)


def topk_scores(queries: jnp.ndarray, corpus: jnp.ndarray, *, k: int,
                block_q: int = None, block_n: int = None,
                use_kernel: bool = True):
    """Top-k inner-product search: (Q, D) x (N, D) -> (Q, k) scores/ids."""
    blocks = tuning.resolve("topk", n=corpus.shape[0], dtype=queries.dtype,
                            block_q=block_q, block_n=block_n)
    return _topk_scores(queries, corpus, k=k, use_kernel=use_kernel,
                        **blocks)


@functools.partial(jax.jit, static_argnames=("k", "block_q", "block_n",
                                             "use_kernel"))
def _topk_scores(queries: jnp.ndarray, corpus: jnp.ndarray, *, k: int,
                 block_q: int, block_n: int, use_kernel: bool):
    n = corpus.shape[0]
    k_eff = min(k, n)
    if not use_kernel or k_eff > _MAX_KERNEL_K:
        return _pad_topk(*ref.topk_scores_ref(queries, corpus, k=k_eff), k)
    qn, d = queries.shape
    bq = min(block_q, max(8, qn))
    bn = min(block_n, _ceil8(n))
    pad_q = (-qn) % bq
    pad_n = (-n) % bn
    # sentinel coordinate: query coord 1, real candidates 0, padding -BIG —
    # padded rows then score -BIG and can never displace real candidates
    qp = jnp.pad(queries.astype(jnp.float32), ((0, pad_q), (0, 1)),
                 constant_values=1.0)
    qp = qp.at[:, d].set(1.0)
    cp = jnp.pad(corpus.astype(jnp.float32), ((0, pad_n), (0, 1)))
    if pad_n:
        cp = cp.at[n:, d].set(-1e30)
    s, i = topk_scores_pallas(qp, cp, k=k_eff, block_q=bq, block_n=bn,
                              interpret=not _on_tpu())
    if pad_n:
        bad = i >= n
        s = jnp.where(bad, -jnp.inf, s)
        i = jnp.where(bad, -1, i)
    return _pad_topk(s[:qn], i[:qn], k)


def topk_scores_int8(q_codes: jnp.ndarray, c_codes: jnp.ndarray, *, k: int,
                     block_q: int = None, block_n: int = None,
                     use_kernel: bool = True):
    """Quantized top-k scan: int8 codes (Q, D) x (N, D) -> (Q, k) int-dot
    scores (as f32) and ids.  Ranking is scale-invariant — dequantizing by
    the global query/corpus scales multiplies every score by the same
    positive constant — so callers rank on the raw dot and rerank the
    winners in float (retrieval/backends.py Int8Backend)."""
    blocks = tuning.resolve("topk", n=c_codes.shape[0], dtype="int8",
                            block_q=block_q, block_n=block_n)
    return _topk_scores_int8(q_codes, c_codes, k=k, use_kernel=use_kernel,
                             **blocks)


@functools.partial(jax.jit, static_argnames=("k", "block_q", "block_n",
                                             "use_kernel"))
def _topk_scores_int8(q_codes: jnp.ndarray, c_codes: jnp.ndarray, *, k: int,
                      block_q: int, block_n: int, use_kernel: bool):
    n = c_codes.shape[0]
    k_eff = min(k, n)
    if not use_kernel or k_eff > _MAX_KERNEL_K_INT8:
        return _pad_topk(
            *ref.topk_scores_int8_ref(q_codes, c_codes, k=k_eff), k)
    qn = q_codes.shape[0]
    bq = min(block_q, max(8, qn))
    bn = min(block_n, _ceil8(n))
    pad_q = (-qn) % bq
    pad_n = (-n) % bn
    # zero-padding only: padded rows are masked by n_valid INSIDE the
    # kernel (the lsh scheme) — an int8 sentinel coordinate can't dominate
    qp = jnp.pad(q_codes, ((0, pad_q), (0, 0)))
    cp = jnp.pad(c_codes, ((0, pad_n), (0, 0)))
    s, i = topk_scores_int8_pallas(qp, cp, k=k_eff, block_q=bq, block_n=bn,
                                   interpret=not _on_tpu(), n_valid=n)
    if pad_n:
        bad = i >= n
        s = jnp.where(bad, -jnp.inf, s)
        i = jnp.where(bad, -1, i)
    return _pad_topk(s[:qn], i[:qn], k)


def gathered_topk(queries: jnp.ndarray, cand_vecs: jnp.ndarray,
                  cand_ids: jnp.ndarray, *, k: int, block_q: int = None,
                  block_c: int = None, use_kernel: bool = True):
    """Per-query candidate top-k (the ivfflat probe-scoring step):
    queries (Q, D), cand_vecs (Q, C, D), cand_ids (Q, C) with −1 marking
    invalid slots -> (scores (Q, k), ids (Q, k)), −inf/−1 for misses."""
    blocks = tuning.resolve("gathered_topk", n=cand_vecs.shape[1],
                            dtype=queries.dtype, block_q=block_q,
                            block_c=block_c)
    return _gathered_topk(queries, cand_vecs, cand_ids, k=k,
                          use_kernel=use_kernel, **blocks)


@functools.partial(jax.jit, static_argnames=("k", "block_q", "block_c",
                                             "use_kernel"))
def _gathered_topk(queries: jnp.ndarray, cand_vecs: jnp.ndarray,
                   cand_ids: jnp.ndarray, *, k: int, block_q: int,
                   block_c: int, use_kernel: bool):
    qn, d = queries.shape
    c = cand_vecs.shape[1]
    k_eff = min(k, c)
    if not use_kernel or k_eff > _MAX_KERNEL_K:
        return _pad_topk(
            *ref.gathered_topk_ref(queries, cand_vecs, cand_ids, k=k_eff), k)
    bq = min(block_q, max(1, qn))
    bc = min(block_c, _ceil8(c))
    pad_q = (-qn) % bq
    pad_c = (-c) % bc
    qp = jnp.pad(queries.astype(jnp.float32), ((0, pad_q), (0, 0)))
    cp = jnp.pad(cand_vecs.astype(jnp.float32),
                 ((0, pad_q), (0, pad_c), (0, 0)))
    ip = jnp.pad(cand_ids.astype(jnp.int32), ((0, pad_q), (0, pad_c)),
                 constant_values=-1)
    s, i = gathered_topk_pallas(qp, cp, ip, k=k_eff, block_q=bq, block_c=bc,
                                interpret=not _on_tpu())
    return _pad_topk(s[:qn], i[:qn], k)
