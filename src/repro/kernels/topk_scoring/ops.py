"""jit'd dispatch wrappers for topk_scoring: pad to block multiples, select
interpret mode off-TPU, fall back to the jnp oracle for k > 32 (the
repeated-max extraction stops paying for itself).

Shape contract (the engine path depends on it): any Q/N/C/k combination is
accepted — k is clamped to the candidate count, inputs are padded to block
multiples, and missing results come back as score −inf / id −1, so callers
never see a ``lax.top_k`` shape error from an undersized corpus.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.topk_scoring import ref
from repro.kernels.topk_scoring.ref import pad_topk as _pad_topk
from repro.kernels.topk_scoring.topk_scoring import (gathered_topk_pallas,
                                                     topk_scores_pallas)

_MAX_KERNEL_K = 32


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("k", "block_q", "block_n",
                                             "use_kernel"))
def topk_scores(queries: jnp.ndarray, corpus: jnp.ndarray, *, k: int,
                block_q: int = 128, block_n: int = 1024,
                use_kernel: bool = True):
    """Top-k inner-product search: (Q, D) x (N, D) -> (Q, k) scores/ids."""
    n = corpus.shape[0]
    k_eff = min(k, n)
    if not use_kernel or k_eff > _MAX_KERNEL_K:
        return _pad_topk(*ref.topk_scores_ref(queries, corpus, k=k_eff), k)
    qn, d = queries.shape
    bq = min(block_q, max(8, qn))
    bn = min(block_n, max(128, n))
    pad_q = (-qn) % bq
    pad_n = (-n) % bn
    # sentinel coordinate: query coord 1, real candidates 0, padding -BIG —
    # padded rows then score -BIG and can never displace real candidates
    qp = jnp.pad(queries.astype(jnp.float32), ((0, pad_q), (0, 1)),
                 constant_values=1.0)
    qp = qp.at[:, d].set(1.0)
    cp = jnp.pad(corpus.astype(jnp.float32), ((0, pad_n), (0, 1)))
    if pad_n:
        cp = cp.at[n:, d].set(-1e30)
    s, i = topk_scores_pallas(qp, cp, k=k_eff, block_q=bq, block_n=bn,
                              interpret=not _on_tpu())
    if pad_n:
        bad = i >= n
        s = jnp.where(bad, -jnp.inf, s)
        i = jnp.where(bad, -1, i)
    return _pad_topk(s[:qn], i[:qn], k)


@functools.partial(jax.jit, static_argnames=("k", "block_q", "block_c",
                                             "use_kernel"))
def gathered_topk(queries: jnp.ndarray, cand_vecs: jnp.ndarray,
                  cand_ids: jnp.ndarray, *, k: int, block_q: int = 8,
                  block_c: int = 256, use_kernel: bool = True):
    """Per-query candidate top-k (the ivfflat probe-scoring step):
    queries (Q, D), cand_vecs (Q, C, D), cand_ids (Q, C) with −1 marking
    invalid slots -> (scores (Q, k), ids (Q, k)), −inf/−1 for misses."""
    qn, d = queries.shape
    c = cand_vecs.shape[1]
    k_eff = min(k, c)
    if not use_kernel or k_eff > _MAX_KERNEL_K:
        return _pad_topk(
            *ref.gathered_topk_ref(queries, cand_vecs, cand_ids, k=k_eff), k)
    bq = min(block_q, max(1, qn))
    bc = min(block_c, max(128, c))
    pad_q = (-qn) % bq
    pad_c = (-c) % bc
    qp = jnp.pad(queries.astype(jnp.float32), ((0, pad_q), (0, 0)))
    cp = jnp.pad(cand_vecs.astype(jnp.float32),
                 ((0, pad_q), (0, pad_c), (0, 0)))
    ip = jnp.pad(cand_ids.astype(jnp.int32), ((0, pad_q), (0, pad_c)),
                 constant_values=-1)
    s, i = gathered_topk_pallas(qp, cp, ip, k=k_eff, block_q=bq, block_c=bc,
                                interpret=not _on_tpu())
    return _pad_topk(s[:qn], i[:qn], k)
