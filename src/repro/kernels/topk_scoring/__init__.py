from repro.kernels.topk_scoring.ops import topk_scores
from repro.kernels.topk_scoring import ref

__all__ = ["topk_scores", "ref"]
