"""Kernel autotuner — ask/tell hillclimb over Pallas block/grid candidates
(DESIGN.md §11).

The Pallas kernels under every retrieval engine and the LP pallas engine
used to run with hard-coded block shapes regardless of corpus size.  This
module turns the lower→compile→roofline machinery of the §Perf hillclimb
(``benchmarks/hillclimb.py::measure``, which now imports the shared helpers
from here) into a per-kernel autotuner:

* :data:`SPACES` — one :class:`TuningSpace` per kernel primitive (``topk``,
  ``hamming_topk``, ``gathered_topk``, ``label_prop_round``) enumerating the
  block/grid candidate axes.
* :class:`HillclimbTuner` — a DeepHyper-style ask/tell optimizer: ``ask()``
  proposes the next untried candidate (the default point first, then
  one-axis neighbours of the incumbent best), ``tell(point, score)`` records
  a measurement and re-seeds the frontier when the incumbent improves.
* :func:`measure` — scores one candidate by lowering + compiling the kernel
  call and reading XLA's cost analysis into the same roofline terms as
  ``launch/dryrun.py`` (compute vs HBM time; optionally a wall-clock
  sample), so padding waste and grid shape changes are visible without a
  TPU attached.
* :class:`TunedTable` — the persisted winners, keyed by
  ``(kernel, corpus-size bucket, dtype)``.  :func:`autotune` regenerates
  ``results/tuned_kernels.json``; a checked-in default table ships at
  ``src/repro/kernels/tuned_default.json``.

Dispatch-time lookup order (what every ``kernels/*/ops.py`` wrapper applies
via :func:`resolve`):

  explicit kwarg  >  tuned table entry  >  hard-coded default

The active table resolves once per process from, in order: the
``REPRO_TUNED_KERNELS`` env var (``off``/``0``/``none`` forces the
hard-coded defaults everywhere — the escape hatch; any other value is a
table path), else ``results/tuned_kernels.json`` when present, else the
checked-in default table.  ``set_table``/``reset_table`` override it in
process (tests, the ``--no-tuned-kernels`` CLI flags).  Note block
resolution happens when a consumer traces, so jitted callers that cached a
trace keep the blocks they were traced with until their jit cache is
cleared.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
import json
import os
import time
from typing import Any, Callable, Dict, Mapping, Optional, Sequence, Tuple

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

ENV_VAR = "REPRO_TUNED_KERNELS"

# TPU v5e hardware constants (per chip) for the roofline terms.  These
# live here (the bottom of the kernel stack) so both the autotuner and
# launch/dryrun.py can read them without a kernels -> launch import.
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # B/s
ICI_BW = 5.0e10               # B/s per link (~50 GB/s)

RESULTS_TABLE_PATH = os.path.join("results", "tuned_kernels.json")
DEFAULT_TABLE_PATH = os.path.join(os.path.dirname(__file__),
                                  "tuned_default.json")

#: hard-coded fallback blocks — the pre-autotuner dispatch defaults
DEFAULTS: Dict[str, Dict[str, int]] = {
    "topk": {"block_q": 128, "block_n": 1024},
    "hamming_topk": {"block_q": 128, "block_n": 1024},
    "gathered_topk": {"block_q": 8, "block_c": 256},
    "label_prop_round": {"block_n": 256},
}

#: corpus-size bucket upper bounds (rows scored per call), ascending
SIZE_BUCKETS: Tuple[Tuple[int, str], ...] = (
    (1024, "le1024"), (4096, "le4096"), (16384, "le16384"),
    (65536, "le65536"),
)
_OVERFLOW_BUCKET = "gt65536"


def size_bucket(n: int) -> str:
    """Corpus-size bucket name for an n-row scoring call."""
    for bound, name in SIZE_BUCKETS:
        if n <= bound:
            return name
    return _OVERFLOW_BUCKET


def bucket_rep_size(bucket: str) -> int:
    """Representative row count the tuner measures a bucket at (the upper
    bound; 2x the last bound for the overflow bucket)."""
    for bound, name in SIZE_BUCKETS:
        if name == bucket:
            return bound
    return SIZE_BUCKETS[-1][0] * 2


def dtype_str(dtype: Any) -> str:
    """Canonical dtype key ('float32', 'int8', ...) from a dtype or str."""
    if isinstance(dtype, str):
        return dtype
    import numpy as np
    return np.dtype(dtype).name


# ---------------------------------------------------------------------------
# Tuning space + ask/tell hillclimb
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TuningSpace:
    """Candidate axes for one kernel primitive: param -> ascending values."""

    kernel: str
    axes: Mapping[str, Tuple[int, ...]]

    def candidates(self):
        """Every point of the cross product, as param dicts."""
        names = list(self.axes)
        for combo in itertools.product(*(self.axes[a] for a in names)):
            yield dict(zip(names, combo))

    def default_point(self) -> Dict[str, int]:
        """The hard-coded default, snapped to the nearest axis value."""
        point = {}
        for name, values in self.axes.items():
            want = DEFAULTS[self.kernel].get(name, values[0])
            point[name] = min(values, key=lambda v: abs(v - want))
        return point

    def neighbours(self, point: Mapping[str, int]):
        """One-axis steps up/down from ``point`` (the hillclimb moves)."""
        for name, values in self.axes.items():
            i = values.index(point[name])
            for j in (i - 1, i + 1):
                if 0 <= j < len(values):
                    yield {**point, name: values[j]}

    def shrink_to(self, limits: Mapping[str, int]) -> "TuningSpace":
        """Drop candidate values above per-axis limits (e.g. blocks larger
        than the padded problem size — they alias the largest useful
        block), keeping at least the smallest value per axis."""
        axes = {}
        for name, values in self.axes.items():
            lim = limits.get(name)
            kept = (tuple(v for v in values if v <= lim)
                    if lim is not None else values)
            axes[name] = kept or values[:1]
        return dataclasses.replace(self, axes=axes)


SPACES: Dict[str, TuningSpace] = {
    "topk": TuningSpace("topk", {
        "block_q": (8, 32, 128, 256),
        "block_n": (128, 256, 512, 1024, 2048),
    }),
    "hamming_topk": TuningSpace("hamming_topk", {
        "block_q": (8, 32, 128, 256),
        "block_n": (128, 256, 512, 1024, 2048),
    }),
    "gathered_topk": TuningSpace("gathered_topk", {
        "block_q": (1, 4, 8, 16),
        "block_c": (128, 256, 512, 1024),
    }),
    "label_prop_round": TuningSpace("label_prop_round", {
        "block_n": (64, 128, 256, 512, 1024),
    }),
}

#: which dtypes each primitive is tuned for (the dispatch key's third axis)
KERNEL_DTYPES: Dict[str, Tuple[str, ...]] = {
    "topk": ("float32", "int8"),
    "hamming_topk": ("int32",),
    "gathered_topk": ("float32",),
    "label_prop_round": ("float32",),
}


def _key(point: Mapping[str, int]) -> Tuple[Tuple[str, int], ...]:
    return tuple(sorted(point.items()))


class HillclimbTuner:
    """Ask/tell hillclimb over one :class:`TuningSpace`.

    The optimizer-side half of the DeepHyper ask/tell loop: the driver owns
    measurement, the tuner owns the frontier.  ``ask()`` returns the next
    untried candidate or ``None`` once every neighbour of the incumbent has
    been measured (converged); ``tell()`` records a score (lower = better)
    and, on improvement, pushes the new incumbent's neighbours.
    """

    def __init__(self, space: TuningSpace, *,
                 start: Optional[Mapping[str, int]] = None):
        self.space = space
        first = dict(start) if start is not None else space.default_point()
        self._frontier = [first]
        self._asked = set()
        self.results: Dict[Tuple, float] = {}
        self.best: Optional[Dict[str, int]] = None
        self.best_score = float("inf")

    def ask(self) -> Optional[Dict[str, int]]:
        while self._frontier:
            point = self._frontier.pop(0)
            k = _key(point)
            if k not in self._asked:
                self._asked.add(k)
                return point
        return None

    def tell(self, point: Mapping[str, int], score: float) -> None:
        self.results[_key(point)] = score
        if score < self.best_score:
            self.best, self.best_score = dict(point), score
            self._frontier.extend(self.space.neighbours(point))

    @property
    def num_evals(self) -> int:
        return len(self.results)


# ---------------------------------------------------------------------------
# Candidate measurement: lower -> compile -> roofline (+ optional wall)
# ---------------------------------------------------------------------------


def compiled_roofline(compiled) -> Dict[str, float]:
    """Roofline terms (ms) from a compiled XLA executable's cost analysis —
    the same reading as ``launch/dryrun.py``/``benchmarks/hillclimb.py``."""
    cost = compiled.cost_analysis()
    cost = cost[0] if isinstance(cost, (list, tuple)) else (cost or {})
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    return {"compute_ms": flops / PEAK_FLOPS_BF16 * 1e3,
            "memory_ms": nbytes / HBM_BW * 1e3}


def measure(fn: Callable, *args, wall_iters: int = 0) -> Dict[str, float]:
    """Score one candidate: jit-lower + compile ``fn(*args)``, read the
    roofline terms, optionally sample wall clock.  ``score_ms`` is the wall
    time when sampled (interpret-mode wall clock still ranks grid/padding
    overheads), else the roofline bound max(compute, memory)."""
    import jax
    t0 = time.time()
    jitted = jax.jit(fn)
    compiled = jitted.lower(*args).compile()
    terms = compiled_roofline(compiled)
    out = {"compile_s": round(time.time() - t0, 2), **terms}
    roof = max(terms["compute_ms"], terms["memory_ms"])
    if wall_iters > 0:
        jax.block_until_ready(jitted(*args))   # warmup retired before t0
        t0 = time.time()
        for _ in range(wall_iters):
            jax.block_until_ready(jitted(*args))
        out["wall_ms"] = (time.time() - t0) / wall_iters * 1e3
        out["score_ms"] = out["wall_ms"]
    else:
        out["score_ms"] = roof
    return out


def _ceil8(n: int) -> int:
    return ((n + 7) // 8) * 8


def _bench_call(kernel: str, n: int, dtype: str):
    """(args, fn(point)->callable) measuring one kernel primitive at a
    representative shape of the bucket; shapes mirror the product path
    (Q=64 queries, D=64 dims, k=8; ELL degree 16 for LP)."""
    import jax
    import jax.numpy as jnp

    key = jax.random.PRNGKey(0)
    if kernel == "topk" and dtype == "int8":
        from repro.kernels.topk_scoring import ops as topk_ops
        q = jax.random.randint(key, (64, 64), -127, 128, dtype=jnp.int8)
        c = jax.random.randint(jax.random.PRNGKey(1), (n, 64), -127, 128,
                               dtype=jnp.int8)
        return (q, c), lambda pt: (
            lambda a, b: topk_ops.topk_scores_int8(a, b, k=8, **pt))
    if kernel == "topk":
        from repro.kernels.topk_scoring import ops as topk_ops
        q = jax.random.normal(key, (64, 64), jnp.dtype(dtype))
        c = jax.random.normal(jax.random.PRNGKey(1), (n, 64), jnp.dtype(dtype))
        return (q, c), lambda pt: (
            lambda a, b: topk_ops.topk_scores(a, b, k=8, **pt))
    if kernel == "hamming_topk":
        from repro.kernels.lsh_hamming import ops as lsh_ops
        q = jax.random.randint(key, (64, 4), -2**31, 2**31 - 1,
                               dtype=jnp.int32)
        c = jax.random.randint(jax.random.PRNGKey(1), (n, 4), -2**31,
                               2**31 - 1, dtype=jnp.int32)
        return (q, c), lambda pt: (
            lambda a, b: lsh_ops.hamming_topk(a, b, k=8, **pt))
    if kernel == "gathered_topk":
        from repro.kernels.topk_scoring import ops as topk_ops
        c = min(n, 4096)      # candidates per query (nprobe * cap scale)
        q = jax.random.normal(key, (8, 64))
        cv = jax.random.normal(jax.random.PRNGKey(1), (8, c, 64))
        ci = jax.random.randint(jax.random.PRNGKey(2), (8, c), -1, n,
                                dtype=jnp.int32)
        return (q, cv, ci), lambda pt: (
            lambda a, b, i: topk_ops.gathered_topk(a, b, i, k=8, **pt))
    if kernel == "label_prop_round":
        from repro.kernels.label_prop import ops as lp_ops
        nbr = jax.random.randint(key, (n, 16), -1, n, dtype=jnp.int32)
        wgt = jnp.abs(jax.random.normal(jax.random.PRNGKey(1), (n, 16)))
        labels = jnp.arange(n, dtype=jnp.int32)
        return (labels, nbr, wgt), lambda pt: (
            lambda lb, nb, w: lp_ops.label_prop_round(lb, nb, w, **pt))
    raise ValueError(f"unknown kernel primitive {kernel!r}; "
                     f"tunable: {', '.join(sorted(SPACES))}")


def _space_for(kernel: str, n: int) -> TuningSpace:
    """Kernel's tuning space with block candidates above the padded problem
    size dropped (they alias the largest useful block)."""
    lim = _ceil8(n)
    limits = {"block_n": lim, "block_c": min(lim, 4096)}
    return SPACES[kernel].shrink_to(limits)


def tune_kernel(kernel: str, *, n: int, dtype: str,
                space: Optional[TuningSpace] = None, max_evals: int = 12,
                wall_iters: int = 0, verbose: bool = False
                ) -> Tuple[Dict[str, int], float, int]:
    """Hillclimb one (kernel, representative size, dtype) cell; returns
    (best params, best score_ms, evals)."""
    space = space or _space_for(kernel, n)
    args, make_fn = _bench_call(kernel, n, dtype)
    tuner = HillclimbTuner(space)
    while tuner.num_evals < max_evals:
        point = tuner.ask()
        if point is None:
            break
        try:
            res = measure(make_fn(point), *args, wall_iters=wall_iters)
            score = res["score_ms"]
        except Exception as e:       # candidate failed to lower/compile
            if verbose:
                print(f"    {kernel} {point}: failed ({e!r})")
            score = float("inf")
        tuner.tell(point, score)
        if verbose:
            print(f"    {kernel}[n={n},{dtype}] {point} -> {score:.4f}ms")
    assert tuner.best is not None
    return tuner.best, tuner.best_score, tuner.num_evals


# ---------------------------------------------------------------------------
# Persisted TunedConfig table
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TunedConfig:
    kernel: str
    bucket: str
    dtype: str
    params: Tuple[Tuple[str, int], ...]   # sorted items, hashable
    score_ms: float = 0.0
    evals: int = 0

    def params_dict(self) -> Dict[str, int]:
        return dict(self.params)


@dataclasses.dataclass
class TunedTable:
    """(kernel, bucket, dtype) -> TunedConfig, with provenance metadata."""

    entries: Dict[Tuple[str, str, str], TunedConfig] = dataclasses.field(
        default_factory=dict)
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def add(self, cfg: TunedConfig) -> None:
        self.entries[(cfg.kernel, cfg.bucket, cfg.dtype)] = cfg

    def lookup(self, kernel: str, bucket: str, dtype: str
               ) -> Dict[str, int]:
        cfg = self.entries.get((kernel, bucket, dtype))
        return cfg.params_dict() if cfg is not None else {}

    def to_json(self) -> dict:
        return {"meta": self.meta,
                "entries": [{"kernel": c.kernel, "bucket": c.bucket,
                             "dtype": c.dtype, "params": c.params_dict(),
                             "score_ms": c.score_ms, "evals": c.evals}
                            for c in sorted(
                                self.entries.values(),
                                key=lambda c: (c.kernel, c.bucket,
                                               c.dtype))]}

    @classmethod
    def from_json(cls, data: dict) -> "TunedTable":
        table = cls(meta=dict(data.get("meta", {})))
        for e in data.get("entries", []):
            table.add(TunedConfig(
                kernel=e["kernel"], bucket=e["bucket"], dtype=e["dtype"],
                params=tuple(sorted((k, int(v))
                                    for k, v in e["params"].items())),
                score_ms=float(e.get("score_ms", 0.0)),
                evals=int(e.get("evals", 0))))
        return table

    def save(self, path: str) -> None:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2)

    @classmethod
    def load(cls, path: str) -> "TunedTable":
        with open(path) as f:
            return cls.from_json(json.load(f))


# active table: resolved once per process, overridable (tests, CLI flags)
_ACTIVE: list = []


def _load_active() -> TunedTable:
    env = os.environ.get(ENV_VAR)
    if env is not None:
        if env.strip().lower() in ("", "0", "off", "none"):
            return TunedTable()          # escape hatch: hard-coded defaults
        return TunedTable.load(env)
    if os.path.exists(RESULTS_TABLE_PATH):
        return TunedTable.load(RESULTS_TABLE_PATH)
    if os.path.exists(DEFAULT_TABLE_PATH):
        return TunedTable.load(DEFAULT_TABLE_PATH)
    return TunedTable()


def get_table() -> TunedTable:
    if not _ACTIVE:
        _ACTIVE.append(_load_active())
    return _ACTIVE[0]


def set_table(table: Optional[TunedTable]) -> None:
    """Override the active table in-process (``None`` = empty table, i.e.
    force the hard-coded defaults — the CLI ``--no-tuned-kernels`` hatch)."""
    _ACTIVE[:] = [table if table is not None else TunedTable()]


def reset_table() -> None:
    """Drop the in-process table so the next lookup re-reads env/disk."""
    _ACTIVE.clear()


def lookup(kernel: str, *, n: int, dtype: Any) -> Dict[str, int]:
    """Tuned params for an n-row call, or {} when none recorded."""
    return get_table().lookup(kernel, size_bucket(n), dtype_str(dtype))


# Observability (DESIGN.md §12): every resolve() bumps the tuned-table
# hit/miss counters, and — while tracing is enabled — appends the concrete
# resolution to a bounded log so the span wrapping the dispatch (e.g.
# SearchSession's per-chunk span) can attach the block choice as attrs.
_RESOLUTION_LOG: "collections.deque" = collections.deque(maxlen=512)
_RESOLUTION_SEQ = itertools.count()


def resolution_mark() -> int:
    """Opaque mark; pass to :func:`resolutions_since` to read back every
    block resolution that happened after it (tracing-enabled only)."""
    return next(_RESOLUTION_SEQ)


def resolutions_since(mark: int) -> list:
    """Resolution records (kernel, bucket, dtype, params, tuned) logged
    after ``mark``; empty when tracing is disabled or nothing dispatched."""
    return [rec for seq, rec in _RESOLUTION_LOG if seq >= mark]


def resolve(kernel: str, *, n: int, dtype: Any,
            **explicit: Optional[int]) -> Dict[str, int]:
    """Final block params for one dispatch: explicit kwarg > tuned table >
    hard-coded default.  ``None`` explicit values mean 'not specified'."""
    params = dict(DEFAULTS[kernel])
    tuned = lookup(kernel, n=n, dtype=dtype)
    obs_metrics.REGISTRY.counter(
        "tuning.resolve.hit" if tuned else "tuning.resolve.miss").inc()
    params.update(tuned)
    for name, value in explicit.items():
        if name not in params:
            raise ValueError(f"kernel {kernel!r} has no block param "
                             f"{name!r}; known: {', '.join(params)}")
        if value is not None:
            params[name] = int(value)
    if obs_trace.is_enabled():
        _RESOLUTION_LOG.append((next(_RESOLUTION_SEQ), {
            "kernel": kernel, "bucket": size_bucket(n),
            "dtype": dtype_str(dtype), "params": dict(params),
            "tuned": bool(tuned)}))
    return params


# ---------------------------------------------------------------------------
# End-to-end autotune driver
# ---------------------------------------------------------------------------


def autotune(kernels: Optional[Sequence[str]] = None, *,
             buckets: Optional[Sequence[str]] = None,
             dtypes: Optional[Mapping[str, Sequence[str]]] = None,
             max_evals: int = 12, wall_iters: int = 1,
             out_path: Optional[str] = RESULTS_TABLE_PATH,
             activate: bool = True, verbose: bool = True) -> TunedTable:
    """Tune every (kernel, bucket, dtype) cell, persist the winners, and
    (by default) make the new table the active dispatch table.

    The CI smoke job runs this with a reduced cell set
    (``benchmarks/run.py --autotune --smoke``); the full sweep is the
    README "make it fast" quickstart.
    """
    import platform

    import jax

    kernels = list(kernels) if kernels is not None else sorted(SPACES)
    buckets = (list(buckets) if buckets is not None
               else [name for _, name in SIZE_BUCKETS])
    table = TunedTable(meta={
        "platform": platform.platform(),
        "python": platform.python_version(),
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "device_kind": jax.devices()[0].device_kind,
        "interpret": jax.default_backend() != "tpu",
        "max_evals": max_evals,
        "wall_iters": wall_iters,
        "generated_by": "repro.kernels.tuning.autotune",
    })
    for kernel in kernels:
        for dt in (dtypes or KERNEL_DTYPES)[kernel]:
            for bucket in buckets:
                n = bucket_rep_size(bucket)
                if verbose:
                    print(f"  tuning {kernel} [{bucket}, {dt}] at n={n}...")
                params, score, evals = tune_kernel(
                    kernel, n=n, dtype=dt, max_evals=max_evals,
                    wall_iters=wall_iters, verbose=verbose)
                table.add(TunedConfig(
                    kernel=kernel, bucket=bucket, dtype=dt,
                    params=tuple(sorted(params.items())),
                    score_ms=round(score, 4), evals=evals))
                if verbose:
                    print(f"  -> {kernel}[{bucket},{dt}] best={params} "
                          f"({score:.4f}ms, {evals} evals)")
    if out_path:
        table.save(out_path)
        if verbose:
            print(f"wrote {out_path} ({len(table.entries)} entries)")
    if activate:
        set_table(table)
    return table
