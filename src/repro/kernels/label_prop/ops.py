"""Dispatch wrapper: gathers neighbour labels (XLA), pads N to the node
block, runs the Pallas round kernel (interpret off-TPU).  The node block
resolves through the autotuner table (kernels/tuning.py): explicit kwarg >
tuned entry for the row-count bucket > hard-coded default, resolved in the
plain-python wrappers before any jitted call."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import tuning
from repro.kernels.label_prop.label_prop import label_prop_round_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def pallas_round_padded(nbr_labels: jnp.ndarray, wgt: jnp.ndarray,
                        own: jnp.ndarray, *, block_n: int = None):
    """Run the Pallas round kernel on pre-gathered neighbour labels
    (N, K), padding N up to the node block; interpret mode off-TPU.
    Shared by the single-device pallas engine and the sharded pipeline's
    local node blocks."""
    rows = nbr_labels.shape[0]
    block_n = tuning.resolve("label_prop_round", n=rows, dtype="float32",
                             block_n=block_n)["block_n"]
    bn = min(block_n, max(8, rows))
    pad = (-rows) % bn
    lab_p = jnp.pad(nbr_labels, ((0, pad), (0, 0)), constant_values=-1)
    wgt_p = jnp.pad(wgt, ((0, pad), (0, 0)))
    own_p = jnp.pad(own, (0, pad))
    out = label_prop_round_pallas(lab_p, wgt_p, own_p, block_n=bn,
                                  interpret=not _on_tpu())
    return out[:rows]


def label_prop_round(labels: jnp.ndarray, nbr: jnp.ndarray,
                     wgt: jnp.ndarray, *, block_n: int = None,
                     use_kernel: bool = True):
    """One LP round over ELL adjacency: labels (N,), nbr (N, K) node ids
    (-1 pad), wgt (N, K). Returns new labels (N,)."""
    block_n = tuning.resolve("label_prop_round", n=labels.shape[0],
                             dtype="float32", block_n=block_n)["block_n"]
    return _label_prop_round(labels, nbr, wgt, block_n=block_n,
                             use_kernel=use_kernel)


@functools.partial(jax.jit, static_argnames=("block_n", "use_kernel"))
def _label_prop_round(labels: jnp.ndarray, nbr: jnp.ndarray,
                      wgt: jnp.ndarray, *, block_n: int, use_kernel: bool):
    lab = jnp.where(nbr >= 0, labels[jnp.maximum(nbr, 0)], -1)
    if not use_kernel:
        from repro.kernels.label_prop.ref import label_prop_round_ref
        return label_prop_round_ref(lab, wgt, labels)
    return pallas_round_padded(lab, wgt, labels, block_n=block_n)
