from repro.kernels.label_prop.ops import label_prop_round
from repro.kernels.label_prop import ref

__all__ = ["label_prop_round", "ref"]
