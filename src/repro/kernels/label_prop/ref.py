"""Pure-jnp oracle for the label_prop kernel — must agree exactly with
core.label_prop.ell_round (same semantics, same tie-break)."""
from __future__ import annotations

import jax.numpy as jnp

_I32_MAX = jnp.iinfo(jnp.int32).max


def label_prop_round_ref(nbr_labels, wgt, labels):
    mask = nbr_labels >= 0
    wm = jnp.where(mask, wgt, 0.0)
    same = (nbr_labels[:, :, None] == nbr_labels[:, None, :]).astype(jnp.float32)
    scores = jnp.einsum("nkj,nk->nj", same, wm)
    scores = jnp.where(mask, scores, -jnp.inf)
    smax = jnp.max(scores, axis=1, keepdims=True)
    cand = jnp.where((scores == smax) & mask, nbr_labels, _I32_MAX)
    best = jnp.min(cand, axis=1)
    has_nbr = jnp.any(mask, axis=1)
    return jnp.where(has_nbr, best, labels).astype(jnp.int32)
