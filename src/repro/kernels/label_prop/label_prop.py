"""Weighted label-propagation round as a Pallas kernel (GraphSampler hot
loop, Alg. 2 steps 1-3).

Layout: degree-capped ELL adjacency. The neighbour-label gather happens
OUTSIDE the kernel (XLA gather, HBM-bound); the kernel fuses the O(K^2)
per-node same-label weight reduction + argmax + min-label tie-break that
dominates compute. The sort-based reference implementation pays an
O(E log E) bitonic sort per round; the ELL kernel is O(N*K^2) dense VPU/MXU
work with zero shuffles — the §Perf hillclimb for the paper-technique cell
measures exactly this trade.

Per node block (bn, K): same-label indicator via lab[:, :, None] ==
lab[:, None, :] folded into an (bn, K, K) f32 tensor contracted with the
weight vector on the MXU; ties broken toward the smaller label with an
exact two-pass (max score, min label among maxima).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

_I32_MAX = jnp.iinfo(jnp.int32).max


def _lp_kernel(lab_ref, w_ref, own_ref, out_ref):
    lab = lab_ref[...]                         # (bn, K) i32, -1 padding
    w = w_ref[...]                             # (bn, K) f32, 0 on padding
    own = own_ref[...]                         # (bn,) i32 current labels
    mask = lab >= 0
    wm = jnp.where(mask, w, 0.0)
    same = (lab[:, :, None] == lab[:, None, :]).astype(jnp.float32)
    # scores[n, j] = sum_k w[n, k] * [lab k == lab j]
    scores = jnp.einsum("nkj,nk->nj", same, wm)
    scores = jnp.where(mask, scores, -jnp.inf)
    smax = jnp.max(scores, axis=1, keepdims=True)
    cand = jnp.where((scores == smax) & mask, lab, _I32_MAX)
    best = jnp.min(cand, axis=1)
    has_nbr = jnp.any(mask, axis=1)
    out_ref[...] = jnp.where(has_nbr, best, own).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def label_prop_round_pallas(nbr_labels: jnp.ndarray, wgt: jnp.ndarray,
                            labels: jnp.ndarray, *, block_n: int = 256,
                            interpret: bool = False):
    """nbr_labels (N, K) i32 (pre-gathered neighbour labels, -1 pad),
    wgt (N, K) f32, labels (N,) i32 -> new labels (N,) i32.
    N must be a multiple of block_n (ops.py pads)."""
    n, k = nbr_labels.shape
    return pl.pallas_call(
        _lp_kernel,
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, k), lambda i: (i, 0)),
            pl.BlockSpec((block_n, k), lambda i: (i, 0)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=interpret,
    )(nbr_labels, wgt, labels)
