"""Pallas TPU kernels for the compute hot spots of the WindTunnel pipeline:

* topk_scoring    — fused candidate scoring + running top-k (ANN / IVF probe
                    / retrieval_cand hot path; paper Fig. 5 online ranking)
* flash_attention — fused online-softmax attention (embedding/indexing cost,
                    the dominant FLOPs of the paper's offline stage)
* label_prop      — one weighted label-propagation round over ELL adjacency
                    (GraphSampler hot loop, Alg. 2 steps 1-3)
* lsh_hamming     — packed sign-LSH Hamming scoring (Grale-style edge
                    building and the LSH index of Fig. 5)

Each kernel ships <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd
dispatch wrapper; interpret=True off-TPU) and ref.py (pure-jnp oracle swept
against the kernel in tests/test_kernels_*.py).
"""
