from repro.kernels.lsh_hamming.ops import hamming_topk
from repro.kernels.lsh_hamming import ref

__all__ = ["hamming_topk", "ref"]
