"""Pure-jnp oracle for lsh_hamming.

Kept free of ``repro.retrieval`` imports: the retrieval layer dispatches
*down* into the kernel package through the scoring-backend registry
(retrieval/backends.py), so anything here importing retrieval back up would
be a cycle.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from repro.kernels.lsh_hamming.lsh_hamming import _popcount


def hamming_topk_ref(q_codes, c_codes, *, k: int):
    ham = _popcount(q_codes[:, None, :] ^ c_codes[None]).sum(-1)
    top_s, top_i = lax.top_k(-ham.astype(jnp.float32), k)
    return top_s, top_i.astype(jnp.int32)
