"""Pure-jnp oracle for lsh_hamming."""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from repro.retrieval.lsh import popcount32


def hamming_topk_ref(q_codes, c_codes, *, k: int):
    ham = popcount32(q_codes[:, None, :] ^ c_codes[None]).sum(-1)
    top_s, top_i = lax.top_k(-ham.astype(jnp.float32), k)
    return top_s, top_i.astype(jnp.int32)
