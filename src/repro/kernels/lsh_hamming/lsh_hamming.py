"""Packed sign-LSH Hamming top-k Pallas kernel.

Codes are n_bits sign bits packed into int32 lanes (retrieval/lsh.py).
Per (query_block, code_block): XOR + branch-free popcount + sum over words,
then the same fused running top-k (k rounds of max/mask) as topk_scoring —
the (Q, N) Hamming matrix never leaves VMEM. Bit ops are pure VPU work;
packing gives a 32x density win over scoring float projections.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _popcount(x: jnp.ndarray) -> jnp.ndarray:
    x = x.astype(jnp.uint32)
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    return ((x * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)


def _hamming_kernel(q_ref, c_ref, s_out_ref, i_out_ref, *, k: int,
                    block_n: int, n_words: int, n_valid: int):
    j = pl.program_id(1)
    q = q_ref[...]                              # (bq, W) int32
    c = c_ref[...]                              # (bn, W) int32
    # dist[a, b] = sum_w popcount(q[a, w] ^ c[b, w])
    dist = jnp.zeros((q.shape[0], c.shape[0]), jnp.int32)
    for w in range(n_words):                    # static unroll over words
        dist = dist + _popcount(q[:, w][:, None] ^ c[:, w][None, :])
    neg = -dist.astype(jnp.float32)             # top-k of -distance
    ids = j * block_n + lax.broadcasted_iota(jnp.int32, neg.shape, 1)
    neg = jnp.where(ids < n_valid, neg, -jnp.inf)   # exact pad masking

    def body(i, carry):
        neg, out_s, out_i = carry
        m = jnp.max(neg, axis=1)
        arg = jnp.argmax(neg, axis=1).astype(jnp.int32)
        out_s = lax.dynamic_update_slice(out_s, m[:, None], (0, i))
        out_i = lax.dynamic_update_slice(
            out_i, (j * block_n + arg)[:, None], (0, i))
        hit = lax.broadcasted_iota(jnp.int32, neg.shape, 1) == arg[:, None]
        return jnp.where(hit, -jnp.inf, neg), out_s, out_i

    out_s = jnp.full((q.shape[0], k), -jnp.inf, jnp.float32)
    out_i = jnp.full((q.shape[0], k), -1, jnp.int32)
    _, out_s, out_i = lax.fori_loop(0, k, body, (neg, out_s, out_i))
    s_out_ref[...] = out_s
    i_out_ref[...] = out_i


@functools.partial(jax.jit, static_argnames=("k", "block_q", "block_n",
                                             "interpret", "n_valid"))
def hamming_topk_pallas(q_codes: jnp.ndarray, c_codes: jnp.ndarray, *,
                        k: int, block_q: int = 128, block_n: int = 1024,
                        interpret: bool = False, n_valid: int = None):
    """q_codes (Q, W) i32, c_codes (N, W) i32 ->
    (neg_hamming (Q, k) f32, ids (Q, k) i32)."""
    qn, w = q_codes.shape
    n = c_codes.shape[0]
    nq, nc = qn // block_q, n // block_n
    partial_s, partial_i = pl.pallas_call(
        functools.partial(_hamming_kernel, k=k, block_n=block_n, n_words=w,
                          n_valid=n if n_valid is None else n_valid),
        grid=(nq, nc),
        in_specs=[
            pl.BlockSpec((block_q, w), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, w), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, k), lambda i, j: (i, j)),
            pl.BlockSpec((block_q, k), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qn, nc * k), jnp.float32),
            jax.ShapeDtypeStruct((qn, nc * k), jnp.int32),
        ],
        interpret=interpret,
    )(q_codes, c_codes)
    top_s, pos = lax.top_k(partial_s, k)
    top_i = jnp.take_along_axis(partial_i, pos, axis=1)
    return top_s, top_i
