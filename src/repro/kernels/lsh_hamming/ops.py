"""Dispatch wrapper for lsh_hamming (pad + interpret off-TPU).

Padding note: padded corpus rows get code 0; a real query could tie with
them, so the kernel masks by true row count (``n_valid``) and padded ids
come back as −1 / −inf.  ``k`` is clamped to the corpus size and the result
padded back, so engine-path shapes never crash ``lax.top_k``.

Block sizes resolve through the autotuner table (kernels/tuning.py):
explicit kwarg > tuned entry for the corpus-size bucket > hard-coded
default, resolved in the plain-python outer wrapper before the inner jit
(a lookup inside a jitted body would go stale when the table changes).
The candidate block clamps to the padded corpus size — no 128-row floor
wasted on small corpora.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import tuning
from repro.kernels.lsh_hamming.lsh_hamming import hamming_topk_pallas
from repro.kernels.lsh_hamming.ref import hamming_topk_ref
from repro.kernels.topk_scoring.ref import pad_topk as _pad_topk


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _ceil8(n: int) -> int:
    return max(8, ((n + 7) // 8) * 8)


def hamming_topk(q_codes: jnp.ndarray, c_codes: jnp.ndarray, *, k: int,
                 block_q: int = None, block_n: int = None,
                 use_kernel: bool = True):
    blocks = tuning.resolve("hamming_topk", n=c_codes.shape[0],
                            dtype=c_codes.dtype, block_q=block_q,
                            block_n=block_n)
    return _hamming_topk(q_codes, c_codes, k=k, use_kernel=use_kernel,
                         **blocks)


@functools.partial(jax.jit, static_argnames=("k", "block_q", "block_n",
                                             "use_kernel"))
def _hamming_topk(q_codes: jnp.ndarray, c_codes: jnp.ndarray, *, k: int,
                  block_q: int, block_n: int, use_kernel: bool):
    n = c_codes.shape[0]
    k_eff = min(k, n)
    if not use_kernel or k_eff > 32:
        return _pad_topk(*hamming_topk_ref(q_codes, c_codes, k=k_eff), k)
    qn, w = q_codes.shape
    bq = min(block_q, max(8, qn))
    bn = min(block_n, _ceil8(n))
    pad_q = (-qn) % bq
    pad_n = (-n) % bn
    qp = jnp.pad(q_codes, ((0, pad_q), (0, 0)))
    cp = jnp.pad(c_codes, ((0, pad_n), (0, 0)))
    s, i = hamming_topk_pallas(qp, cp, k=k_eff, block_q=bq, block_n=bn,
                               interpret=not _on_tpu(), n_valid=n)
    if pad_n:
        bad = i >= n
        s = jnp.where(bad, -jnp.inf, s)
        i = jnp.where(bad, -1, i)
    return _pad_topk(s[:qn], i[:qn], k)
