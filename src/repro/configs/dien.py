"""dien [recsys]: embed_dim=18 seq_len=100 gru_dim=108 mlp=200-80,
GRU + attention + AUGRU interest evolution. [arXiv:1809.03672; unverified]
"""
from repro.configs import ArchSpec, RECSYS_SHAPES
from repro.models.recsys import RecsysConfig


def make_config() -> RecsysConfig:
    return RecsysConfig(
        arch="dien", embed_dim=18, seq_len=100, gru_dim=108,
        dien_mlp=(200, 80), item_vocab=1_000_000, cat_vocab=10_000,
        n_dense=0, n_sparse=0)


def make_reduced() -> RecsysConfig:
    return RecsysConfig(
        arch="dien", embed_dim=8, seq_len=12, gru_dim=16, dien_mlp=(16, 8),
        item_vocab=128, cat_vocab=16, n_dense=0, n_sparse=0)


SPEC = ArchSpec(
    arch_id="dien", family="recsys",
    make_config=make_config, make_reduced=make_reduced,
    shapes=RECSYS_SHAPES,
)
