"""yi-9b [dense]: 48L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000 —
llama-architecture GQA. [arXiv:2403.04652; hf]

Pure full attention -> long_500k SKIPPED (DESIGN.md §5).
"""
import jax.numpy as jnp

from repro.configs import ArchSpec, LM_SHAPES
from repro.models.transformer import TransformerConfig


def make_config() -> TransformerConfig:
    return TransformerConfig(
        vocab_size=64_000, d_model=4096, n_layers=48, n_heads=32,
        n_kv_heads=4, d_head=128, d_ff=11_008,
        activation="swiglu", rope_theta=10_000.0, causal=True,
        dtype=jnp.bfloat16, remat="full",
    )


def make_reduced() -> TransformerConfig:
    return TransformerConfig(
        vocab_size=512, d_model=64, n_layers=2, n_heads=4, n_kv_heads=2,
        d_head=16, d_ff=128, activation="swiglu", causal=True,
        dtype=jnp.float32)


SPEC = ArchSpec(
    arch_id="yi-9b", family="lm",
    make_config=make_config, make_reduced=make_reduced,
    shapes=LM_SHAPES, skip_shapes=("long_500k",),
    notes="llama-arch GQA; full attention -> long_500k skipped",
)
