"""dlrm-mlperf [recsys]: n_dense=13 n_sparse=26 embed_dim=128
bot_mlp=13-512-256-128 top_mlp=1024-1024-512-256-1, dot interaction —
MLPerf DLRM benchmark config (Criteo 1TB cardinalities).
[arXiv:1906.00091; paper]
"""
from repro.configs import ArchSpec, RECSYS_SHAPES
from repro.models.recsys import CRITEO_TB_CARDS, RecsysConfig


def make_config() -> RecsysConfig:
    return RecsysConfig(
        arch="dlrm", n_dense=13, n_sparse=26, embed_dim=128,
        vocab_sizes=CRITEO_TB_CARDS,
        bot_mlp=(512, 256, 128), top_mlp=(1024, 1024, 512, 256, 1))


def make_reduced() -> RecsysConfig:
    return RecsysConfig(
        arch="dlrm", n_dense=13, n_sparse=26, embed_dim=16,
        vocab_sizes=tuple([64] * 26), bot_mlp=(32, 16), top_mlp=(32, 16, 1))


SPEC = ArchSpec(
    arch_id="dlrm-mlperf", family="recsys",
    make_config=make_config, make_reduced=make_reduced,
    shapes=RECSYS_SHAPES,
    notes="188M embedding rows x 128 -> 96GB fp32, row-sharded over 'model'",
)
