"""Architecture registry: one module per assigned architecture (exact
public-literature configs) + the paper's own experiment config.

``get_arch(arch_id)`` returns the ArchSpec; ``--arch <id>`` in the
launchers resolves through here.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Callable, Dict, Optional

ARCH_IDS = [
    "llama4-scout-17b-a16e",
    "mixtral-8x22b",
    "starcoder2-7b",
    "gemma-2b",
    "yi-9b",
    "mace",
    "autoint",
    "dcn-v2",
    "dien",
    "dlrm-mlperf",
]

_MODULES = {
    "llama4-scout-17b-a16e": "llama4_scout",
    "mixtral-8x22b": "mixtral_8x22b",
    "starcoder2-7b": "starcoder2_7b",
    "gemma-2b": "gemma_2b",
    "yi-9b": "yi_9b",
    "mace": "mace",
    "autoint": "autoint",
    "dcn-v2": "dcn_v2",
    "dien": "dien",
    "dlrm-mlperf": "dlrm_mlperf",
}


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str                       # lm | gnn | recsys
    make_config: Callable[[], Any]    # full published config
    make_reduced: Callable[[], Any]   # smoke-test config
    shapes: Dict[str, dict]           # shape name -> shape params
    skip_shapes: tuple = ()           # e.g. long_500k for full-attention
    notes: str = ""
    rules_override: Optional[dict] = None  # per-arch sharding-rule deltas


def get_arch(arch_id: str) -> ArchSpec:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.SPEC


def list_archs():
    return list(ARCH_IDS)


def iter_cells(include_skipped: bool = False):
    """All (arch_id, shape_name) dry-run cells."""
    for a in ARCH_IDS:
        spec = get_arch(a)
        for s in spec.shapes:
            if not include_skipped and s in spec.skip_shapes:
                continue
            yield a, s


# LM-family shared input shapes (seq_len x global_batch)
LM_SHAPES = {
    "train_4k": {"kind": "train", "seq_len": 4096, "global_batch": 256},
    "prefill_32k": {"kind": "prefill", "seq_len": 32768, "global_batch": 32},
    "decode_32k": {"kind": "decode", "seq_len": 32768, "global_batch": 128},
    "long_500k": {"kind": "decode", "seq_len": 524288, "global_batch": 1},
}

RECSYS_SHAPES = {
    "train_batch": {"kind": "train", "batch": 65536},
    "serve_p99": {"kind": "serve", "batch": 512},
    "serve_bulk": {"kind": "serve", "batch": 262144},
    "retrieval_cand": {"kind": "retrieval", "batch": 1,
                       "n_candidates": 1_000_000},
}

GNN_SHAPES = {
    # citation/product graphs are node-prediction benchmarks -> node loss;
    # the molecular cell trains the physical objective (energy + forces)
    "full_graph_sm": {"kind": "train_node", "n_nodes": 2708, "n_edges": 10556,
                      "d_feat": 1433, "n_graphs": 1},
    "minibatch_lg": {"kind": "train_sampled", "n_nodes": 232965,
                     "n_edges": 114615892, "batch_nodes": 1024,
                     "fanouts": (15, 10)},
    "ogb_products": {"kind": "train_node", "n_nodes": 2449029,
                     "n_edges": 61859140, "d_feat": 100, "n_graphs": 1},
    "molecule": {"kind": "train", "n_nodes": 30, "n_edges": 64,
                 "batch": 128},
}
