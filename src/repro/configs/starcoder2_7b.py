"""starcoder2-7b [dense]: 32L d_model=4608 36H (GQA kv=4) d_ff=18432
vocab=49152 — GQA, RoPE. [arXiv:2402.19173; hf]

Assigned as a pure full-attention dense arch -> long_500k is SKIPPED
(DESIGN.md §5: sub-quadratic attention required for that cell).
"""
import jax.numpy as jnp

from repro.configs import ArchSpec, LM_SHAPES
from repro.models.transformer import TransformerConfig


def make_config() -> TransformerConfig:
    return TransformerConfig(
        vocab_size=49_152, d_model=4608, n_layers=32, n_heads=36,
        n_kv_heads=4, d_head=128, d_ff=18_432,
        activation="gelu", rope_theta=100_000.0, causal=True,
        dtype=jnp.bfloat16, remat="full",
    )


def make_reduced() -> TransformerConfig:
    return TransformerConfig(
        vocab_size=512, d_model=64, n_layers=2, n_heads=4, n_kv_heads=2,
        d_head=16, d_ff=128, activation="gelu", causal=True,
        dtype=jnp.float32)


SPEC = ArchSpec(
    arch_id="starcoder2-7b", family="lm",
    make_config=make_config, make_reduced=make_reduced,
    shapes=LM_SHAPES, skip_shapes=("long_500k",),
    notes="pure full attention -> long_500k skipped per assignment",
)
