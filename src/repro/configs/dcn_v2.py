"""dcn-v2 [recsys]: n_dense=13 n_sparse=26 embed_dim=16 n_cross_layers=3
mlp=1024-1024-512, cross interaction. [arXiv:2008.13535; paper]
"""
from repro.configs import ArchSpec, RECSYS_SHAPES
from repro.models.recsys import CRITEO_KAGGLE_CARDS, RecsysConfig


def make_config() -> RecsysConfig:
    return RecsysConfig(
        arch="dcn_v2", n_dense=13, n_sparse=26, embed_dim=16,
        vocab_sizes=CRITEO_KAGGLE_CARDS,
        n_cross_layers=3, mlp_dims=(1024, 1024, 512))


def make_reduced() -> RecsysConfig:
    return RecsysConfig(
        arch="dcn_v2", n_dense=13, n_sparse=26, embed_dim=8,
        vocab_sizes=tuple([64] * 26), n_cross_layers=2, mlp_dims=(32, 16))


SPEC = ArchSpec(
    arch_id="dcn-v2", family="recsys",
    make_config=make_config, make_reduced=make_reduced,
    shapes=RECSYS_SHAPES,
)
