"""mixtral-8x22b [moe]: 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, MoE 8 experts top-2, sliding-window attention.
[arXiv:2401.04088; hf]
"""
import jax.numpy as jnp

from repro.configs import ArchSpec, LM_SHAPES
from repro.models.transformer import MoEConfig, TransformerConfig


def make_config() -> TransformerConfig:
    return TransformerConfig(
        vocab_size=32_768, d_model=6144, n_layers=56, n_heads=48,
        n_kv_heads=8, d_head=128, d_ff=16_384,
        moe=MoEConfig(num_experts=8, top_k=2),
        activation="swiglu", rope_theta=1_000_000.0,
        window=4096, causal=True,
        dtype=jnp.bfloat16, remat="full",
    )


def make_reduced() -> TransformerConfig:
    return TransformerConfig(
        vocab_size=512, d_model=64, n_layers=2, n_heads=4, n_kv_heads=2,
        d_head=16, d_ff=96, moe=MoEConfig(num_experts=4, top_k=2),
        activation="swiglu", window=16, causal=True, dtype=jnp.float32)


SPEC = ArchSpec(
    arch_id="mixtral-8x22b", family="lm",
    make_config=make_config, make_reduced=make_reduced,
    shapes=LM_SHAPES,
    notes="8 experts top-2; SWA 4096 -> long_500k runs with rolling cache",
)
