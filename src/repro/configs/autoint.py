"""autoint [recsys]: n_sparse=39 embed_dim=16 n_attn_layers=3 n_heads=2
d_attn=32, self-attention feature interaction. [arXiv:1810.11921; paper]

39 Criteo fields = 13 bucketised dense + 26 categorical (Kaggle cards).
"""
from repro.configs import ArchSpec, RECSYS_SHAPES
from repro.models.recsys import CRITEO_KAGGLE_CARDS, RecsysConfig


def make_config() -> RecsysConfig:
    return RecsysConfig(
        arch="autoint", n_dense=0, n_sparse=39, embed_dim=16,
        vocab_sizes=CRITEO_KAGGLE_CARDS,
        n_attn_layers=3, n_heads=2, d_attn=32)


def make_reduced() -> RecsysConfig:
    return RecsysConfig(
        arch="autoint", n_dense=0, n_sparse=39, embed_dim=8,
        vocab_sizes=tuple([64] * 26), n_attn_layers=2, n_heads=2, d_attn=8)


SPEC = ArchSpec(
    arch_id="autoint", family="recsys",
    make_config=make_config, make_reduced=make_reduced,
    shapes=RECSYS_SHAPES,
)
