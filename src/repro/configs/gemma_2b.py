"""gemma-2b [dense]: 18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=256000
— GeGLU, head_dim=256, embeddings scaled by sqrt(d_model), tied LM head.
[arXiv:2403.08295; hf]

Pure full attention -> long_500k SKIPPED (DESIGN.md §5).
"""
import jax.numpy as jnp

from repro.configs import ArchSpec, LM_SHAPES
from repro.models.transformer import TransformerConfig


def make_config() -> TransformerConfig:
    return TransformerConfig(
        vocab_size=256_000, d_model=2048, n_layers=18, n_heads=8,
        n_kv_heads=1, d_head=256, d_ff=16_384,
        activation="geglu", rope_theta=10_000.0, causal=True,
        tie_embeddings=True, embed_scale=True,
        dtype=jnp.bfloat16, remat="full",
    )


def make_reduced() -> TransformerConfig:
    return TransformerConfig(
        vocab_size=512, d_model=64, n_layers=2, n_heads=4, n_kv_heads=1,
        d_head=32, d_ff=128, activation="geglu", causal=True,
        tie_embeddings=True, embed_scale=True, dtype=jnp.float32)


SPEC = ArchSpec(
    arch_id="gemma-2b", family="lm",
    make_config=make_config, make_reduced=make_reduced,
    shapes=LM_SHAPES, skip_shapes=("long_500k",),
    notes="MQA (kv=1), GeGLU, head_dim 256; full attention -> long_500k skipped",
)
