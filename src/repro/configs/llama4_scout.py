"""llama4-scout-17b-a16e [moe]: 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16 experts top-1, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

Llama-4 uses iRoPE chunked local attention on most layers (chunk 8192),
which is what makes its long_500k decode cell sub-quadratic (DESIGN.md §5).
The [vlm]-style early-fusion frontend is a stub per the assignment:
input_specs provide token ids / precomputed embeddings only.
"""
import jax.numpy as jnp

from repro.configs import ArchSpec, LM_SHAPES
from repro.models.transformer import MoEConfig, TransformerConfig


def make_config() -> TransformerConfig:
    return TransformerConfig(
        vocab_size=202_048, d_model=5120, n_layers=48, n_heads=40,
        n_kv_heads=8, d_head=128, d_ff=8192,
        moe=MoEConfig(num_experts=16, top_k=1),
        activation="swiglu", rope_theta=500_000.0,
        attention_chunk=8192, causal=True,
        dtype=jnp.bfloat16, remat="full",
    )


def make_reduced() -> TransformerConfig:
    return TransformerConfig(
        vocab_size=512, d_model=64, n_layers=2, n_heads=4, n_kv_heads=2,
        d_head=16, d_ff=96, moe=MoEConfig(num_experts=4, top_k=1),
        activation="swiglu", attention_chunk=16, causal=True,
        dtype=jnp.float32)


SPEC = ArchSpec(
    arch_id="llama4-scout-17b-a16e", family="lm",
    make_config=make_config, make_reduced=make_reduced,
    shapes=LM_SHAPES,
    notes="MoE top-1, chunked attention 8192 -> long_500k runs windowed",
    # 16 experts == data axis: exact expert parallelism, expert-weight
    # gradients stay (1, D, F/16) per device instead of f32 full-D partials
    rules_override={"experts": "data", "embed": None},
)
