"""The paper's own experiment configuration: synthetic MSMarco-scale corpus
+ WindTunnel pipeline + semantic-search evaluation (Fig. 5, Tables I/II).
"""
import dataclasses

from repro.core.pipeline import WindTunnelConfig
from repro.retrieval.encoder import EncoderConfig


@dataclasses.dataclass(frozen=True)
class WindTunnelExperimentConfig:
    # corpus (calibrated — DESIGN.md §6, EXPERIMENTS.md §Repro)
    num_queries: int = 1280
    qrels_per_query: int = 32
    num_topics: int = 96
    aux_fraction: float = 2.0
    vocab_size: int = 3072
    query_len: int = 24
    # Fig. 4 corpus (degree-law calibration: gamma ~ 2.8-3.0)
    fig4_num_queries: int = 20000
    fig4_qrels_per_query: int = 3
    # pipeline
    windtunnel: WindTunnelConfig = WindTunnelConfig(
        tau_quantile=0.5, fanout=16, lp_rounds=5)
    sample_fraction: float = 0.15    # of judged entities (paper: 100K/corpus)
    # embedder
    encoder: EncoderConfig = EncoderConfig(vocab_size=3072)
    encoder_steps: int = 400
    seed: int = 0


CONFIG = WindTunnelExperimentConfig()
