"""mace [gnn]: n_layers=2 d_hidden=128 l_max=2 correlation_order=3 n_rbf=8,
E(3)-ACE higher-order equivariant message passing. [arXiv:2206.07697; paper]

WindTunnel applicability: NONE (no QRel structure on molecular graphs) —
implemented without the technique per DESIGN.md §5; shares the segment-sum
message-passing substrate with core label propagation.
"""
from repro.configs import ArchSpec, GNN_SHAPES
from repro.models.mace import MACEConfig


def make_config() -> MACEConfig:
    return MACEConfig(n_layers=2, channels=128, l_max=2, correlation=3,
                      n_rbf=8, d_feat=16)


def make_reduced() -> MACEConfig:
    return MACEConfig(n_layers=2, channels=8, l_max=2, correlation=3,
                      n_rbf=4, d_feat=8)


SPEC = ArchSpec(
    arch_id="mace", family="gnn",
    make_config=make_config, make_reduced=make_reduced,
    shapes=GNN_SHAPES,
    notes="d_feat per shape overrides config (full_graph_sm 1433, "
          "ogb_products 100); minibatch_lg uses the real neighbour sampler",
)
