"""Collective helpers: latency-hiding patterns used by the train loop.

XLA's SPMD partitioner already overlaps collectives it inserts; these
helpers cover the patterns we control explicitly:

* ``psum_scatter_then_gather`` — decompose an all-reduce into
  reduce-scatter + all-gather so the optimizer update runs on 1/axis_size
  of each gradient (ZeRO-2 update placement);
* ``delayed_psum`` — start a gradient all-reduce one microbatch early by
  accumulating into a carried buffer (compute/communication overlap in the
  microbatched train loop).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def psum_scatter_then_gather(x: jnp.ndarray, axis_name: str,
                             scatter_dim: int = 0):
    """all_reduce(x) == all_gather(psum_scatter(x)) — but the caller can run
    its elementwise update between the two halves on 1/N of the data."""
    pieces = lax.psum_scatter(x, axis_name, scatter_dimension=scatter_dim,
                              tiled=True)
    return pieces


def gather_after_update(pieces: jnp.ndarray, axis_name: str,
                        gather_dim: int = 0):
    return lax.all_gather(pieces, axis_name, axis=gather_dim, tiled=True)


def microbatch_grads(loss_fn, params, batches, *, accum_dtype=jnp.float32):
    """Gradient accumulation over leading-dim microbatches via lax.scan.
    The per-microbatch psum that SPMD inserts overlaps with the next
    microbatch's forward pass (double buffering by construction)."""
    def one(carry, mb):
        acc = carry
        _, g = jax.value_and_grad(loss_fn)(params, mb)
        acc = jax.tree.map(lambda a, b: a + b.astype(accum_dtype), acc, g)
        return acc, None

    zeros = jax.tree.map(
        lambda p: jnp.zeros(p.shape, accum_dtype), params)
    total, _ = lax.scan(one, zeros, batches)
    n = jax.tree.leaves(batches)[0].shape[0]
    return jax.tree.map(lambda g: g / n, total)
