"""Collective helpers: latency-hiding patterns used by the train loop.

XLA's SPMD partitioner already overlaps collectives it inserts; these
helpers cover the patterns we control explicitly:

* ``psum_scatter_then_gather`` — decompose an all-reduce into
  reduce-scatter + all-gather so the optimizer update runs on 1/axis_size
  of each gradient (ZeRO-2 update placement);
* ``delayed_psum`` — start a gradient all-reduce one microbatch early by
  accumulating into a carried buffer (compute/communication overlap in the
  microbatched train loop);
* ``flat_axis_index`` / ``all_concat`` — gather/merge
  primitives for the sharded WindTunnel pipeline (core/sharded_pipeline):
  a tuple of mesh axes treated as one flattened collective axis, with the
  first name most significant — consistent with ``lax.all_gather`` tiled
  concatenation order over the same tuple;
* ``pvary_compat`` / ``unvary_compat`` — portability shims for the
  varying-manual-axes annotations newer JAX requires on replicated
  ``shard_map`` scan carries (no-ops where ``lax.pvary`` is absent).
"""
from __future__ import annotations

from typing import Sequence, Union

import jax
import jax.numpy as jnp
from jax import lax

AxisNames = Union[str, Sequence[str]]


def _as_tuple(axis_names: AxisNames) -> tuple:
    return (axis_names,) if isinstance(axis_names, str) else tuple(axis_names)


def flat_axis_index(axis_names: AxisNames) -> jnp.ndarray:
    """Row-major linear index over a tuple of mesh axes (first name most
    significant), matching the shard order of a leading array dimension
    partitioned with ``PartitionSpec(tuple(axis_names), ...)``."""
    idx = jnp.int32(0)
    for name in _as_tuple(axis_names):
        idx = idx * lax.psum(jnp.int32(1), name) + lax.axis_index(name)
    return idx


def all_concat(tree, axis_names: AxisNames):
    """All-gather every array leaf along its leading dim (tiled), i.e.
    concatenate the per-shard tables into the replicated global table —
    the merge half of the sharded GraphBuilder's edge dedup."""
    axes = _as_tuple(axis_names)
    return jax.tree.map(
        lambda x: lax.all_gather(x, axes, axis=0, tiled=True), tree)


def pvary_compat(x, axis_names: AxisNames):
    """Mark a replicated value device-varying over ``axis_names`` where the
    installed JAX tracks varying manual axes; identity elsewhere."""
    if hasattr(lax, "pvary"):
        return lax.pvary(x, _as_tuple(axis_names))
    return x


def unvary_compat(x, axis_names: AxisNames):
    """Collapse a device-varying-but-equal value back to replicated."""
    return lax.pmax(x, _as_tuple(axis_names))


def psum_scatter_then_gather(x: jnp.ndarray, axis_name: str,
                             scatter_dim: int = 0):
    """all_reduce(x) == all_gather(psum_scatter(x)) — but the caller can run
    its elementwise update between the two halves on 1/N of the data."""
    pieces = lax.psum_scatter(x, axis_name, scatter_dimension=scatter_dim,
                              tiled=True)
    return pieces


def gather_after_update(pieces: jnp.ndarray, axis_name: str,
                        gather_dim: int = 0):
    return lax.all_gather(pieces, axis_name, axis=gather_dim, tiled=True)


def microbatch_grads(loss_fn, params, batches, *, accum_dtype=jnp.float32):
    """Gradient accumulation over leading-dim microbatches via lax.scan.
    The per-microbatch psum that SPMD inserts overlaps with the next
    microbatch's forward pass (double buffering by construction)."""
    def one(carry, mb):
        acc = carry
        _, g = jax.value_and_grad(loss_fn)(params, mb)
        acc = jax.tree.map(lambda a, b: a + b.astype(accum_dtype), acc, g)
        return acc, None

    zeros = jax.tree.map(
        lambda p: jnp.zeros(p.shape, accum_dtype), params)
    total, _ = lax.scan(one, zeros, batches)
    n = jax.tree.leaves(batches)[0].shape[0]
    return jax.tree.map(lambda g: g / n, total)
