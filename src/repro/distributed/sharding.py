"""Logical-axis sharding rules (the MaxText pattern).

Models annotate every parameter dim with a logical name
(models.transformer.param_logical_axes); the rules below map names to mesh
axes, so changing the parallelism layout never touches model code.

Default LM layout (single pod, mesh (data=16, model=16)):
  * TP (Megatron): qkv/ffn output features + vocab over 'model';
  * ZeRO: the complementary 'embed' dim of every matrix over 'data' — the
    fp32 master params AND AdamW m/v shard over the full 2-D mesh, which is
    what makes a 102B-param MoE fit 16GB v5e chips (4.8GB/chip fp32x3);
  * EP: MoE 'experts' over 'model';
  * batch over ('pod', 'data').
Multi-pod adds a pure-DP 'pod' axis: params replicated across pods, grads
all-reduced over DCI (compression hook lives there).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# logical axis -> mesh axis (None = replicate). Tuples shard one logical
# axis over multiple mesh axes.
LM_RULES = {
    "layers": None,
    "embed": "data",              # ZeRO dimension
    "embed_noshard": None,
    "qkv_features": "model",      # Megatron TP
    "kv_features": "model",
    "ffn": "model",
    # Baseline MoE layout: intra-expert TP (experts replicated as an axis,
    # each expert's (D, F) matrices sharded data x model). No padding waste
    # when num_experts < mesh axis (Mixtral: 8 experts on a 16-wide axis).
    # True EP (experts -> 'model') is a per-arch override / §Perf lever.
    "experts": None,
    "experts_noshard": None,
    "vocab": "model",
    # activations / batch
    "batch": ("pod", "data"),
    "seq": None,
    "seq_sharded": "data",        # sequence parallelism (long-context cells)
    "heads": "model",
    "kv_heads": "model",
    "cache_batch": ("pod", "data"),
}

RECSYS_RULES = {
    "table_rows": "model",        # row-sharded embedding tables
    "table_dim": None,
    "mlp_in": None,
    "mlp_out": "model",           # wide MLP layers TP'd
    "batch": ("pod", "data"),
    "candidates": "model",        # retrieval_cand candidate sharding
    "cross": None,
    "small": None,
}

GNN_RULES = {
    "nodes": ("data", "model"),   # node/edge arrays over the whole grid
    "edges": ("data", "model"),
    "queries": ("data", "model"),  # WindTunnel QRel table, query-partitioned
    "feat": None,
    "param": None,                # MACE params are small -> replicate
    "batch": ("pod", "data"),
}

RETRIEVAL_RULES = {
    "corpus": ("data", "model"),  # corpus vectors / LSH codes, row-sharded
    "lists": ("data", "model"),   # ivfflat inverted lists, list-sharded
    "queries": None,              # query batches replicate (small, chunked)
    "feat": None,
}


def _mesh_axes_for(mesh: Mesh, axis):
    """Filter rule target axes to those present in the mesh (so the same
    rules serve single-pod, multi-pod and 1-device test meshes)."""
    if axis is None:
        return None
    axes = axis if isinstance(axis, tuple) else (axis,)
    present = tuple(a for a in axes if a in mesh.axis_names)
    if not present:
        return None
    return present if len(present) > 1 else present[0]


def partition_axes(mesh: Mesh, logical_name: str, rules: dict) -> tuple:
    """Mesh axes (present in ``mesh``) that a logical dimension partitions
    over, as a tuple — e.g. GNN_RULES['nodes'] on the production mesh is
    ('data', 'model'), on a 1-device host mesh ('data', 'model') of size 1.
    The sharded WindTunnel pipeline treats the tuple as one flattened
    collective axis (collectives.flat_axis_index)."""
    axes = _mesh_axes_for(mesh, rules.get(logical_name))
    if axes is None:
        return ()
    return axes if isinstance(axes, tuple) else (axes,)


def logical_to_spec(mesh: Mesh, logical_axes: Optional[tuple],
                    rules: dict) -> P:
    if logical_axes is None:
        return P()
    return P(*(_mesh_axes_for(mesh, rules.get(name)) for name in logical_axes))


def tree_shardings(mesh: Mesh, logical_tree: Any, rules: dict):
    """Map a pytree of logical-axis tuples to NamedShardings."""
    def to_sharding(axes):
        return NamedSharding(mesh, logical_to_spec(mesh, axes, rules))
    return jax.tree.map(to_sharding, logical_tree,
                        is_leaf=lambda x: x is None or
                        (isinstance(x, tuple) and
                         all(isinstance(a, str) for a in x)))


def shaped(shape, dtype, mesh, logical_axes, rules):
    """ShapeDtypeStruct carrying its NamedSharding (dry-run input specs)."""
    return jax.ShapeDtypeStruct(
        shape, dtype,
        sharding=NamedSharding(mesh, logical_to_spec(mesh, logical_axes, rules)))
