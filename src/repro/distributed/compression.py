"""Error-feedback gradient compression for the cross-pod all-reduce.

At 512+ chips the pod-to-pod gradient all-reduce crosses DCI links with
~10x less bandwidth than intra-pod ICI; int8 quantisation cuts that traffic
4x (vs fp32 masters) with error feedback keeping the optimisation unbiased
(1-bit Adam / EF-SGD lineage).

Mechanics: grads are quantised per-leaf to int8 with a per-leaf fp32 scale,
the quantisation residual is carried in the error buffer and added back
next step. ``compressed_psum`` runs inside shard_map over the 'pod' axis —
the int8 tensor is what crosses the wire.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P


def ef_init(grads_like: Any):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)


def quantize_int8(x: jnp.ndarray):
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray):
    return q.astype(jnp.float32) * scale


def compress_leaf(g: jnp.ndarray, err: jnp.ndarray):
    """Error-feedback int8 compression of one gradient leaf.
    Returns (q, scale, new_err)."""
    corrected = g.astype(jnp.float32) + err
    q, scale = quantize_int8(corrected)
    new_err = corrected - dequantize_int8(q, scale)
    return q, scale, new_err


def compressed_grad_allreduce(grads: Any, err: Any, axis_name: str = "pod"):
    """Inside shard_map(..., axis_names including 'pod'): all-reduce grads
    across pods in int8 with error feedback. Returns (mean grads, new err)."""
    def leaf(g, e):
        q, scale, new_e = compress_leaf(g, e)
        # wire format: int8 payload + fp32 scale, summed across pods
        summed = lax.psum(dequantize_int8(q, scale), axis_name)
        n = lax.psum(jnp.ones((), jnp.float32), axis_name)
        return (summed / n).astype(g.dtype), new_e

    out = jax.tree.map(leaf, grads, err)
    new_grads = jax.tree.map(lambda t: t[0], out,
                             is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree.map(lambda t: t[1], out,
                           is_leaf=lambda x: isinstance(x, tuple))
    return new_grads, new_err


def topk_sparsify(g: jnp.ndarray, err: jnp.ndarray, frac: float = 0.01):
    """Deep-gradient-compression style top-k sparsification with error
    feedback (alternative compressor for very thin cross-site links)."""
    corrected = g.astype(jnp.float32) + err
    flat = corrected.reshape(-1)
    k = max(1, int(frac * flat.shape[0]))
    thresh = jnp.sort(jnp.abs(flat))[-k]
    mask = jnp.abs(corrected) >= thresh
    sent = jnp.where(mask, corrected, 0.0)
    return sent, corrected - sent
