"""Distributed runtime: logical-axis sharding rules (DP/TP/EP/SP),
error-feedback gradient compression for the cross-pod all-reduce, and
collective helpers."""
from repro.distributed.sharding import (tree_shardings, logical_to_spec,
                                        LM_RULES, RECSYS_RULES, GNN_RULES)

__all__ = ["tree_shardings", "logical_to_spec", "LM_RULES", "RECSYS_RULES",
           "GNN_RULES"]
