"""Sharded-from-birth corpora (DESIGN.md §13).

The legacy dataflow builds the full index / affinity graph on one device
and only then partitions the *work*; corpus size is therefore capped by a
single device's memory — exactly the regime the paper targets.  This
module inverts the flow: a host-resident corpus is streamed, chunk by
chunk, directly into per-device shard buffers, and everything downstream
(per-shard index construction in ``retrieval/sharded.py``, the shard-local
graph build in ``core/sharded_pipeline.py``) consumes the row-partitioned
global array without ever gathering it.  Peak per-device memory is
O(corpus / n_shards + chunk).

Two birth containers:

  * :class:`ShardedCorpus` — corpus vectors f32[N, D] row-partitioned over
    a mesh axis tuple (zero rows pad the tail to ``rows_per_shard × d``;
    pad rows carry global ids ≥ n and are masked by every consumer).
  * :class:`ShardedQRels` — a QRel table routed by query shard at birth:
    shard ``q // queries_per_shard`` owns every row of query q, matching
    ``core/sharded_pipeline._route_by_query`` (same stable original-row
    order within a shard, so downstream stable sorts see the same tie
    order as the single-device path — the bit-parity invariant).  Buffers
    are (d, n_buf) with global query ids; invalid rows are dropped at
    routing time.

Streaming mechanics: each device's block is copied ``chunk_rows`` rows at
a time into a donated on-device buffer (``lax.dynamic_update_slice`` with
``donate_argnums=0`` — no second buffer materialises), then the per-device
buffers are assembled into one global ``jax.Array`` with
``jax.make_array_from_single_device_arrays``.  Each shard's transfer is
wrapped in a ``search.build.shard`` / ``sampling.graph.shard`` trace span
so the build path is visible in ``launch/trace.py``.
"""
from __future__ import annotations

import functools
import warnings
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.sharding import (GNN_RULES, RETRIEVAL_RULES,
                                        partition_axes)
from repro.obs import trace

__all__ = ["ShardedCorpus", "ShardedQRels", "sharded_row_buffer",
           "stream_to_sharded", "resolve_corpus_axes", "resolve_query_axes"]


def _axis_count(mesh: Mesh, axes: tuple) -> int:
    d = 1
    for a in axes:
        d *= mesh.shape[a]
    return d


def _lead(axes: tuple):
    return axes if len(axes) > 1 else axes[0]


def resolve_corpus_axes(mesh: Mesh, axes: Optional[tuple]) -> tuple:
    """Mesh axes the corpus rows partition over (retrieval rule set)."""
    if axes is None:
        axes = partition_axes(mesh, "corpus", RETRIEVAL_RULES)
    axes = tuple(axes) if axes else ()
    if not axes:
        raise ValueError(
            f"mesh {mesh} has none of the retrieval corpus axes "
            f"({RETRIEVAL_RULES['corpus']})")
    return axes


def resolve_query_axes(mesh: Mesh, axes: Optional[tuple]) -> tuple:
    """Mesh axes the QRel query shards partition over (GNN rule set)."""
    if axes is None:
        axes = partition_axes(mesh, "queries", GNN_RULES)
    axes = tuple(axes) if axes else ()
    if not axes:
        raise ValueError(f"mesh {mesh} has none of the GNN query axes "
                         f"({GNN_RULES['queries']})")
    return axes


@functools.partial(jax.jit, donate_argnums=(0,))
def _chunk_update(buf, chunk, start):
    zeros = (jnp.int32(0),) * (chunk.ndim - 1)
    return lax.dynamic_update_slice(buf, chunk, (start,) + zeros)


def _stream_block(host_block: np.ndarray, device, buf_rows: int, *,
                  chunk_rows: int):
    """Move host rows onto one device as a ``buf_rows``-row buffer
    (zero-padded tail), ``chunk_rows`` rows at a time, so the transient
    footprint is the shard buffer plus one chunk."""
    tail = host_block.shape[1:]
    real = int(host_block.shape[0])
    if real == buf_rows and real <= chunk_rows:
        return jax.device_put(np.ascontiguousarray(host_block), device)
    buf = jax.device_put(np.zeros((buf_rows,) + tail, host_block.dtype),
                         device)
    with warnings.catch_warnings():
        # backends without buffer donation (CPU) warn per call; the donation
        # is a memory optimisation, not a correctness requirement
        warnings.filterwarnings("ignore", message=".*donated buffer.*")
        warnings.filterwarnings("ignore", message=".*[Dd]onation.*")
        for r0 in range(0, real, chunk_rows):
            chunk = np.ascontiguousarray(host_block[r0:r0 + chunk_rows])
            buf = _chunk_update(buf, jax.device_put(chunk, device),
                                jnp.int32(r0))
    return buf


def _device_blocks(sharding: NamedSharding, global_shape: tuple):
    """Ordered (device, row_start, row_stop) for a leading-dim row
    sharding, ascending by row offset."""
    imap = sharding.addressable_devices_indices_map(global_shape)
    blocks = []
    for dev, idx in imap.items():
        sl = idx[0]
        start = 0 if sl.start is None else int(sl.start)
        stop = global_shape[0] if sl.stop is None else int(sl.stop)
        blocks.append((dev, start, stop))
    blocks.sort(key=lambda b: b[1])
    return blocks


def stream_to_sharded(host: np.ndarray, sharding: NamedSharding,
                      global_shape: tuple, *, chunk_rows: int = 65536,
                      span: Optional[str] = None, **span_attrs):
    """Assemble a row-sharded global ``jax.Array`` of ``global_shape`` from
    a host array (rows beyond ``host.shape[0]`` become zero padding),
    without materialising more than one shard (+ one chunk) per device."""
    host = np.asarray(host)
    chunk_rows = max(1, int(chunk_rows))
    arrays = []
    for i, (dev, start, stop) in enumerate(
            _device_blocks(sharding, global_shape)):
        block = host[start:min(stop, host.shape[0])]
        ctx = (trace.span(span, shard=i, rows=int(block.shape[0]),
                          buf_rows=stop - start, **span_attrs)
               if span else _NULL_CTX)
        with ctx:
            arrays.append(_stream_block(block, dev, stop - start,
                                        chunk_rows=chunk_rows))
    return jax.make_array_from_single_device_arrays(
        global_shape, sharding, arrays)


class _NullCtx:
    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_CTX = _NullCtx()


class ShardedCorpus(NamedTuple):
    """Row-partitioned corpus vectors, sharded from birth.

    ``vecs`` is a global ``jax.Array`` f32[rows_per_shard·d, D] row-sharded
    over ``axes`` (zero rows pad the tail shard; their global ids are ≥ n,
    masked by every consumer); ``n`` is the true corpus row count.
    """

    vecs: Any
    n: int
    mesh: Mesh
    axes: Tuple[str, ...]

    @property
    def num_shards(self) -> int:
        return _axis_count(self.mesh, self.axes)

    @property
    def rows_per_shard(self) -> int:
        return self.vecs.shape[0] // self.num_shards

    @property
    def dim(self) -> int:
        return self.vecs.shape[1]

    @property
    def pad(self) -> int:
        return self.vecs.shape[0] - self.n

    @classmethod
    def from_host(cls, vecs, *, mesh: Mesh, axes: Optional[tuple] = None,
                  chunk_rows: int = 65536,
                  span: str = "search.build.shard") -> "ShardedCorpus":
        """Stream a host-resident corpus f32[N, D] into per-shard buffers."""
        host = np.asarray(vecs)
        if host.ndim != 2:
            raise ValueError(f"corpus must be 2-D (N, D); got {host.shape}")
        host = host.astype(np.float32, copy=False)
        axes = resolve_corpus_axes(mesh, axes)
        d = _axis_count(mesh, axes)
        n = int(host.shape[0])
        rows = -(-n // d)
        sharding = NamedSharding(mesh, P(_lead(axes), None))
        arr = stream_to_sharded(host, sharding, (rows * d, host.shape[1]),
                                chunk_rows=chunk_rows, span=span)
        return cls(arr, n, mesh, axes)


def sharded_row_buffer(host_rows: np.ndarray, *, capacity: int, dim: int,
                       mesh: Mesh, axes: Optional[tuple] = None,
                       chunk_rows: int = 65536,
                       span: str = "serve.ingest.shard"):
    """Fixed-capacity row-sharded append buffer (the serving tier's
    live-ingest structure, DESIGN.md §14): the first ``len(host_rows)``
    global rows carry the pending documents, the remainder is zeroed spare
    capacity.  Same geometry and streaming mechanics as a sharded-from-birth
    corpus — per-device blocks filled ``chunk_rows`` at a time — so the
    buffer is just one more shard-local structure next to the frozen index.
    Returns a global row-sharded jax.Array f32[ceil(capacity/d)·d, dim];
    which rows are live is the caller's dynamic ``n_valid`` scalar
    (retrieval/sharded.sharded_buffer_topk), so appends never retrace."""
    host = np.asarray(host_rows, np.float32).reshape(-1, dim)
    if host.shape[0] > capacity:
        raise ValueError(f"{host.shape[0]} pending rows exceed the buffer "
                         f"capacity {capacity}")
    axes = resolve_corpus_axes(mesh, axes)
    d = _axis_count(mesh, axes)
    rows = -(-max(int(capacity), 1) // d)
    sharding = NamedSharding(mesh, P(_lead(axes), None))
    return stream_to_sharded(host, sharding, (rows * d, dim),
                             chunk_rows=chunk_rows, span=span)


class QRelRows(NamedTuple):
    """Flat QRel rows, field-compatible with ``core.graph_builder.
    QRelTable`` (duck-typed by every draw-stage consumer) — defined here so
    ``table()`` needs no distributed -> core import against the layering."""

    query_ids: Any
    entity_ids: Any
    scores: Any
    valid: Any


class ShardedQRels(NamedTuple):
    """Query-routed QRel buffers, sharded from birth.

    Four (d, n_buf) buffers row-sharded over ``axes``: shard
    ``q // queries_per_shard`` owns every row of query q, in the original
    table's row order (host-side stable routing — the same tie order
    ``core/sharded_pipeline._route_by_query`` produces on device, which is
    what keeps the shard-local graph build bit-consistent with the global
    path).  Query ids are GLOBAL; invalid rows were dropped at routing
    time; unused buffer slots have ``valid == 0``.
    """

    query_ids: Any    # i32[d, n_buf] row-sharded
    entity_ids: Any   # i32[d, n_buf]
    scores: Any       # f32[d, n_buf]
    valid: Any        # i32[d, n_buf]
    num_queries: int
    num_entities: int
    queries_per_shard: int
    mesh: Mesh
    axes: Tuple[str, ...]

    @property
    def num_shards(self) -> int:
        return _axis_count(self.mesh, self.axes)

    @property
    def buffer_rows(self) -> int:
        return self.query_ids.shape[1]

    def table(self) -> "QRelRows":
        """The routed rows as a flat :class:`QRelRows` (global query ids,
        field-compatible with ``QRelTable``) — what the per-draw stages
        consume; row order differs from the birth table, which no
        draw-stage consumer depends on (reconstruction is row-order-free)."""
        return QRelRows(self.query_ids.reshape(-1),
                        self.entity_ids.reshape(-1),
                        self.scores.reshape(-1),
                        self.valid.reshape(-1).astype(bool))

    @classmethod
    def from_host(cls, qrels, *, num_queries: int, num_entities: int,
                  mesh: Mesh, axes: Optional[tuple] = None,
                  chunk_rows: int = 65536,
                  span: str = "sampling.graph.shard") -> "ShardedQRels":
        """Route a host-resident QRel table into per-shard buffers.

        ``qrels`` is anything with ``query_ids / entity_ids / scores /
        valid`` fields (a ``QRelTable`` or numpy equivalent).
        """
        q = np.asarray(qrels.query_ids).astype(np.int32, copy=False)
        e = np.asarray(qrels.entity_ids).astype(np.int32, copy=False)
        s = np.asarray(qrels.scores).astype(np.float32, copy=False)
        v = np.asarray(qrels.valid).astype(bool)
        axes = resolve_query_axes(mesh, axes)
        d = _axis_count(mesh, axes)
        qps = -(-int(num_queries) // d)
        # stable routing in original row order; invalid rows -> drop bucket
        shard = np.where(v, q // qps, d)
        order = np.argsort(shard, kind="stable")
        counts = np.bincount(shard[order], minlength=d + 1)[:d]
        n_buf = max(int(counts.max()) if counts.size else 0, 1)
        offsets = np.concatenate([[0], np.cumsum(counts)])
        sharding = NamedSharding(mesh, P(_lead(axes), None))
        blocks = _device_blocks(sharding, (d, n_buf))
        bufs = {name: [] for name in ("q", "e", "s", "v")}
        for i, (dev, start, stop) in enumerate(blocks):
            rows = order[offsets[start]:offsets[stop]]
            with trace.span(span, shard=i, rows=int(rows.size),
                            buf_rows=(stop - start) * n_buf):
                for name, field, dtype in (("q", q, np.int32),
                                           ("e", e, np.int32),
                                           ("s", s, np.float32),
                                           ("v", v, np.int32)):
                    block = np.zeros((stop - start, n_buf), dtype)
                    # rows grouped per owned shard, original order kept
                    for j, sh in enumerate(range(start, stop)):
                        owned = order[offsets[sh]:offsets[sh + 1]]
                        block[j, :owned.size] = field[owned]
                    bufs[name].append(_stream_block(
                        block, dev, stop - start, chunk_rows=chunk_rows))
        mk = functools.partial(jax.make_array_from_single_device_arrays,
                               (d, n_buf), sharding)
        return cls(mk(bufs["q"]), mk(bufs["e"]), mk(bufs["s"]),
                   mk(bufs["v"]), int(num_queries), int(num_entities),
                   qps, mesh, axes)
