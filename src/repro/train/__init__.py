"""Training substrate: optimizers (from scratch), sharded checkpointing with
async writes + atomic rename, elastic re-mesh resume, straggler policy."""
from repro.train.optimizer import (AdamWConfig, adamw_init, adamw_update,
                                   AdafactorConfig, adafactor_init,
                                   adafactor_update)
from repro.train.checkpoint import (save_checkpoint, restore_checkpoint,
                                    latest_step, AsyncCheckpointer)

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "AdafactorConfig",
           "adafactor_init", "adafactor_update", "save_checkpoint",
           "restore_checkpoint", "latest_step", "AsyncCheckpointer"]
