"""Fault-tolerant checkpointing without orbax: per-leaf npz shards + a JSON
manifest, written to a temp dir and atomically renamed (a crashed writer can
never corrupt the latest checkpoint). AsyncCheckpointer runs saves on a
background thread so the train loop never blocks on disk.

Restore is mesh-agnostic: leaves are stored unsharded (gathered); the
restoring launcher re-applies whatever NamedSharding the *current* mesh
prescribes — this is what makes elastic re-mesh resume (train/elastic.py)
a pure metadata operation.
"""
from __future__ import annotations

import json
import os
import queue
import re
import shutil
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

_MANIFEST = "manifest.json"


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save_checkpoint(directory: str, step: int, tree: Any) -> str:
    """Blocking save. Returns the final checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:010d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    paths, leaves, _ = _flatten_with_paths(tree)
    arrays = {}
    for i, (p, leaf) in enumerate(zip(paths, leaves)):
        arrays[f"leaf_{i}"] = np.asarray(jax.device_get(leaf))
    np.savez(os.path.join(tmp, "leaves.npz"), **arrays)
    manifest = {"step": step,
                "paths": paths,
                "dtypes": [str(a.dtype) for a in arrays.values()],
                "shapes": [list(a.shape) for a in arrays.values()]}
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)          # atomic publish
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for d in os.listdir(directory)
             if (m := re.fullmatch(r"step_(\d+)", d))]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, like: Any, step: Optional[int] = None,
                       shardings: Any = None) -> tuple[Any, int]:
    """Restore into the structure of ``like``; optionally re-shard each leaf
    with a matching pytree (or flat list) of NamedShardings."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:010d}")
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "leaves.npz"))
    paths, leaves, treedef = _flatten_with_paths(like)
    if paths != manifest["paths"]:
        raise ValueError(
            "checkpoint structure mismatch:\n saved=%s\n want=%s" %
            (manifest["paths"][:5], paths[:5]))
    shard_list = (jax.tree.leaves(shardings,
                                  is_leaf=lambda s: isinstance(s, jax.sharding.Sharding))
                  if shardings is not None else [None] * len(leaves))
    out = []
    for i, (leaf, sh) in enumerate(zip(leaves, shard_list)):
        arr = data[f"leaf_{i}"]
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jnp.asarray(arr))
    return jax.tree.unflatten(treedef, out), step


class AsyncCheckpointer:
    """Background-thread checkpoint writer with bounded queue (depth 1:
    a newer pending save supersedes an older one, like orbax's behaviour)."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._q: queue.Queue = queue.Queue(maxsize=1)
        self._err: Optional[BaseException] = None
        self._t = threading.Thread(target=self._worker, daemon=True)
        self._t.start()

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, host_tree = item
            try:
                save_checkpoint(self.directory, step, host_tree)
                self._gc()
            except BaseException as e:  # surfaced on next save()/close()
                self._err = e

    def _gc(self):
        steps = sorted(int(m.group(1)) for d in os.listdir(self.directory)
                       if (m := re.fullmatch(r"step_(\d+)", d)))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory,
                                       f"step_{s:010d}"), ignore_errors=True)

    def save(self, step: int, tree: Any):
        if self._err:
            raise self._err
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        try:
            self._q.put_nowait((step, host_tree))
        except queue.Full:
            # drop the superseded pending save, enqueue the newer one
            try:
                self._q.get_nowait()
            except queue.Empty:
                pass
            self._q.put_nowait((step, host_tree))

    def close(self):
        self._q.put(None)
        self._t.join()
        if self._err:
            raise self._err
