"""Optimizers from scratch (no optax offline): AdamW and Adafactor.

AdamW keeps fp32 m/v with the same sharding as the parameters (the launcher
shards both over the mesh, ZeRO-style — see distributed/sharding.py), plus
linear-warmup cosine decay. Adafactor factors the second moment of matrices
into row/col statistics — 1/r the optimizer memory, the standard choice for
the biggest MoE archs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    grad_clip: float = 1.0


def _schedule(step, cfg):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps) /
                 jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gn


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(grads, state, params, cfg: AdamWConfig):
    step = state["step"] + 1
    grads, gn = clip_by_global_norm(grads, cfg.grad_clip)
    lr = _schedule(step, cfg)
    b1, b2 = cfg.b1, cfg.b2

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * g32
        v_new = b2 * v + (1 - b2) * g32 * g32
        mhat = m_new / (1 - b1 ** step)
        vhat = v_new / (1 - b2 ** step)
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {"grad_norm": gn, "lr": lr}


# ---------------------------------------------------------------------------
# Adafactor (factored second moments)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AdafactorConfig:
    lr: float = 1e-2
    decay: float = 0.8
    eps1: float = 1e-30
    eps2: float = 1e-3
    clip_threshold: float = 1.0
    weight_decay: float = 0.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def _factored(shape):
    return len(shape) >= 2


def adafactor_init(params):
    def init(p):
        if _factored(p.shape):
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    return {"slots": jax.tree.map(init, params,
                                  is_leaf=lambda x: isinstance(x, jnp.ndarray)),
            "step": jnp.zeros((), jnp.int32)}


def adafactor_update(grads, state, params, cfg: AdafactorConfig):
    step = state["step"] + 1
    beta = 1.0 - (step.astype(jnp.float32) + 1.0) ** (-cfg.decay)
    sched = AdamWConfig(lr=cfg.lr, warmup_steps=cfg.warmup_steps,
                        total_steps=cfg.total_steps,
                        min_lr_ratio=cfg.min_lr_ratio)
    lr = _schedule(step, sched)

    def upd(g, slot, p):
        g32 = g.astype(jnp.float32)
        g2 = g32 * g32 + cfg.eps1
        if _factored(p.shape):
            vr = beta * slot["vr"] + (1 - beta) * g2.mean(-1)
            vc = beta * slot["vc"] + (1 - beta) * g2.mean(-2)
            denom = (vr / jnp.maximum(vr.mean(-1, keepdims=True), cfg.eps1))[..., None] * vc[..., None, :]
            u = g32 / jnp.sqrt(denom + cfg.eps1)
            new_slot = {"vr": vr, "vc": vc}
        else:
            v = beta * slot["v"] + (1 - beta) * g2
            u = g32 / jnp.sqrt(v + cfg.eps1)
            new_slot = {"v": v}
        rms_u = jnp.sqrt(jnp.mean(u * u) + cfg.eps1)
        u = u / jnp.maximum(1.0, rms_u / cfg.clip_threshold)
        scale = jnp.maximum(jnp.sqrt(jnp.mean(p.astype(jnp.float32) ** 2)), cfg.eps2)
        new_p = (p.astype(jnp.float32) - lr * scale * u
                 - lr * cfg.weight_decay * p.astype(jnp.float32))
        return new_p.astype(p.dtype), new_slot

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_s = treedef.flatten_up_to(state["slots"])
    out = [upd(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_s = treedef.unflatten([o[1] for o in out])
    return new_p, {"slots": new_s, "step": step}, {"lr": lr}
