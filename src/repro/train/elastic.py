"""Elastic re-mesh resume + fault-tolerance policies.

Checkpoints are stored unsharded (train/checkpoint.py), so resuming on a
DIFFERENT mesh (a pod dropped out, or capacity grew) is a pure placement
operation: rebuild the sharding tree for the new mesh and device_put each
leaf. Global batch is preserved by rescaling gradient-accumulation steps.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax

from repro.train.checkpoint import restore_checkpoint


@dataclasses.dataclass
class ElasticPlan:
    mesh: Any
    accum_steps: int            # microbatches to keep the global batch fixed
    per_step_batch: int


def plan_for_mesh(mesh, *, global_batch: int, base_data_parallel: int) -> ElasticPlan:
    """Given a (possibly shrunken/grown) mesh, keep the global batch constant
    by trading data-parallel width against gradient-accumulation depth."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = sizes.get("data", 1) * sizes.get("pod", 1)
    accum = max(1, base_data_parallel // dp)
    return ElasticPlan(mesh, accum, global_batch // accum)


def resume_on_mesh(ckpt_dir: str, like: Any, mesh, shardings) -> tuple:
    """Restore the latest checkpoint onto ``mesh`` with ``shardings``
    (a pytree matching ``like``). Works regardless of the writing mesh."""
    return restore_checkpoint(ckpt_dir, like, shardings=shardings)


class StragglerPolicy:
    """Step-deadline straggler mitigation for the synchronous train loop.

    The launcher wraps each step; if wall time exceeds
    ``deadline_factor`` x the rolling median, the step is flagged. After
    ``max_flags`` consecutive flags the runner requests a re-mesh without
    the slow host (in this single-host research harness that surfaces as an
    ElasticPlan with smaller data-parallel width). Deterministic and
    side-effect free so it is unit-testable.
    """

    def __init__(self, deadline_factor: float = 3.0, max_flags: int = 3,
                 window: int = 32):
        self.deadline_factor = deadline_factor
        self.max_flags = max_flags
        self.window = window
        self._times: list = []
        self._flags = 0

    def observe(self, step_seconds: float) -> str:
        """Returns 'ok' | 'flag' | 'remesh'."""
        self._times = (self._times + [step_seconds])[-self.window:]
        med = sorted(self._times)[len(self._times) // 2]
        if len(self._times) >= 5 and step_seconds > self.deadline_factor * med:
            self._flags += 1
            if self._flags >= self.max_flags:
                self._flags = 0
                return "remesh"
            return "flag"
        self._flags = 0
        return "ok"


class HeartbeatMonitor:
    """Host-level liveness: workers call ``beat(worker_id)``; ``dead()``
    reports workers silent for longer than ``timeout_s``. The launcher
    converts dead workers into an elastic re-mesh."""

    def __init__(self, timeout_s: float = 60.0, now: Callable = time.time):
        self.timeout_s = timeout_s
        self._now = now
        self._last: dict = {}

    def beat(self, worker_id: str):
        self._last[worker_id] = self._now()

    def dead(self) -> list:
        t = self._now()
        return [w for w, last in self._last.items()
                if t - last > self.timeout_s]
