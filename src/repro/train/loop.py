"""Fault-tolerant training loop: jitted step + async checkpoints + straggler
policy + elastic resume. Used by launch/train.py and examples/.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.train.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.train.elastic import StragglerPolicy


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    log_every: int = 10
    checkpoint_every: int = 50
    checkpoint_dir: Optional[str] = None
    keep_checkpoints: int = 3


def train_loop(step_fn: Callable, params: Any, opt_state: Any,
               batch_fn: Callable[[int], Any], cfg: LoopConfig,
               *, metrics_cb: Optional[Callable] = None) -> tuple:
    """Runs ``step_fn(params, opt_state, batch) -> (params, opt_state, loss)``
    for cfg.total_steps, resuming from the latest checkpoint if present.
    The data order is a pure function of the step index (data/batching.py),
    so restarts are exactly-once without an iterator checkpoint."""
    start = 0
    ckpt = None
    if cfg.checkpoint_dir:
        ckpt = AsyncCheckpointer(cfg.checkpoint_dir, keep=cfg.keep_checkpoints)
        last = latest_step(cfg.checkpoint_dir)
        if last is not None:
            (params, opt_state), start = restore_checkpoint(
                cfg.checkpoint_dir, (params, opt_state))
            print(f"resumed from step {start}")

    policy = StragglerPolicy()
    losses = []
    for step in range(start, cfg.total_steps):
        t0 = time.time()
        batch = batch_fn(step)
        params, opt_state, loss = step_fn(params, opt_state, batch)
        jax.block_until_ready(loss)
        status = policy.observe(time.time() - t0)
        if status == "remesh":
            print(f"step {step}: persistent straggler -> snapshot + remesh "
                  f"requested (see train/elastic.py)")
            if ckpt:
                ckpt.save(step + 1, (params, opt_state))
        losses.append(float(loss))
        if cfg.log_every and step % cfg.log_every == 0:
            print(f"step {step}: loss {float(loss):.4f} "
                  f"({time.time() - t0:.2f}s)", flush=True)
        if ckpt and (step + 1) % cfg.checkpoint_every == 0:
            ckpt.save(step + 1, (params, opt_state))
    if ckpt:
        ckpt.save(cfg.total_steps, (params, opt_state))
        ckpt.close()
    return params, opt_state, losses
