"""Shim package so ``python -m launch.lint`` (the documented short form)
resolves with only ``src`` on PYTHONPATH; delegates to repro.launch."""
