"""``python -m launch.lint`` — thin alias for repro.launch.lint."""
import sys

from repro.launch.lint import main

if __name__ == "__main__":
    sys.exit(main())
