"""Search-core tests (DESIGN.md §9): scoring-backend registry and
jnp-vs-pallas parity for every retrieval engine, sharded-search equivalence
on 1-device and 2x1 meshes, and the SearchSession front door shared by the
offline grid and the serving path."""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.mesh import make_host_mesh
from repro.retrieval.backends import (ScoringBackend, available_backends,
                                      get_backend)
from repro.retrieval.engines import (available_retrieval_engines,
                                     get_retrieval_engine)
from repro.retrieval.search_core import SearchConfig, SearchSession
from repro.retrieval.sharded import sharded_search

ENGINES = ("exact", "ivfflat", "lsh", "tfidf")


@pytest.fixture(scope="module")
def data():
    key = jax.random.PRNGKey(0)
    vecs = jax.random.normal(key, (301, 24))
    queries = jax.random.normal(jax.random.PRNGKey(1), (9, 24))
    return vecs, queries


def _row_sets(ids):
    return [set(int(x) for x in row if x >= 0) for row in np.asarray(ids)]


def test_backend_registry_contents():
    assert {"jnp", "pallas", "int8"} <= set(available_backends())
    for name in available_backends():
        assert isinstance(get_backend(name), ScoringBackend)
    assert set(ENGINES) == set(available_retrieval_engines())


def test_unknown_backend_raises():
    with pytest.raises(ValueError, match="registered backends"):
        get_backend("cuda")


@pytest.mark.parametrize("engine", ENGINES)
def test_pallas_backend_matches_jnp(data, engine):
    """Every registered engine produces pallas-backend top-k set-equal to
    the jnp backend (the documented tie policy breaks ties to lower ids on
    both, so continuous scores give exact id-array equality too)."""
    vecs, queries = data
    eng = get_retrieval_engine(engine)
    index = eng.build(jax.random.PRNGKey(0), vecs)
    ids_j = eng.search(index, queries, k=5)
    ids_p = dataclasses.replace(eng, backend="pallas").search(
        index, queries, k=5)
    assert _row_sets(ids_j) == _row_sets(ids_p)


@pytest.mark.parametrize("engine", ENGINES)
def test_int8_backend_matches_jnp(data, engine):
    """int8 quantized scoring + float rerank is top-k set-equal to the jnp
    backend for every engine: with rerank_factor*k >= k the true top-k sits
    inside the int8 candidate pool and the float rerank restores the exact
    ordering (the DESIGN.md §11 exactness argument)."""
    vecs, queries = data
    eng = get_retrieval_engine(engine)
    index = eng.build(jax.random.PRNGKey(0), vecs)
    ids_j = eng.search(index, queries, k=5)
    eng8 = dataclasses.replace(eng, backend="int8")
    ids_8 = eng8.search(eng8.build(jax.random.PRNGKey(0), vecs),
                        queries, k=5)
    assert _row_sets(ids_j) == _row_sets(ids_8)


def test_int8_backend_quantizes_once_at_build(data):
    """ExactEngine.build under the int8 backend returns a QuantizedCorpus
    (corpus quantized once at session build, not per search call)."""
    from repro.retrieval.backends import QuantizedCorpus
    vecs, queries = data
    eng = dataclasses.replace(get_retrieval_engine("exact"), backend="int8")
    index = eng.build(jax.random.PRNGKey(0), vecs)
    assert isinstance(index, QuantizedCorpus)
    assert index.codes.dtype == jnp.int8
    assert index.codes.shape == vecs.shape


def test_session_int8_backend(data):
    """Front-door int8: SearchSession(backend='int8') == jnp session as id
    sets, and the sharded+int8 combination is rejected at build."""
    vecs, queries = data
    ref = SearchSession(vecs, SearchConfig(backend="jnp")).search(
        queries, k=5)
    ids = SearchSession(vecs, SearchConfig(backend="int8")).search(
        queries, k=5)
    assert _row_sets(ids) == _row_sets(ref)
    with pytest.raises(ValueError, match="int8"):
        SearchSession(vecs, SearchConfig(backend="int8", sharded=True,
                                         mesh=make_host_mesh()))


def test_sharded_int8_raises(data):
    vecs, queries = data
    eng = dataclasses.replace(get_retrieval_engine("exact"), backend="int8")
    index = eng.build(jax.random.PRNGKey(0), vecs)
    with pytest.raises(ValueError, match="int8"):
        sharded_search(eng, index, queries, k=5, mesh=make_host_mesh())


@pytest.mark.parametrize("backend", ("jnp", "pallas"))
@pytest.mark.parametrize("engine", ENGINES)
def test_sharded_matches_single_device_1dev(data, engine, backend):
    """Layer 2 on a 1-device mesh is bit-consistent with single-device
    search for every engine x backend."""
    vecs, queries = data
    eng = dataclasses.replace(get_retrieval_engine(engine), backend=backend)
    index = eng.build(jax.random.PRNGKey(0), vecs)
    ref = np.asarray(eng.search(index, queries, k=5))
    _, ids = sharded_search(eng, index, queries, k=5, mesh=make_host_mesh())
    assert (np.asarray(ids) == ref).all(), engine


def test_sharded_k_exceeds_corpus(data):
    vecs, queries = data
    eng = get_retrieval_engine("exact")
    index = eng.build(jax.random.PRNGKey(0), vecs[:3])
    s, ids = sharded_search(eng, index, queries, k=7, mesh=make_host_mesh())
    ids = np.asarray(ids)
    assert ids.shape == (queries.shape[0], 7)
    assert (ids[:, 3:] == -1).all()
    assert np.isneginf(np.asarray(s)[:, 3:]).all()


def test_sharded_unknown_engine(data):
    vecs, queries = data

    class FaissEngine:
        name = "faiss"

    with pytest.raises(ValueError, match="sharded search plan"):
        sharded_search(FaissEngine(), vecs, queries, k=3,
                       mesh=make_host_mesh())


_TWO_DEVICE_SCRIPT = textwrap.dedent("""\
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    assert len(jax.devices()) == 2, jax.devices()
    from repro.retrieval.engines import (available_retrieval_engines,
                                         get_retrieval_engine)
    from repro.retrieval.lsh import search_lsh
    from repro.retrieval.sharded import sharded_search
    mesh = jax.make_mesh((2, 1), ("data", "model"))
    # N=301 is odd on purpose: the padded shard row must never displace a
    # real candidate; the -2.0 shift makes every score negative, the case a
    # zero-scoring pad row would win
    for shift in (0.0, -2.0):
        vecs = jax.random.normal(jax.random.PRNGKey(0), (301, 24)) + shift
        queries = jax.random.normal(jax.random.PRNGKey(1), (9, 24))
        for name in available_retrieval_engines():
            eng = get_retrieval_engine(name)
            index = eng.build(jax.random.PRNGKey(0), vecs)
            ref = np.asarray(eng.search(index, queries, k=5))
            _, ids = sharded_search(eng, index, queries, k=5, mesh=mesh)
            ids = np.asarray(ids)
            for a, b in zip(ids, ref):
                assert set(a.tolist()) == set(b.tolist()), (name, a, b)
    # reviewer repro: all-negative 1-d corpus, N=5 -> pad row on shard 1
    corpus = jnp.asarray([[-10.], [-11.], [-12.], [-1.], [-2.]])
    eng = get_retrieval_engine("exact")
    _, ids = sharded_search(eng, corpus, jnp.asarray([[1.]]), k=2,
                            mesh=mesh)
    assert np.asarray(ids)[0].tolist() == [3, 4], np.asarray(ids)
    # lsh without rerank: pure Hamming ranking must also survive padding
    eng = dataclasses.replace(get_retrieval_engine("lsh"), n_bits=32,
                              rerank=0)
    vecs = jax.random.normal(jax.random.PRNGKey(2), (157, 8))
    queries = jax.random.normal(jax.random.PRNGKey(3), (7, 8))
    index = eng.build(jax.random.PRNGKey(0), vecs)
    d_ref, _ = search_lsh(index, queries, k=5, rerank=0)
    d_sh, _ = sharded_search(eng, index, queries, k=5, mesh=mesh)
    assert np.allclose(np.sort(np.asarray(d_sh), 1),
                       np.sort(np.asarray(d_ref), 1))
    print("2x1-OK")
""")


def test_sharded_two_device_mesh():
    """Satellite: per-shard top-k + global merge equals single-device top-k
    (set equality under ties) on a 2x1 mesh for every registered engine.
    Subprocess because the test session itself must see 1 CPU device."""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ, JAX_PLATFORMS="cpu",
               XLA_FLAGS="--xla_force_host_platform_device_count=2",
               PYTHONPATH=src + os.pathsep + os.environ.get("PYTHONPATH", ""))
    out = subprocess.run([sys.executable, "-c", _TWO_DEVICE_SCRIPT],
                         env=env, capture_output=True, text=True,
                         timeout=900)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "2x1-OK" in out.stdout


# ---------------------------------------------------------------------------
# SearchSession front door
# ---------------------------------------------------------------------------

def test_session_chunks_and_maps_like_engine(data):
    """Chunked session search == one-shot engine search, mapped through the
    sample's global ids (−1 preserved)."""
    vecs, queries = data
    kept = (np.arange(vecs.shape[0]) * 2 + 100).astype(np.int64)
    session = SearchSession(vecs, SearchConfig(engine="exact",
                                               query_chunk=4),
                            ids_map=kept)
    eng = get_retrieval_engine("exact")
    ref = np.asarray(eng.search(eng.build(jax.random.PRNGKey(0), vecs),
                                queries, k=5))
    assert (session.search(queries, k=5) == kept[ref]).all()


def test_session_k_clamped_to_corpus(data):
    vecs, queries = data
    session = SearchSession(vecs[:4], SearchConfig(engine="exact"))
    ids = session.search(queries, k=9)
    assert ids.shape == (queries.shape[0], 9)
    assert (ids[:, 4:] == -1).all()
    assert (ids[:, :4] >= 0).all()


def test_session_registry_error_ux(data):
    vecs, _ = data
    with pytest.raises(ValueError, match="registered engines"):
        SearchSession(vecs, SearchConfig(engine="faiss"))
    with pytest.raises(ValueError, match="registered backends"):
        SearchSession(vecs, SearchConfig(backend="cuda"))
    with pytest.raises(ValueError, match="mesh"):
        SearchSession(vecs, SearchConfig(sharded=True))
    with pytest.raises(ValueError, match="ids_map"):
        SearchSession(vecs, ids_map=np.arange(3))


def test_session_engine_opts_and_sharded_front_door(data):
    vecs, queries = data
    session = SearchSession(
        vecs, SearchConfig(engine="ivfflat",
                           engine_opts={"n_lists": 4, "nprobe": 4}))
    assert session.engine.n_lists == 4
    plain = session.search(queries, k=3)
    sharded = SearchSession(
        vecs, SearchConfig(engine="ivfflat", sharded=True,
                           mesh=make_host_mesh(),
                           engine_opts={"n_lists": 4, "nprobe": 4}))
    assert (sharded.search(queries, k=3) == plain).all()


def test_retrieval_frontend_routes_through_search_core(data):
    """serve path: RetrievalFrontend.retrieve == SearchSession.search on
    the same config (the online/offline unification of DESIGN.md §9)."""
    from repro.serve.engine import RetrievalFrontend
    vecs, queries = data
    frontend = RetrievalFrontend(vecs, lambda q: jnp.asarray(q),
                                 config=SearchConfig(engine="lsh"))
    session = SearchSession(vecs, SearchConfig(engine="lsh"))
    assert (frontend.retrieve(queries, k=4) ==
            session.search(queries, k=4)).all()


def test_grid_cells_identical_across_backends():
    """eval path routes through the search core: with the deterministic
    engines (exact/tfidf) every grid cell is identical under jnp and pallas
    backends."""
    from repro.data.synthetic import generate_corpus
    from repro.eval import GridSpec, run_grid
    corpus = generate_corpus(num_queries=64, qrels_per_query=6,
                             num_topics=8, aux_fraction=0.3,
                             vocab_size=256, seed=0)
    spec = GridSpec(samplers=("full",), engines=("exact", "tfidf"),
                    ks=(3,), metrics=("precision", "mrr"), max_queries=64)
    r_jnp = run_grid(corpus, spec)
    r_pal = run_grid(corpus, spec, search=SearchConfig(backend="pallas"))
    assert r_jnp.cells.keys() == r_pal.cells.keys()
    for cell, value in r_jnp.cells.items():
        assert value == pytest.approx(r_pal.cells[cell], abs=1e-12), cell
