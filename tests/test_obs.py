"""Observability layer tests (DESIGN.md §12): the span tracer's disabled
no-op fast path and JSONL round-trip, histogram percentiles against
hand-computed fixtures, serve latency percentiles end-to-end, PlanTrie
counter parity with the legacy per-node sums, the drain step-bound guard,
draw-cache hit/miss counters, and the launch/trace.py aggregator."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import trace as trace_cli
from repro.obs import REGISTRY, Histogram, Registry, trace
from repro.obs.timing import provenance, timeit


@pytest.fixture(autouse=True)
def _tracer_disabled():
    """Every test starts and ends with the tracer off (process-global)."""
    trace.disable()
    yield
    trace.disable()


# --------------------------------------------------------------------------
# tracer: disabled no-op fast path
# --------------------------------------------------------------------------

def test_disabled_tracer_is_strict_noop(tmp_path):
    assert not trace.is_enabled()
    before = trace._STATE.records_written
    s = trace.span("anything", attr=1)
    j = trace.jax_span("anything.jax", compile_key="k", attr=2)
    # no span objects allocated: both return the one shared singleton
    assert s is trace.NOOP and j is trace.NOOP
    with trace.span("outer") as sp:
        sp.set(x=1).declare(jnp.zeros(3))   # chainable, retains nothing
        with trace.jax_span("inner") as inner:
            inner.declare(jnp.ones(2))
    assert trace._STATE.records_written == before   # nothing written
    assert trace.enabled_path() is None


def test_env_configure_blank_and_off_values(monkeypatch, tmp_path):
    for off in ("", "0", "off", "none", "  "):
        monkeypatch.setenv(trace.ENV_VAR, off)
        trace.configure_from_env()
        assert not trace.is_enabled()
    sink = tmp_path / "t.jsonl"
    monkeypatch.setenv(trace.ENV_VAR, str(sink))
    trace.configure_from_env()
    assert trace.is_enabled() and trace.enabled_path() == str(sink)
    trace.disable()
    assert not trace.is_enabled()


# --------------------------------------------------------------------------
# tracer: JSONL round-trip, nesting, attrs, first/steady, block_s
# --------------------------------------------------------------------------

def test_span_nesting_and_attrs_roundtrip(tmp_path):
    sink = tmp_path / "spans.jsonl"
    trace.enable(str(sink))
    with trace.span("outer", stage="build") as outer:
        with trace.span("inner", i=3) as inner:
            inner.set(found=True)
        outer.set(n=7)
    trace.disable()

    recs = trace_cli.load_spans(str(sink))
    assert [r["name"] for r in recs] == ["inner", "outer"]  # close order
    inner_r, outer_r = recs
    assert inner_r["parent"] == outer_r["id"]
    assert outer_r["parent"] is None
    assert inner_r["attrs"] == {"i": 3, "found": True}
    assert outer_r["attrs"] == {"stage": "build", "n": 7}
    assert outer_r["dur_s"] >= inner_r["dur_s"] >= 0.0


def test_jax_span_first_flag_and_block(tmp_path):
    sink = tmp_path / "spans.jsonl"
    trace.enable(str(sink))
    f = jax.jit(lambda x: x * 2 + 1)
    for _ in range(3):
        with trace.jax_span("stage.x", compile_key="stage.x/shape1") as sp:
            sp.declare(f(jnp.arange(8.0)))
    trace.disable()
    recs = trace_cli.load_spans(str(sink))
    assert [r["first"] for r in recs] == [True, False, False]
    assert all("block_s" in r and r["block_s"] >= 0.0 for r in recs)
    # distinct compile key -> its own first flag
    trace.enable(str(sink))
    with trace.jax_span("stage.x", compile_key="stage.x/shape2") as sp:
        sp.declare(f(jnp.arange(16.0)))
    trace.disable()
    assert trace_cli.load_spans(str(sink))[-1]["first"] is True


def test_span_records_error(tmp_path):
    sink = tmp_path / "spans.jsonl"
    trace.enable(str(sink))
    with pytest.raises(ValueError, match="boom"):
        with trace.span("failing"):
            raise ValueError("boom")
    trace.disable()
    (rec,) = trace_cli.load_spans(str(sink))
    assert rec["error"] == "ValueError: boom"


# --------------------------------------------------------------------------
# metrics: histogram percentiles on hand-computed fixtures
# --------------------------------------------------------------------------

def test_histogram_percentile_fixture():
    h = Histogram("t", buckets=(1.0, 2.0, 4.0))
    for v in (0.5, 1.5, 3.0, 3.0, 8.0):
        h.observe(v)
    # rank(p50) = 2.5 -> third bucket (2, 4]: lo=2, hi=4, frac=0.25 -> 2.5
    assert h.percentile(50) == pytest.approx(2.5)
    # rank(p99) = 4.95 -> overflow bucket -> observed max
    assert h.percentile(99) == pytest.approx(8.0)
    assert h.percentile(0) == pytest.approx(0.5)    # clamped to observed min
    assert h.percentile(100) == pytest.approx(8.0)
    assert h.count == 5 and h.mean == pytest.approx(3.2)
    assert h.min == 0.5 and h.max == 8.0
    d = h.to_dict()
    assert d["p50"] == pytest.approx(2.5) and d["p99"] == pytest.approx(8.0)


def test_histogram_empty_and_bounds():
    h = Histogram("t", buckets=(1.0,))
    assert h.percentile(50) == 0.0
    assert h.to_dict()["count"] == 0
    with pytest.raises(ValueError):
        h.percentile(101)
    with pytest.raises(ValueError):
        Histogram("t", buckets=())


def test_registry_get_or_create_and_snapshot():
    reg = Registry()
    reg.counter("a").inc()
    reg.counter("a").inc(2)
    reg.gauge("g").set(0.5)
    reg.histogram("h", buckets=(1.0,)).observe(0.3)
    snap = reg.snapshot()
    assert snap["counters"] == {"a": 3}
    assert snap["gauges"] == {"g": 0.5}
    assert snap["histograms"]["h"]["count"] == 1
    json.dumps(snap)                 # snapshot must be JSON-able
    reg.reset()
    assert reg.snapshot() == {"counters": {}, "gauges": {}, "histograms": {}}


# --------------------------------------------------------------------------
# serve: latency histogram e2e + drain guard
# --------------------------------------------------------------------------

def _tiny_engine(max_batch=2, max_new=4):
    from repro.models.transformer import TransformerConfig, init_transformer
    from repro.serve.engine import ServeConfig, ServeEngine
    cfg = TransformerConfig(vocab_size=64, d_model=32, n_layers=2, n_heads=4,
                            n_kv_heads=2, d_ff=48, dtype=jnp.float32)
    params = init_transformer(jax.random.PRNGKey(0), cfg)
    return ServeEngine(params, cfg, ServeConfig(
        max_batch=max_batch, max_seq=32, max_new_tokens=max_new))


def test_serve_latency_percentiles_e2e():
    eng = _tiny_engine()
    hist = REGISTRY.histogram("serve.request_latency_s")
    done0 = REGISTRY.counter("serve.completed").value
    count0 = hist.count
    eng.submit(np.array([1, 2, 3], np.int32))
    eng.submit(np.array([4, 5], np.int32))
    eng.drain()
    assert REGISTRY.counter("serve.completed").value == done0 + 2
    assert hist.count == count0 + 2
    p = hist.percentiles()
    assert 0.0 < p["p50"] <= p["p99"]
    assert REGISTRY.counter("serve.tokens").value > 0
    assert 0.0 <= REGISTRY.gauge("serve.slot_occupancy").value <= 1.0


def test_drain_completes_within_derived_bound():
    eng = _tiny_engine()
    r1 = eng.submit(np.array([1, 2, 3], np.int32))
    r2 = eng.submit(np.array([4, 5], np.int32))
    # bound: (remaining_prompt - 1 overlaps first token) + max_new per req
    bound = sum(r.remaining_prompt + eng.cfg.max_new_tokens
                for r in (r1, r2))
    steps = eng.drain()
    assert r1.done and r2.done
    assert 0 < steps <= bound


def test_drain_guard_raises_with_engine_state():
    eng = _tiny_engine(max_batch=1)
    eng.submit(np.array([1, 2, 3, 4], np.int32))
    with pytest.raises(RuntimeError, match="step bound") as ei:
        eng.drain(max_steps=2)
    state = ei.value.engine_state
    assert state["max_batch"] == 1
    slot = state["slots"][0]
    assert slot is not None and not slot["done"]
    # the engine is still steppable after the guard fires
    assert eng.drain() > 0
    assert eng.slots == [None]


# --------------------------------------------------------------------------
# plan trie: registry counters == legacy per-node sums
# --------------------------------------------------------------------------

def test_plan_trie_counter_parity():
    from repro.eval.plans import (GridSpec, execute_plan, expand_grid)
    runs = expand_grid(GridSpec(samplers=("a", "b"), engines=("x",),
                                ks=(1, 2), metrics=("m", "n")))
    noop = lambda parent, run: (parent, run.key)
    _, trie = execute_plan(runs, {s: noop for s in
                                  ("corpus", "embed", "sample", "index",
                                   "search", "metric")})
    counters = trie.metrics.snapshot()["counters"]
    by_stage = {}
    for node in trie.nodes.values():
        ex, rq = by_stage.get(node.stage, (0, 0))
        by_stage[node.stage] = (ex + node.executions, rq + node.requests)
    for stage, (ex, rq) in by_stage.items():
        assert counters[f"plan.executions.{stage}"] == ex
        assert counters[f"plan.requests.{stage}"] == rq
    assert trie.stage_counts() == by_stage
    # sharing actually happened: 8 cells, corpus executed once
    assert trie.stage_counts()["corpus"] == (1, 8)
    assert trie.stage_counts()["metric"] == (8, 8)


def test_plan_trie_isolated_registries():
    from repro.eval.plans import PlanTrie
    t1, t2 = PlanTrie(), PlanTrie()
    t1.run((("corpus",),), lambda: 1)
    assert t2.metrics.snapshot()["counters"] == {}
    assert t1.metrics is not t2.metrics is not REGISTRY


# --------------------------------------------------------------------------
# sampling core: draw-cache hit/miss counters
# --------------------------------------------------------------------------

def test_sampler_draw_cache_counters():
    from repro.core import QRelTable
    from repro.core.sampling_core import SamplerSession, SamplerSpec
    from repro.data.synthetic import generate_qrels
    q, e, s, _, _, ne = generate_qrels(num_queries=64, qrels_per_query=4,
                                       num_topics=8, seed=0)
    qrels = QRelTable(jnp.asarray(q), jnp.asarray(e), jnp.asarray(s),
                      jnp.ones(len(q), bool))
    sess = SamplerSession(qrels, num_queries=64, num_entities=ne,
                          spec=SamplerSpec(target_size=16.0, seed=0))
    hit0 = REGISTRY.counter("sampling.draw.hit").value
    miss0 = REGISTRY.counter("sampling.draw.miss").value
    sess.draw(seed=1)
    sess.draw(seed=1)     # cached
    sess.draw(seed=2)     # new key
    assert REGISTRY.counter("sampling.draw.miss").value == miss0 + 2
    assert REGISTRY.counter("sampling.draw.hit").value == hit0 + 1


def test_tuning_resolve_counters():
    from repro.kernels import tuning
    hit0 = REGISTRY.counter("tuning.resolve.hit").value
    miss0 = REGISTRY.counter("tuning.resolve.miss").value
    tuning.resolve("topk", n=1024, dtype="float32")
    hit1 = REGISTRY.counter("tuning.resolve.hit").value
    miss1 = REGISTRY.counter("tuning.resolve.miss").value
    assert (hit1 + miss1) - (hit0 + miss0) == 1   # exactly one resolution


# --------------------------------------------------------------------------
# launch/trace.py: aggregation + CLI
# --------------------------------------------------------------------------

def test_trace_cli_aggregate_compile_share():
    spans = (
        [{"name": "s", "id": i, "parent": None, "t0": 0.0, "dur_s": 1.0,
          "first": i == 1} for i in range(1, 5)]      # 1 first + 3 steady
        + [{"name": "plain", "id": 9, "parent": None, "t0": 0.0,
            "dur_s": 0.5}])
    aggs = trace_cli.aggregate(spans)
    s = aggs["s"]
    assert s["count"] == 4 and s["total_s"] == pytest.approx(4.0)
    # steady mean 1.0, one first call of 1.0 -> no compile surplus
    assert s["compile_s"] == pytest.approx(0.0)
    assert aggs["plain"]["first_count"] == 0
    assert aggs["plain"]["compile_share"] == 0.0
    # compile-dominated first call
    aggs2 = trace_cli.aggregate(
        [{"name": "s", "dur_s": 5.0, "first": True},
         {"name": "s", "dur_s": 1.0, "first": False}])
    assert aggs2["s"]["compile_s"] == pytest.approx(4.0)
    assert aggs2["s"]["compile_share"] == pytest.approx(4.0 / 6.0)


def test_trace_cli_percentile_exact():
    vals = sorted([1.0, 2.0, 3.0, 4.0])
    assert trace_cli._percentile(vals, 50) == pytest.approx(2.5)
    assert trace_cli._percentile(vals, 100) == pytest.approx(4.0)
    assert trace_cli._percentile([7.0], 99) == 7.0
    assert trace_cli._percentile([], 50) == 0.0


def test_trace_cli_main_json(tmp_path, capsys):
    sink = tmp_path / "t.jsonl"
    trace.enable(str(sink))
    with trace.span("alpha", x=1):
        with trace.jax_span("beta") as sp:
            sp.declare(jnp.arange(4))
    trace.disable()
    out_json = tmp_path / "agg.json"
    assert trace_cli.main([str(sink), "--json", str(out_json)]) == 0
    payload = json.loads(out_json.read_text())
    assert payload["spans"] == 2
    assert set(payload["stages"]) == {"alpha", "beta"}
    table = capsys.readouterr().out
    assert "alpha" in table and "beta" in table
    # --json - prints the JSON payload only
    assert trace_cli.main([str(sink), "--json", "-"]) == 0
    assert json.loads(capsys.readouterr().out)["spans"] == 2


def test_trace_cli_rejects_bad_jsonl(tmp_path):
    bad = tmp_path / "bad.jsonl"
    bad.write_text('{"name": "ok"}\nnot json\n')
    with pytest.raises(ValueError, match="bad.jsonl:2"):
        trace_cli.load_spans(str(bad))
    assert trace_cli.main([str(tmp_path / "missing.jsonl")]) == 2


# --------------------------------------------------------------------------
# timing helpers
# --------------------------------------------------------------------------

def test_timeit_and_provenance():
    us = timeit(lambda: jnp.arange(16.0) * 2, n=2)
    assert us > 0.0
    meta = provenance()
    assert meta["jax"] and meta["backend"] and meta["device_count"] >= 1
    assert set(meta) >= {"platform", "python", "jax", "backend",
                         "device_kind", "device_count", "git_sha"}


# --------------------------------------------------------------------------
# instrumented stages emit spans end-to-end (search + sampling + eval)
# --------------------------------------------------------------------------

def test_instrumented_stages_emit_spans(tmp_path):
    from repro.core import QRelTable
    from repro.core.sampling_core import SamplerSession, SamplerSpec
    from repro.data.synthetic import generate_qrels
    from repro.retrieval.search_core import SearchConfig, SearchSession
    sink = tmp_path / "trace.jsonl"
    trace.enable(str(sink))
    vecs = jax.random.normal(jax.random.PRNGKey(0), (128, 16))
    session = SearchSession(vecs, SearchConfig(engine="exact"),
                            key=jax.random.PRNGKey(0))
    session.search(vecs[:8], k=3)
    q, e, s, _, _, ne = generate_qrels(num_queries=64, qrels_per_query=4,
                                       num_topics=8, seed=0)
    qrels = QRelTable(jnp.asarray(q), jnp.asarray(e), jnp.asarray(s),
                      jnp.ones(len(q), bool))
    samp = SamplerSession(qrels, num_queries=64, num_entities=ne,
                          spec=SamplerSpec(target_size=16.0, seed=0))
    samp.draw(seed=3)
    trace.disable()
    names = {r["name"] for r in trace_cli.load_spans(str(sink))}
    assert {"search.build", "search.chunk", "sampling.graph",
            "sampling.labels", "sampling.draw"} <= names
