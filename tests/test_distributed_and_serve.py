"""Distributed-substrate + serving tests: shard_map label propagation,
elastic/straggler policies, the continuous-batching engine, the neighbour
sampler, and a distributed-vs-single-device consistency check."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distributed import distributed_propagate_ell
from repro.core.label_prop import propagate_ell
from repro.data.neighbor_sampler import NeighborSampler
from repro.models.transformer import TransformerConfig, init_transformer
from repro.serve.engine import ServeConfig, ServeEngine
from repro.train.elastic import (HeartbeatMonitor, StragglerPolicy,
                                 plan_for_mesh)


def test_distributed_label_prop_matches_single_device():
    mesh = jax.make_mesh((1,), ("data",))
    rng = np.random.default_rng(0)
    n, k = 64, 6
    nbr = jnp.asarray(rng.integers(-1, n, (n, k)), jnp.int32)
    wgt = jnp.asarray(np.abs(rng.normal(size=(n, k))), jnp.float32)
    dist = distributed_propagate_ell(mesh, nbr, wgt, rounds=3)
    ref = propagate_ell(nbr, wgt, rounds=3).labels
    assert (np.asarray(dist) == np.asarray(ref)).all()


def test_elastic_plan_keeps_global_batch():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    plan = plan_for_mesh(mesh, global_batch=256, base_data_parallel=16)
    assert plan.accum_steps == 16
    assert plan.accum_steps * plan.per_step_batch == 256


def test_straggler_policy_flags_then_remeshes():
    pol = StragglerPolicy(deadline_factor=2.0, max_flags=2)
    for _ in range(8):
        assert pol.observe(1.0) == "ok"
    assert pol.observe(5.0) == "flag"
    assert pol.observe(5.0) == "remesh"
    assert pol.observe(1.0) == "ok"


def test_heartbeat_monitor():
    t = [0.0]
    mon = HeartbeatMonitor(timeout_s=10.0, now=lambda: t[0])
    mon.beat("w0")
    mon.beat("w1")
    t[0] = 5.0
    mon.beat("w0")
    t[0] = 12.0
    assert mon.dead() == ["w1"]


def test_serve_engine_continuous_batching():
    cfg = TransformerConfig(vocab_size=64, d_model=32, n_layers=2, n_heads=4,
                            n_kv_heads=2, d_ff=48, dtype=jnp.float32)
    params = init_transformer(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(params, cfg, ServeConfig(max_batch=2, max_seq=32,
                                               max_new_tokens=4))
    r1 = eng.submit(np.array([1, 2, 3], np.int32))
    r2 = eng.submit(np.array([4, 5], np.int32))
    assert eng.submit(np.array([6], np.int32)) is None   # batch full
    eng.drain()
    assert len(r1.out) == 4 and len(r2.out) == 4
    # freed slots accept new requests (continuous batching)
    r3 = eng.submit(np.array([7, 8], np.int32))
    assert r3 is not None
    eng.drain()
    assert len(r3.out) == 4


def test_serve_engine_greedy_matches_decode_loop():
    """Engine output for a single request == plain greedy decode."""
    from repro.models.transformer import decode_step, init_kv_cache
    cfg = TransformerConfig(vocab_size=64, d_model=32, n_layers=2, n_heads=4,
                            n_kv_heads=2, d_ff=48, dtype=jnp.float32)
    params = init_transformer(jax.random.PRNGKey(0), cfg)
    prompt = np.array([3, 9, 27], np.int32)
    eng = ServeEngine(params, cfg, ServeConfig(max_batch=1, max_seq=32,
                                               max_new_tokens=5))
    req = eng.submit(prompt)
    eng.drain()
    # reference: token-by-token greedy
    cache = init_kv_cache(cfg, 1, 32)
    toks = list(prompt)
    out = []
    for t in range(len(prompt) + 4):
        cur = jnp.asarray([[toks[t] if t < len(toks) else out[-1]]],
                          jnp.int32)
        logits, cache = decode_step(params, cache, cur, cfg)
        nxt = int(jnp.argmax(logits[0, 0]))
        if t >= len(prompt) - 1:
            out.append(nxt)
    assert req.out == out[:5]


def test_serve_slot_reuse_has_clean_kv_position():
    """Regression: a slot freed by a finished request is immediately
    reusable by submit with a clean KV position — the recycled request's
    output equals a fresh engine's output for the same prompt."""
    cfg = TransformerConfig(vocab_size=64, d_model=32, n_layers=2, n_heads=4,
                            n_kv_heads=2, d_ff=48, dtype=jnp.float32)
    params = init_transformer(jax.random.PRNGKey(0), cfg)
    scfg = ServeConfig(max_batch=1, max_seq=32, max_new_tokens=4)
    eng = ServeEngine(params, cfg, scfg)
    r1 = eng.submit(np.array([5, 11, 2], np.int32))
    eng.drain()
    assert len(r1.out) == 4
    r2 = eng.submit(np.array([9, 3], np.int32))    # reuses the freed slot
    assert r2 is not None
    eng.drain()
    fresh = ServeEngine(params, cfg, scfg)
    rf = fresh.submit(np.array([9, 3], np.int32))
    fresh.drain()
    assert r2.out == rf.out


def test_serve_drain_terminates_on_simultaneous_finish():
    """Regression: drain() terminates when every slot finishes on the same
    step (equal prompt lengths and budgets), leaving all slots free."""
    cfg = TransformerConfig(vocab_size=64, d_model=32, n_layers=2, n_heads=4,
                            n_kv_heads=2, d_ff=48, dtype=jnp.float32)
    params = init_transformer(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(params, cfg, ServeConfig(max_batch=3, max_seq=32,
                                               max_new_tokens=3))
    reqs = [eng.submit(np.array([i + 1, i + 2], np.int32))
            for i in range(3)]
    assert all(r is not None for r in reqs)
    eng.drain()
    assert all(len(r.out) == 3 for r in reqs)
    # every slot must have been freed on that same finishing step
    assert all(s is None for s in eng.slots)
    assert eng.submit(np.array([7], np.int32)) is not None


def test_neighbor_sampler_blocks():
    rng = np.random.default_rng(0)
    n, e = 200, 1500
    src = rng.integers(0, n, e)
    dst = rng.integers(0, n, e)
    samp = NeighborSampler(src, dst, n, seed=0)
    batch = rng.choice(n, 16, replace=False)
    blocks = samp.sample(batch, fanouts=(5, 3))
    assert len(blocks) == 2
    outer = blocks[-1]                       # layer closest to the batch
    assert outer.n_dst == 16
    assert (outer.src_nodes[:16] == batch).all()   # dst-first local ids
    # every sampled edge endpoint resolves to a real neighbour
    adj = {i: set() for i in range(n)}
    for s_, d_ in zip(src, dst):
        adj[d_].add(s_)
    for le, ld, ok in zip(outer.edge_src, outer.edge_dst, outer.edge_mask):
        if ok:
            assert int(outer.src_nodes[le]) in adj[int(batch[ld])]
