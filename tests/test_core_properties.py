"""Property-based tests (hypothesis) for the WindTunnel core invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="hypothesis not installed; see requirements.txt")
from hypothesis import given, settings, strategies as st

from repro.core import graph_builder as gb
from repro.core import label_prop as lp
from repro.core.yule_simon import fit_em

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


@st.composite
def qrel_tables(draw):
    n = draw(st.integers(8, 64))
    nq = draw(st.integers(2, 10))
    ne = draw(st.integers(2, 20))
    rng = np.random.default_rng(draw(st.integers(0, 2**31)))
    q = rng.integers(0, nq, n).astype(np.int32)
    e = rng.integers(0, ne, n).astype(np.int32)
    s = rng.random(n).astype(np.float32)
    valid = rng.random(n) < 0.9
    return gb.QRelTable(jnp.asarray(q), jnp.asarray(e), jnp.asarray(s),
                        jnp.asarray(valid)), nq, ne


@given(qrel_tables())
def test_affinity_graph_invariants(data):
    """Alg. 1 invariants: canonical orientation, dedup, affinity = min rule,
    affinity bounded by the member scores."""
    qrels, nq, ne = data
    edges = gb.build_affinity_graph(qrels, num_queries=nq,
                                    tau_quantile=0.0, fanout=8)
    u = np.asarray(edges.u)[np.asarray(edges.valid)]
    v = np.asarray(edges.v)[np.asarray(edges.valid)]
    w = np.asarray(edges.w)[np.asarray(edges.valid)]
    assert (u < v).all()                       # canonical orientation
    pairs = list(zip(u.tolist(), v.tolist()))
    assert len(pairs) == len(set(pairs))       # dedup
    assert (w >= 0).all() and (u >= 0).all() and (v.max(initial=-1) < ne)

    # brute-force oracle over the same (thresholded, fanout-capped) table
    q = np.asarray(qrels.query_ids)
    e = np.asarray(qrels.entity_ids)
    s = np.asarray(qrels.scores)
    val = np.asarray(qrels.valid)
    if val.any():   # the paper's strict 's > tau' drops the minimum too
        tau = np.quantile(s[val], 0.0)
        val = val & (s > tau)
    best = {}
    for qi in range(nq):
        rows = np.nonzero(val & (q == qi))[0]
        rows = rows[np.argsort(-s[rows], kind="stable")][:8]
        for i in range(len(rows)):
            for j in range(len(rows)):
                if i == j:
                    continue
                e1, e2 = e[rows[i]], e[rows[j]]
                if e1 == e2:
                    continue
                key = (min(e1, e2), max(e1, e2))
                aff = min(s[rows[i]], s[rows[j]])
                best[key] = max(best.get(key, -1.0), aff)
    got = dict(zip(pairs, w.tolist()))
    assert set(got) == set(best)
    for k in best:
        assert abs(got[k] - best[k]) < 1e-5


@given(st.integers(0, 2**31), st.integers(10, 60), st.integers(2, 6))
def test_label_prop_engines_agree(seed, n_edges, max_deg):
    """Sort-based and ELL label propagation agree when no edges are dropped
    by the degree cap."""
    rng = np.random.default_rng(seed)
    n_nodes = 16
    u = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    v = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    keep = u != v
    u, v = u[keep], v[keep]
    # dedup so degree cap can be exact
    pairs = sorted({(min(a, b), max(a, b)) for a, b in zip(u, v)})
    if not pairs:
        return
    u = np.array([p[0] for p in pairs], np.int32)
    v = np.array([p[1] for p in pairs], np.int32)
    w = rng.random(u.size).astype(np.float32) + 0.1
    edges = gb.EdgeList(jnp.asarray(u), jnp.asarray(v), jnp.asarray(w),
                        jnp.ones(u.size, bool))
    src, dst, ww, valid = gb.symmetrize(edges)
    res_sort = lp.propagate(src, dst, ww, valid, num_nodes=n_nodes, rounds=3)
    nbr, wgt = lp.edges_to_ell(src, dst, ww, valid, num_nodes=n_nodes,
                               max_degree=n_nodes)
    res_ell = lp.propagate_ell(nbr, wgt, rounds=3)
    assert (np.asarray(res_sort.labels) == np.asarray(res_ell.labels)).all()


@given(st.floats(0.8, 3.0), st.integers(0, 2**31))
def test_yule_simon_em_recovers_rho(rho, seed):
    rng = np.random.default_rng(seed)
    wts = rng.exponential(1.0 / rho, 20000)
    k = rng.geometric(np.exp(-wts))
    fit = fit_em(jnp.asarray(k), max_iters=300)
    assert abs(float(fit.rho) - rho) / rho < 0.15
    assert float(fit.stderr) > 0
