"""Int8 quantization + error-feedback compression tests
(distributed/compression.py) — the same quantizer the int8 scoring
backend reuses for corpus codes (retrieval/backends.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.compression import (compress_leaf, dequantize_int8,
                                           ef_init, quantize_int8,
                                           topk_sparsify)


@pytest.mark.parametrize("shape", [(64,), (17, 9), (4, 8, 3)])
def test_quantize_roundtrip_error_bound(shape):
    """|x - deq(q(x))| <= scale/2 elementwise: round-to-nearest onto a
    127-level symmetric grid."""
    x = jax.random.normal(jax.random.PRNGKey(sum(shape)), shape) * 3.0
    q, scale = quantize_int8(x)
    assert q.dtype == jnp.int8
    err = np.abs(np.asarray(x) - np.asarray(dequantize_int8(q, scale)))
    assert (err <= float(scale) / 2 + 1e-7).all()


def test_quantize_all_zero_leaf():
    """All-zero leaves must not divide by zero and round-trip exactly."""
    x = jnp.zeros((8, 8))
    q, scale = quantize_int8(x)
    assert np.isfinite(float(scale)) and float(scale) > 0
    assert (np.asarray(q) == 0).all()
    assert (np.asarray(dequantize_int8(q, scale)) == 0).all()


@pytest.mark.parametrize("peak", [1e-20, 1.0, 1e20, -1e20])
def test_quantize_extreme_values(peak):
    """±extreme magnitudes: the max-|x| element always maps to ±127 and
    dequantizes back to the exact peak."""
    x = jnp.zeros((16,)).at[3].set(peak)
    q, scale = quantize_int8(x)
    assert int(np.asarray(q)[3]) == (127 if peak > 0 else -127)
    deq = np.asarray(dequantize_int8(q, scale))
    np.testing.assert_allclose(deq[3], peak, rtol=1e-5)
    assert np.isfinite(deq).all()


def test_quantize_ranking_invariant():
    """Global symmetric scaling preserves dot-product ranking up to
    quantization noise — the property the int8 search backend relies on:
    the exact top-1 must be inside a small int8-scored candidate pool."""
    vecs = jax.random.normal(jax.random.PRNGKey(0), (100, 32))
    qv = jax.random.normal(jax.random.PRNGKey(1), (5, 32))
    cq, _ = quantize_int8(vecs)
    qq, _ = quantize_int8(qv)
    s8 = np.asarray(jnp.dot(qq.astype(jnp.int32), cq.astype(jnp.int32).T))
    sf = np.asarray(jnp.dot(qv, vecs.T))
    for row8, rowf in zip(s8, sf):
        pool = set(np.argsort(row8)[-4:].tolist())
        assert int(np.argmax(rowf)) in pool


def test_error_feedback_converges():
    """Repeated compress_leaf of a constant gradient: the running mean of
    dequantized outputs converges to the true gradient (EF unbiasedness),
    and each residual stays bounded by scale/2."""
    g = jax.random.normal(jax.random.PRNGKey(7), (33,)) * 0.1
    err = ef_init({"w": g})["w"]
    acc = np.zeros_like(np.asarray(g))
    steps = 64
    for _ in range(steps):
        q, scale, err = compress_leaf(g, err)
        acc += np.asarray(dequantize_int8(q, scale))
        assert (np.abs(np.asarray(err)) <= float(scale) / 2 + 1e-7).all()
    np.testing.assert_allclose(acc / steps, np.asarray(g),
                               atol=5e-4, rtol=0)


def test_topk_sparsify_error_feedback():
    g = jax.random.normal(jax.random.PRNGKey(3), (256,))
    sent, resid = topk_sparsify(g, jnp.zeros_like(g), frac=0.05)
    nz = int((np.asarray(sent) != 0).sum())
    assert 1 <= nz <= int(0.05 * 256) + 1
    np.testing.assert_allclose(np.asarray(sent) + np.asarray(resid),
                               np.asarray(g), atol=1e-6)
