"""Engine-registry parity and sharded-pipeline equivalence tests.

Property-style over seeded random graphs: every registered engine must
produce identical labels (and, through the pipeline, identical sampled
masks) on graphs whose maximum degree fits the ELL cap; the sharded
pipeline on a 1-device mesh must reproduce the single-device entity_mask
bit-exactly.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (QRelTable, WindTunnelConfig, available_engines,
                        engines as eng, graph_builder as gb, run_windtunnel,
                        run_windtunnel_sharded)
from repro.data.synthetic import generate_corpus
from repro.launch.mesh import make_host_mesh

N_NODES = 24


def _random_graph(seed, n_nodes=N_NODES, n_edges=48):
    """Random undirected weighted graph, deduped so the ELL cap (set to
    n_nodes) can never drop an edge."""
    rng = np.random.default_rng(seed)
    u = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    v = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    keep = u != v
    pairs = sorted({(min(a, b), max(a, b)) for a, b in zip(u[keep], v[keep])})
    if not pairs:
        pairs = [(0, 1)]
    u = np.array([p[0] for p in pairs], np.int32)
    v = np.array([p[1] for p in pairs], np.int32)
    w = rng.random(u.size).astype(np.float32) + 0.1
    edges = gb.EdgeList(jnp.asarray(u), jnp.asarray(v), jnp.asarray(w),
                        jnp.ones(u.size, bool))
    return gb.symmetrize(edges)


def test_registry_contents():
    assert {"sort", "ell", "pallas"} <= set(available_engines())
    for name in available_engines():
        assert isinstance(eng.get_engine(name), eng.LPEngine)


def test_unknown_engine_raises():
    with pytest.raises(ValueError, match="registered engines"):
        eng.get_engine("spark")


@pytest.mark.parametrize("seed", range(5))
def test_engines_produce_identical_labels(seed):
    src, dst, w, valid = _random_graph(seed)
    results = {}
    for name in available_engines():
        res = eng.run_engine(eng.get_engine(name), src, dst, w, valid,
                             num_nodes=N_NODES, max_degree=N_NODES,
                             rounds=4)
        results[name] = np.asarray(res.labels)
    ref = results["sort"]
    for name, labels in results.items():
        assert (labels == ref).all(), f"{name} diverged from sort"


@pytest.fixture(scope="module")
def corpus():
    return generate_corpus(num_queries=96, qrels_per_query=8, num_topics=10,
                           aux_fraction=0.3, seed=0, vocab_size=256)


def _run(corpus, engine, **kw):
    qrels = QRelTable(*(jnp.asarray(x) for x in corpus.qrels))
    cfg = WindTunnelConfig(fanout=8, lp_rounds=4,
                           max_degree=corpus.num_entities, engine=engine,
                           target_size=0.3 * corpus.num_primary, seed=0)
    if kw.get("mesh") is not None:
        return run_windtunnel_sharded(
            qrels, num_queries=corpus.num_queries,
            num_entities=corpus.num_entities, config=cfg, mesh=kw["mesh"])
    return jax.jit(lambda q: run_windtunnel(
        q, num_queries=corpus.num_queries,
        num_entities=corpus.num_entities, config=cfg))(qrels)


def test_pipeline_masks_identical_across_engines(corpus):
    """With a degree cap covering the whole graph, every engine's pipeline
    run yields the same labels AND the same sampled entity mask."""
    runs = {name: _run(corpus, name) for name in available_engines()}
    ref = runs["sort"]
    for name, res in runs.items():
        assert (np.asarray(res.labels) == np.asarray(ref.labels)).all(), name
        assert (np.asarray(res.sample.entity_mask) ==
                np.asarray(ref.sample.entity_mask)).all(), name


@pytest.mark.parametrize("engine", ["ell", "pallas"])
def test_sharded_pipeline_matches_single_device(corpus, engine):
    """1-device mesh: the sharded path reproduces run_windtunnel bit-exactly
    — labels, entity mask, per-round change counts and degrees."""
    mesh = make_host_mesh()
    ref = _run(corpus, engine)
    sh = _run(corpus, engine, mesh=mesh)
    assert (np.asarray(sh.labels) == np.asarray(ref.labels)).all()
    assert (np.asarray(sh.sample.entity_mask) ==
            np.asarray(ref.sample.entity_mask)).all()
    assert (np.asarray(sh.changes_per_round) ==
            np.asarray(ref.changes_per_round)).all()
    assert (np.asarray(sh.degrees) == np.asarray(ref.degrees)).all()


def test_sharded_pipeline_rejects_sort_engine(corpus):
    with pytest.raises(ValueError, match="ELL-family"):
        _run(corpus, "sort", mesh=make_host_mesh())
