"""Contract-analyzer tests (DESIGN.md §15): one positive + one negative
fixture per rule family, suppression comments, baseline round-trip, the
--json report schema, import cycle/layering fixtures, and the meta-test —
the analyzer run over src/repro itself must report zero error-severity
findings (the repo obeys its own contracts)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.analysis import core as acore
from repro.analysis.concurrency_rules import graph_cycle, lock_order_graph
from repro.analysis.core import (Finding, Project, analyze,
                                 load_default_rules)
from repro.launch import lint as lint_cli

load_default_rules()

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC_REPRO = os.path.join(REPO, "src", "repro")


def _project(tmp_path, sources, pkg="fix"):
    """Write {relpath: source} under a package dir and load it."""
    root = tmp_path / pkg
    root.mkdir(parents=True, exist_ok=True)
    (root / "__init__.py").write_text("")
    for rel, src in sources.items():
        path = root / rel
        path.parent.mkdir(parents=True, exist_ok=True)
        if path.parent != root and not \
                (path.parent / "__init__.py").exists():
            (path.parent / "__init__.py").write_text("")
        path.write_text(textwrap.dedent(src))
    return Project.load([str(root)])


def _rules_hit(findings, rule):
    return [f for f in findings if f.rule == rule]


# ---------------------------------------------------------------------------
# Family 1: JAX trace hazards
# ---------------------------------------------------------------------------


JIT_BAD = """
    import jax

    @jax.jit
    def f(x, y):
        v = float(x)          # host cast on a traced value
        if y > 0:             # python branch on a traced value
            v = v + 1.0
        return v
"""

JIT_OK = """
    import jax
    import jax.numpy as jnp
    import functools

    @functools.partial(jax.jit, static_argnames=("k",))
    def f(x, *, k):
        steps = float(k)          # k is static: fine
        if x.shape[0] > 4:        # shapes are static under tracing: fine
            x = x * steps
        return jnp.where(x > 0, x, 0.0)
"""


def test_host_cast_positive_and_negative(tmp_path):
    bad = analyze(_project(tmp_path, {"bad.py": JIT_BAD}))
    hits = _rules_hit(bad, "jax-host-cast")
    assert len(hits) == 1 and hits[0].severity == "error"
    assert "float()" in hits[0].message
    good = analyze(_project(tmp_path, {"sub/good.py": JIT_OK},
                            pkg="fixok"))
    assert not _rules_hit(good, "jax-host-cast")


def test_traced_branch_positive_and_negative(tmp_path):
    bad = analyze(_project(tmp_path, {"bad.py": JIT_BAD}))
    assert len(_rules_hit(bad, "jax-traced-branch")) == 1
    good = analyze(_project(tmp_path, {"sub/good.py": JIT_OK},
                            pkg="fixok"))
    assert not _rules_hit(good, "jax-traced-branch")


def test_item_method_flagged(tmp_path):
    src = """
        import jax

        @jax.jit
        def f(x):
            return x.sum().item()
    """
    hits = _rules_hit(analyze(_project(tmp_path, {"m.py": src})),
                      "jax-host-cast")
    assert len(hits) == 1 and ".item()" in hits[0].message


def test_unbounded_static_flags_free_value_not_clamped(tmp_path):
    src = """
        import functools
        import jax

        K_MAX = 16

        @functools.partial(jax.jit, static_argnames=("k", "width"))
        def topk(x, *, k, width):
            return x[:k]

        def serve(x, user_k, rows):
            return topk(x, k=user_k, width=rows)   # both unbounded

        def serve_clamped(x, user_k):
            k = min(user_k, K_MAX)                 # min-clamp: bounded
            return topk(x, k=k, width=1024)
    """
    findings = analyze(_project(tmp_path, {"m.py": src}))
    hits = _rules_hit(findings, "jax-unbounded-static")
    assert {(f.symbol, f.severity) for f in hits} == \
        {("serve", "warning")}
    assert len(hits) == 2          # k and width at the bare call site


def test_tuned_block_kwargs_are_known_static(tmp_path):
    # block_q comes from the finite kernels/tuning.py table: never flagged
    src = """
        import functools
        import jax

        @functools.partial(jax.jit, static_argnames=("block_q",))
        def kernel(x, *, block_q):
            return x

        def dispatch(x, resolved):
            return kernel(x, block_q=resolved["block_q"])
    """
    findings = analyze(_project(tmp_path, {"m.py": src}))
    assert not _rules_hit(findings, "jax-unbounded-static")


# ---------------------------------------------------------------------------
# Family 2: donation safety
# ---------------------------------------------------------------------------


def test_donated_reuse_positive_and_negative(tmp_path):
    src = """
        import functools
        import jax

        @functools.partial(jax.jit, donate_argnums=(0,))
        def step(buf, x):
            return buf + x

        def bad(buf, x):
            out = step(buf, x)
            return out + buf.sum()     # buf read after donation

        def good(buf, x):
            buf = step(buf, x)         # rebind: the donated name dies
            return buf.sum()
    """
    findings = analyze(_project(tmp_path, {"m.py": src}))
    hits = _rules_hit(findings, "jax-donated-reuse")
    assert len(hits) == 1
    assert hits[0].symbol == "bad" and hits[0].severity == "error"


def test_serve_donated_append_contract(tmp_path):
    src = """
        import functools
        import jax
        from jax import lax

        @functools.partial(jax.jit, donate_argnums=(0,))
        def write(buf, rows, start):
            return lax.dynamic_update_slice(buf, rows, (start, 0))
    """
    # same code outside serve/: the LiveIndex contract does not apply
    ok = analyze(_project(tmp_path, {"other.py": src}, pkg="elsewhere"))
    assert not _rules_hit(ok, "serve-donated-append")
    bad = analyze(_project(tmp_path, {"ingest.py": src}, pkg="serve"))
    hits = _rules_hit(bad, "serve-donated-append")
    assert len(hits) == 1 and hits[0].severity == "error"
    # the real append path declares donate_argnums=() — meta-test covers it


# ---------------------------------------------------------------------------
# Family 3: concurrency
# ---------------------------------------------------------------------------


GUARDED_BAD = """
    import threading

    class Box:
        def __init__(self):
            self._lock = threading.Lock()
            self._items = []
            self._n = 0

        def put(self, x):
            with self._lock:
                self._items.append(x)
                self._n += 1

        def drop_all(self):
            self._items = []      # bare write: races put()

        def size(self):
            return self._n        # bare read
"""

GUARDED_OK = """
    import threading

    class Box:
        def __init__(self):
            self._lock = threading.Lock()
            self._items = []

        def put(self, x):
            with self._lock:
                self._items.append(x)

        def size(self):
            with self._lock:
                return len(self._items)
"""


def test_unguarded_write_and_read(tmp_path):
    findings = analyze(_project(tmp_path, {"box.py": GUARDED_BAD},
                                pkg="serve"))
    writes = _rules_hit(findings, "conc-unguarded-write")
    reads = _rules_hit(findings, "conc-unguarded-read")
    assert [f.symbol for f in writes] == ["Box.drop_all"]
    assert writes[0].severity == "error"
    assert [f.symbol for f in reads] == ["Box.size"]
    assert reads[0].severity == "warning"
    clean = analyze(_project(tmp_path, {"box2.py": GUARDED_OK},
                             pkg="obs"))
    assert not _rules_hit(clean, "conc-unguarded-write")
    assert not _rules_hit(clean, "conc-unguarded-read")


LOCK_CYCLE = """
    import threading

    class A:
        def __init__(self, b):
            self._lock = threading.Lock()
            self._b = b

        def step(self):
            with self._lock:
                self._b.poke()     # A.lock held -> takes B.lock

    class B:
        def __init__(self):
            self._lock = threading.Lock()
            self._a = A(self)

        def poke(self):
            with self._lock:
                pass

        def kick(self):
            with self._lock:
                self._a.step()     # B.lock held -> takes A.lock: cycle
"""


def test_lock_order_cycle(tmp_path):
    project = _project(tmp_path, {"locks.py": LOCK_CYCLE}, pkg="serve")
    edges = lock_order_graph(project)
    assert graph_cycle(edges) is not None
    hits = _rules_hit(analyze(project), "conc-lock-order")
    assert len(hits) == 1 and "A" in hits[0].message \
        and "B" in hits[0].message


THREAD_BAD = """
    import threading

    class Fire:
        def start(self):
            t = threading.Thread(target=self._work, daemon=True)
            t.start()

        def _work(self):
            pass
"""

THREAD_OK = """
    import threading

    class Fire:
        def __init__(self):
            self._err = None

        def start(self):
            self._t = threading.Thread(target=self._work, daemon=True)
            self._t.start()

        def _work(self):
            try:
                pass
            except BaseException as e:
                self._err = e

        def close(self):
            self._t.join()
            if self._err is not None:
                raise self._err
"""


def test_thread_failure_surfacing(tmp_path):
    bad = analyze(_project(tmp_path, {"t.py": THREAD_BAD}, pkg="serve"))
    hits = _rules_hit(bad, "conc-thread-no-surface")
    assert len(hits) == 1 and hits[0].severity == "error"
    good = analyze(_project(tmp_path, {"t.py": THREAD_OK}, pkg="serve"))
    assert not _rules_hit(good, "conc-thread-no-surface")


# ---------------------------------------------------------------------------
# Family 4: registry conformance
# ---------------------------------------------------------------------------


REGISTRY_SRC = """
    from typing import Dict, Protocol, runtime_checkable

    @runtime_checkable
    class Engine(Protocol):
        name: str

        def run(self, state, *, rounds): ...

    _REGISTRY: Dict[str, "Engine"] = {}

    def register(cls):
        inst = cls()
        _REGISTRY[inst.name] = inst
        return cls

    @register
    class Good:
        name = "good"

        def run(self, state, *, rounds):
            return state

    @register
    class MissingMethod:
        name = "missing"

    @register
    class BadSignature:
        name = "badsig"

        def run(self, state, extra_required, *, rounds):
            return state

    @register
    class MissingAttr:
        def run(self, state, *, rounds):
            return state
"""


def test_registry_conformance(tmp_path):
    findings = analyze(_project(tmp_path, {"engines.py": REGISTRY_SRC}))
    hits = _rules_hit(findings, "reg-conformance")
    by_symbol = {f.symbol: f for f in hits}
    assert "Good" not in {s.split(".")[0] for s in by_symbol}
    assert any(s.startswith("MissingMethod") for s in by_symbol)
    assert any(s.startswith("BadSignature") for s in by_symbol)
    assert any(s.startswith("MissingAttr") for s in by_symbol)
    assert all(f.severity == "error" for f in hits)


# ---------------------------------------------------------------------------
# Imports: cycles + layering
# ---------------------------------------------------------------------------


def _repro_tree(tmp_path, files):
    """A fake repro.* package tree (module names resolve as repro.<pkg>)."""
    root = tmp_path / "repro"
    root.mkdir(parents=True, exist_ok=True)
    (root / "__init__.py").write_text("")
    for rel, src in files.items():
        path = root / rel
        path.parent.mkdir(parents=True, exist_ok=True)
        init = path.parent / "__init__.py"
        if not init.exists():
            init.write_text("")
        path.write_text(textwrap.dedent(src))
    return Project.load([str(root)])


def test_import_cycle_detected(tmp_path):
    project = _repro_tree(tmp_path, {
        "core/a.py": "from repro.data import b\n",
        "data/b.py": "from repro.core import a\n",
    })
    hits = _rules_hit(analyze(project, rules=["import-cycle"]),
                      "import-cycle")
    assert len(hits) == 1 and hits[0].severity == "error"
    assert "core" in hits[0].message and "data" in hits[0].message


def test_latent_deferred_cycle_warns(tmp_path):
    project = _repro_tree(tmp_path, {
        "core/a.py": "from repro.data import b\n",
        "data/b.py": ("def late():\n"
                      "    from repro.core import a\n"
                      "    return a\n"),
    })
    hits = _rules_hit(analyze(project, rules=["import-cycle"]),
                      "import-cycle")
    assert len(hits) == 1 and hits[0].severity == "warning"
    assert "latent" in hits[0].message


def test_layering_eval_upward_is_error(tmp_path):
    project = _repro_tree(tmp_path, {
        "eval/metrics.py": "from repro.serve import engine\n",
        "serve/engine.py": "",
    })
    hits = _rules_hit(analyze(project, rules=["import-layering"]),
                      "import-layering")
    assert len(hits) == 1 and hits[0].severity == "error"
    assert hits[0].symbol == "eval"


def test_layering_downward_is_clean(tmp_path):
    project = _repro_tree(tmp_path, {
        "eval/metrics.py": "from repro.core import thing\n"
                           "from repro.obs import trace\n",
        "core/thing.py": "from repro.obs import trace\n",
        "obs/trace.py": "",
    })
    assert not analyze(project, rules=["import-layering", "import-cycle"])


def test_real_tree_imports_clean():
    project = Project.load([SRC_REPRO])
    findings = analyze(project, rules=["import-cycle", "import-layering"])
    assert findings == [], [f.format() for f in findings]


# ---------------------------------------------------------------------------
# Framework: suppression, baseline, CLI
# ---------------------------------------------------------------------------


def test_suppression_comment_silences(tmp_path):
    src = JIT_BAD.replace("v = float(x)",
                          "v = float(x)  # lint: disable=jax-host-cast")
    findings = analyze(_project(tmp_path, {"m.py": src}))
    assert not _rules_hit(findings, "jax-host-cast")
    assert _rules_hit(findings, "jax-traced-branch")   # others still fire


def test_suppression_line_above_and_bare(tmp_path):
    src = """
        import jax

        @jax.jit
        def f(x):
            # lint: disable
            return float(x)
    """
    assert not analyze(_project(tmp_path, {"m.py": src}))


def test_baseline_round_trip(tmp_path):
    project = _project(tmp_path, {"bad.py": JIT_BAD})
    findings = analyze(project)
    assert findings
    path = str(tmp_path / "baseline.json")
    acore.save_baseline(path, findings)
    baseline = acore.load_baseline(path)
    assert acore.new_findings(findings, baseline) == []
    extra = Finding("jax-host-cast", "error", "x.py", 1, "new issue")
    assert acore.new_findings(findings + [extra], baseline) == [extra]
    # fingerprints are line-free: moving a finding does not churn
    moved = [Finding(f.rule, f.severity, f.path, f.line + 7, f.message,
                     f.symbol) for f in findings]
    assert acore.new_findings(moved, baseline) == []


def test_missing_baseline_is_empty(tmp_path):
    assert acore.load_baseline(str(tmp_path / "absent.json")) == frozenset()


def test_cli_json_schema_and_exit_codes(tmp_path, capsys):
    root = tmp_path / "fix"
    root.mkdir()
    (root / "__init__.py").write_text("")
    (root / "bad.py").write_text(textwrap.dedent(JIT_BAD))
    baseline = str(tmp_path / "b.json")
    rc = lint_cli.main(["--json", str(root), "--baseline", baseline])
    assert rc == 1                       # new error findings
    report = json.loads(capsys.readouterr().out)
    assert report["version"] == 1
    assert set(report["counts"]) == {"info", "warning", "error"}
    assert report["counts"]["error"] >= 2
    assert report["failing"] == report["counts"]["error"]
    for f in report["findings"]:
        assert {"rule", "severity", "path", "line", "symbol", "message",
                "fingerprint", "new"} <= set(f)
    # accept into the baseline -> clean run
    assert lint_cli.main(["--write-baseline", str(root),
                          "--baseline", baseline]) == 0
    assert lint_cli.main([str(root), "--baseline", baseline]) == 0


def test_cli_rules_subset_and_unknown(tmp_path):
    root = tmp_path / "fix"
    root.mkdir()
    (root / "__init__.py").write_text("")
    (root / "bad.py").write_text(textwrap.dedent(JIT_BAD))
    rc = lint_cli.main(["--rules", "import-cycle", str(root),
                        "--baseline", str(tmp_path / "nb.json")])
    assert rc == 0                       # jax rules not selected
    with pytest.raises(ValueError):
        lint_cli.main(["--rules", "no-such-rule", str(root)])


def test_module_shim_entrypoint():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
               JAX_PLATFORMS="cpu")
    out = subprocess.run(
        [sys.executable, "-m", "launch.lint", "--list-rules"],
        capture_output=True, text=True, env=env, cwd=REPO)
    assert out.returncode == 0, out.stderr
    assert "jax-host-cast" in out.stdout
    assert "reg-conformance" in out.stdout


# ---------------------------------------------------------------------------
# Meta: the repo obeys its own contracts
# ---------------------------------------------------------------------------


def test_meta_no_error_findings_on_src_repro():
    findings = analyze(Project.load([SRC_REPRO]))
    errors = [f for f in findings if f.severity == "error"]
    assert errors == [], "\n".join(f.format() for f in errors)


def test_meta_registries_discovered():
    from repro.analysis.registry_rules import find_registries
    project = Project.load([SRC_REPRO])
    by_proto = {r.protocol.name: len(r.implementations)
                for r in find_registries(project)}
    for proto in ("LPEngine", "SamplerStrategy", "RetrievalEngine",
                  "ScoringBackend"):
        assert by_proto.get(proto, 0) >= 2, by_proto


def test_meta_baseline_matches_tree():
    """The committed baseline covers every current finding (no drift)."""
    baseline_path = os.path.join(REPO, "lint_baseline.json")
    findings = analyze(Project.load([SRC_REPRO]))
    baseline = acore.load_baseline(baseline_path)
    fresh = acore.new_findings(findings, baseline)
    assert fresh == [], "\n".join(f.format() for f in fresh)
