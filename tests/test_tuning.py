"""Kernel-autotuner tests (kernels/tuning.py, DESIGN.md §11): size
buckets, the explicit > tuned > default resolution order, the env/CLI
escape hatch, table persistence, the ask/tell hillclimb, and kernel
parity under arbitrary tuned block choices."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import tuning
from repro.kernels.topk_scoring.ops import topk_scores
from repro.kernels.topk_scoring.ref import topk_scores_ref


@pytest.fixture(autouse=True)
def _restore_table():
    """Every test leaves the process-wide active table as it found it."""
    yield
    tuning.reset_table()


def test_size_bucket_boundaries():
    assert tuning.size_bucket(1) == "le1024"
    assert tuning.size_bucket(1024) == "le1024"
    assert tuning.size_bucket(1025) == "le4096"
    assert tuning.size_bucket(65536) == "le65536"
    assert tuning.size_bucket(65537) == "gt65536"
    assert tuning.bucket_rep_size("le4096") == 4096
    assert tuning.bucket_rep_size("gt65536") == 2 * 65536


def test_dtype_str():
    assert tuning.dtype_str("int8") == "int8"
    assert tuning.dtype_str(jnp.float32) == "float32"
    assert tuning.dtype_str(jnp.int8) == "int8"
    assert tuning.dtype_str(np.dtype("int32")) == "int32"


def test_resolve_order_explicit_over_table_over_default():
    table = tuning.TunedTable()
    table.add(tuning.TunedConfig("topk", "le1024", "float32",
                                 (("block_n", 256), ("block_q", 32))))
    tuning.set_table(table)
    # tuned entry beats the hard-coded default
    assert tuning.resolve("topk", n=500, dtype="float32") == {
        "block_q": 32, "block_n": 256}
    # explicit kwarg beats the tuned entry; None means unspecified
    assert tuning.resolve("topk", n=500, dtype="float32",
                          block_n=128, block_q=None) == {
        "block_q": 32, "block_n": 128}
    # other buckets / dtypes fall through to the defaults
    assert tuning.resolve("topk", n=5000, dtype="float32") == \
        tuning.DEFAULTS["topk"]
    assert tuning.resolve("topk", n=500, dtype="int8") == \
        tuning.DEFAULTS["topk"]


def test_resolve_unknown_param_raises():
    with pytest.raises(ValueError, match="no block param"):
        tuning.resolve("topk", n=100, dtype="float32", block_z=64)


def test_set_table_none_forces_defaults():
    table = tuning.TunedTable()
    table.add(tuning.TunedConfig("topk", "le1024", "float32",
                                 (("block_n", 128), ("block_q", 8))))
    tuning.set_table(table)
    assert tuning.resolve("topk", n=100, dtype="float32")["block_n"] == 128
    tuning.set_table(None)        # the --no-tuned-kernels hatch
    assert tuning.resolve("topk", n=100, dtype="float32") == \
        tuning.DEFAULTS["topk"]


def test_env_escape_hatch_and_path(tmp_path):
    """REPRO_TUNED_KERNELS=off forces defaults; =<path> loads that table.
    Subprocess because the active table resolves once per process."""
    table = tuning.TunedTable(meta={"origin": "test"})
    table.add(tuning.TunedConfig("topk", "le1024", "float32",
                                 (("block_n", 512), ("block_q", 8))))
    path = tmp_path / "t.json"
    table.save(str(path))
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    script = ("from repro.kernels import tuning; "
              "print(tuning.resolve('topk', n=100, dtype='float32'))")
    def run(env_value):
        env = dict(os.environ, PYTHONPATH=src + os.pathsep
                   + os.environ.get("PYTHONPATH", ""))
        env[tuning.ENV_VAR] = env_value
        out = subprocess.run([sys.executable, "-c", script], env=env,
                             capture_output=True, text=True, timeout=300)
        assert out.returncode == 0, out.stdout + out.stderr
        return out.stdout.strip()
    assert run("off") == str(tuning.DEFAULTS["topk"])
    assert "512" in run(str(path))


def test_table_save_load_roundtrip(tmp_path):
    table = tuning.TunedTable(meta={"backend": "cpu"})
    table.add(tuning.TunedConfig("hamming_topk", "le4096", "int32",
                                 (("block_n", 256), ("block_q", 32)),
                                 score_ms=1.25, evals=7))
    path = str(tmp_path / "round.json")
    table.save(path)
    loaded = tuning.TunedTable.load(path)
    assert loaded.meta == {"backend": "cpu"}
    assert loaded.entries == table.entries
    # file is plain JSON with params as a dict (human-diffable)
    raw = json.load(open(path))
    assert raw["entries"][0]["params"] == {"block_n": 256, "block_q": 32}


def test_hillclimb_converges_on_synthetic_score():
    """Ask/tell finds the global optimum of a separable convex score from
    the default start, without exhausting the cross product."""
    space = tuning.SPACES["topk"]
    target = {"block_q": 8, "block_n": 2048}
    tuner = tuning.HillclimbTuner(space)
    while True:
        point = tuner.ask()
        if point is None:
            break
        score = sum(abs(np.log2(point[a]) - np.log2(target[a]))
                    for a in target)
        tuner.tell(point, score)
    assert tuner.best == target
    assert tuner.num_evals < sum(1 for _ in space.candidates())


def test_space_shrink_and_neighbours():
    space = tuning.SPACES["topk"].shrink_to({"block_n": 300})
    assert space.axes["block_n"] == (128, 256)
    assert space.axes["block_q"] == (8, 32, 128, 256)
    nbrs = list(space.neighbours({"block_q": 8, "block_n": 256}))
    assert {"block_q": 32, "block_n": 256} in nbrs
    assert {"block_q": 8, "block_n": 128} in nbrs
    assert len(nbrs) == 2
    # shrink below the smallest candidate keeps one value per axis
    tiny = tuning.SPACES["topk"].shrink_to({"block_n": 8})
    assert tiny.axes["block_n"] == (128,)


def test_autotune_smoke_writes_table_backends_consult(tmp_path):
    """Tiny autotune end to end: tunes one cell, persists it, activates it,
    and the dispatch wrappers resolve through it."""
    out = str(tmp_path / "tuned.json")
    table = tuning.autotune(["label_prop_round"], buckets=("le1024",),
                            max_evals=3, wall_iters=0, out_path=out,
                            activate=True, verbose=False)
    assert os.path.exists(out)
    entry = table.entries[("label_prop_round", "le1024", "float32")]
    assert tuning.resolve("label_prop_round", n=1000,
                          dtype="float32") == entry.params_dict()
    assert entry.evals >= 1 and np.isfinite(entry.score_ms)


def test_parity_under_absurd_tuned_blocks():
    """Correctness is block-independent: a tuned table pinning oversized
    blocks (clamped by the padded-n floor inside the kernels) must not
    change results."""
    table = tuning.TunedTable()
    table.add(tuning.TunedConfig("topk", "le1024", "float32",
                                 (("block_n", 2048), ("block_q", 256))))
    tuning.set_table(table)
    qs = jax.random.normal(jax.random.PRNGKey(0), (5, 16))
    cs = jax.random.normal(jax.random.PRNGKey(1), (37, 16))
    s, i = topk_scores(qs, cs, k=4)
    s_ref, i_ref = topk_scores_ref(qs, cs, k=4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               rtol=1e-5, atol=1e-5)
    assert (np.asarray(i) == np.asarray(i_ref)).all()
