"""End-to-end behaviour tests for the WindTunnel system."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (QRelTable, WindTunnelConfig, run_windtunnel,
                        run_uniform_baseline, query_density)
from repro.data.synthetic import generate_corpus


@pytest.fixture(scope="module")
def corpus():
    return generate_corpus(num_queries=256, qrels_per_query=8, num_topics=16,
                           aux_fraction=0.3, seed=0, vocab_size=512)


@pytest.fixture(scope="module")
def wt_result(corpus):
    qrels = QRelTable(*(jnp.asarray(x) for x in corpus.qrels))
    cfg = WindTunnelConfig(tau_quantile=0.5, fanout=8, lp_rounds=4,
                           target_size=0.3 * corpus.num_primary, seed=0)
    fn = jax.jit(lambda q: run_windtunnel(
        q, num_queries=corpus.num_queries,
        num_entities=corpus.num_entities, config=cfg))
    return fn(qrels), corpus


def test_pipeline_produces_sample(wt_result):
    res, corpus = wt_result
    size = int(res.sample.entity_mask.sum())
    assert 0 < size < corpus.num_entities
    # sample only contains qrel'd (primary) entities — aux have no edges
    kept = np.nonzero(np.asarray(res.sample.entity_mask))[0]
    assert kept.max() < corpus.num_primary


def test_sample_size_calibration(wt_result):
    res, corpus = wt_result
    target = 0.3 * corpus.num_primary
    size = int(res.sample.entity_mask.sum())
    assert abs(size - target) / target < 0.5   # stochastic but calibrated


def test_communities_are_topic_pure(wt_result):
    """Label-propagation communities should align with planted topics."""
    res, corpus = wt_result
    labels = np.asarray(res.labels)[:corpus.num_primary]
    topics = corpus.entity_topic[:corpus.num_primary]
    from collections import Counter
    pure = 0
    for lab in np.unique(labels):
        members = topics[labels == lab]
        pure += Counter(members).most_common(1)[0][1]
    assert pure / labels.size > 0.95


def test_cluster_sampling_keeps_whole_communities(wt_result):
    res, corpus = wt_result
    labels = np.asarray(res.labels)
    mask = np.asarray(res.sample.entity_mask)
    kept_labels = np.unique(labels[mask])
    for lab in kept_labels[:50]:
        members = labels == lab
        assert mask[members].all(), "cluster sampling must keep whole communities"


def test_windtunnel_density_beats_uniform(wt_result):
    res, corpus = wt_result
    qrels = QRelTable(*(jnp.asarray(x) for x in corpus.qrels))
    size = int(res.sample.entity_mask.sum())
    uni = run_uniform_baseline(qrels, num_queries=corpus.num_queries,
                               num_entities=corpus.num_entities,
                               rate=size / corpus.num_entities, seed=3)
    rho_wt = float(query_density(qrels, res.sample.entity_mask,
                                 res.reconstructed.query_mask,
                                 num_queries=corpus.num_queries,
                                 num_entities=corpus.num_entities))
    rho_uni = float(query_density(qrels, uni.entity_mask, uni.query_mask,
                                  num_queries=corpus.num_queries,
                                  num_entities=corpus.num_entities))
    assert rho_wt > rho_uni, (rho_wt, rho_uni)   # Table II direction


def test_reconstruction_schema(wt_result):
    res, corpus = wt_result
    rec = res.reconstructed
    # output rows are a subset of input rows with the same schema
    assert rec.qrels.query_ids.shape == corpus.qrels.query_ids.shape
    v_in = np.asarray(corpus.qrels.valid)
    v_out = np.asarray(rec.qrels.valid)
    assert (v_out <= v_in).all()
    # every surviving row's entity is in the sample
    e = np.asarray(corpus.qrels.entity_ids)[v_out]
    assert np.asarray(res.sample.entity_mask)[e].all()
