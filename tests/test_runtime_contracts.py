"""Runtime contract tests (DESIGN.md §15): the recompile sentinel
(per-region XLA compilation counting, the scheduler's steady-state
zero-recompile contract), the instrumented debug locks (acquisition
counts, order edges, inversion detection, the LiveIndex lock contract),
and regressions for the serve-tier findings the static analyzer
surfaced (compaction in-flight TOCTOU, compact(wait=True) join-under-
lock deadlock, metrics snapshot under concurrent mutation)."""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.obs import locks, recompile
from repro.obs.metrics import Registry
from repro.retrieval.search_core import SearchConfig
from repro.serve import (IngestConfig, LiveIndex, SchedulerConfig,
                         SearchServer)

D = 16


def _corpus(n, seed=0, dim=D):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(n, dim)).astype(np.float32)


@pytest.fixture
def sentinel():
    """Recompile counting on, zeroed, and off again afterwards."""
    recompile.enable()
    recompile.reset()
    yield recompile
    recompile.disable()
    recompile.reset()


@pytest.fixture
def debug_locks():
    """DebugLock wrappers from make_lock()/make_rlock(), reset + off after."""
    locks.enable()
    locks.reset()
    yield locks
    locks.disable()
    locks.reset()


# ---------------------------------------------------------------------------
# recompile sentinel
# ---------------------------------------------------------------------------


def test_sentinel_counts_cold_compile_not_warm(sentinel):
    @jax.jit
    def f(x):
        return x * 2.0

    with sentinel.region("contract.cold"):
        f(jnp.ones((3,))).block_until_ready()
    cold = sentinel.total("contract.cold")
    assert cold >= 1                     # the cold call compiled
    with sentinel.region("contract.warm"):
        f(jnp.ones((3,))).block_until_ready()
    assert sentinel.total("contract.warm") == 0   # warm shape: no compile
    # a NEW shape is a new trace -> a counted compilation
    with sentinel.region("contract.warm"):
        f(jnp.ones((5,))).block_until_ready()
    assert sentinel.total("contract.warm") >= 1


def test_sentinel_mark_since_waterline(sentinel):
    @jax.jit
    def g(x):
        return x + 1.0

    g(jnp.ones((4,))).block_until_ready()
    sentinel.mark()
    assert sentinel.since() == 0
    g(jnp.ones((4,))).block_until_ready()    # warm: waterline holds
    assert sentinel.since() == 0
    g(jnp.ones((6,))).block_until_ready()    # new shape: crosses it
    assert sentinel.since() >= 1


def test_sentinel_region_nesting_innermost_wins(sentinel):
    @jax.jit
    def h(x):
        return x - 1.0

    with sentinel.region("outer"):
        with sentinel.region("inner"):
            h(jnp.ones((7,))).block_until_ready()
    assert sentinel.total("inner") >= 1
    assert sentinel.total("outer") == 0


def test_sentinel_disabled_counts_nothing():
    recompile.disable()
    recompile.reset()

    @jax.jit
    def q(x):
        return x * 3.0

    q(jnp.ones((9,))).block_until_ready()
    assert recompile.total() == 0


def test_scheduler_steady_state_never_recompiles(sentinel):
    """The serving contract CI enforces: once every bucket shape is warm,
    >= 10 further ticks compile nothing (bucket + k_max pinning holds)."""
    server = SearchServer(lambda t: _corpus(256, seed=3),
                          config=SearchConfig(),
                          scheduler=SchedulerConfig(max_queue=128,
                                                    max_batch=8, k_max=10))
    rng = np.random.default_rng(0)
    sched = server.scheduler
    buckets = sched.config.bucket_set()

    def fill(n):
        for _ in range(n):
            q = rng.normal(size=(D,)).astype(np.float32)
            assert server.submit(q, k=5, tenant="tenant-0") is not None

    for b in buckets:                    # warm every dispatch shape
        fill(b)
        sched.tick()
    sentinel.mark()
    for i in range(12):                  # steady state across the bucket set
        fill(buckets[i % len(buckets)])
        assert sched.tick() > 0
    assert sentinel.since() == 0, recompile.counts()


# ---------------------------------------------------------------------------
# instrumented debug locks
# ---------------------------------------------------------------------------


def test_make_lock_plain_when_disabled():
    locks.disable()
    try:
        lk = locks.make_lock("plain")
        assert not isinstance(lk, locks.DebugLock)
        with lk:
            pass
    finally:
        locks.reset()


def test_debug_lock_counts_and_edges(debug_locks):
    a = debug_locks.make_lock("A")
    b = debug_locks.make_lock("B")
    with a:
        with b:
            pass
    with a:
        pass
    assert debug_locks.acquire_counts() == {"A": 2, "B": 1}
    assert ("A", "B") in debug_locks.edges()
    assert debug_locks.inversions() == []


def test_debug_lock_detects_inversion(debug_locks):
    a = debug_locks.make_lock("A")
    b = debug_locks.make_lock("B")
    with a:
        with b:
            pass
    with b:
        with a:
            pass
    assert debug_locks.inversions() == [("A", "B")]


def test_debug_rlock_reentrant_no_self_edge(debug_locks):
    r = debug_locks.make_rlock("R")
    with r:
        with r:
            pass
    assert debug_locks.acquire_counts()["R"] == 2
    assert all(e != ("R", "R") for e in debug_locks.edges())


def test_live_index_reads_take_the_lock(debug_locks):
    """The conc-unguarded-read contract, as a counted fact: geometry
    properties acquire the live-index lock."""
    li = LiveIndex(_corpus(32), SearchConfig())
    debug_locks.reset()                  # drop construction-time acquires
    _ = li.pending_rows
    _ = li.frozen_n
    _ = li.dim
    assert debug_locks.acquire_counts().get("live-index", 0) >= 3


# ---------------------------------------------------------------------------
# serve-tier regressions (the analyzer's real findings, pinned)
# ---------------------------------------------------------------------------


def _gated_session(monkeypatch):
    """Patch ingest.SearchSession so the SECOND construction (the
    compaction rebuild — the first built the frozen index) signals
    ``entered`` and blocks on ``gate``.  The build's session construction
    runs OUTSIDE the index lock, so appends/compacts stay live meanwhile."""
    from repro.serve import ingest as ingest_mod
    real = ingest_mod.SearchSession
    gate, entered = threading.Event(), threading.Event()
    calls = {"n": 0}

    def slow(*a, **kw):
        calls["n"] += 1
        if calls["n"] > 1:
            entered.set()
            gate.wait(timeout=10)
        return real(*a, **kw)

    monkeypatch.setattr(ingest_mod, "SearchSession", slow)
    return gate, entered


def test_compact_in_flight_flag_blocks_second_compaction(monkeypatch):
    """Between Thread creation and start(), is_alive() is False — the
    in-flight FLAG must close that window so two compactions never run
    concurrently (the TOCTOU the analyzer's donation/race pass flagged)."""
    gate, entered = _gated_session(monkeypatch)
    li = LiveIndex(_corpus(64), SearchConfig(), ingest=IngestConfig(
        append_cap=512, compact_threshold=10 ** 9))
    li.append(_corpus(8, seed=1))
    assert li.compact(background=True) is True
    assert entered.wait(timeout=10)      # the worker is mid-build
    li.append(_corpus(8, seed=2))
    assert li.compact(background=True) is False   # refused: in flight
    gate.set()
    li.flush()
    assert li.frozen_n == 72             # only the first batch folded


def test_compact_wait_while_in_flight_does_not_deadlock(monkeypatch):
    """compact(wait=True) joining the worker must NOT hold the index lock
    (the worker needs it to land the swap) — the deadlock the analyzer's
    lock-order pass surfaced, pinned with a timeout."""
    gate, entered = _gated_session(monkeypatch)
    li = LiveIndex(_corpus(64), SearchConfig(), ingest=IngestConfig(
        append_cap=512, compact_threshold=10 ** 9))
    li.append(_corpus(8, seed=1))
    assert li.compact(background=True) is True
    assert entered.wait(timeout=10)
    done = threading.Event()

    def second():
        li.compact(background=True, wait=True)   # must block, then return
        done.set()

    t = threading.Thread(target=second, daemon=True)
    t.start()
    gate.set()
    assert done.wait(timeout=10), "compact(wait=True) deadlocked"
    t.join(timeout=10)
    assert li.frozen_n == 72


def test_background_compaction_error_surfaces():
    li = LiveIndex(_corpus(32), SearchConfig(), ingest=IngestConfig(
        append_cap=512, compact_threshold=10 ** 9))
    li.append(_corpus(4, seed=1))

    def boom(*a, **kw):
        raise RuntimeError("synthetic build failure")

    li._rebuild_buffer = boom
    li.compact(background=True)
    with pytest.raises(RuntimeError, match="background compaction failed"):
        li.flush()


def test_metrics_snapshot_under_concurrent_mutation():
    """counters()/snapshot() iterate under the registry lock — no
    RuntimeError from a dict resized mid-iteration (the unguarded-read
    finding in obs/metrics.py, fixed and pinned)."""
    reg = Registry()
    stop = threading.Event()
    errors = []

    def mutate():
        i = 0
        while not stop.is_set():
            reg.counter(f"c.{i % 997}").inc()
            i += 1

    def snapshot():
        try:
            while not stop.is_set():
                reg.counters()
                reg.snapshot()
        except RuntimeError as e:     # "dictionary changed size ..."
            errors.append(e)

    threads = [threading.Thread(target=mutate, daemon=True)
               for _ in range(2)] + \
              [threading.Thread(target=snapshot, daemon=True)]
    for t in threads:
        t.start()
    stop.wait(timeout=0.5)
    stop.set()
    for t in threads:
        t.join(timeout=5)
    assert errors == []
