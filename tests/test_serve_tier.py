"""Serving-tier tests (DESIGN.md §14): microbatch scheduler admission /
FIFO / fixed-shape dispatch, per-tenant LRU session cache, live shard-local
ingest with append-then-search parity against a from-scratch rebuild
(every engine x jnp+int8 backend), background compaction that never
stalls or staleness-misses a search, the frontend's bounded context
cache, and the serve.* span/metric surfaces."""
import threading

import jax
import numpy as np
import pytest

from repro.obs import trace
from repro.obs.metrics import DEFAULT_BUCKETS, Registry
from repro.retrieval.search_core import SearchConfig, SearchSession
from repro.serve import ingest as ingest_mod
from repro.serve import (IngestConfig, LiveIndex, LoadSpec,
                         MicrobatchScheduler, RetrievalFrontend,
                         SchedulerConfig, SearchServer, TenantCache,
                         run_load)

D = 16


def _corpus(n, seed=0, dim=D):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(n, dim)).astype(np.float32)


def _sparse(n, seed=0, dim=D):
    """Non-negative sparse rows (tfidf-shaped data with real df variation)."""
    rng = np.random.default_rng(seed)
    x = np.abs(rng.normal(size=(n, dim))).astype(np.float32)
    x[x < 0.8] = 0.0
    return x


def _sets(ids):
    return [set(int(i) for i in row if i >= 0) for row in ids]


# ---------------------------------------------------------------------------
# live ingest: append-then-search parity vs from-scratch rebuild
# ---------------------------------------------------------------------------

# exact-recall hyper-parameters per engine: the parity criterion is
# set-equality with a full rebuild, so ANN engines run in their exhaustive
# configurations (probe all lists / rerank everything)
ENGINE_OPTS = {
    "exact": None,
    "tfidf": None,
    "ivfflat": {"n_lists": 4, "nprobe": 64},
    "lsh": {"n_bits": 256, "rerank": 10 ** 6},
}


@pytest.mark.parametrize("backend", ["jnp", "int8"])
@pytest.mark.parametrize("engine", sorted(ENGINE_OPTS))
def test_append_then_search_matches_rebuild(engine, backend):
    make = _sparse if engine == "tfidf" else _corpus
    base, extra = make(120, seed=1), make(45, seed=2)
    queries = make(6, seed=3)
    cfg = SearchConfig(engine=engine, backend=backend,
                       engine_opts=ENGINE_OPTS[engine])
    li = LiveIndex(base, cfg, ingest=IngestConfig(
        append_cap=8, compact_threshold=10 ** 9))
    start, stop = li.append(extra)
    assert (start, stop) == (120, 165)
    rebuilt = SearchSession(np.concatenate([base, extra]), cfg)
    live_ids = li.search(queries, k=10)
    full_ids = rebuilt.search(queries, k=10)
    assert _sets(live_ids) == _sets(full_ids)
    # scores of the merged ranking are ordered and finite at k <= n
    scores, _ = li.search_scored(queries, k=10)
    assert np.isfinite(scores).all()
    assert (np.diff(scores, axis=1) <= 1e-5).all()


def test_live_index_multiple_appends_and_capacity_growth():
    base = _corpus(64, seed=0)
    li = LiveIndex(base, SearchConfig(), ingest=IngestConfig(
        append_cap=4, compact_threshold=10 ** 9))
    chunks = [_corpus(7, seed=s + 10) for s in range(5)]
    for c in chunks:
        li.append(c)                      # forces repeated buffer growth
    assert li.pending_rows == 35 and li.n == 99
    rebuilt = SearchSession(np.concatenate([base] + chunks), SearchConfig())
    q = _corpus(4, seed=99)
    assert _sets(li.search(q, k=12)) == _sets(rebuilt.search(q, k=12))


def test_live_index_k_larger_than_corpus_pads():
    li = LiveIndex(_corpus(5), SearchConfig(),
                   ingest=IngestConfig(compact_threshold=10 ** 9))
    li.append(_corpus(3, seed=4))
    scores, ids = li.search_scored(_corpus(2, seed=5), k=12)
    assert ids.shape == (2, 12)
    assert (ids[:, :8] >= 0).all() and (ids[:, 8:] == -1).all()
    assert np.isinf(scores[:, 8:]).all()


def test_live_index_streamed_sharded_path():
    mesh = jax.make_mesh((1,), ("data",))
    cfg = SearchConfig(engine="exact", streamed=True, mesh=mesh)
    base, extra = _corpus(96, seed=6), _corpus(33, seed=7)
    li = LiveIndex(base, cfg, ingest=IngestConfig(
        append_cap=16, compact_threshold=10 ** 9))
    li.append(extra)
    rebuilt = SearchSession(np.concatenate([base, extra]), cfg)
    q = _corpus(5, seed=8)
    assert _sets(li.search(q, k=10)) == _sets(rebuilt.search(q, k=10))
    li.compact(background=False)
    assert li.pending_rows == 0 and li.frozen_n == 129
    assert _sets(li.search(q, k=10)) == _sets(rebuilt.search(q, k=10))


def test_live_index_rejects_no_rerank_lsh():
    with pytest.raises(ValueError, match="rerank"):
        LiveIndex(_corpus(64), SearchConfig(
            engine="lsh", engine_opts={"rerank": 0}))


def test_compaction_threshold_triggers_and_preserves_ids():
    reg = Registry()
    li = LiveIndex(_corpus(50, seed=0), SearchConfig(),
                   ingest=IngestConfig(append_cap=8, compact_threshold=10,
                                       background=False), registry=reg)
    li.append(_corpus(6, seed=1))
    assert li.pending_rows == 6            # below threshold: no compaction
    start, stop = li.append(_corpus(6, seed=2))
    assert (start, stop) == (56, 62)
    assert li.pending_rows == 0 and li.frozen_n == 62
    assert reg.counter("serve.ingest.compactions").value == 1
    # ids are stable across the compaction: a third append continues on
    start, stop = li.append(_corpus(3, seed=3))
    assert (start, stop) == (62, 65)


def test_searches_succeed_during_background_compaction(monkeypatch):
    """The compaction state machine's core guarantee: while the rebuild is
    in flight, searches keep answering from the old snapshot and see every
    appended row (no stale-index miss, no error, no stall)."""
    base, extra = _corpus(80, seed=0), _corpus(30, seed=1)
    queries = _corpus(4, seed=2)
    li = LiveIndex(base, SearchConfig(), ingest=IngestConfig(
        append_cap=64, compact_threshold=10 ** 9))
    li.append(extra)
    expect = _sets(li.search(queries, k=10))

    started, release = threading.Event(), threading.Event()
    real_session = ingest_mod.SearchSession

    class BlockingSession(real_session):
        def __init__(self, *a, **kw):
            started.set()
            assert release.wait(timeout=30)
            super().__init__(*a, **kw)

    monkeypatch.setattr(ingest_mod, "SearchSession", BlockingSession)
    assert li.compact(background=True)
    assert started.wait(timeout=30)
    # rebuild is mid-flight and parked; searches must not block or miss
    for _ in range(3):
        assert _sets(li.search(queries, k=10)) == expect
    # appends mid-compaction stay searchable and survive the swap
    late = _corpus(5, seed=3)
    li.append(late)
    release.set()
    li.flush()
    assert li.frozen_n == 110 and li.pending_rows == 5
    rebuilt = real_session(np.concatenate([base, extra, late]),
                           SearchConfig())
    assert _sets(li.search(queries, k=10)) == _sets(
        rebuilt.search(queries, k=10))


def test_background_compaction_failure_surfaces(monkeypatch):
    li = LiveIndex(_corpus(40), SearchConfig(), ingest=IngestConfig(
        append_cap=8, compact_threshold=10 ** 9))
    li.append(_corpus(4, seed=1))

    def boom(*a, **kw):
        raise RuntimeError("injected build failure")

    monkeypatch.setattr(ingest_mod, "SearchSession", boom)
    li.compact(background=True)
    with pytest.raises(RuntimeError, match="compaction failed"):
        li.flush()
    # error is consumed; the index keeps serving from the old snapshot
    assert li.search(_corpus(2, seed=2), k=5).shape == (2, 5)


# ---------------------------------------------------------------------------
# microbatch scheduler: admission, FIFO, fixed shapes, futures
# ---------------------------------------------------------------------------

def test_scheduler_rejects_when_full_and_serves_fifo():
    reg = Registry()
    session = SearchSession(_corpus(64), SearchConfig())
    sched = MicrobatchScheduler(
        lambda t: session,
        SchedulerConfig(max_queue=6, max_batch=2, k_max=5), registry=reg)
    reqs = [sched.submit(_corpus(1, seed=i)[0], k=3) for i in range(9)]
    admitted = [r for r in reqs if r is not None]
    assert len(admitted) == 6 and reqs[6:] == [None] * 3
    assert reg.counter("serve.queue.rejected").value == 3
    assert sched.drain() == 6
    assert reg.counter("serve.queue.completed").value == 6
    # FIFO: completion order follows admission order
    times = [r.completed_at for r in admitted]
    assert times == sorted(times)
    for r in admitted:
        scores, ids = r.result(timeout=0)
        assert scores.shape == (3,) and ids.shape == (3,)


def test_scheduler_results_match_direct_search():
    session = SearchSession(_corpus(128, seed=0), SearchConfig())
    sched = MicrobatchScheduler(lambda t: session,
                                SchedulerConfig(max_batch=4, k_max=8),
                                registry=Registry())
    queries = _corpus(6, seed=1)
    reqs = [sched.submit(q, k=5) for q in queries]
    sched.drain()
    direct_s, direct_i = session.search_scored(queries, k=5)
    for i, r in enumerate(reqs):
        scores, ids = r.result(timeout=0)
        np.testing.assert_array_equal(ids, direct_i[i])
        np.testing.assert_allclose(scores, direct_s[i], rtol=1e-5)


def test_scheduler_batches_per_tenant_in_order():
    calls = []

    class Spy:
        def __init__(self, session):
            self.session = session

        def search_scored(self, q, *, k):
            calls.append(np.asarray(q).shape[0])
            return self.session.search_scored(q, k=k)

    spy = Spy(SearchSession(_corpus(64), SearchConfig()))
    sched = MicrobatchScheduler(lambda t: spy,
                                SchedulerConfig(max_batch=8, k_max=4),
                                registry=Registry())
    order = ["a", "a", "b", "a", "b"]
    reqs = [sched.submit(_corpus(1, seed=i)[0], tenant=t)
            for i, t in enumerate(order)]
    # tick 1: all of tenant a (head of line), padded to bucket 4;
    # tick 2: tenant b, padded to bucket 2
    assert sched.tick() == 3 and calls[-1] == 4
    assert [r.done for r in reqs] == [True, True, False, True, False]
    assert sched.tick() == 2 and calls[-1] == 2
    assert all(r.done for r in reqs)


def test_scheduler_k_bounds_and_failure_propagates():
    sched = MicrobatchScheduler(lambda t: None,
                                SchedulerConfig(k_max=4), registry=Registry())
    with pytest.raises(ValueError, match="k_max"):
        sched.submit(np.zeros(D, np.float32), k=9)

    class Broken:
        def search_scored(self, q, *, k):
            raise RuntimeError("engine exploded")

    sched = MicrobatchScheduler(lambda t: Broken(),
                                SchedulerConfig(k_max=4), registry=Registry())
    req = sched.submit(np.zeros(D, np.float32), k=2)
    sched.tick()
    with pytest.raises(RuntimeError, match="engine exploded"):
        req.result(timeout=0)


def test_loadgen_completes_and_reports():
    session = SearchSession(_corpus(128), SearchConfig())
    sched = MicrobatchScheduler(lambda t: session,
                                SchedulerConfig(max_batch=8, k_max=8),
                                registry=Registry())
    rep = run_load(sched, _corpus(8, seed=1),
                   LoadSpec(n_requests=32, k=5, tenants=2))
    assert rep.completed == 32 and rep.rejected == 0
    assert rep.throughput_rps > 0
    assert rep.p50_s <= rep.p99_s
    assert set(rep.to_row()) >= {"throughput_rps", "p50_s", "p99_s"}


# ---------------------------------------------------------------------------
# tenant cache: LRU eviction, observable, transparently rebuilt
# ---------------------------------------------------------------------------

def test_tenant_cache_evicts_lru_and_rebuilds_identically():
    reg = Registry()
    builds = []

    def provider(tenant):
        builds.append(tenant)
        return SearchSession(_corpus(64, seed=hash(tenant) % 100),
                             SearchConfig())

    cache = TenantCache(provider, capacity=2, registry=reg)
    q = _corpus(3, seed=5)
    first = cache.get("t1").search(q, k=5)
    cache.get("t2"), cache.get("t1")          # t1 most recent
    assert cache.get("t3") is not None        # evicts t2 (LRU)
    assert set(cache.resident) == {"t1", "t3"}
    assert reg.counter("serve.tenant.evict").value == 1
    assert reg.counter("serve.tenant.miss").value == 3
    # re-admission is a rebuild (miss), and results are identical
    again = cache.get("t2").search(q, k=5)
    assert builds == ["t1", "t2", "t3", "t2"]
    np.testing.assert_array_equal(
        again, SearchSession(_corpus(64, seed=hash("t2") % 100),
                             SearchConfig()).search(q, k=5))
    # t2's re-admission evicted t1 (LRU); its rebuild is transparent too
    np.testing.assert_array_equal(
        first, cache.get("t1").search(q, k=5))
    assert reg.counter("serve.tenant.hit").value == 1
    assert reg.counter("serve.tenant.evict").value == 3
    assert reg.gauge("serve.tenant.resident_bytes").value >= 0


def test_search_server_end_to_end_with_ingest():
    server = SearchServer(
        lambda t: _corpus(64, seed=len(t)),
        scheduler=SchedulerConfig(max_batch=4, k_max=8),
        ingest=IngestConfig(append_cap=8, compact_threshold=10 ** 9),
        max_tenants=2)
    reqs = [server.submit(_corpus(1, seed=i)[0], k=4,
                          tenant=f"t{i % 3}") for i in range(6)]
    assert server.drain() == 6
    assert all(r.done for r in reqs)
    start, stop = server.append("t0", _corpus(16, seed=9))
    assert (start, stop) == (64, 80)
    req = server.submit(_corpus(1, seed=42)[0], k=4, tenant="t0")
    server.drain()
    assert req.result(timeout=0)[1].shape == (4,)


# ---------------------------------------------------------------------------
# frontend context cache: bounded, observable, correct after eviction
# ---------------------------------------------------------------------------

def test_frontend_ctx_cache_bounds_memory_and_revalidates():
    from repro.obs import REGISTRY
    evict0 = REGISTRY.counter("serve.ctx.evict").value
    fe = RetrievalFrontend(_corpus(64), lambda q: np.asarray(q),
                           ctx_cache_size=2)
    queries = _corpus(5, seed=1)
    first = fe.retrieve(queries, k=4)
    assert len(fe._ctx_cache) <= 2            # eviction caps the cache
    assert REGISTRY.counter("serve.ctx.evict").value - evict0 == 3
    # re-retrieval of evicted queries recomputes identical contexts
    np.testing.assert_array_equal(first, fe.retrieve(queries, k=4))
    # and a genuinely cached query short-circuits to the same answer
    np.testing.assert_array_equal(first[-1:],
                                  fe.retrieve(queries[-1:], k=4))


def test_frontend_live_ingest_append_invalidates_ctx_cache():
    fe = RetrievalFrontend(_corpus(32, seed=0), lambda q: np.asarray(q),
                           ctx_cache_size=8,
                           ingest=IngestConfig(compact_threshold=10 ** 9))
    target = _corpus(1, seed=7) * 10.0        # dominant-score doc
    before = fe.retrieve(target, k=3)
    fe.append(target)                          # the doc itself joins
    after = fe.retrieve(target, k=3)
    assert 32 in after[0].tolist()             # new row is visible
    assert not np.array_equal(before, after)


# ---------------------------------------------------------------------------
# observability: serve spans aggregate, default buckets resolve sub-ms
# ---------------------------------------------------------------------------

def test_serve_spans_aggregate_with_filter(tmp_path):
    from repro.launch.trace import aggregate, load_spans
    path = str(tmp_path / "trace.jsonl")
    trace.enable(path)
    try:
        session = SearchSession(_corpus(64), SearchConfig())
        sched = MicrobatchScheduler(lambda t: session,
                                    SchedulerConfig(max_batch=4, k_max=4),
                                    registry=Registry())
        for i in range(5):
            sched.submit(_corpus(1, seed=i)[0], k=2)
        sched.drain()
    finally:
        trace.disable()
    aggs = aggregate(load_spans(path), prefix="serve.")
    assert {"serve.tick", "serve.batch"} <= set(aggs)
    assert all(name.startswith("serve.") for name in aggs)
    assert aggs["serve.tick"]["count"] >= 2
    assert aggs["serve.tick"]["p99_s"] >= aggs["serve.tick"]["p50_s"]


def test_default_buckets_resolve_microseconds():
    assert DEFAULT_BUCKETS[0] <= 1e-6
    assert list(DEFAULT_BUCKETS) == sorted(DEFAULT_BUCKETS)
    # at least five rungs below the old 100 µs floor: a 30 µs serving
    # latency must land in a real bucket, not the bottom catch-all
    assert sum(1 for b in DEFAULT_BUCKETS if b < 1e-4) >= 5
    reg = Registry()
    h = reg.histogram("serve.request_latency_s")
    h.observe(3e-5)
    assert h.uppers[0] <= 1e-6
