"""Retrieval substrate + training substrate tests: index recall, metrics,
optimizers, checkpoint/restore (incl. elastic re-shard), compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.compression import (compress_leaf, dequantize_int8,
                                           ef_init, quantize_int8)
from repro.retrieval.exact import exact_topk
from repro.retrieval.ivfflat import build_ivfflat, search_ivfflat
from repro.retrieval.lsh import build_lsh, search_lsh, popcount32
from repro.retrieval.metrics import precision_at_k, qrel_set
from repro.train.checkpoint import (AsyncCheckpointer, latest_step,
                                    restore_checkpoint, save_checkpoint)
from repro.train.optimizer import (AdamWConfig, AdafactorConfig, adamw_init,
                                   adamw_update, adafactor_init,
                                   adafactor_update)


@pytest.fixture(scope="module")
def vectors():
    key = jax.random.PRNGKey(0)
    corpus = jax.random.normal(key, (1500, 32))
    corpus = corpus / jnp.linalg.norm(corpus, axis=1, keepdims=True)
    queries = corpus[:40] + 0.05 * jax.random.normal(jax.random.PRNGKey(1),
                                                     (40, 32))
    full = np.asarray(queries @ corpus.T)
    gt = np.argsort(-full, axis=1)[:, :5]
    return corpus, queries, gt


def test_exact_topk_is_exact(vectors):
    corpus, queries, gt = vectors
    _, ids = exact_topk(queries, corpus, k=5, block=256)
    assert (np.asarray(ids) == gt).all()


def test_ivfflat_recall(vectors):
    corpus, queries, gt = vectors
    idx = build_ivfflat(jax.random.PRNGKey(0), corpus, n_lists=32)
    _, ids = search_ivfflat(idx, queries, k=5, nprobe=16)
    rec = np.mean([len(set(a.tolist()) & set(b.tolist())) / 5
                   for a, b in zip(np.asarray(ids), gt)])
    assert rec > 0.7


def test_ivfflat_full_probe_is_exact(vectors):
    corpus, queries, gt = vectors
    idx = build_ivfflat(jax.random.PRNGKey(0), corpus, n_lists=8,
                        cap_factor=8.0)
    _, ids = search_ivfflat(idx, queries, k=5, nprobe=8)
    assert (np.sort(np.asarray(ids), 1) == np.sort(gt, 1)).all()


def test_lsh_rerank_recall(vectors):
    corpus, queries, gt = vectors
    idx = build_lsh(jax.random.PRNGKey(0), corpus, n_bits=128)
    _, ids = search_lsh(idx, queries, k=5, rerank=80)
    rec = np.mean([len(set(a.tolist()) & set(b.tolist())) / 5
                   for a, b in zip(np.asarray(ids), gt)])
    assert rec > 0.6


def test_popcount():
    x = jnp.asarray([0, 1, 3, -1, 2**30], jnp.int32)
    assert popcount32(x).tolist() == [0, 1, 2, 32, 1]


def test_precision_at_k():
    qrels = {(0, 10), (0, 11), (1, 20)}
    retrieved = np.array([[10, 11, 99], [20, 21, 22]])
    p = precision_at_k(retrieved, np.array([0, 1]), qrels, k=3)
    assert abs(p - 3 / 6) < 1e-9


def _toy_params(key):
    k1, k2 = jax.random.split(key)
    return {"w": jax.random.normal(k1, (8, 4)), "b": jnp.zeros((4,))}


def test_adamw_descends():
    params = _toy_params(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (16, 8))
    y = jax.random.normal(jax.random.PRNGKey(2), (16, 4))
    loss = lambda p: jnp.mean((x @ p["w"] + p["b"] - y) ** 2)
    cfg = AdamWConfig(lr=3e-2, warmup_steps=1, total_steps=200,
                      weight_decay=0.0)
    state = adamw_init(params)
    l0 = float(loss(params))
    for _ in range(100):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(g, state, params, cfg)
    assert float(loss(params)) < 0.3 * l0


def test_adafactor_descends_and_is_factored():
    params = _toy_params(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (16, 8))
    y = jax.random.normal(jax.random.PRNGKey(2), (16, 4))
    loss = lambda p: jnp.mean((x @ p["w"] + p["b"] - y) ** 2)
    cfg = AdafactorConfig(lr=2e-1, warmup_steps=1, total_steps=300)
    state = adafactor_init(params)
    assert state["slots"]["w"]["vr"].shape == (8,)    # factored moments
    assert state["slots"]["w"]["vc"].shape == (4,)
    l0 = float(loss(params))
    for _ in range(150):
        g = jax.grad(loss)(params)
        params, state, _ = adafactor_update(g, state, params, cfg)
    assert float(loss(params)) < 0.5 * l0


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "nested": {"b": jnp.ones((4,), jnp.int32)}}
    save_checkpoint(str(tmp_path), 7, tree)
    assert latest_step(str(tmp_path)) == 7
    restored, step = restore_checkpoint(str(tmp_path), tree)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))


def test_checkpoint_atomicity(tmp_path):
    """A crashed writer must never corrupt the published checkpoint."""
    tree = {"a": jnp.ones((3,))}
    save_checkpoint(str(tmp_path), 1, tree)
    # simulate a stale tmp dir from a crashed writer
    os.makedirs(os.path.join(str(tmp_path), "step_0000000002.tmp"))
    assert latest_step(str(tmp_path)) == 1
    restored, _ = restore_checkpoint(str(tmp_path), tree)
    assert float(restored["a"].sum()) == 3.0


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    tree = {"a": jnp.ones((3,))}
    for step in (1, 2, 3):
        ck.save(step, jax.tree.map(lambda x: x * step, tree))
    ck.close()
    assert latest_step(str(tmp_path)) == 3
    restored, _ = restore_checkpoint(str(tmp_path), tree, step=3)
    assert float(restored["a"][0]) == 3.0


def test_elastic_reshard_roundtrip(tmp_path):
    """Checkpoint written under one mesh restores under a different mesh
    (elastic re-mesh resume): values identical, shardings re-applied."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh1 = jax.make_mesh((1,), ("data",))
    tree = {"w": jnp.arange(8.0).reshape(4, 2)}
    with mesh1:
        sharded = jax.device_put(tree["w"], NamedSharding(mesh1, P("data")))
    save_checkpoint(str(tmp_path), 5, {"w": sharded})
    mesh2 = jax.make_mesh((1, 1), ("data", "model"))
    shardings = {"w": NamedSharding(mesh2, P("model", None))}
    restored, _ = restore_checkpoint(str(tmp_path), tree, shardings=shardings)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))


def test_int8_error_feedback_compression():
    g = jnp.asarray(np.random.default_rng(0).normal(size=(64,)) * 1e-3,
                    jnp.float32)
    err = jnp.zeros_like(g)
    # accumulated dequantized updates converge to the true gradient sum
    total_sent = jnp.zeros_like(g)
    for _ in range(50):
        q, scale, err = compress_leaf(g, err)
        total_sent = total_sent + dequantize_int8(q, scale)
    np.testing.assert_allclose(np.asarray(total_sent / 50), np.asarray(g),
                               atol=float(jnp.abs(g).max()) * 0.02)


def test_quantize_int8_bounds():
    x = jnp.asarray([-3.0, 0.0, 5.0])
    q, scale = quantize_int8(x)
    assert q.dtype == jnp.int8
    np.testing.assert_allclose(np.asarray(dequantize_int8(q, scale)),
                               np.asarray(x), atol=float(scale))
