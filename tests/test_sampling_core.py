"""Sampling-core tests (DESIGN.md §10): strategy registry, the
build-once/draw-many SamplerSession, sweep stage counters, bit-parity with
the legacy one-shot entry points (single-device and 1-device mesh), the
associated-queries / reconstructor cross-check, and the CLI registry-error
contract shared by launch/sample.py and launch/evaluate.py.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (QRelTable, SamplerSession, SamplerSpec,
                        WindTunnelConfig, associated_queries,
                        available_samplers, get_sampler, reconstruct,
                        run_uniform_baseline, run_windtunnel,
                        run_windtunnel_sharded)
from repro.core import engines as eng
from repro.core import graph_builder as gb
from repro.core import sampler as sm
from repro.core.samplers import SamplerStrategy, judged_entities
from repro.data.synthetic import generate_corpus
from repro.launch.mesh import make_host_mesh


@pytest.fixture(scope="module")
def corpus():
    return generate_corpus(num_queries=96, qrels_per_query=8, num_topics=10,
                           aux_fraction=0.3, seed=0, vocab_size=256)


@pytest.fixture(scope="module")
def qrels(corpus):
    return QRelTable(*(jnp.asarray(x) for x in corpus.qrels))


def _spec(corpus, **kw):
    kw.setdefault("fanout", 8)
    kw.setdefault("lp_rounds", 4)
    kw.setdefault("max_degree", corpus.num_entities)
    kw.setdefault("target_size", 0.3 * corpus.num_primary)
    return SamplerSpec(**kw)


def _session(corpus, qrels, **kw):
    return SamplerSession(qrels, num_queries=corpus.num_queries,
                          num_entities=corpus.num_entities,
                          spec=_spec(corpus, **kw))


# ---------------------------------------------------------------------------
# strategy registry
# ---------------------------------------------------------------------------

def test_registry_contents():
    assert {"full", "uniform", "windtunnel",
            "degree_stratified"} <= set(available_samplers())
    for name in available_samplers():
        assert isinstance(get_sampler(name), SamplerStrategy)


def test_unknown_strategy_raises_with_registered_names():
    with pytest.raises(ValueError, match="registered strategies"):
        get_sampler("stratified-by-vibes")


def test_session_validates_registries_up_front(corpus, qrels):
    with pytest.raises(ValueError, match="registered strategies"):
        _session(corpus, qrels, strategy="nope")
    with pytest.raises(ValueError, match="registered engines"):
        _session(corpus, qrels, engine="spark")
    with pytest.raises(ValueError, match="needs a mesh"):
        _session(corpus, qrels, engine="ell", sharded=True)
    with pytest.raises(ValueError, match="ELL-family"):
        _session(corpus, qrels, sharded=True, mesh=make_host_mesh())


# ---------------------------------------------------------------------------
# sweep cache: graph + LP execute exactly once for an S x R sweep
# ---------------------------------------------------------------------------

def test_sweep_stages_graph_and_lp_exactly_once(corpus, qrels):
    session = _session(corpus, qrels)
    sizes = [0.2 * corpus.num_primary, 0.3 * corpus.num_primary,
             0.4 * corpus.num_primary]
    sweep = session.sweep(sizes, [0, 1, 2])
    assert len(sweep.draws) == 9
    counts = session.stage_counts()
    assert counts["graph"][0] == 1
    assert counts["labels"][0] == 1
    assert counts["draw"] == (9, 9)
    # every draw requested the staged prefixes (the PlanTrie reading)
    assert counts["graph"][1] >= 9 and counts["labels"][1] >= 9
    js = sweep.to_json()
    assert js["stage_counts"]["labels"]["executions"] == 1
    assert len(js["draws"]) == 9


def test_draws_distinct_seeds_differ_and_cache_hits(corpus, qrels):
    session = _session(corpus, qrels)
    d0 = session.draw(seed=0)
    d1 = session.draw(seed=1)
    assert (np.asarray(d0.entity_mask) != np.asarray(d1.entity_mask)).any()
    assert session.draw(seed=0) is d0          # cached, not recomputed
    assert session.stage_counts()["draw"] == (2, 3)


def test_identical_sessions_are_bit_equal(corpus, qrels):
    a = _session(corpus, qrels).draw(seed=5)
    b = _session(corpus, qrels).draw(seed=5)
    assert (np.asarray(a.entity_mask) == np.asarray(b.entity_mask)).all()
    assert (np.asarray(a.reconstructed.qrels.valid) ==
            np.asarray(b.reconstructed.qrels.valid)).all()


# ---------------------------------------------------------------------------
# parity with the legacy entry points (the acceptance criterion)
# ---------------------------------------------------------------------------

def test_sweep_draws_bit_equal_fresh_run_windtunnel(corpus, qrels):
    """Each (size, seed) cell of a 3x3 sweep matches a fresh one-shot
    run_windtunnel at the same config bit-for-bit."""
    session = _session(corpus, qrels)
    sizes = [0.2 * corpus.num_primary, 0.3 * corpus.num_primary,
             0.4 * corpus.num_primary]
    seeds = [0, 1, 2]
    sweep = session.sweep(sizes, seeds)
    for size in sizes:
        for seed in seeds:
            cfg = WindTunnelConfig(fanout=8, lp_rounds=4,
                                   max_degree=corpus.num_entities,
                                   target_size=size, seed=seed)
            ref = jax.jit(lambda q, cfg=cfg: run_windtunnel(
                q, num_queries=corpus.num_queries,
                num_entities=corpus.num_entities, config=cfg))(qrels)
            draw = sweep.draws[(float(size), seed)]
            assert (np.asarray(draw.entity_mask) ==
                    np.asarray(ref.sample.entity_mask)).all(), (size, seed)
            assert (np.asarray(draw.reconstructed.query_mask) ==
                    np.asarray(ref.reconstructed.query_mask)).all()
    assert session.stage_counts()["labels"][0] == 1


def test_run_windtunnel_wrapper_matches_manual_pipeline(corpus, qrels):
    """Wrapper parity: run_windtunnel equals the historical inline
    composition graph -> LP -> cluster_sample -> reconstruct bit-for-bit."""
    cfg = WindTunnelConfig(fanout=8, lp_rounds=4,
                           max_degree=corpus.num_entities,
                           target_size=0.3 * corpus.num_primary, seed=0)

    def manual(q):
        edges = gb.build_affinity_graph(
            q, num_queries=corpus.num_queries,
            tau_quantile=cfg.tau_quantile, fanout=cfg.fanout)
        degrees = gb.node_degrees(edges, corpus.num_entities)
        src, dst, w, valid = gb.symmetrize(edges)
        lp_res = eng.run_engine(eng.get_engine(cfg.engine), src, dst, w,
                                valid, num_nodes=corpus.num_entities,
                                max_degree=cfg.max_degree,
                                rounds=cfg.lp_rounds)
        sample = sm.cluster_sample(lp_res.labels,
                                   jax.random.PRNGKey(cfg.seed),
                                   num_nodes=corpus.num_entities,
                                   target_size=cfg.target_size,
                                   eligible=degrees > 0)
        return lp_res.labels, sample.entity_mask

    labels_ref, mask_ref = jax.jit(manual)(qrels)
    res = jax.jit(lambda q: run_windtunnel(
        q, num_queries=corpus.num_queries,
        num_entities=corpus.num_entities, config=cfg))(qrels)
    assert (np.asarray(res.labels) == np.asarray(labels_ref)).all()
    assert (np.asarray(res.sample.entity_mask) == np.asarray(mask_ref)).all()
    assert "deprecated" in run_windtunnel.__doc__


def test_run_uniform_baseline_wrapper_matches_legacy_draw(corpus, qrels):
    """Wrapper parity: the uniform baseline reproduces the legacy
    whole-corpus Bernoulli mask bit-exactly for the same (rate, seed)."""
    for rate, seed in [(0.2, 3), (0.45, 7)]:
        res = run_uniform_baseline(qrels, num_queries=corpus.num_queries,
                                   num_entities=corpus.num_entities,
                                   rate=rate, seed=seed)
        legacy = sm.uniform_sample(corpus.num_entities,
                                   jax.random.PRNGKey(seed), rate=rate)
        assert (np.asarray(res.entity_mask) == np.asarray(legacy)).all()
        ref = reconstruct(qrels, legacy, num_queries=corpus.num_queries)
        assert (np.asarray(res.query_mask) == np.asarray(ref.query_mask)).all()
    assert "deprecated" in run_uniform_baseline.__doc__


@pytest.mark.parametrize("engine", ["ell", "pallas"])
def test_sharded_session_bit_equal_on_host_mesh(corpus, qrels, engine):
    """One config, mesh in the spec: the sharded session reproduces the
    unsharded session AND both legacy entry points on a 1-device mesh."""
    mesh = make_host_mesh()
    sh = _session(corpus, qrels, engine=engine, sharded=True, mesh=mesh)
    ref = _session(corpus, qrels, engine=engine)
    d_sh, d_ref = sh.draw(), ref.draw()
    assert (np.asarray(d_sh.entity_mask) ==
            np.asarray(d_ref.entity_mask)).all()
    assert (np.asarray(sh.labels()[0]) == np.asarray(ref.labels()[0])).all()
    # both stage slots were filled by ONE shard_map region
    assert sh.stage_counts()["graph"][0] == 1
    assert sh.stage_counts()["labels"][0] == 1
    cfg = _spec(corpus, engine=engine).to_config()
    legacy = run_windtunnel_sharded(
        qrels, num_queries=corpus.num_queries,
        num_entities=corpus.num_entities, config=cfg, mesh=mesh)
    assert (np.asarray(legacy.sample.entity_mask) ==
            np.asarray(d_sh.entity_mask)).all()


# ---------------------------------------------------------------------------
# strategies: fraction targets, universes, degree stratification
# ---------------------------------------------------------------------------

def test_fraction_target_matches_absolute_target(corpus, qrels):
    session = _session(corpus, qrels)
    deg = np.asarray(session.graph()[1])
    n_elig = int((deg > 0).sum())
    frac = session.draw(target_size=0.3, seed=0)
    absolute = session.draw(target_size=float(0.3 * n_elig), seed=0)
    assert (np.asarray(frac.entity_mask) ==
            np.asarray(absolute.entity_mask)).all()


def test_uniform_judged_universe_excludes_aux(corpus, qrels):
    session = _session(corpus, qrels, strategy="uniform")
    mask = np.asarray(session.draw(target_size=0.4, seed=0).entity_mask)
    assert mask[:corpus.num_primary].any()
    assert not mask[corpus.num_primary:].any()
    judged = np.asarray(judged_entities(qrels, corpus.num_entities))
    assert judged.sum() == corpus.num_primary
    # no graph/LP staged for a Bernoulli baseline
    assert session.stage_counts()["graph"] == (0, 0)
    assert session.stage_counts()["labels"] == (0, 0)


def test_uniform_requires_target(corpus, qrels):
    with pytest.raises(ValueError, match="target_size"):
        _session(corpus, qrels, strategy="uniform",
                 target_size=None).draw()


def test_degree_stratified_preserves_degree_distribution(corpus, qrels):
    session = _session(corpus, qrels, strategy="degree_stratified")
    deg = np.asarray(session.graph()[1])
    strat = get_sampler("degree_stratified")
    d0 = session.draw(target_size=0.4, seed=0)
    mask = np.asarray(d0.entity_mask)
    eligible = deg > 0
    assert eligible[mask].all()               # only affinity-graph nodes
    # quota per stratum -> realized size within rounding of the target
    target = 0.4 * eligible.sum()
    assert abs(mask.sum() - target) <= strat.num_strata
    # per-stratum keep fraction ~ rate for every populated bucket
    buckets = np.clip(np.floor(np.log2(np.maximum(deg, 1))), 0,
                      strat.num_strata - 1).astype(int)
    for b in np.unique(buckets[eligible]):
        members = eligible & (buckets == b)
        kept = (mask & members).sum()
        assert abs(kept - 0.4 * members.sum()) <= 1.0, b
    # distinct seeds pick different members at the same per-bucket quota
    d1 = session.draw(target_size=0.4, seed=1)
    assert (np.asarray(d1.entity_mask) != mask).any()
    assert np.asarray(d1.entity_mask).sum() == mask.sum()


def test_same_seed_strategies_are_decorrelated(corpus, qrels):
    """Per-strategy key salts: baselines drawn at the SAME seed must not
    consume the same uniform array (else uniform and degree_stratified keep
    near-identical sets and the grid compares a sampler with itself)."""
    session = _session(corpus, qrels)
    uni = np.asarray(session.draw(target_size=0.4, seed=0,
                                  strategy="uniform").entity_mask)
    ds = np.asarray(session.draw(target_size=0.4, seed=0,
                                 strategy="degree_stratified").entity_mask)
    both = uni.sum() + ds.sum()
    overlap = (uni & ds).sum()
    # independent 0.4-rate draws overlap ~0.16 of the universe; identical
    # draws would overlap ~min(|uni|, |ds|). Require clearly-below-identical.
    assert overlap < 0.75 * min(uni.sum(), ds.sum()), (overlap, both)


def test_sweep_stage_counts_are_per_sweep_deltas(corpus, qrels):
    session = _session(corpus, qrels)
    first = session.sweep([0.2, 0.3], [0, 1])
    again = session.sweep([0.2, 0.3], [0, 1])     # fully cache-served
    assert first.stage_counts["draw"] == (4, 4)
    assert first.stage_counts["labels"][0] == 1
    assert again.stage_counts["draw"] == (0, 4)   # no re-execution
    assert again.stage_counts["labels"][0] == 0
    fresh = session.sweep([0.2, 0.3], [2, 3])
    assert fresh.stage_counts["draw"] == (4, 4)
    assert fresh.stage_counts["graph"][0] == 0    # staged before this sweep


def test_full_strategy_and_result_guard(corpus, qrels):
    session = _session(corpus, qrels, strategy="full")
    mask = np.asarray(session.draw().entity_mask)
    assert mask.all()
    with pytest.raises(ValueError, match="cluster-sample"):
        session.result()


# ---------------------------------------------------------------------------
# associated_queries <-> reconstructor cross-check (moved from eval/runner)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(3))
def test_associated_queries_matches_reconstruct_rule(corpus, qrels, seed):
    rng = np.random.default_rng(seed)
    mask = rng.random(corpus.num_entities) < 0.35
    assoc, qids = associated_queries(corpus.qrels, mask,
                                     num_queries=corpus.num_queries)
    ref = reconstruct(qrels, jnp.asarray(mask),
                      num_queries=corpus.num_queries)
    assert (assoc == np.asarray(ref.query_mask)).all()
    assert (assoc[qids]).all() and qids.size == assoc.sum()


def test_associated_queries_subsample_cap(corpus):
    mask = np.ones(corpus.num_entities, bool)
    assoc, qids = associated_queries(corpus.qrels, mask,
                                     num_queries=corpus.num_queries,
                                     max_queries=10, seed=1)
    assert qids.size == 10
    assert assoc[qids].all()
    assert (np.diff(qids) > 0).all()       # sorted, unique
    _, again = associated_queries(corpus.qrels, mask,
                                  num_queries=corpus.num_queries,
                                  max_queries=10, seed=1)
    assert (qids == again).all()           # deterministic in the seed


# ---------------------------------------------------------------------------
# CLI registry-error contract (launch/sample.py and launch/evaluate.py)
# ---------------------------------------------------------------------------

def test_sample_cli_unknown_strategy_lists_registered():
    from repro.launch import sample
    with pytest.raises(ValueError, match="registered strategies"):
        sample.main(["--strategy", "bogus", "--queries", "32"])


def test_sample_cli_unknown_engine_lists_registered():
    from repro.launch import sample
    with pytest.raises(ValueError, match="registered engines"):
        sample.main(["--engine", "spark", "--queries", "32"])


def test_evaluate_cli_unknown_sampler_lists_registered():
    from repro.launch import evaluate
    with pytest.raises(ValueError, match="registered strategies"):
        evaluate.main(["--grid", "smoke", "--samplers", "bogus",
                       "--queries", "32"])


def test_evaluate_cli_unknown_engine_lists_registered():
    from repro.launch import evaluate
    with pytest.raises(ValueError, match="registered engines"):
        evaluate.main(["--grid", "smoke", "--engines", "faiss",
                       "--queries", "32"])


def test_sample_cli_sweep_smoke(tmp_path, capsys):
    from repro.launch import sample
    sample.main(["--queries", "48", "--qrels-per-query", "4",
                 "--topics", "4", "--aux-fraction", "0.2",
                 "--fanout", "4", "--lp-rounds", "2",
                 "--sweep-sizes", "0.2,0.4", "--sweep-seeds", "0,1",
                 "--out", str(tmp_path)])
    out = capsys.readouterr().out
    assert "graph" in out and "sweep: 2 sizes x 2 seeds" in out
    import json
    stats = json.loads((tmp_path / "stats.json").read_text())
    assert len(stats["sweep"]["draws"]) == 4
    assert stats["sweep"]["stage_counts"]["labels"]["executions"] == 1
