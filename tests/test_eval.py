"""Eval subsystem tests: retrieval-engine registry round-trip, hand-computed
nDCG/MRR/Kendall-τ, plan-trie shared-prefix execution counts, and the grid
runner + fidelity report end-to-end on a tiny corpus."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.synthetic import generate_corpus
from repro.eval.engines import (available_retrieval_engines,
                                get_retrieval_engine)
from repro.eval.fidelity import (build_fidelity_report,
                                 format_fidelity_report, kendall_tau)
from repro.eval.plans import (GridSpec, PlanTrie, RunSpec, execute_plan,
                              expand_grid)
from repro.eval.runner import available_samplers, run_grid
from repro.retrieval.metrics import mrr, ndcg_at_k


# ---------------------------------------------------------------------------
# metrics: hand-computed values
# ---------------------------------------------------------------------------

def test_ndcg_hand_computed():
    # ranks: rel, miss, rel -> DCG = 1/log2(2) + 1/log2(4) = 1.5
    # 3 judged docs, k=3 -> IDCG = 1 + 1/log2(3) + 1/log2(4)
    retrieved = np.array([[10, 99, 11]])
    by_q = {0: {10, 11, 12}}
    idcg = 1.0 + 1.0 / np.log2(3.0) + 0.5
    expect = 1.5 / idcg
    assert abs(ndcg_at_k(retrieved, np.array([0]), by_q, k=3) - expect) < 1e-9


def test_ndcg_perfect_ranking_is_one():
    retrieved = np.array([[10, 11, 99]])
    by_q = {0: {10, 11}}  # only 2 judged -> ideal = first 2 slots
    assert abs(ndcg_at_k(retrieved, np.array([0]), by_q, k=3) - 1.0) < 1e-9


def test_ndcg_ignores_padding_and_unjudged_queries():
    retrieved = np.array([[10, -1, -1], [5, 6, 7]])
    by_q = {0: {10}}  # query 1 has no judgments -> excluded from the mean
    assert abs(ndcg_at_k(retrieved, np.array([0, 1]), by_q, k=3) - 1.0) < 1e-9


def test_mrr_hand_computed():
    # first relevant at rank 1 and rank 3 -> (1 + 1/3) / 2
    retrieved = np.array([[10, 11, 12], [98, 99, 20]])
    by_q = {0: {10}, 1: {20}}
    assert abs(mrr(retrieved, np.array([0, 1]), by_q) - 2.0 / 3.0) < 1e-9


def test_mrr_counts_misses_as_zero():
    retrieved = np.array([[10, 11], [98, 99]])
    by_q = {0: {10}, 1: {20}}
    assert abs(mrr(retrieved, np.array([0, 1]), by_q, k=2) - 0.5) < 1e-9


def test_kendall_tau_hand_computed():
    assert kendall_tau([1, 2, 3, 4], [10, 20, 30, 40]) == pytest.approx(1.0)
    assert kendall_tau([1, 2, 3, 4], [4, 3, 2, 1]) == pytest.approx(-1.0)
    # pairs: (1,2) C, (1,3) C, (2,3) D -> (2 - 1) / 3
    assert kendall_tau([1, 2, 3], [1, 3, 2]) == pytest.approx(1.0 / 3.0)
    # tie in b on the (2,3) pair -> tau-b denominator sqrt(3 * 2)
    assert kendall_tau([1, 2, 3], [1, 2, 2]) == pytest.approx(
        2.0 / np.sqrt(6.0))


# ---------------------------------------------------------------------------
# retrieval-engine registry
# ---------------------------------------------------------------------------

def test_registry_lists_all_four_engines():
    assert set(available_retrieval_engines()) >= {"exact", "ivfflat", "lsh",
                                                  "tfidf"}
    with pytest.raises(ValueError, match="unknown retrieval engine"):
        get_retrieval_engine("annoy")


@pytest.fixture(scope="module")
def engine_vectors():
    key = jax.random.PRNGKey(0)
    corpus = jax.random.normal(key, (600, 32))
    corpus = corpus / jnp.linalg.norm(corpus, axis=1, keepdims=True)
    queries = corpus[:24] + 0.03 * jax.random.normal(jax.random.PRNGKey(1),
                                                     (24, 32))
    gt = np.argsort(-np.asarray(queries @ corpus.T), axis=1)[:, :5]
    return corpus, queries, gt


@pytest.mark.parametrize("name", ["exact", "ivfflat", "lsh", "tfidf"])
def test_registry_round_trip(name, engine_vectors):
    """build -> search through the protocol alone: valid ids, decent recall
    of the exact top-5 (exact recovers it outright)."""
    corpus, queries, gt = engine_vectors
    eng = get_retrieval_engine(name)
    index = eng.build(jax.random.PRNGKey(0), corpus)
    ids = np.asarray(eng.search(index, queries, k=5))
    assert ids.shape == (24, 5)
    assert (ids >= 0).all() and (ids < corpus.shape[0]).all()
    rec = np.mean([len(set(a.tolist()) & set(b.tolist())) / 5
                   for a, b in zip(ids, gt)])
    assert rec > (0.99 if name == "exact" else 0.5)


def test_lsh_engine_clamps_rerank_to_corpus():
    eng = get_retrieval_engine("lsh")
    assert eng.rerank > 10  # default would exceed this tiny corpus
    vecs = jax.random.normal(jax.random.PRNGKey(0), (10, 32))
    index = eng.build(jax.random.PRNGKey(1), vecs)
    ids = np.asarray(eng.search(index, vecs[:3], k=3))
    assert ids.shape == (3, 3)
    assert ids[np.arange(3), 0].tolist() == [0, 1, 2]  # self-retrieval


def test_engine_hyperparams_are_replaceable():
    eng = get_retrieval_engine("ivfflat")
    tuned = dataclasses.replace(eng, n_lists=4, nprobe=2)
    assert tuned.n_lists == 4 and eng.n_lists == 64  # registry untouched


# ---------------------------------------------------------------------------
# plan trie: shared prefixes execute exactly once
# ---------------------------------------------------------------------------

def test_trie_counts_pure():
    """2 samplers x 2 engines x 2 ks x 1 metric walked through dummy stages:
    executions follow the trie node count, requests the cell count."""
    spec = GridSpec(samplers=("a", "b"), engines=("x", "y"), ks=(2, 3),
                    metrics=("m",))
    runs = expand_grid(spec)
    assert len(runs) == 8
    calls = []

    def stage(label):
        def fn(parent, run):
            calls.append(label)
            return (label, parent)
        return fn

    results, trie = execute_plan(runs, {
        s: stage(s) for s in ("corpus", "embed", "sample", "index",
                              "search", "metric")})
    assert len(results) == 8
    counts = trie.stage_counts()
    assert counts["corpus"] == (1, 8)
    assert counts["embed"] == (1, 8)
    assert counts["sample"] == (2, 8)
    assert counts["index"] == (4, 8)
    assert counts["search"] == (8, 8)
    assert counts["metric"] == (8, 8)
    # the stage fns really ran only once per node
    assert calls.count("corpus") == 1 and calls.count("embed") == 1
    assert calls.count("sample") == 2 and calls.count("index") == 4


def test_runspec_paths_share_prefixes():
    a = RunSpec("s1", "e1", 3, "precision").path()
    b = RunSpec("s1", "e1", 3, "mrr").path()
    c = RunSpec("s1", "e2", 3, "precision").path()
    assert a[:5] == b[:5]        # same up to search
    assert a[:3] == c[:3]        # same up to sample
    assert a[3] != c[3]          # diverge at index


def test_trie_rerun_hits_cache():
    trie = PlanTrie()
    seen = []
    for _ in range(3):
        trie.run((("corpus",),), lambda: seen.append(1))
    assert len(seen) == 1
    node = trie.nodes[(("corpus",),)]
    assert node.executions == 1 and node.requests == 3


# ---------------------------------------------------------------------------
# runner + fidelity report end-to-end on a tiny corpus
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_corpus():
    return generate_corpus(num_queries=96, qrels_per_query=8, num_topics=8,
                           aux_fraction=0.5, vocab_size=256, passage_len=32,
                           query_len=8, seed=0, pad_multiple=64)


def test_run_grid_counts_and_values(tiny_corpus):
    spec = GridSpec(samplers=("full", "uniform"),
                    engines=("exact", "tfidf"), ks=(2, 3),
                    metrics=("precision",), sample_frac=0.4, max_queries=64)
    res = run_grid(tiny_corpus, spec)
    assert len(res.cells) == spec.num_cells == 8
    assert all(0.0 <= v <= 1.0 for v in res.cells.values())
    counts = res.trie.stage_counts()
    assert counts["corpus"] == (1, 8) and counts["embed"] == (1, 8)
    assert counts["sample"] == (2, 8) and counts["index"] == (4, 8)
    assert counts["search"] == (8, 8) and counts["metric"] == (8, 8)
    assert res.sampler_stats["full"]["n_entities"] == \
        tiny_corpus.num_entities
    assert 0 < res.sampler_stats["uniform"]["n_entities"] < \
        tiny_corpus.num_primary


def test_run_grid_windtunnel_sampler_and_fidelity(tiny_corpus):
    assert set(available_samplers()) >= {"full", "uniform", "windtunnel"}
    spec = GridSpec(samplers=("full", "uniform", "windtunnel"),
                    engines=("exact", "tfidf"), ks=(3,),
                    metrics=("precision", "mrr"), sample_frac=0.4,
                    max_queries=64)
    res = run_grid(tiny_corpus, spec)
    report = build_fidelity_report(res.cells, spec)
    for s in ("uniform", "windtunnel"):
        for m in spec.metrics:
            assert (s, m) in report.mean_abs_delta
            assert -1.0 <= report.tau[(s, m)] <= 1.0
            assert report.winners[(s, m)] in spec.engines
    # deltas really are sampler-vs-full differences
    key = ("uniform", "exact", 3, "precision")
    assert report.cell_deltas[key] == pytest.approx(
        res.cells[key] - res.cells[("full", "exact", 3, "precision")])
    text = format_fidelity_report(report, spec)
    assert "windtunnel" in text and "baseline winners" in text


def test_fidelity_identical_cells_give_tau_one():
    spec = GridSpec(samplers=("full", "s"), engines=("e1", "e2", "e3"),
                    ks=(3,), metrics=("precision",))
    cells = {}
    for s in spec.samplers:
        for i, e in enumerate(spec.engines):
            cells[(s, e, 3, "precision")] = 0.1 * (i + 1)
    report = build_fidelity_report(cells, spec)
    assert report.tau[("s", "precision")] == pytest.approx(1.0)
    assert report.mean_abs_delta[("s", "precision")] == pytest.approx(0.0)
    assert report.winner_agreement[("s", "precision")]


def test_fidelity_unknown_baseline_raises():
    spec = GridSpec(samplers=("full",), engines=("exact",), ks=(3,),
                    metrics=("precision",))
    with pytest.raises(ValueError, match="baseline"):
        build_fidelity_report({("full", "exact", 3, "precision"): 1.0},
                              spec, baseline="nope")


def test_evaluate_sample_uses_registry(tiny_corpus):
    """Satellite: the legacy experiment path now accepts every registered
    engine, including the new lsh/tfidf backends."""
    from repro.eval.runner import tfidf_embedder
    from repro.retrieval.experiment import evaluate_sample
    ev, qv = tfidf_embedder(tiny_corpus)
    for engine in ("exact", "ivfflat", "lsh", "tfidf"):
        r = evaluate_sample(engine, tiny_corpus, ev, qv, None, seed=0,
                            engine=engine, max_queries=48, query_chunk=32)
        assert 0.0 <= r.p_at_3 <= 1.0
        assert r.n_queries > 0
    with pytest.raises(ValueError, match="unknown retrieval engine"):
        evaluate_sample("bad", tiny_corpus, ev, qv, None, engine="faiss")
