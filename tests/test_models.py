"""Model-zoo tests: per-arch smoke (reduced configs, one step, shape + no
NaN), transformer equivalences, MACE equivariance."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, list_archs, iter_cells
from repro.launch.cells import build_cell
from repro.launch.mesh import make_host_mesh
from repro.models import so3
from repro.models.mace import (MACEConfig, init_mace, mace_energy_forces,
                               random_graph_batch)
from repro.models.transformer import (MoEConfig, TransformerConfig,
                                      attention_blocked, attention_naive,
                                      decode_step, expand_kv, init_kv_cache,
                                      init_transformer, lm_loss,
                                      transformer_forward)
from repro.train.optimizer import adamw_init


@pytest.fixture(scope="module")
def host_mesh():
    return make_host_mesh()


def _realize(sds, rng):
    def one(s):
        if not hasattr(s, "shape"):
            return s
        if s.dtype == jnp.int32:
            return jnp.asarray(rng.integers(0, 2, size=s.shape), jnp.int32)
        if s.dtype == jnp.bool_:
            return jnp.ones(s.shape, bool)
        return jnp.asarray(rng.normal(size=s.shape) * 0.02, s.dtype)
    return jax.tree.map(one, sds)


_SMOKE = []
_seen = set()
for _a, _s in iter_cells():
    _k = (_a, get_arch(_a).shapes[_s]["kind"])
    if _k not in _seen:
        _seen.add(_k)
        _SMOKE.append((_a, _s))


@pytest.mark.parametrize("arch,shape", _SMOKE)
def test_arch_smoke(arch, shape, host_mesh):
    """Reduced config of every (arch x step-kind): one step on CPU,
    output shapes hold and no NaNs."""
    rng = np.random.default_rng(0)
    cell = build_cell(arch, shape, host_mesh, reduced=True)
    args = list(_realize(cell.args, rng))
    # proper optimizer state (zeros) where the cell carries one
    if cell.kind == "train":
        args[1] = adamw_init(args[0])
    with host_mesh:
        out = cell.fn(*args)
    for leaf in jax.tree.leaves(out):
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            assert not bool(jnp.isnan(leaf).any()), (arch, shape)


def test_blocked_attention_matches_naive():
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (2, 33, 4, 16))
    k = expand_kv(jax.random.normal(jax.random.PRNGKey(1), (2, 33, 2, 16)), 4)
    v = expand_kv(jax.random.normal(jax.random.PRNGKey(2), (2, 33, 2, 16)), 4)
    pos = jnp.broadcast_to(jnp.arange(33)[None], (2, 33))
    for causal in (True, False):
        for window in (None, 7):
            cfg = TransformerConfig(vocab_size=1, d_model=64, n_layers=1,
                                    n_heads=4, n_kv_heads=2, d_ff=1,
                                    dtype=jnp.float32, block_kv=8,
                                    causal=causal, window=window)
            a = attention_naive(q, k, v, pos, pos, cfg)
            b = attention_blocked(q, k, v, pos, pos, cfg)
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("moe", [None, MoEConfig(num_experts=4, top_k=2,
                                                 capacity_factor=8.0)])
def test_decode_matches_forward(moe):
    cfg = TransformerConfig(vocab_size=64, d_model=32, n_layers=2, n_heads=4,
                            n_kv_heads=2, d_ff=48, dtype=jnp.float32, moe=moe)
    params = init_transformer(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, 64)
    logits, _ = transformer_forward(params, toks, cfg)
    cache = init_kv_cache(cfg, 2, 12)
    outs = []
    for t in range(12):
        lg, cache = decode_step(params, cache, toks[:, t:t + 1], cfg)
        outs.append(lg[:, 0])
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(logits), rtol=2e-4, atol=2e-4)


def test_rolling_cache_matches_windowed_forward():
    cfg = TransformerConfig(vocab_size=64, d_model=32, n_layers=2, n_heads=4,
                            n_kv_heads=4, d_ff=48, dtype=jnp.float32, window=5)
    params = init_transformer(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 16), 0, 64)
    logits, _ = transformer_forward(params, toks, cfg)
    cache = init_kv_cache(cfg, 1, 16)          # rolling size = window = 5
    assert cache["k"].shape[2] == 5
    outs = []
    for t in range(16):
        lg, cache = decode_step(params, cache, toks[:, t:t + 1], cfg)
        outs.append(lg[:, 0])
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(logits), rtol=2e-4, atol=2e-4)


def test_lm_loss_vocab_chunks_equal():
    base = TransformerConfig(vocab_size=96, d_model=32, n_layers=1, n_heads=4,
                             n_kv_heads=2, d_ff=48, dtype=jnp.float32)
    params = init_transformer(jax.random.PRNGKey(0), base)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 9), 0, 96)
    l1 = lm_loss(params, toks, base)
    l2 = lm_loss(params, toks, dataclasses.replace(base, vocab_chunks=4))
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)


def _rand_rot(seed=0):
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(3, 3))
    q, _ = np.linalg.qr(a)
    if np.linalg.det(q) < 0:
        q[:, 0] *= -1
    return jnp.asarray(q, jnp.float32)


def test_cg_equivariance():
    rng = np.random.default_rng(0)
    R = np.asarray(_rand_rot(1))

    def wigner(l):
        vs = rng.normal(size=(60, 3))
        vs /= np.linalg.norm(vs, axis=1, keepdims=True)
        Y = so3.spherical_harmonics(vs, np)[l]
        YR = so3.spherical_harmonics(vs @ R.T, np)[l]
        D, *_ = np.linalg.lstsq(Y, YR, rcond=None)
        return D.T

    for (l1, l2, l3) in so3.valid_paths(2):
        C = so3.real_clebsch_gordan(l1, l2, l3)
        D1, D2, D3 = wigner(l1), wigner(l2), wigner(l3)
        x = rng.normal(size=(2 * l1 + 1,))
        y = rng.normal(size=(2 * l2 + 1,))
        lhs = np.einsum("abc,a,b->c", C, D1 @ x, D2 @ y)
        rhs = D3 @ np.einsum("abc,a,b->c", C, x, y)
        np.testing.assert_allclose(lhs, rhs, atol=1e-5)


def test_mace_equivariance():
    cfg = MACEConfig(channels=8, d_feat=8, n_rbf=4)
    params = init_mace(jax.random.PRNGKey(0), cfg)
    batch = random_graph_batch(jax.random.PRNGKey(0), n_nodes=20, n_edges=60,
                               d_feat=8, n_graphs=2)
    R = _rand_rot(2)
    e, f = mace_energy_forces(params, batch, cfg)
    er, fr = mace_energy_forces(
        params, {**batch, "positions": batch["positions"] @ R.T}, cfg)
    np.testing.assert_allclose(np.asarray(e), np.asarray(er), atol=1e-5)
    np.testing.assert_allclose(np.asarray(fr), np.asarray(f @ R.T), atol=1e-5)
