"""Per-kernel shape/dtype sweeps: Pallas (interpret mode on CPU) vs the
pure-jnp ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.label_prop.ops import label_prop_round
from repro.kernels.label_prop.ref import label_prop_round_ref
from repro.kernels.lsh_hamming.ops import hamming_topk
from repro.kernels.lsh_hamming.ref import hamming_topk_ref
from repro.kernels.topk_scoring.ops import (gathered_topk, topk_scores,
                                            topk_scores_int8)
from repro.kernels.topk_scoring.ref import (gathered_topk_ref,
                                            topk_scores_int8_ref,
                                            topk_scores_ref)
from repro.core.label_prop import ell_round


@pytest.mark.parametrize("q,n,d,k", [
    (16, 256, 32, 3), (64, 1000, 64, 8), (7, 513, 16, 5), (128, 4096, 128, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_topk_scoring(q, n, d, k, dtype):
    key = jax.random.PRNGKey(q * n)
    qs = (jax.random.normal(key, (q, d)) - 0.3).astype(dtype)
    cs = (jax.random.normal(jax.random.PRNGKey(1), (n, d)) - 0.3).astype(dtype)
    s1, i1 = topk_scores(qs, cs, k=k, block_q=32, block_n=256)
    s2, i2 = topk_scores_ref(qs.astype(jnp.float32), cs.astype(jnp.float32), k=k)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5,
                               atol=1e-2 if dtype == jnp.bfloat16 else 1e-5)
    if dtype == jnp.float32:
        assert (np.asarray(i1) == np.asarray(i2)).all()


@pytest.mark.parametrize("q,n,d,k,use_kernel", [
    (3, 50, 16, 7, True),     # q below block_q floor, n below block_n floor
    (5, 40, 8, 60, True),     # k > 32 -> ref fallback, and k > n
    (4, 8, 8, 33, True),      # ref fallback with k > n
    (3, 5, 8, 9, True),       # kernel path with k > n
    (3, 5, 8, 9, False),      # forced ref with k > n
])
def test_topk_scoring_odd_shapes(q, n, d, k, use_kernel):
    """Satellite: non-block-multiple k/N never crash the dispatch wrapper;
    the valid prefix matches the oracle and the k > N tail is -inf/-1."""
    key = jax.random.PRNGKey(q * n + k)
    qs = jax.random.normal(key, (q, d))
    cs = jax.random.normal(jax.random.PRNGKey(1), (n, d))
    s, i = topk_scores(qs, cs, k=k, use_kernel=use_kernel)
    k_eff = min(k, n)
    s_ref, i_ref = topk_scores_ref(qs, cs, k=k_eff)
    assert s.shape == (q, k) and i.shape == (q, k)
    np.testing.assert_allclose(np.asarray(s)[:, :k_eff],
                               np.asarray(s_ref), rtol=1e-5, atol=1e-5)
    assert (np.asarray(i)[:, :k_eff] == np.asarray(i_ref)).all()
    assert (np.asarray(i)[:, k_eff:] == -1).all()
    assert np.isneginf(np.asarray(s)[:, k_eff:]).all()


@pytest.mark.parametrize("q,n,d,k", [
    (16, 256, 32, 3), (64, 1000, 64, 8), (7, 513, 16, 5),
    (3, 50, 16, 7),           # q and n below the block floors
    (3, 5, 8, 9),             # odd-small shape, k > n (pad-row hazard)
    (5, 40, 8, 70),           # k > _MAX_KERNEL_K_INT8 -> ref fallback
])
def test_topk_scoring_int8(q, n, d, k):
    """int8 scoring kernel vs the int32-accumulate oracle.  Codes are drawn
    all-negative-capable so a zero-valued pad row would win without the
    kernel's n_valid masking (the same hazard as the sharded pad test)."""
    key = jax.random.PRNGKey(q * n + k)
    qc = jax.random.randint(key, (q, d), -127, 128, dtype=jnp.int8)
    cc = jax.random.randint(jax.random.PRNGKey(1), (n, d), -127, 128,
                            dtype=jnp.int8)
    s, i = topk_scores_int8(qc, cc, k=k)
    k_eff = min(k, n)
    s_ref, i_ref = topk_scores_int8_ref(qc, cc, k=k_eff)
    assert s.shape == (q, k) and i.shape == (q, k)
    np.testing.assert_allclose(np.asarray(s)[:, :k_eff], np.asarray(s_ref))
    assert (np.asarray(i)[:, :k_eff] == np.asarray(i_ref)).all()
    assert (np.asarray(i)[:, k_eff:] == -1).all()
    assert np.isneginf(np.asarray(s)[:, k_eff:]).all()


def test_topk_scoring_int8_all_negative():
    """Every true score negative: the padded tail must never be selected."""
    qc = -jnp.ones((4, 16), jnp.int8) * 3
    cc = jnp.abs(jax.random.randint(jax.random.PRNGKey(0), (37, 16), 1, 100)
                 ).astype(jnp.int8)
    s, i = topk_scores_int8(qc, cc, k=5)
    assert (np.asarray(s) < 0).all()
    assert (np.asarray(i) >= 0).all() and (np.asarray(i) < 37).all()


@pytest.mark.parametrize("q,n,w,k", [(5, 40, 2, 60), (3, 5, 2, 9),
                                     (37, 130, 3, 11)])
def test_lsh_hamming_odd_shapes(q, n, w, k):
    kq = jax.random.PRNGKey(q + k)
    qc = jax.random.randint(kq, (q, w), -2**31, 2**31 - 1, dtype=jnp.int32)
    cc = jax.random.randint(jax.random.PRNGKey(7), (n, w), -2**31,
                            2**31 - 1, dtype=jnp.int32)
    s, i = hamming_topk(qc, cc, k=k, block_q=32, block_n=256)
    k_eff = min(k, n)
    s_ref, _ = hamming_topk_ref(qc, cc, k=k_eff)
    assert i.shape == (q, k)
    np.testing.assert_allclose(np.asarray(s)[:, :k_eff], np.asarray(s_ref))
    assert (np.asarray(i)[:, k_eff:] == -1).all()


@pytest.mark.parametrize("q,c,d,k", [
    (7, 100, 16, 5), (3, 513, 8, 10), (1, 40, 4, 45), (9, 257, 8, 32),
])
def test_gathered_topk(q, c, d, k):
    """Per-query candidate kernel (the ivfflat probe-scoring step) vs the
    jnp oracle, with -1 holes in the candidate lists and odd shapes."""
    key = jax.random.PRNGKey(q * c)
    qs = jax.random.normal(key, (q, d))
    cv = jax.random.normal(jax.random.PRNGKey(2), (q, c, d))
    ci = jax.random.randint(jax.random.PRNGKey(3), (q, c), -1, 10_000,
                            dtype=jnp.int32)
    s, i = gathered_topk(qs, cv, ci, k=k)
    k_eff = min(k, c)
    s_ref, i_ref = gathered_topk_ref(qs, cv, ci, k=k_eff)
    np.testing.assert_allclose(np.asarray(s)[:, :k_eff], np.asarray(s_ref),
                               rtol=1e-5, atol=1e-5)
    assert (np.asarray(i)[:, :k_eff] == np.asarray(i_ref)).all()
    assert (np.asarray(i)[:, k_eff:] == -1).all()


@pytest.mark.parametrize("b,s,h,hkv,d", [
    (2, 64, 4, 2, 32), (1, 128, 8, 8, 64), (2, 96, 4, 1, 32), (1, 200, 4, 2, 16),
])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 40), (False, None)])
def test_flash_attention(b, s, h, hkv, d, causal, window):
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (b, s, h, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, hkv, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, hkv, d))
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=32, block_kv=32)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (2, 64, 4, 32)).astype(dtype)
    k = jax.random.normal(jax.random.PRNGKey(4), (2, 64, 2, 32)).astype(dtype)
    v = jax.random.normal(jax.random.PRNGKey(5), (2, 64, 2, 32)).astype(dtype)
    out = flash_attention(q, k, v, causal=True, block_q=32, block_kv=32)
    ref = flash_attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                              v.astype(jnp.float32), causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("n,kdeg", [(64, 4), (300, 12), (1000, 7)])
def test_label_prop_kernel(n, kdeg):
    key = jax.random.PRNGKey(n)
    nbr = jax.random.randint(key, (n, kdeg), -1, n)
    wgt = jnp.abs(jax.random.normal(jax.random.PRNGKey(1), (n, kdeg)))
    labels = jnp.arange(n, dtype=jnp.int32)
    out_k = label_prop_round(labels, nbr, wgt, block_n=64)
    lab = jnp.where(nbr >= 0, labels[jnp.maximum(nbr, 0)], -1)
    out_r = label_prop_round_ref(lab, wgt, labels)
    out_c = ell_round(labels, nbr, wgt)
    assert (np.asarray(out_k) == np.asarray(out_r)).all()
    assert (np.asarray(out_k) == np.asarray(out_c)).all()


@pytest.mark.parametrize("q,n,w,k", [(16, 512, 4, 3), (37, 1111, 8, 5),
                                     (128, 2048, 2, 10)])
def test_lsh_hamming(q, n, w, k):
    kq = jax.random.PRNGKey(q)
    qc = jax.random.randint(kq, (q, w), -2**31, 2**31 - 1, dtype=jnp.int32)
    cc = jax.random.randint(jax.random.PRNGKey(7), (n, w), -2**31, 2**31 - 1,
                            dtype=jnp.int32)
    s1, i1 = hamming_topk(qc, cc, k=k, block_q=32, block_n=256)
    s2, i2 = hamming_topk_ref(qc, cc, k=k)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2))
    # distances equal => id sets equal per query (ties may reorder)
    for a, b in zip(np.asarray(s1), np.asarray(s2)):
        assert (a == b).all()
