"""Streaming shard-local build (DESIGN.md §13): sharded-from-birth corpora.

Covers the tentpole invariants:

  * 1-device mesh: the born build/search path is bit-identical to the
    global build for every engine x backend (including int8 — the lift),
    and the born sampler session is bit-identical to the legacy sharded
    one (same labels, same draws).
  * Streaming: chunked host->device transfer reassembles the host array
    exactly, for any chunk size; ShardedQRels host-side routing matches
    the on-device `_route_by_query` compaction.
  * 2-device host mesh (subprocess): set-equal top-k for every engine x
    backend — including int8 (per-shard scales + float rerank) and
    ivfflat (shard-local centroid refinement) — identical LP labels, and
    uneven/tiny-shard padding regressions.
  * The legacy build-globally-then-partition path keeps its int8
    rejection (pinned messages), and `build.peak_bytes_per_device` is
    reported after every born build.
"""
import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import graph_builder as gb
from repro.core.sampling_core import SamplerSession, SamplerSpec
from repro.distributed.sharded_corpus import (ShardedCorpus, ShardedQRels,
                                              stream_to_sharded)
from repro.launch.mesh import make_host_mesh
from repro.obs.memory import PEAK_GAUGE
from repro.obs.metrics import REGISTRY
from repro.retrieval.engines import (available_retrieval_engines,
                                     get_retrieval_engine)
from repro.retrieval.backends import available_backends
from repro.retrieval.search_core import SearchConfig, SearchSession
from repro.retrieval.sharded import sharded_search


@pytest.fixture(scope="module")
def mesh():
    return make_host_mesh()


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(0)
    vecs = rng.standard_normal((300, 16)).astype(np.float32)
    queries = rng.standard_normal((9, 16)).astype(np.float32)
    return vecs, queries


# ---------------------------------------------------------------------------
# streaming transfer + ShardedCorpus / ShardedQRels construction
# ---------------------------------------------------------------------------

def test_stream_to_sharded_chunked_equals_host(mesh):
    """Chunked streaming (chunk smaller than the shard) reassembles the
    host array bit-exactly, including the zero pad rows."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    host = np.arange(7 * 3, dtype=np.float32).reshape(7, 3)
    sharding = NamedSharding(mesh, P(("data", "model"), None))
    out = stream_to_sharded(host, sharding, (8, 3), chunk_rows=2)
    got = np.asarray(out)
    assert np.array_equal(got[:7], host)
    assert (got[7:] == 0).all()


def test_sharded_corpus_geometry(mesh, data):
    vecs, _ = data
    corpus = ShardedCorpus.from_host(vecs[:299], mesh=mesh, chunk_rows=64)
    assert corpus.n == 299
    assert corpus.num_shards == 1
    assert corpus.rows_per_shard * corpus.num_shards >= corpus.n
    assert corpus.pad == corpus.rows_per_shard * corpus.num_shards - 299
    assert np.array_equal(np.asarray(corpus.vecs)[:299], vecs[:299])


def test_sharded_qrels_table_matches_routing(mesh):
    """Host-side routing + table() reproduces exactly the valid qrel rows
    (as a multiset), with per-shard stable original order."""
    rng = np.random.default_rng(3)
    nq, ne, nnz = 17, 50, 120
    q = rng.integers(0, nq, nnz).astype(np.int32)
    e = rng.integers(0, ne, nnz).astype(np.int32)
    s = rng.random(nnz).astype(np.float32)
    v = rng.random(nnz) < 0.8
    qrels = gb.QRelTable(q, e, s, v)
    born = ShardedQRels.from_host(qrels, num_queries=nq, num_entities=ne,
                                  mesh=mesh, chunk_rows=16)
    assert born.num_shards == 1
    tab = born.table()
    got = sorted(zip(np.asarray(tab.query_ids)[np.asarray(tab.valid)],
                     np.asarray(tab.entity_ids)[np.asarray(tab.valid)],
                     np.asarray(tab.scores)[np.asarray(tab.valid)]))
    want = sorted(zip(q[v], e[v], s[v]))
    assert got == want


# ---------------------------------------------------------------------------
# 1-device bit parity: born search == global search, all engine x backend
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ["exact", "tfidf", "lsh", "ivfflat"])
def test_streamed_search_bit_identical_one_device(mesh, data, engine):
    vecs, queries = data
    for backend in available_backends():
        ref = SearchSession(vecs, SearchConfig(engine=engine,
                                               backend=backend))
        got = SearchSession(vecs, SearchConfig(engine=engine,
                                               backend=backend,
                                               streamed=True, mesh=mesh))
        assert got.config.sharded and got.config.streamed
        assert np.array_equal(got.search(queries, k=5),
                              ref.search(queries, k=5)), (engine, backend)


def test_streamed_accepts_prebuilt_sharded_corpus(mesh, data):
    """Passing a ShardedCorpus directly == streaming the host array."""
    vecs, queries = data
    corpus = ShardedCorpus.from_host(vecs, mesh=mesh)
    via_corpus = SearchSession(corpus, SearchConfig(engine="exact"))
    via_flag = SearchSession(vecs, SearchConfig(engine="exact",
                                                streamed=True, mesh=mesh))
    assert via_corpus.corpus_size == vecs.shape[0]
    assert np.array_equal(via_corpus.search(queries, k=4),
                          via_flag.search(queries, k=4))


# ---------------------------------------------------------------------------
# 1-device bit parity: born sampler == legacy sharded sampler
# ---------------------------------------------------------------------------

def test_streamed_sampler_bit_identical_one_device(mesh):
    rng = np.random.default_rng(1)
    nq, ne, nnz = 40, 120, 500
    qrels = gb.QRelTable(rng.integers(0, nq, nnz).astype(np.int32),
                         rng.integers(0, ne, nnz).astype(np.int32),
                         rng.random(nnz).astype(np.float32),
                         np.ones(nnz, bool))
    legacy = SamplerSession(qrels, num_queries=nq, num_entities=ne,
                            spec=SamplerSpec(engine="ell", sharded=True,
                                             mesh=mesh))
    born = SamplerSession(qrels, num_queries=nq, num_entities=ne,
                          spec=SamplerSpec(engine="ell", streamed=True,
                                           mesh=mesh))
    l0, c0 = legacy.labels()
    l1, c1 = born.labels()
    assert np.array_equal(np.asarray(l0), np.asarray(l1))
    assert np.array_equal(np.asarray(c0), np.asarray(c1))
    assert np.array_equal(np.asarray(legacy.draw(seed=3).entity_mask),
                          np.asarray(born.draw(seed=3).entity_mask))


def test_streamed_sampler_accepts_prebuilt_qrels(mesh):
    rng = np.random.default_rng(2)
    nq, ne, nnz = 20, 60, 200
    qrels = gb.QRelTable(rng.integers(0, nq, nnz).astype(np.int32),
                         rng.integers(0, ne, nnz).astype(np.int32),
                         rng.random(nnz).astype(np.float32),
                         np.ones(nnz, bool))
    born = ShardedQRels.from_host(qrels, num_queries=nq, num_entities=ne,
                                  mesh=mesh)
    s0 = SamplerSession(born, num_queries=nq, num_entities=ne,
                        spec=SamplerSpec(engine="ell"))
    s1 = SamplerSession(qrels, num_queries=nq, num_entities=ne,
                        spec=SamplerSpec(engine="ell", streamed=True,
                                         mesh=mesh))
    assert np.array_equal(np.asarray(s0.labels()[0]),
                          np.asarray(s1.labels()[0]))
    with pytest.raises(ValueError, match="routed for"):
        SamplerSession(born, num_queries=nq + 7, num_entities=ne,
                       spec=SamplerSpec(engine="ell")).labels()


# ---------------------------------------------------------------------------
# satellites: legacy int8 rejection pins, peak gauge
# ---------------------------------------------------------------------------

def test_legacy_sharded_int8_rejection_pinned(mesh, data):
    """The build-globally-then-partition path keeps rejecting int8 (the
    padding sentinel would destroy the shard's quantization scale) — the
    born path is the supported route.  Both messages are pinned."""
    vecs, queries = data
    with pytest.raises(ValueError, match="padding sentinel would destroy"):
        SearchSession(vecs, SearchConfig(sharded=True, backend="int8",
                                         mesh=mesh))
    eng = dataclasses.replace(get_retrieval_engine("exact"), backend="int8")
    index = eng.build(jax.random.PRNGKey(0), jnp.asarray(vecs))
    with pytest.raises(ValueError,
                       match="use backend='jnp' or 'pallas' for sharded"):
        sharded_search(eng, index, jnp.asarray(queries), k=3, mesh=mesh)
    # ...but the same config over a born corpus works (the int8 lift)
    session = SearchSession(vecs, SearchConfig(backend="int8",
                                               streamed=True, mesh=mesh))
    assert session.search(queries, k=3).shape == (queries.shape[0], 3)


def test_peak_gauge_recorded_on_born_build(mesh, data):
    vecs, queries = data
    REGISTRY.gauge(PEAK_GAUGE).set(0)
    SearchSession(vecs, SearchConfig(engine="exact", streamed=True,
                                     mesh=mesh))
    assert REGISTRY.gauge(PEAK_GAUGE).value > 0


def test_streamed_requires_mesh(data):
    vecs, _ = data
    with pytest.raises(ValueError, match="streamed build needs a mesh"):
        SearchSession(vecs, SearchConfig(streamed=True))
    with pytest.raises(ValueError, match="streamed sampling needs a mesh"):
        SamplerSession(gb.QRelTable(np.zeros(4, np.int32),
                                    np.zeros(4, np.int32),
                                    np.ones(4, np.float32),
                                    np.ones(4, bool)),
                       num_queries=2, num_entities=2,
                       spec=SamplerSpec(engine="ell", streamed=True))


# ---------------------------------------------------------------------------
# 2-device host mesh (subprocess: the test session itself sees 1 device)
# ---------------------------------------------------------------------------

_TWO_DEVICE_SCRIPT = r"""
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import Mesh

from repro.core import graph_builder as gb
from repro.core.sampling_core import SamplerSession, SamplerSpec
from repro.obs.memory import PEAK_GAUGE
from repro.obs.metrics import REGISTRY
from repro.retrieval.backends import available_backends
from repro.retrieval.search_core import SearchConfig, SearchSession

assert len(jax.devices()) == 2, jax.devices()
mesh = Mesh(np.array(jax.devices()), ("data",))
rng = np.random.default_rng(0)

# --- search: every engine x backend, uneven shards (N=97) -----------------
vecs = rng.standard_normal((97, 16)).astype(np.float32)
queries = rng.standard_normal((7, 16)).astype(np.float32)
for engine in ("exact", "tfidf", "lsh", "ivfflat"):
    opts = {"n_lists": 4, "nprobe": 4} if engine == "ivfflat" else None
    for backend in available_backends():
        ref = SearchSession(vecs, SearchConfig(engine=engine,
                                               backend=backend,
                                               engine_opts=opts))
        got = SearchSession(vecs, SearchConfig(engine=engine,
                                               backend=backend,
                                               engine_opts=opts,
                                               streamed=True, mesh=mesh))
        a = np.sort(ref.search(queries, k=5), 1)
        b = np.sort(got.search(queries, k=5), 1)
        assert np.array_equal(a, b), (engine, backend, a, b)

# --- tiny corpus: shard pad dominates (N=5 over 2 shards) -----------------
tiny = rng.standard_normal((5, 8)).astype(np.float32)
tq = rng.standard_normal((3, 8)).astype(np.float32)
for backend in available_backends():
    ref = SearchSession(tiny, SearchConfig(backend=backend))
    got = SearchSession(tiny, SearchConfig(backend=backend,
                                           streamed=True, mesh=mesh))
    assert np.array_equal(np.sort(ref.search(tq, k=5), 1),
                          np.sort(got.search(tq, k=5), 1)), backend

# --- all-negative scores: pad sentinels must not displace real rows ------
neg = -np.abs(rng.standard_normal((9, 8))).astype(np.float32) - 1.0
nq_ = np.abs(rng.standard_normal((3, 8))).astype(np.float32)
for backend in available_backends():
    ref = SearchSession(neg, SearchConfig(backend=backend))
    got = SearchSession(neg, SearchConfig(backend=backend,
                                          streamed=True, mesh=mesh))
    assert np.array_equal(np.sort(ref.search(nq_, k=4), 1),
                          np.sort(got.search(nq_, k=4), 1)), backend

# --- sampler: identical LP labels + draws, born vs legacy sharded ---------
nq, ne, nnz = 40, 120, 500
qrels = gb.QRelTable(rng.integers(0, nq, nnz).astype(np.int32),
                     rng.integers(0, ne, nnz).astype(np.int32),
                     rng.random(nnz).astype(np.float32),
                     np.ones(nnz, bool))
legacy = SamplerSession(qrels, num_queries=nq, num_entities=ne,
                        spec=SamplerSpec(engine="ell", sharded=True,
                                         mesh=mesh))
born = SamplerSession(qrels, num_queries=nq, num_entities=ne,
                      spec=SamplerSpec(engine="ell", streamed=True,
                                       mesh=mesh))
assert np.array_equal(np.asarray(legacy.labels()[0]),
                      np.asarray(born.labels()[0]))
assert np.array_equal(np.asarray(legacy.draw(seed=5).entity_mask),
                      np.asarray(born.draw(seed=5).entity_mask))
assert REGISTRY.gauge(PEAK_GAUGE).value > 0
print("STREAM-2DEV-OK")
"""


def test_streamed_two_device_mesh():
    """Tentpole acceptance on a real 2-shard mesh: set-equal top-k for
    every engine x backend (int8 included — the lift), identical LP
    labels, and uneven/tiny/all-negative shard-padding regressions.
    Subprocess because the test session itself must see 1 CPU device."""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ, JAX_PLATFORMS="cpu",
               XLA_FLAGS="--xla_force_host_platform_device_count=2",
               PYTHONPATH=src + os.pathsep + os.environ.get("PYTHONPATH", ""))
    out = subprocess.run([sys.executable, "-c", _TWO_DEVICE_SCRIPT],
                         env=env, capture_output=True, text=True,
                         timeout=900)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "STREAM-2DEV-OK" in out.stdout
