"""Benchmark harness — one function per paper table/figure plus kernel
micro-benches and the roofline reader. Prints ``name,us_per_call,derived``
CSV rows (derived = the table's headline number).

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run --only fig4,table1
  PYTHONPATH=src python -m benchmarks.run --only kernels --json results/bench
  PYTHONPATH=src python -m benchmarks.run --autotune --only retrieval --json results/bench

Timing and provenance come from the obs layer (repro.obs.timing,
DESIGN.md §12) so the benches, the autotuner, and traced production runs
all measure the same way.  REPRO_TRACE=<path> additionally streams span
records from the instrumented cores while the benches run.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.timing import provenance
from repro.obs.timing import timeit as _timeit

ROWS = []


def row(name, us, derived, **extra):
    """Record one bench row; ``extra`` keys become first-class JSON columns
    (e.g. ``peak_bytes_per_device`` on the streamed-build rows)."""
    ROWS.append((name, us, derived, extra))
    print(f"{name},{us:.1f},{derived}", flush=True)


def bench_meta() -> dict:
    """Host/device/backend/git provenance stamped into every BENCH_*.json —
    perf trajectories across machines are uninterpretable without it."""
    return {**provenance(), "smoke": SMOKE}


# ---------------------------------------------------------------------------
# Fig. 4: degree distribution + Yule-Simon EM fit (paper: gamma = 2.94)
# ---------------------------------------------------------------------------

def bench_fig4():
    from repro.core import QRelTable, fit_em
    from repro.core.graph_builder import build_affinity_graph, node_degrees
    from repro.data.synthetic import generate_qrels

    q, e, s, _, _, ne = generate_qrels(num_queries=20000, qrels_per_query=3,
                                       alpha=0.5, num_topics=64, seed=1)
    qr = QRelTable(jnp.asarray(q), jnp.asarray(e), jnp.asarray(s),
                   jnp.ones(len(q), bool))
    build = jax.jit(lambda t: build_affinity_graph(
        t, num_queries=20000, tau_quantile=0.5, fanout=8))
    us = _timeit(lambda: build(qr))
    edges = build(qr)
    deg = np.asarray(node_degrees(edges, ne))
    fit = fit_em(jnp.asarray(deg[deg > 0]), max_iters=500)
    row("fig4_graph_build", us, f"gamma={float(fit.gamma):.3f}")
    row("fig4_em_fit",
        _timeit(lambda: fit_em(jnp.asarray(deg[deg > 0]), max_iters=500)),
        f"stderr={float(fit.stderr):.2e}")


# ---------------------------------------------------------------------------
# Tables I & II: p@3 + query density, full vs uniform vs WindTunnel
# ---------------------------------------------------------------------------

def bench_table1_table2():
    from repro.core import QRelTable, WindTunnelConfig, run_windtunnel
    from repro.data.synthetic import generate_corpus
    from repro.retrieval.experiment import evaluate_sample
    from repro.retrieval.tfidf import tfidf_vectors

    corpus = generate_corpus(num_queries=1280, qrels_per_query=32,
                             num_topics=96, aux_fraction=2.0, seed=0,
                             query_len=24, vocab_size=3072)
    ev, df = tfidf_vectors(corpus.passage_tokens, corpus.vocab_size)
    qv, _ = tfidf_vectors(corpus.query_tokens, corpus.vocab_size)

    qrels = QRelTable(*(jnp.asarray(x) for x in corpus.qrels))
    cfg = WindTunnelConfig(tau_quantile=0.5, fanout=16, lp_rounds=5,
                           target_size=0.15 * corpus.num_primary, seed=0)
    wt_fn = jax.jit(lambda q: run_windtunnel(
        q, num_queries=corpus.num_queries,
        num_entities=corpus.num_entities, config=cfg))
    us_wt = _timeit(lambda: wt_fn(qrels).sample.entity_mask, n=1)
    res = wt_fn(qrels)
    wt_mask = np.asarray(res.sample.entity_mask)
    rate = wt_mask.sum() / corpus.num_primary
    rng = np.random.default_rng(7)
    uni = np.zeros(corpus.num_entities, bool)
    uni[:corpus.num_primary] = rng.random(corpus.num_primary) < rate

    out = {}
    for name, mask in [("full", None), ("uniform", uni),
                       ("windtunnel", wt_mask)]:
        out[name] = evaluate_sample(name, corpus, ev, qv, mask, seed=0,
                                    engine="exact", query_chunk=128,
                                    max_queries=768)
    row("table1_p_at_3(windtunnel_pipeline)", us_wt,
        "p@3 full=%.3f uniform=%.3f windtunnel=%.3f" %
        (out["full"].p_at_3, out["uniform"].p_at_3,
         out["windtunnel"].p_at_3))
    row("table2_query_density", 0.0,
        "rho_q uniform=%.3f windtunnel=%.3f ratio=%.2f" %
        (out["uniform"].rho_q, out["windtunnel"].rho_q,
         out["windtunnel"].rho_q / max(out["uniform"].rho_q, 1e-9)))
    # the trained-encoder run (slow path) is persisted by examples/
    if os.path.exists("results/table1.json"):
        with open("results/table1.json") as f:
            enc = json.load(f)
        row("table1_trained_encoder", 0.0,
            "p@3 full=%.3f uniform=%.3f windtunnel=%.3f" %
            (enc["full"]["p_at_3"], enc["uniform"]["p_at_3"],
             enc["windtunnel"]["p_at_3"]))


# ---------------------------------------------------------------------------
# Kernel micro-benches (CPU interpret mode: correctness-path timing only;
# the TPU roofline story lives in EXPERIMENTS.md §Roofline)
# ---------------------------------------------------------------------------

def bench_kernels():
    from repro.kernels.topk_scoring.ops import topk_scores
    from repro.kernels.topk_scoring.ref import topk_scores_ref
    from repro.kernels.label_prop.ops import label_prop_round
    from repro.core.graph_builder import EdgeList, symmetrize

    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (64, 64))
    c = jax.random.normal(jax.random.PRNGKey(1), (8192, 64))
    row("kernel_topk_scoring(pallas-interpret)",
        _timeit(lambda: topk_scores(q, c, k=8)), "k=8 n=8192")
    row("kernel_topk_scoring(jnp-ref)",
        _timeit(lambda: topk_scores_ref(q, c, k=8)), "k=8 n=8192")

    n, kdeg = 4096, 16
    nbr = jax.random.randint(key, (n, kdeg), -1, n)
    wgt = jnp.abs(jax.random.normal(key, (n, kdeg)))
    labels = jnp.arange(n, dtype=jnp.int32)
    row("kernel_label_prop(pallas-interpret)",
        _timeit(lambda: label_prop_round(labels, nbr, wgt)), f"n={n} K={kdeg}")

    # every registered LP engine, side-by-side on the same graph (the §Perf
    # trade for Alg. 2: sort's O(E log E) shuffle vs ELL's dense O(N K^2))
    from repro.core import engines as eng
    rng = np.random.default_rng(0)
    u = rng.integers(0, n, 4 * n).astype(np.int32)
    v = rng.integers(0, n, 4 * n).astype(np.int32)
    w = rng.random(4 * n).astype(np.float32)
    edges = EdgeList(jnp.asarray(u), jnp.asarray(v), jnp.asarray(w),
                     jnp.asarray(u != v))
    src, dst, ww, val = symmetrize(edges)
    for name in eng.available_engines():
        engine = eng.get_engine(name)
        f = jax.jit(lambda engine=engine: eng.run_engine(
            engine, src, dst, ww, val, num_nodes=n, max_degree=32,
            rounds=3).labels)
        row(f"labelprop_engine[{name}]", _timeit(f),
            f"E={4*n} rounds=3 K=32")


# ---------------------------------------------------------------------------
# Eval subsystem: retrieval-engine build/search across corpus sizes
# (rows = engine x corpus size; the grid runner's index/search stages)
# ---------------------------------------------------------------------------

def bench_eval():
    from repro.data.synthetic import generate_corpus
    from repro.eval.engines import (available_retrieval_engines,
                                    get_retrieval_engine)
    from repro.eval.runner import tfidf_embedder

    key = jax.random.PRNGKey(0)
    for nq in (128, 512):
        corpus = generate_corpus(num_queries=nq, qrels_per_query=8,
                                 num_topics=16, aux_fraction=0.5,
                                 vocab_size=1024, passage_len=32,
                                 query_len=12, seed=0, pad_multiple=256)
        ev, qv = tfidf_embedder(corpus)
        vecs = jnp.asarray(ev)
        queries = jnp.asarray(qv[:min(128, corpus.num_queries)])
        n = corpus.num_entities
        for name in available_retrieval_engines():
            eng = get_retrieval_engine(name)
            t0 = time.time()
            index = jax.block_until_ready(eng.build(key, vecs))
            us_build = (time.time() - t0) * 1e6
            us = _timeit(lambda: eng.search(index, queries, k=10))
            row(f"eval_search[{name}|N={n}]", us,
                f"build_us={us_build:.0f} Q={queries.shape[0]} k=10")


# ---------------------------------------------------------------------------
# Search core: engine x scoring backend x corpus size through SearchSession
# (the hot path of DESIGN.md §9 — what both the grid and serving run)
# ---------------------------------------------------------------------------

def bench_retrieval():
    from repro.eval.fidelity import backend_recall_curve
    from repro.kernels import tuning
    from repro.retrieval.backends import available_backends
    from repro.retrieval.engines import available_retrieval_engines
    from repro.retrieval.search_core import SearchConfig, SearchSession

    d, q_n, k = 64, 64, 10
    sizes = (1024,) if SMOKE else (1024, 4096, 16384)
    engines = (("exact", "lsh") if SMOKE
               else available_retrieval_engines())
    queries = jax.random.normal(jax.random.PRNGKey(1), (q_n, d))
    us_by = {}                         # (engine, backend, n) -> us
    for n in sizes:
        vecs = jax.random.normal(jax.random.PRNGKey(0), (n, d))
        for engine in engines:
            for backend in available_backends():
                t0 = time.time()
                session = SearchSession(
                    vecs, SearchConfig(engine=engine, backend=backend),
                    key=jax.random.PRNGKey(0))
                jax.block_until_ready(session.index)
                us_build = (time.time() - t0) * 1e6
                us = _timeit(lambda: session.search(queries, k=k))
                us_by[(engine, backend, n)] = us
                row(f"retrieval[{engine}|{backend}|N={n}]", us,
                    f"build_us={us_build:.0f} Q={q_n} k={k}")

    # int8-vs-f32 speedup column per engine x size (same SearchSession rows)
    for n in sizes:
        for engine in engines:
            f32 = us_by[(engine, "jnp", n)]
            i8 = us_by[(engine, "int8", n)]
            row(f"retrieval_int8_vs_f32[{engine}|N={n}]", i8,
                f"f32_us={f32:.1f} speedup={f32 / max(i8, 1e-9):.2f}x")

    # tuned-vs-default speedup column per kernel primitive x size: explicit
    # default blocks vs the autotuner table's resolution (explicit kwargs on
    # both sides, so stale jit caches can't blur the comparison)
    from repro.kernels.lsh_hamming.ops import hamming_topk
    from repro.kernels.topk_scoring.ops import topk_scores, topk_scores_int8
    from repro.retrieval.lsh import build_lsh, encode
    for n in sizes:
        vecs = jax.random.normal(jax.random.PRNGKey(0), (n, d))
        lsh = build_lsh(jax.random.PRNGKey(0), vecs, n_bits=128)
        qcodes = encode(lsh.proj, queries)
        q8 = jnp.clip(jnp.round(queries * 10), -127, 127).astype(jnp.int8)
        c8 = jnp.clip(jnp.round(vecs * 10), -127, 127).astype(jnp.int8)
        cases = {
            ("topk", "float32"):
                lambda blk: topk_scores(queries, vecs, k=k, **blk),
            ("topk", "int8"):
                lambda blk: topk_scores_int8(q8, c8, k=k, **blk),
            ("hamming_topk", "int32"):
                lambda blk: hamming_topk(qcodes, lsh.codes, k=k, **blk),
        }
        for (kernel, dt), fn in cases.items():
            default = dict(tuning.DEFAULTS[kernel])
            tuned = tuning.resolve(kernel, n=n, dtype=dt)
            us_def = _timeit(lambda: fn(default))
            us_tun = _timeit(lambda: fn(tuned))
            row(f"retrieval_tuned_vs_default[{kernel}|{dt}|N={n}]", us_tun,
                f"default_us={us_def:.1f} tuned={tuned} "
                f"speedup={us_def / max(us_tun, 1e-9):.2f}x")

    # int8 recall-vs-speed curve at the largest size (recall@k vs jnp exact)
    n = sizes[-1]
    vecs = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    for r in backend_recall_curve(vecs, queries, k=k,
                                  rerank_factors=(1, 2, 4, 8)):
        rf = "-" if r["rerank_factor"] is None else r["rerank_factor"]
        row(f"retrieval_recall[{r['backend']}|rf={rf}|N={n}]",
            r["us_per_call"], f"recall@{k}={r['recall_at_k']:.4f}")

    # streamed shard-local build at 10x the largest global size above:
    # weak scaling, per-shard rows constant as the shard count grows
    _streamed_rows("retrieval", per_shard=2048 if SMOKE else 163840)


# ---------------------------------------------------------------------------
# Streamed shard-local build (DESIGN.md §13): weak-scaling rows — the
# per-shard size is held constant while the shard count (and hence total
# corpus) grows, so the per-device peak should stay flat.  Each point runs
# in a subprocess because the host device count is fixed at backend
# startup (XLA_FLAGS=--xla_force_host_platform_device_count=<shards>).
# ---------------------------------------------------------------------------

def _streamed_child(spec: str) -> None:
    """Hidden subprocess entry: build one streamed session and print a
    machine-readable result line (``STREAMED_CHILD {json}``)."""
    kind, per_shard, shards, chunk = spec.split(":")
    per_shard, shards, chunk = int(per_shard), int(shards), int(chunk)
    from jax.sharding import Mesh
    from repro.obs.memory import PEAK_GAUGE
    from repro.obs.metrics import REGISTRY
    devs = jax.devices()
    if len(devs) < shards:
        raise SystemExit(f"need {shards} devices, have {len(devs)} "
                         f"(set XLA_FLAGS=--xla_force_host_platform_"
                         f"device_count={shards})")
    mesh = Mesh(np.array(devs[:shards]), ("data",))
    out = {"kind": kind, "per_shard": per_shard, "shards": shards}
    if kind == "retrieval":
        from repro.retrieval.search_core import SearchConfig, SearchSession
        d, q_n, k = 64, 64, 10
        n = per_shard * shards
        rng = np.random.default_rng(0)
        vecs = rng.standard_normal((n, d)).astype(np.float32)
        queries = jnp.asarray(
            rng.standard_normal((q_n, d)).astype(np.float32))
        t0 = time.time()
        session = SearchSession(
            vecs, SearchConfig(engine="exact", backend="jnp",
                               streamed=True, mesh=mesh, stream_chunk=chunk),
            key=jax.random.PRNGKey(0))
        jax.block_until_ready(session.index)
        out["build_us"] = (time.time() - t0) * 1e6
        out["search_us"] = _timeit(lambda: session.search(queries, k=k))
        out["n"] = n
    elif kind == "sampling":
        from repro.core import QRelTable
        from repro.core import sampling_core as sc
        from repro.data.synthetic import generate_corpus
        nq = per_shard * shards
        corpus = generate_corpus(num_queries=nq, qrels_per_query=16,
                                 num_topics=32, aux_fraction=1.0, seed=0,
                                 vocab_size=1024)
        qrels = QRelTable(*(np.asarray(x) for x in corpus.qrels))
        session = sc.SamplerSession(
            qrels, num_queries=corpus.num_queries,
            num_entities=corpus.num_entities,
            spec=sc.SamplerSpec(engine="ell", streamed=True, mesh=mesh,
                                stream_chunk=chunk,
                                target_size=0.15 * corpus.num_primary,
                                seed=0))
        t0 = time.time()
        session.labels()                    # stage shard-local graph + LP
        out["build_us"] = (time.time() - t0) * 1e6
        out["draw_us"] = _timeit(lambda: session.draw(seed=1).entity_mask,
                                 n=1)
        out["n"] = corpus.num_entities
        out["nq"] = nq
    else:
        raise SystemExit(f"unknown streamed-child kind {kind!r}")
    out["peak_bytes_per_device"] = int(REGISTRY.gauge(PEAK_GAUGE).value)
    print("STREAMED_CHILD " + json.dumps(out), flush=True)


def _run_streamed_point(kind: str, per_shard: int, shards: int,
                        chunk: int = 65536) -> dict:
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count="
                        f"{shards}").strip()
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--streamed-child",
         f"{kind}:{per_shard}:{shards}:{chunk}"],
        capture_output=True, text=True, env=env,
        cwd=os.path.join(os.path.dirname(__file__), ".."))
    for line in proc.stdout.splitlines():
        if line.startswith("STREAMED_CHILD "):
            return json.loads(line[len("STREAMED_CHILD "):])
    raise RuntimeError(
        f"streamed child {kind}:{per_shard}:{shards} failed "
        f"(rc={proc.returncode}):\n{proc.stderr[-2000:]}")


def _streamed_rows(kind: str, per_shard: int,
                   shard_counts=(1, 2)) -> None:
    peaks = {}
    for shards in shard_counts:
        r = _run_streamed_point(kind, per_shard, shards)
        peaks[shards] = r["peak_bytes_per_device"]
        work_us = r.get("search_us", r.get("draw_us", 0.0))
        tag = (f"{kind}_streamed[exact|jnp|N={r['n']}|shards={shards}]"
               if kind == "retrieval" else
               f"{kind}_streamed[ell|nq={r['nq']}|shards={shards}]")
        row(tag, r["build_us"],
            f"work_us={work_us:.0f} per_shard={per_shard} "
            f"peak_bytes_per_device={r['peak_bytes_per_device']}",
            peak_bytes_per_device=r["peak_bytes_per_device"],
            shards=shards, per_shard=per_shard)
    base = max(peaks[shard_counts[0]], 1)
    worst = max(peaks[s] / base for s in shard_counts)
    row(f"{kind}_streamed_peak_flat", 0.0,
        " ".join(f"s{s}={peaks[s]}" for s in shard_counts) +
        f" worst_ratio={worst:.2f} (weak scaling: flat per-device peak)",
        peak_ratio=worst)


# ---------------------------------------------------------------------------
# Sampling core: staged graph-build / LP / per-draw timings per LP engine,
# and the sweep-reuse speedup of SamplerSession (DESIGN.md §10) — the
# draws-per-second win of cached labels vs the one-shot legacy entry point
# ---------------------------------------------------------------------------

def bench_sampling():
    import itertools

    from repro.core import QRelTable, WindTunnelConfig, run_windtunnel
    from repro.core import engines as eng
    from repro.core import sampling_core as sc
    from repro.data.synthetic import generate_corpus

    nq = 256 if SMOKE else 1280
    corpus = generate_corpus(num_queries=nq, qrels_per_query=16,
                             num_topics=32, aux_fraction=1.0, seed=0,
                             vocab_size=1024)
    qrels = QRelTable(*(jnp.asarray(x) for x in corpus.qrels))
    n_ent, n_q = corpus.num_entities, corpus.num_queries
    target = 0.15 * corpus.num_primary
    engines = ("sort", "ell") if SMOKE else eng.available_engines()

    us_graph = _timeit(lambda: sc._graph_stage(
        qrels, num_queries=n_q, num_entities=n_ent, tau_quantile=0.5,
        fanout=16))
    row("sampling_graph_build", us_graph, f"N={n_ent} Q={n_q}")
    edges, _ = sc._graph_stage(qrels, num_queries=n_q, num_entities=n_ent,
                               tau_quantile=0.5, fanout=16)
    for name in engines:
        us_lp = _timeit(lambda name=name: sc._labels_stage(
            edges, engine=name, num_entities=n_ent, max_degree=32,
            rounds=5))
        row(f"sampling_lp[{name}]", us_lp, f"N={n_ent} rounds=5 K=32")

    for name in engines:
        session = sc.SamplerSession(
            qrels, num_queries=n_q, num_entities=n_ent,
            spec=sc.SamplerSpec(engine=name, target_size=target, seed=0))
        session.labels()                    # stage graph + LP up front
        seeds = itertools.count()
        us_draw = _timeit(
            lambda: session.draw(seed=next(seeds)).entity_mask)
        row(f"sampling_draw[{name}]", us_draw,
            f"target={target:.0f} cached_labels=True")

    # sweep-reuse speedup: K draws against one staged session vs K one-shot
    # run_windtunnel calls (each re-paying graph build + LP)
    k_draws = 4 if SMOKE else 8
    cfg = WindTunnelConfig(target_size=target, seed=0, engine="ell")
    session = sc.SamplerSession(qrels, num_queries=n_q, num_entities=n_ent,
                                spec=sc.SamplerSpec.from_config(cfg))
    session.labels()
    seeds = itertools.count()

    def cached_draws():
        return [session.draw(seed=next(seeds)).entity_mask
                for _ in range(k_draws)]

    us_cached = _timeit(cached_draws, n=1)
    wt_fn = jax.jit(lambda q: run_windtunnel(
        q, num_queries=n_q, num_entities=n_ent,
        config=cfg).sample.entity_mask)
    us_full = _timeit(lambda: [wt_fn(qrels) for _ in range(k_draws)], n=1)
    dps_cached = k_draws / (us_cached / 1e6)
    dps_full = k_draws / (us_full / 1e6)
    row("sampling_sweep_reuse", us_cached,
        f"draws_per_s cached={dps_cached:.1f} full={dps_full:.1f} "
        f"speedup={dps_cached / max(dps_full, 1e-9):.2f}x")

    # streamed shard-local graph build at 10x the nq above: weak scaling,
    # per-shard queries constant as the shard count grows
    _streamed_rows("sampling", per_shard=320 if SMOKE else 12800)


# ---------------------------------------------------------------------------
# Serving tier (DESIGN.md §14): load-generator rows — throughput + p50/p99
# vs offered load, microbatch size and tenant count, plus the headline
# microbatched-vs-serial throughput ratio.  Latencies come off each
# request's completion future (the serve.request_latency_s data), so the
# bench measures exactly what the scheduler observes.
# ---------------------------------------------------------------------------

def bench_serve():
    from repro.retrieval.search_core import SearchConfig
    from repro.serve import (IngestConfig, LoadSpec, SchedulerConfig,
                             SearchServer, run_load)

    docs = 2048 if SMOKE else 16384
    d = 64
    n_req = 64 if SMOKE else 512
    rng = np.random.default_rng(0)
    corpora = {}

    def provider(tenant):
        if tenant not in corpora:
            corpora[tenant] = rng.normal(size=(docs, d)).astype(np.float32)
        return corpora[tenant]

    queries = rng.normal(size=(min(n_req, 256), d)).astype(np.float32)

    def make_server(max_batch, tenants):
        server = SearchServer(
            provider, config=SearchConfig(engine="exact", backend="jnp"),
            scheduler=SchedulerConfig(max_queue=max(n_req, 256),
                                      max_batch=max_batch, k_max=16),
            ingest=IngestConfig(compact_threshold=10 ** 9),
            max_tenants=max(tenants, 8))
        # warm every bucket shape so the rows measure steady state, not
        # the one-off XLA compiles the bucket set exists to amortise
        for t in range(tenants):
            for b in server.scheduler.config.bucket_set():
                for i in range(b):
                    server.submit(queries[i % queries.shape[0]],
                                  tenant=f"tenant-{t}")
                server.tick()
        server.drain()
        return server

    def load_row(tag, max_batch, tenants, rate):
        server = make_server(max_batch, tenants)
        rep = run_load(server.scheduler, queries,
                       LoadSpec(n_requests=n_req, rate=rate,
                                tenants=tenants, k=10))
        rate_s = "inf" if not np.isfinite(rate) else f"{rate:g}"
        row(f"serve_load[{tag}|rate={rate_s}|batch={max_batch}"
            f"|tenants={tenants}]",
            rep.p50_s * 1e6,
            f"thr={rep.throughput_rps:.1f}rps p99={rep.p99_s * 1e3:.2f}ms "
            f"mean_batch={rep.mean_batch:.1f}",
            throughput_rps=rep.throughput_rps, p50_s=rep.p50_s,
            p99_s=rep.p99_s, offered_rate=(None if not np.isfinite(rate)
                                           else rate),
            max_batch=max_batch, tenants=tenants,
            completed=rep.completed, rejected=rep.rejected)
        return rep

    # offered-load sweep at the full microbatch
    batched = None
    for rate in ((float("inf"),) if SMOKE
                 else (500.0, 2000.0, float("inf"))):
        rep = load_row("load_sweep", 32, 1, rate)
        if not np.isfinite(rate):
            batched = rep
    # microbatch-size sweep (batch=1 is the serial baseline: one search
    # dispatch per request, the pre-scheduler serving path)
    serial = None
    for mb in ((1, 8) if SMOKE else (1, 4, 8, 32)):
        rep = load_row("batch_sweep", mb, 1, float("inf"))
        if mb == 1:
            serial = rep
        if SMOKE and mb == 8:
            batched = rep
    # tenant-count sweep (per-tenant sessions via the TenantCache)
    for tenants in ((2,) if SMOKE else (2, 4)):
        load_row("tenant_sweep", 32, tenants, float("inf"))

    ratio = batched.throughput_rps / max(serial.throughput_rps, 1e-9)
    row("serve_microbatch_speedup", 0.0,
        f"serial={serial.throughput_rps:.1f}rps "
        f"batched={batched.throughput_rps:.1f}rps ratio={ratio:.2f}x",
        ratio=ratio, serial_rps=serial.throughput_rps,
        batched_rps=batched.throughput_rps)


# ---------------------------------------------------------------------------
# Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline)
# ---------------------------------------------------------------------------

def bench_roofline(path="results/dryrun.json"):
    if not os.path.exists(path):
        row("roofline", 0.0, f"missing {path}; run repro.launch.dryrun first")
        return
    with open(path) as f:
        cells = json.load(f)
    ok = [c for c in cells if c.get("ok")]
    n_bottleneck = {}
    for c in ok:
        if c["mesh"] != "single-pod-16x16":
            continue
        r = c["roofline"]
        bot = r["bottleneck"].replace("_s", "")
        n_bottleneck[bot] = n_bottleneck.get(bot, 0) + 1
        row(f"roofline[{c['arch']}x{c['shape']}]",
            max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e6,
            f"bottleneck={bot} compute={r['compute_s']*1e3:.2f}ms "
            f"memory={r['memory_s']*1e3:.2f}ms "
            f"collective={r['collective_s']*1e3:.2f}ms")
    row("roofline_summary", 0.0,
        " ".join(f"{k}:{v}" for k, v in sorted(n_bottleneck.items())))


BENCHES = {
    "fig4": bench_fig4,
    "table1": bench_table1_table2,
    "kernels": bench_kernels,
    "eval": bench_eval,
    "retrieval": bench_retrieval,
    "sampling": bench_sampling,
    "serve": bench_serve,
    "roofline": bench_roofline,
}

SMOKE = False


def run_autotune() -> None:
    """Regenerate results/tuned_kernels.json and activate it for the
    benches that follow (the README 'make it fast' entry point).  Smoke
    mode tunes a reduced cell set so CI stays fast."""
    from repro.kernels import tuning
    if SMOKE:
        table = tuning.autotune(buckets=("le1024", "le4096"), max_evals=4,
                                wall_iters=0)
    else:
        table = tuning.autotune(max_evals=12, wall_iters=1)
    row("autotune", 0.0,
        f"entries={len(table.entries)} -> {tuning.RESULTS_TABLE_PATH}")


def main() -> None:
    global SMOKE
    p = argparse.ArgumentParser()
    p.add_argument("--only", "--section", dest="only", default=None,
                   help="comma-separated subset of " + ",".join(BENCHES))
    p.add_argument("--smoke", action="store_true",
                   help="reduced sweep (CI: smallest corpus, 2 engines)")
    p.add_argument("--autotune", action="store_true",
                   help="regenerate results/tuned_kernels.json with the "
                        "kernel autotuner (kernels/tuning.py) before "
                        "running the benches, and bench with it active")
    p.add_argument("--json", default=None, metavar="PATH",
                   help="directory to persist each section's rows as "
                        "BENCH_<name>.json (the perf trajectory record)")
    p.add_argument("--streamed-child", default=None, help=argparse.SUPPRESS)
    args = p.parse_args()
    if args.streamed_child:
        _streamed_child(args.streamed_child)
        return
    SMOKE = args.smoke
    names = args.only.split(",") if args.only else list(BENCHES)
    print("name,us_per_call,derived")
    if args.autotune:
        run_autotune()
    meta = bench_meta()
    for n in names:
        start = len(ROWS)
        BENCHES[n]()
        if args.json:
            os.makedirs(args.json, exist_ok=True)
            out = os.path.join(args.json, f"BENCH_{n}.json")
            with open(out, "w") as f:
                json.dump({"meta": meta,
                           "rows": [{"name": r[0], "us_per_call": r[1],
                                     "derived": r[2], **r[3]}
                                    for r in ROWS[start:]]},
                          f, indent=2)
            print(f"# wrote {out}", flush=True)


if __name__ == "__main__":
    main()
