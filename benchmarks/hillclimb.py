"""§Perf hillclimb driver: per chosen cell, lower+compile the baseline and
each candidate change, extract roofline terms, and record
hypothesis -> change -> before -> after. Writes results/hillclimb.json.

  PYTHONPATH=src python -m benchmarks.hillclimb
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

import json
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

from repro.kernels.tuning import compiled_roofline  # noqa: E402
from repro.launch.cells import build_cell  # noqa: E402
from repro.launch.dryrun import collective_bytes, ICI_BW  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402


def measure(arch, shape, mesh, overrides=None):
    t0 = time.time()
    cell = build_cell(arch, shape, mesh, overrides=overrides)
    with mesh:
        comp = cell.fn.lower(*cell.args).compile()
    coll = collective_bytes(comp.as_text())
    mem = comp.memory_analysis()
    return {
        "overrides": overrides or {},
        "compile_s": round(time.time() - t0, 1),
        # compute/memory roofline terms shared with the kernel autotuner
        # (repro.kernels.tuning scores block candidates the same way)
        **compiled_roofline(comp),
        "collective_ms": sum(coll.values()) / ICI_BW * 1e3,
        "collectives": coll,
        "temp_gib": mem.temp_size_in_bytes / 2**30,
        "args_gib": mem.argument_size_in_bytes / 2**30,
    }


EXPERIMENTS = [
    # (arch, shape, variant-name, overrides, hypothesis)
    ("mace", "ogb_products", "baseline", None,
     "collective-bound: 6 replicated-output scatter all-reduces/step "
     "(3 l3-channels x 2 layers) over (2.45M,128,2l+1) f32 node tensors"),
    ("mace", "ogb_products", "fused_scatter", {"fused_scatter": True},
     "1 concatenated scatter per layer -> 1/3 the all-reduce launches and "
     "replicated buffers; bytes unchanged"),
    ("mace", "ogb_products", "fused+bf16_msgs",
     {"fused_scatter": True, "msg_dtype": "bf16"},
     "bf16 messages halve scatter + all-reduce bytes -> collective term /2"),
    ("dlrm-mlperf", "retrieval_cand", "baseline", None,
     "collective-bound: global lax.top_k over the model-sharded (B,1M) "
     "score row all-gathers the full score matrix"),
    ("dlrm-mlperf", "retrieval_cand", "sharded_topk", {"sharded_topk": True},
     "shard_map local top-k (100 per shard) then tiny merge -> collective "
     "payload drops from 1M scores to 16x100"),
    ("dlrm-mlperf", "retrieval_cand", "local_candidates",
     {"sharded_topk": "local"},
     "REVISED after sharded_topk refuted the top-k hypothesis: the real "
     "cost is the (1M,128) row gather lowered to a 488MiB all-reduce; "
     "shard-local candidate pools (production sharded-ANN layout) make the "
     "gather local — only (256 x k) merge payloads cross the wire"),
    ("mixtral-8x22b", "train_4k", "baseline", None,
     "memory wall: 55 GiB/dev temp — per-layer f32 expert-grad partials + "
     "full-batch activations"),
    ("mixtral-8x22b", "train_4k", "microbatch4", {"microbatches": 4},
     "4 gradient-accumulation microbatches cut activation/dispatch temps "
     "~4x at the cost of 4x weight re-gathers (acceptable: weights "
     "already stream per layer)"),
    ("mixtral-8x22b", "train_4k", "microbatch8", {"microbatches": 8},
     "8 microbatches push further if microbatch4 confirms"),
]


def main():
    import jax.numpy as jnp
    mesh = make_production_mesh()
    out = []
    for arch, shape, name, overrides, hypothesis in EXPERIMENTS:
        ov = dict(overrides) if overrides else None
        if ov and ov.get("msg_dtype") == "bf16":
            ov["msg_dtype"] = jnp.bfloat16
        try:
            res = measure(arch, shape, mesh, ov)
            res.update(arch=arch, shape=shape, variant=name,
                       hypothesis=hypothesis, ok=True)
        except Exception as e:
            res = {"arch": arch, "shape": shape, "variant": name,
                   "ok": False, "error": repr(e)[:300]}
        out.append(res)
        print(json.dumps(res, default=str), flush=True)
        with open("results/hillclimb.json", "w") as f:
            json.dump(out, f, indent=2, default=str)


if __name__ == "__main__":
    main()
