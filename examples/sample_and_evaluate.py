"""End-to-end driver (paper §III-B): run the (sampler × engine × k × metric)
experiment grid over full / uniform / WindTunnel samples through the
trie-shared plan runner (repro.eval) and print the sample-fidelity report —
metric deltas vs the full corpus plus Kendall-τ preservation of the engine
ranking.  Then runs a multi-resolution sweep through ONE
:class:`~repro.core.sampling_core.SamplerSession` — graph build + label
propagation staged once, every (size, seed) drawn against the cached labels
— and reports the fidelity curve (p@3 / rho_q vs sample size).  Persists
results/table1.json (p@3 + rho_q per sampler, the Table I/II numbers, plus
the curve) for the benchmark harness, and the full grid.

  PYTHONPATH=src python examples/sample_and_evaluate.py [--fast]

--fast uses the deterministic tf-idf reference embedder; the default trains
the transformer encoder and plugs it into the same runner as the embedder.
"""
import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--fast", action="store_true",
                   help="tf-idf reference embedder instead of training")
    p.add_argument("--encoder-steps", type=int, default=800)
    p.add_argument("--full-grid", action="store_true",
                   help="also run k=10 (doubles the search stages)")
    p.add_argument("--sweep-fracs", default="0.05,0.1,0.15,0.25",
                   help="sample fractions for the multi-resolution sweep")
    p.add_argument("--sweep-seeds", default="0,1,2",
                   help="draw seeds for the multi-resolution sweep")
    p.add_argument("--out", default="results/table1.json")
    args = p.parse_args()

    from repro.data.synthetic import generate_corpus
    from repro.eval import (GridSpec, build_fidelity_report,
                            format_fidelity_report, run_grid)
    corpus = generate_corpus(num_queries=1280, qrels_per_query=32,
                             num_topics=96, aux_fraction=2.0, seed=0,
                             query_len=24, vocab_size=3072)
    print(f"corpus: {corpus.num_entities} entities "
          f"({corpus.num_primary} judged)")

    import numpy as np
    from repro.eval import tfidf_embedder

    if args.fast:
        base_embedder = tfidf_embedder  # deterministic reference embedder
    else:
        from repro.retrieval.encoder import EncoderConfig, embed_corpus
        from repro.retrieval.experiment import train_encoder
        enc = EncoderConfig(vocab_size=3072, d_model=192, n_layers=2,
                            n_heads=4, d_ff=384)
        print("training embedding model...")
        params, _ = train_encoder(corpus, enc, steps=args.encoder_steps,
                                  seed=0)

        def base_embedder(c):
            return (embed_corpus(params, c.passage_tokens, enc),
                    embed_corpus(params, c.query_tokens, enc))

    # embed ONCE; the grid's embed stage and the sweep section below share
    # the cached vectors instead of re-running the encoder forward pass
    ev, qv = base_embedder(corpus)
    ev, qv = np.asarray(ev), np.asarray(qv)

    spec = GridSpec(samplers=("full", "uniform", "windtunnel"),
                    engines=("exact", "ivfflat", "lsh", "tfidf"),
                    ks=(3, 10) if args.full_grid else (3,),
                    metrics=("precision", "recall", "ndcg", "mrr"),
                    sample_frac=0.15, max_queries=512, seed=0)
    result = run_grid(corpus, spec, embedder=lambda c: (ev, qv),
                      query_chunk=128, verbose=True)

    print("\nplan-trie stage counters:")
    print(result.trie.summary())
    report = build_fidelity_report(result.cells, spec)
    print()
    print(format_fidelity_report(report, spec))

    # Table I/II summary (p@3 on the paper's ivfflat index + rho_q), kept in
    # the shape benchmarks/run.py reads back.
    out = {}
    for s in spec.samplers:
        stats = result.sampler_stats[s]
        out[s] = {"p_at_3": result.cells[(s, "ivfflat", 3, "precision")],
                  "rho_q": stats["rho_q"],
                  "n_entities": stats["n_entities"],
                  "n_queries": stats["n_queries"]}
        print(f"  {s:12s} p@3={out[s]['p_at_3']:.3f} "
              f"rho_q={out[s]['rho_q']:.3f}")
    out["grid"] = result.to_json()
    out["fidelity"] = report.to_json()

    # --- multi-resolution fidelity curve: one SamplerSession, one staged
    # graph + LP, every (fraction, seed) drawn against the cached labels ---
    import jax.numpy as jnp
    from repro.core import QRelTable, SamplerSession, SamplerSpec
    from repro.retrieval.experiment import evaluate_sample

    fracs = tuple(float(x) for x in args.sweep_fracs.split(",") if x)
    seeds = tuple(int(x) for x in args.sweep_seeds.split(",") if x)
    qrels = QRelTable(*(jnp.asarray(x) for x in corpus.qrels))
    session = SamplerSession(qrels, num_queries=corpus.num_queries,
                             num_entities=corpus.num_entities,
                             spec=SamplerSpec(seed=0))
    sweep = session.sweep(fracs, seeds)
    full_p3 = out["full"]["p_at_3"]
    print(f"\nmulti-resolution sweep ({len(fracs)} fractions x "
          f"{len(seeds)} seeds, graph+LP staged once):")
    curve = []
    for frac in fracs:
        rows = []
        for seed in seeds:
            mask = np.asarray(sweep.draws[(frac, seed)].entity_mask)
            r = evaluate_sample("windtunnel", corpus, ev, qv, mask,
                                seed=seed, engine="ivfflat",
                                query_chunk=128, max_queries=512)
            rows.append(r)
        p3 = float(np.mean([r.p_at_3 for r in rows]))
        rho = float(np.mean([r.rho_q for r in rows]))
        n_ent = float(np.mean([r.n_entities for r in rows]))
        curve.append({"frac": frac, "p_at_3": p3, "rho_q": rho,
                      "n_entities": n_ent,
                      "delta_p3_vs_full": p3 - full_p3})
        print(f"  frac={frac:<6g} entities~{n_ent:7.0f} p@3={p3:.3f} "
              f"(Δ vs full {p3 - full_p3:+.3f}) rho_q={rho:.3f}")
    print("session stage counters:")
    print(session.summary())
    out["fidelity_curve"] = curve
    out["sweep_stage_counts"] = sweep.to_json()["stage_counts"]

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print("wrote", args.out)


if __name__ == "__main__":
    main()
