"""End-to-end driver (paper §III-B): run the (sampler × engine × k × metric)
experiment grid over full / uniform / WindTunnel samples through the
trie-shared plan runner (repro.eval) and print the sample-fidelity report —
metric deltas vs the full corpus plus Kendall-τ preservation of the engine
ranking.  Persists results/table1.json (p@3 + rho_q per sampler, the
Table I/II numbers) for the benchmark harness, plus the full grid.

  PYTHONPATH=src python examples/sample_and_evaluate.py [--fast]

--fast uses the deterministic tf-idf reference embedder; the default trains
the transformer encoder and plugs it into the same runner as the embedder.
"""
import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--fast", action="store_true",
                   help="tf-idf reference embedder instead of training")
    p.add_argument("--encoder-steps", type=int, default=800)
    p.add_argument("--full-grid", action="store_true",
                   help="also run k=10 (doubles the search stages)")
    p.add_argument("--out", default="results/table1.json")
    args = p.parse_args()

    from repro.data.synthetic import generate_corpus
    from repro.eval import (GridSpec, build_fidelity_report,
                            format_fidelity_report, run_grid)
    corpus = generate_corpus(num_queries=1280, qrels_per_query=32,
                             num_topics=96, aux_fraction=2.0, seed=0,
                             query_len=24, vocab_size=3072)
    print(f"corpus: {corpus.num_entities} entities "
          f"({corpus.num_primary} judged)")

    if args.fast:
        embedder = None  # runner default: tf-idf reference embedder
    else:
        from repro.retrieval.encoder import EncoderConfig, embed_corpus
        from repro.retrieval.experiment import train_encoder
        enc = EncoderConfig(vocab_size=3072, d_model=192, n_layers=2,
                            n_heads=4, d_ff=384)
        print("training embedding model...")
        params, _ = train_encoder(corpus, enc, steps=args.encoder_steps,
                                  seed=0)

        def embedder(c):
            return (embed_corpus(params, c.passage_tokens, enc),
                    embed_corpus(params, c.query_tokens, enc))

    spec = GridSpec(samplers=("full", "uniform", "windtunnel"),
                    engines=("exact", "ivfflat", "lsh", "tfidf"),
                    ks=(3, 10) if args.full_grid else (3,),
                    metrics=("precision", "recall", "ndcg", "mrr"),
                    sample_frac=0.15, max_queries=512, seed=0)
    result = run_grid(corpus, spec, embedder=embedder, query_chunk=128,
                      verbose=True)

    print("\nplan-trie stage counters:")
    print(result.trie.summary())
    report = build_fidelity_report(result.cells, spec)
    print()
    print(format_fidelity_report(report, spec))

    # Table I/II summary (p@3 on the paper's ivfflat index + rho_q), kept in
    # the shape benchmarks/run.py reads back.
    out = {}
    for s in spec.samplers:
        stats = result.sampler_stats[s]
        out[s] = {"p_at_3": result.cells[(s, "ivfflat", 3, "precision")],
                  "rho_q": stats["rho_q"],
                  "n_entities": stats["n_entities"],
                  "n_queries": stats["n_queries"]}
        print(f"  {s:12s} p@3={out[s]['p_at_3']:.3f} "
              f"rho_q={out[s]['rho_q']:.3f}")
    out["grid"] = result.to_json()
    out["fidelity"] = report.to_json()

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print("wrote", args.out)


if __name__ == "__main__":
    main()
