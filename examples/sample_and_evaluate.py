"""End-to-end driver (paper §III-B): train the embedding model, index three
corpus variants (full / uniform / WindTunnel), run the semantic-search
pipeline, and report Tables I & II. Persists results/table1.json for the
benchmark harness.

  PYTHONPATH=src python examples/sample_and_evaluate.py [--fast]
"""
import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--fast", action="store_true",
                   help="tf-idf reference embedder instead of training")
    p.add_argument("--encoder-steps", type=int, default=800)
    p.add_argument("--out", default="results/table1.json")
    args = p.parse_args()

    from repro.data.synthetic import generate_corpus
    corpus = generate_corpus(num_queries=1280, qrels_per_query=32,
                             num_topics=96, aux_fraction=2.0, seed=0,
                             query_len=24, vocab_size=3072)
    print(f"corpus: {corpus.num_entities} entities "
          f"({corpus.num_primary} judged)")

    if args.fast:
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import QRelTable, WindTunnelConfig, run_windtunnel
        from repro.retrieval.experiment import evaluate_sample
        from repro.retrieval.tfidf import tfidf_vectors
        ev, df = tfidf_vectors(corpus.passage_tokens, corpus.vocab_size)
        qv, _ = tfidf_vectors(corpus.query_tokens, corpus.vocab_size)
        qrels = QRelTable(*(jnp.asarray(x) for x in corpus.qrels))
        cfg = WindTunnelConfig(tau_quantile=0.5, fanout=16, lp_rounds=5,
                               target_size=0.15 * corpus.num_primary, seed=0)
        res = jax.jit(lambda q: run_windtunnel(
            q, num_queries=corpus.num_queries,
            num_entities=corpus.num_entities, config=cfg))(qrels)
        wt = np.asarray(res.sample.entity_mask)
        rng = np.random.default_rng(7)
        uni = np.zeros(corpus.num_entities, bool)
        uni[:corpus.num_primary] = rng.random(corpus.num_primary) < \
            wt.sum() / corpus.num_primary
        results = {}
        for name, mask in [("full", None), ("uniform", uni),
                           ("windtunnel", wt)]:
            r = evaluate_sample(name, corpus, ev, qv, mask, seed=0,
                                engine="exact", query_chunk=128)
            results[name] = r
            print(f"  {name:12s} p@3={r.p_at_3:.3f} rho_q={r.rho_q:.3f}")
        out = {k: {"p_at_3": v.p_at_3, "rho_q": v.rho_q,
                   "n_entities": v.n_entities, "n_queries": v.n_queries}
               for k, v in results.items()}
    else:
        from repro.retrieval.encoder import EncoderConfig
        from repro.retrieval.experiment import run_table1_experiment
        enc = EncoderConfig(vocab_size=3072, d_model=192, n_layers=2,
                            n_heads=4, d_ff=384)
        results = run_table1_experiment(corpus, encoder_cfg=enc,
                                        encoder_steps=args.encoder_steps,
                                        seed=0)
        out = {k: {"p_at_3": v.p_at_3, "rho_q": v.rho_q,
                   "n_entities": v.n_entities, "n_queries": v.n_queries}
               for k, v in results.items()}

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print("wrote", args.out)


if __name__ == "__main__":
    main()
