"""Serving example: WindTunnel-sampled corpus + ANN retrieval + generative
decode through the continuous-batching engine (a miniature RAG stack over
the paper's Fig. 5 online component).

The retrieval hop routes through the search core's SearchSession — the same
engine/backend/shard configuration the offline experiment grid benchmarks —
via serve.RetrievalFrontend + serve.RagEngine.

  PYTHONPATH=src python examples/serve_rag.py
  PYTHONPATH=src python examples/serve_rag.py --backend pallas
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import QRelTable, WindTunnelConfig, run_windtunnel
from repro.data.synthetic import generate_corpus
from repro.models.transformer import TransformerConfig, init_transformer
from repro.retrieval.search_core import SearchConfig
from repro.retrieval.tfidf import tfidf_vectors
from repro.serve.engine import (RagEngine, RetrievalFrontend, ServeConfig,
                                ServeEngine)


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--engine", default="ivfflat")
    p.add_argument("--backend", default="jnp",
                   help="scoring backend (retrieval/backends.py)")
    args = p.parse_args(argv)

    corpus = generate_corpus(num_queries=384, qrels_per_query=12,
                             num_topics=24, seed=0)
    # 1. sample the corpus with WindTunnel (cheap index, communities intact)
    qrels = QRelTable(*(jnp.asarray(x) for x in corpus.qrels))
    cfg = WindTunnelConfig(tau_quantile=0.5, fanout=16, lp_rounds=4,
                           target_size=0.3 * corpus.num_primary, seed=0)
    res = jax.jit(lambda q: run_windtunnel(
        q, num_queries=corpus.num_queries,
        num_entities=corpus.num_entities, config=cfg))(qrels)
    kept = np.nonzero(np.asarray(res.sample.entity_mask))[0]
    print(f"indexing {kept.size} of {corpus.num_entities} passages "
          f"(WindTunnel sample, engine={args.engine}, "
          f"backend={args.backend})")

    # 2. index the sample through the search core (build-once session);
    #    queries embed with the document df so both sides share geometry
    vecs, df = tfidf_vectors(corpus.passage_tokens[kept], corpus.vocab_size)
    embed = lambda toks: tfidf_vectors(np.asarray(toks), corpus.vocab_size,
                                       df)[0]
    frontend = RetrievalFrontend(
        vecs, embed,
        config=SearchConfig(engine=args.engine, backend=args.backend,
                            engine_opts={"n_lists": 16}
                            if args.engine == "ivfflat" else None),
        key=jax.random.PRNGKey(0), ids_map=kept)

    # 3. generate with retrieved context through the batched engine
    mcfg = TransformerConfig(vocab_size=corpus.vocab_size, d_model=64,
                             n_layers=2, n_heads=4, n_kv_heads=2, d_ff=128,
                             dtype=jnp.float32)
    params = init_transformer(jax.random.PRNGKey(1), mcfg)
    serve = ServeEngine(params, mcfg, ServeConfig(max_batch=4, max_seq=128,
                                                  max_new_tokens=8))
    rag = RagEngine(frontend, serve,
                    lambda gid: corpus.passage_tokens[gid], ctx_tokens=24)
    retrieved = []
    for qi in range(4):
        _, ids = rag.submit_query(corpus.query_tokens[qi],
                                  corpus.query_tokens[qi], k=3)
        retrieved.append(ids)
    serve.drain()
    print("4 RAG requests served through continuous batching; retrieved ids:")
    for qi in range(4):
        print(f"  query {qi}: passages {retrieved[qi].tolist()}")


if __name__ == "__main__":
    main()
