"""Serving example: WindTunnel-sampled corpus + ANN retrieval + generative
decode through the continuous-batching engine (a miniature RAG stack over
the paper's Fig. 5 online component).

  PYTHONPATH=src python examples/serve_rag.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import QRelTable, WindTunnelConfig, run_windtunnel
from repro.data.synthetic import generate_corpus
from repro.models.transformer import TransformerConfig, init_transformer
from repro.retrieval.ivfflat import build_ivfflat, search_ivfflat
from repro.retrieval.tfidf import tfidf_vectors
from repro.serve.engine import ServeConfig, ServeEngine


def main():
    corpus = generate_corpus(num_queries=384, qrels_per_query=12,
                             num_topics=24, seed=0)
    # 1. sample the corpus with WindTunnel (cheap index, communities intact)
    qrels = QRelTable(*(jnp.asarray(x) for x in corpus.qrels))
    cfg = WindTunnelConfig(tau_quantile=0.5, fanout=16, lp_rounds=4,
                           target_size=0.3 * corpus.num_primary, seed=0)
    res = jax.jit(lambda q: run_windtunnel(
        q, num_queries=corpus.num_queries,
        num_entities=corpus.num_entities, config=cfg))(qrels)
    kept = np.nonzero(np.asarray(res.sample.entity_mask))[0]
    print(f"indexing {kept.size} of {corpus.num_entities} passages "
          f"(WindTunnel sample)")

    # 2. index the sample
    vecs, df = tfidf_vectors(corpus.passage_tokens[kept], corpus.vocab_size)
    index = build_ivfflat(jax.random.PRNGKey(0), jnp.asarray(vecs),
                          n_lists=16)

    # 3. retrieve for a few queries
    qv, _ = tfidf_vectors(corpus.query_tokens[:4], corpus.vocab_size, df)
    _, ids = search_ivfflat(index, jnp.asarray(qv), k=3, nprobe=8)
    ids = np.asarray(ids)

    # 4. generate with retrieved context through the batched engine
    mcfg = TransformerConfig(vocab_size=corpus.vocab_size, d_model=64,
                             n_layers=2, n_heads=4, n_kv_heads=2, d_ff=128,
                             dtype=jnp.float32)
    params = init_transformer(jax.random.PRNGKey(1), mcfg)
    engine = ServeEngine(params, mcfg, ServeConfig(max_batch=4, max_seq=128,
                                                   max_new_tokens=8))
    for qi in range(4):
        ctx = corpus.passage_tokens[kept[ids[qi, 0]]][:24]
        prompt = np.concatenate([corpus.query_tokens[qi], ctx])
        engine.submit(prompt.astype(np.int32))
    engine.drain()
    print("4 RAG requests served through continuous batching; retrieved ids:")
    for qi in range(4):
        print(f"  query {qi}: passages {kept[ids[qi]].tolist()}")


if __name__ == "__main__":
    main()
