"""Distributed-training example: train the retrieval encoder (~1M params,
a few hundred steps) with the full production substrate — sharded train
step, AdamW, async checkpointing, elastic resume, straggler policy.

  PYTHONPATH=src python examples/train_embedder.py --steps 120
  # kill it mid-run, rerun the same command: it resumes from the last
  # checkpoint at the exact step (deterministic data order).
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.data.batching import TokenBatcher
from repro.data.synthetic import generate_corpus
from repro.launch.mesh import make_host_mesh
from repro.retrieval.encoder import EncoderConfig, contrastive_loss, init_encoder
from repro.train.loop import LoopConfig, train_loop
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=120)
    p.add_argument("--batch-size", type=int, default=48)
    p.add_argument("--checkpoint-dir", default="results/embedder_ckpt")
    args = p.parse_args()

    corpus = generate_corpus(num_queries=512, qrels_per_query=12,
                             num_topics=32, seed=0)
    cfg = EncoderConfig(vocab_size=corpus.vocab_size, d_model=96,
                        n_layers=2, n_heads=4, d_ff=192)
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=10,
                          total_steps=args.steps, weight_decay=0.01)
    mesh = make_host_mesh()
    params = init_encoder(jax.random.PRNGKey(0), cfg)
    opt_state = adamw_init(params)
    batcher = TokenBatcher(corpus, args.batch_size, seed=0)

    @jax.jit
    def step_fn(params, opt_state, batch):
        loss, grads = jax.value_and_grad(contrastive_loss)(params, batch, cfg)
        params, opt_state, _ = adamw_update(grads, opt_state, params, opt_cfg)
        return params, opt_state, loss

    def batch_fn(step):
        b = batcher.contrastive_batch(step)
        return {k: jnp.asarray(v) for k, v in b.items()
                if k.endswith("_tokens")}

    with mesh:
        params, _, losses = train_loop(
            step_fn, params, opt_state, batch_fn,
            LoopConfig(total_steps=args.steps, log_every=10,
                       checkpoint_every=25,
                       checkpoint_dir=args.checkpoint_dir))
    print(f"final loss {losses[-1]:.4f} (start {losses[0]:.4f})")


if __name__ == "__main__":
    main()
