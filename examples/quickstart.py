"""Quickstart: run the WindTunnel pipeline on a synthetic corpus and look at
the communities it preserves (paper Figs. 1/2 qualitatively).

  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import QRelTable, WindTunnelConfig, fit_em, run_windtunnel
from repro.data.synthetic import generate_corpus


def main():
    corpus = generate_corpus(num_queries=512, qrels_per_query=12,
                             num_topics=24, aux_fraction=0.5, seed=0)
    print(f"corpus: {corpus.num_entities} entities "
          f"({corpus.num_primary} judged), {corpus.num_queries} queries")

    qrels = QRelTable(*(jnp.asarray(x) for x in corpus.qrels))
    cfg = WindTunnelConfig(tau_quantile=0.5, fanout=16, lp_rounds=5,
                           target_size=0.25 * corpus.num_primary, seed=0)
    res = jax.jit(lambda q: run_windtunnel(
        q, num_queries=corpus.num_queries,
        num_entities=corpus.num_entities, config=cfg))(qrels)

    deg = np.asarray(res.degrees)
    fit = fit_em(jnp.asarray(deg[deg > 0]))
    print(f"affinity graph: {int(res.edges.num_valid)} edges; "
          f"Yule-Simon gamma = {float(fit.gamma):.2f} (paper: 2.94)")

    labels = np.asarray(res.labels)
    mask = np.asarray(res.sample.entity_mask)
    kept_labels, counts = np.unique(labels[mask], return_counts=True)
    print(f"sample: {mask.sum()} entities in {kept_labels.size} communities")
    print("\nfive sampled communities (entity id -> planted topic), note the")
    print("thematic consistency the sampler preserves (paper Fig. 2):")
    order = np.argsort(-counts)
    for li in order[:5]:
        members = np.nonzero((labels == kept_labels[li]) & mask)[0][:8]
        topics = corpus.entity_topic[members]
        print(f"  community {kept_labels[li]:6d}: entities {members.tolist()}"
              f" topics {topics.tolist()}")


if __name__ == "__main__":
    main()
